"""Per-kernel allclose sweeps: every Pallas kernel vs its ref.py oracle,
across shapes and dtypes, in interpret mode (CPU executes the kernel body)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# tiled_gemm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (8, 64, 64), (8, 192, 256), (16, 128, 384), (33, 100, 130),  # ragged
    (8, 512, 512), (1, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tiled_gemm_shapes(m, k, n, dtype):
    x, w = randn((m, k), dtype), randn((k, n), dtype)
    out = ops.tiled_gemm(x, w, block_m=8, block_k=64, block_n=128)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref.tiled_gemm(x, w), np.float32),
        rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("blocks", [(8, 128, 128), (16, 64, 256), (32, 256, 128)])
def test_tiled_gemm_block_sweep(blocks):
    bm, bk, bn = blocks
    x, w = randn((32, 256)), randn((256, 512))
    out = ops.tiled_gemm(x, w, block_m=bm, block_k=bk, block_n=bn)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.tiled_gemm(x, w)),
                               rtol=1e-5, atol=1e-4)


def test_tiled_gemm_int8_accum():
    x = jnp.asarray(RNG.integers(-127, 127, (8, 256)), jnp.int8)
    w = jnp.asarray(RNG.integers(-127, 127, (256, 128)), jnp.int8)
    out = ops.tiled_gemm(x, w, block_m=32, block_k=128, block_n=128)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.tiled_gemm(x, w)))


# ---------------------------------------------------------------------------
# fused_dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act", ["none", "relu", "gelu", "silu", "tanh"])
@pytest.mark.parametrize("residual", [False, True])
def test_fused_dense(act, residual):
    x, w = randn((8, 192)), randn((192, 256))
    b = randn((256,))
    r = randn((8, 256)) if residual else None
    out = ops.fused_dense(x, w, b, r, act=act, block_m=8, block_k=64,
                          block_n=128)
    exp = ref.fused_dense(x, w, b, r, act=act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# gemm_int8
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (8, 256, 384), (24, 250, 300)])
def test_gemm_int8(m, k, n):
    x = jnp.asarray(RNG.integers(-127, 127, (m, k)), jnp.int8)
    w = jnp.asarray(RNG.integers(-127, 127, (k, n)), jnp.int8)
    sw = jnp.asarray(RNG.uniform(0.01, 0.1, (n,)), jnp.float32)
    out = ops.gemm_int8(x, w, sw, 0.07, block_m=8, block_k=128, block_n=128,
                        out_dtype=jnp.float32)
    exp = ref.gemm_int8(x, w, sw, 0.07, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-3,
                               atol=1e-2)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=64),
    dict(causal=True, softcap=30.0),
    dict(causal=True, window=96, softcap=50.0),
])
def test_flash_attention_variants(kw):
    B, Hq, Hkv, S, D = 2, 4, 2, 256, 64
    q, k, v = randn((B, Hq, S, D)), randn((B, Hkv, S, D)), randn((B, Hkv, S, D))
    out = ops.flash_attention(q, k, v, block_q=64, block_kv=64, **kw)
    exp = ref.attention(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("s,block", [(128, 128), (192, 64), (512, 256)])
def test_flash_attention_block_sweep(s, block):
    q = randn((1, 2, s, 32))
    k = randn((1, 2, s, 32))
    v = randn((1, 2, s, 32))
    out = ops.flash_attention(q, k, v, causal=True, block_q=block,
                              block_kv=block)
    exp = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q = randn((1, 2, 128, 64), jnp.bfloat16)
    k = randn((1, 2, 128, 64), jnp.bfloat16)
    v = randn((1, 2, 128, 64), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    exp = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=3e-2,
                               atol=3e-2)


# ---------------------------------------------------------------------------
# recurrences
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,block_t", [(64, 16), (100, 32), (256, 128)])
def test_linear_scan(t, block_t):
    a = jnp.asarray(RNG.uniform(0.4, 0.999, (2, t, 128)), jnp.float32)
    b = randn((2, t, 128))
    out = ops.linear_scan(a, b, block_t=block_t)
    exp = ref.linear_scan(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_rglru_layer_finite():
    x = randn((2, 64, 128))
    ga, gx = randn((2, 64, 128)), randn((2, 64, 128))
    ll = randn((128,))
    h = ops.rglru(x, ga, gx, ll, block_t=16)
    assert h.shape == x.shape
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())


@pytest.mark.parametrize("t,block_t", [(64, 16), (96, 32)])
def test_rwkv6_kernel(t, block_t):
    BH, D = 3, 64
    r, k, v = (randn((BH, t, D), scale=0.5) for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.5, 0.99, (BH, t, D)), jnp.float32)
    u = randn((D,), scale=0.3)
    out = ops.rwkv6_scan(r, k, v, w, u, block_t=block_t)
    exp = ref.rwkv6_scan(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


def test_rwkv6_chunked_model_form():
    """models.rwkv chunk-recurrent == sequential oracle."""
    from repro.models.rwkv import rwkv6_chunked
    B, H, T, D = 2, 2, 100, 32
    r, k, v = (randn((B, H, T, D), scale=0.5) for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.3, 0.999, (B, H, T, D)), jnp.float32)
    u = randn((H, D), scale=0.3)
    out, _ = rwkv6_chunked(r, k, v, w, u, chunk=32)
    for bi in range(B):
        for hi in range(H):
            exp = ref.rwkv6_scan(r[bi, hi][None], k[bi, hi][None],
                                 v[bi, hi][None], w[bi, hi][None], u[hi])
            np.testing.assert_allclose(np.asarray(out[bi, hi]),
                                       np.asarray(exp[0]), rtol=2e-3,
                                       atol=2e-3)
