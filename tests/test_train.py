"""Training-substrate tests: optimizers, losses, checkpoints, fault
tolerance, gradient compression, pipeline parallelism, data determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro import compat, configs
from repro.data.pipeline import synth_batch
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.train import (checkpoint as ckpt_lib, compression, fault,
                         optimizer as opt_lib, schedule, step as step_lib)

CFG = configs.get("qwen2_5_3b").smoke


def _batch(cfg, step=0, b=4, s=16):
    return {k: jnp.asarray(v)
            for k, v in synth_batch(cfg, batch=b, seq=s, step=step).items()}


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,kw", [
    ("adamw", {"state_dtype": "float32"}),
    ("adamw", {"state_dtype": "bfloat16"}),
    ("adamw", {"state_dtype": "int8"}),
    ("adafactor", {}),
    ("sgd", {}),
])
def test_optimizers_reduce_quadratic(name, kw):
    """Each optimizer makes progress on a quadratic bowl."""
    opt = opt_lib.make(name, lr=0.1, **kw)
    target = jnp.asarray([1.0, -2.0, 3.0, 0.5] * 16)
    params = {"w": jnp.zeros((64,))}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for i in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params,
                                   jnp.asarray(i, jnp.int32))
    assert float(loss(params)) < 0.2 * l0


def test_adamw_int8_state_bytes():
    """int8 states are ~4x smaller than f32 (framing for the 671B story)."""
    opt = opt_lib.make("adamw", lr=1e-3, state_dtype="int8")
    params = {"w": jnp.zeros((1024, 256), jnp.bfloat16)}
    st_ = opt.init(params)
    q = st_["m"]["w"]["q"]
    assert q.dtype == jnp.int8 and q.size == 1024 * 256


def test_chunked_xent_equals_dense():
    from repro.train import loss as loss_lib
    from repro.models import transformer
    cfg = configs.get("gemma2_2b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    labels = jnp.roll(toks, -1, axis=1)
    out = transformer.lm_forward(params, cfg, toks, want_hidden=True)
    dense_logits = transformer.lm_forward(params, cfg, toks)["logits"]
    dense = loss_lib.softmax_xent(dense_logits, labels)
    chunked = loss_lib.chunked_xent(params, cfg, out["hidden"], labels,
                                    chunk=8)
    np.testing.assert_allclose(float(chunked), float(dense), rtol=2e-3)


def test_schedule_warmup_cosine():
    lr = schedule.warmup_cosine(1e-3, warmup_steps=10, total_steps=100)
    assert float(lr(0)) < float(lr(9))
    assert abs(float(lr(10)) - 1e-3) / 1e-3 < 0.15
    assert float(lr(99)) < float(lr(50)) < float(lr(10)) + 1e-9


# ---------------------------------------------------------------------------
# Checkpoint / restore / elastic
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    opt = opt_lib.make("adamw", lr=1e-3)
    init_fn, step_fn = step_lib.build_train_step(CFG, opt)
    state = jax.jit(init_fn)(jax.random.PRNGKey(0))
    state, _ = jax.jit(step_fn)(state, _batch(CFG))
    path = ckpt_lib.save(str(tmp_path), state, 1)
    assert os.path.exists(os.path.join(path, "manifest.json"))
    abstract = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                            state)
    restored, step = ckpt_lib.restore(str(tmp_path), abstract)
    assert step == 1
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    ck = ckpt_lib.AsyncCheckpointer(str(tmp_path), keep=2)
    state = {"w": jnp.arange(8.0), "step": jnp.asarray(0)}
    for s in (1, 2, 3, 4):
        ck.save_async(dict(state, step=jnp.asarray(s)), s)
    ck.wait()
    assert ckpt_lib.latest_steps(str(tmp_path)) == [3, 4]


def test_elastic_restore_other_mesh(tmp_path):
    """A checkpoint written unsharded restores onto a (1,1) host mesh with
    explicit shardings (the elastic path; on 1 CPU device the mesh is
    trivial, but the code path is identical)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt_lib.save(str(tmp_path), state, 5)
    mesh = make_host_mesh(model=1)
    sh = {"w": NamedSharding(mesh, P())}
    abstract = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    restored, step = ckpt_lib.restore(str(tmp_path), abstract, shardings=sh)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_driver_survives_injected_failures(tmp_path):
    opt = opt_lib.make("adamw", lr=1e-3)
    init_fn, step_fn = step_lib.build_train_step(CFG, opt)
    state = jax.jit(init_fn)(jax.random.PRNGKey(0))
    jstep = jax.jit(step_fn)

    fails = {7: True, 13: True}

    def hook(step):
        if fails.pop(step, None):
            raise fault.SimulatedNodeFailure(f"node died at step {step}")

    driver = fault.TrainDriver(
        cfg=fault.DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=5),
        step_fn=jstep, batch_fn=lambda s: _batch(CFG, step=s), state=state)
    driver.run(20, failure_hook=hook)
    assert driver.step == 20
    kinds = [e[0] for e in driver.events]
    assert kinds.count("failure") == 2
    assert "restored" in kinds
    assert "checkpoint" in kinds


def test_driver_determinism_after_restart(tmp_path):
    """Replayed steps after a restart produce the same loss trajectory."""
    opt = opt_lib.make("sgd", lr=1e-2, momentum=0.0)
    init_fn, step_fn = step_lib.build_train_step(CFG, opt)
    jstep = jax.jit(step_fn)

    # Uninterrupted run.
    state = jax.jit(init_fn)(jax.random.PRNGKey(0))
    losses = []
    for s in range(8):
        state, m = jstep(state, _batch(CFG, step=s))
        losses.append(float(m["loss"]))

    # Interrupted run with restart from the step-4 checkpoint.
    state2 = jax.jit(init_fn)(jax.random.PRNGKey(0))
    fails = {6: True}

    def hook(step):
        if fails.pop(step, None):
            raise fault.SimulatedNodeFailure("boom")

    driver = fault.TrainDriver(
        cfg=fault.DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=4),
        step_fn=jstep, batch_fn=lambda s: _batch(CFG, step=s), state=state2)
    driver.run(8, failure_hook=hook)
    # The final loss of the replayed trajectory matches the uninterrupted one.
    final_batch = _batch(CFG, step=8)
    _, m1 = jstep(state, final_batch)
    _, m2 = jstep(driver.state, final_batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)


def test_straggler_detection():
    import time as _t
    driver = fault.TrainDriver(
        cfg=fault.DriverConfig(ckpt_dir="/tmp/unused_ckpts",
                               straggler_factor=2.5),
        step_fn=None, batch_fn=None, state={"step": jnp.asarray(0)})
    for dt in [0.01] * 8 + [0.2] + [0.01] * 3:
        driver._detect_straggler(dt, 0)
    assert any(e[0] == "straggler" for e in driver.events)


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_compressed_psum_roundtrip():
    mesh = make_host_mesh(model=1)          # 1 device: psum over axis size 1
    from jax.sharding import PartitionSpec as P

    def f(x):
        return compression.compressed_psum(x, "data")

    x = jnp.linspace(-3, 3, 64)
    out = jax.jit(compat.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                                check_vma=False))(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=0.06)


def test_error_feedback_residual_carries_quant_error():
    mesh = make_host_mesh(model=1)
    from jax.sharding import PartitionSpec as P
    g = {"w": jnp.asarray([1.0, 1e-4, -2.0, 3e-5])}
    e = {"w": jnp.zeros((4,))}

    def f(gg, ee):
        red, new_e = compression.ErrorFeedback.apply(gg, ee, "data", world=1)
        return red, new_e

    red, new_e = jax.jit(compat.shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False))(g, e)
    # quantization error is exactly what is carried
    np.testing.assert_allclose(
        np.asarray(g["w"] - red["w"]), np.asarray(new_e["w"]), atol=1e-7)


def test_manual_dp_step_trains():
    mesh = make_host_mesh(model=1)
    opt = opt_lib.make("sgd", lr=0.2, momentum=0.9)

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    params = {"w": jnp.zeros((4, 8))}
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.asarray(0, jnp.int32),
             "residual": compression.ErrorFeedback.init(params, world=1)}
    step = compression.build_manual_dp_step(loss_fn, opt, mesh,
                                            compress=True)
    jstep = jax.jit(step)
    rng = np.random.default_rng(0)
    losses = []
    for i in range(120):
        x = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
        y = x @ jnp.ones((4, 8))
        l, _ = loss_fn(state["params"], {"x": x, "y": y})
        losses.append(float(l))
        state = jstep(state, {"x": x, "y": y})
    # int8-compressed gradient reduction with error feedback converges
    assert losses[-1] < 0.1 * losses[0], (losses[0], losses[-1])


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

@given(st.integers(0, 1000), st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_data_deterministic(step, seed):
    a = synth_batch(CFG, batch=2, seq=8, step=step, seed=seed)
    b = synth_batch(CFG, batch=2, seq=8, step=step, seed=seed)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_labels_are_shifted_tokens():
    b = synth_batch(CFG, batch=2, seq=16, step=3)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetcher():
    from repro.data.pipeline import Prefetcher
    pf = Prefetcher(CFG, batch=2, seq=8, depth=2)
    it = iter(pf)
    s0, b0 = next(it)
    s1, b1 = next(it)
    pf.close()
    assert s1 == s0 + 1
    assert b0["tokens"].shape == (2, 8)
