"""Multi-tenant serving runtime tests: fleet planner (joint column packing),
FleetPlan artifact (schema v2 + v1 compat), router/tenant/metrics,
plan-driven continuous batcher, calibration feedback, BENCH trend."""

import json
import time

import jax
import numpy as np
import pytest

from benchmarks import trend
from repro import configs
from repro import hw as hwlib
from repro import plan as plan_lib
from repro.models import api, edge
from repro.serve import (Router, TenantMetrics, TenantOverBudget,
                         TenantQueueFull, engine, write_serve_snapshots)


# ---------------------------------------------------------------------------
# Fleet planner: joint column packing (paper Section V-C)
# ---------------------------------------------------------------------------

def test_fleet_aie_columns_disjoint_within_array():
    cfgs = [edge.edge_config(n) for n in ("jet_tagger", "tau_select", "vae")]
    fleet = plan_lib.plan_fleet(cfgs, target="aie", pl_budget=0.0)
    assert len(fleet.tenants) == 3
    assert fleet.band1_cols_used <= hwlib.AIE_ML.usable_cols
    # Contiguous, non-overlapping column ranges in placement order.
    spans = [(t.col_offset, t.col_offset + t.cols) for t in fleet.tenants]
    for (_, a_end), (b_start, _) in zip(spans, spans[1:]):
        assert a_end == b_start
    for t in fleet.tenants:
        assert set(t.plan.regimes()) == {"aie"}
        assert t.cols > 0 and t.crossing_s > 0
        assert t.latency_budget_s > t.plan.est_latency_s


def test_fleet_aie_all_nets_contention():
    """All five Table-I nets jointly: the shared column budget still holds,
    and no net gets FASTER than its solo plan (co-residency can shrink or
    spill a net's splits, never improve them)."""
    cfgs = [edge.edge_config(n) for n in edge.EDGE_NETS]
    fleet = plan_lib.plan_fleet(cfgs, target="aie", pl_budget=0.0)
    band1 = sum(l.p_k for t in fleet.tenants for l in t.plan.layers
                if l.regime == "aie" and l.band == 1)
    assert band1 <= hwlib.AIE_ML.usable_cols
    for cfg, t in zip(cfgs, fleet.tenants):
        solo = plan_lib.plan_deployment(cfg, target="aie", pl_budget=0.0)
        assert t.plan.est_interval_s >= solo.est_interval_s - 1e-15


def test_fleet_tpu_serve_policy_injected():
    """LM tenants get the batching policy in their serve section; edge
    tenants keep the plain executor serve section."""
    lm_cfg = configs.get("qwen2_5_3b").smoke
    fleet = plan_lib.plan_fleet(
        [edge.edge_config("jet_tagger"), lm_cfg], target="tpu",
        serve_slots_total=6, prefill_chunk=4)
    edge_t, lm_t = fleet.tenants
    assert edge_t.plan.kind == "edge" and "slots" not in edge_t.plan.serve
    assert lm_t.plan.kind == "lm"
    assert lm_t.plan.serve["slots"] == 6          # only LM tenant -> all slots
    assert lm_t.plan.serve["prefill_chunk"] == 4
    assert lm_t.plan.serve["admit_per_tick"] == 1


def test_fleet_key_sensitivity():
    cfgs = [edge.edge_config("jet_tagger"), edge.edge_config("tau_select")]
    f1 = plan_lib.plan_fleet(cfgs, target="aie", pl_budget=0.0)
    f2 = plan_lib.plan_fleet(list(reversed(cfgs)), target="aie",
                             pl_budget=0.0)
    assert f1.key != f2.key                       # placement order matters
    assert f1.key != plan_lib.plan_fleet(cfgs, target="tpu").key


def test_fleet_duplicate_nets_get_unique_ids():
    cfgs = [edge.edge_config("jet_tagger")] * 2
    fleet = plan_lib.plan_fleet(cfgs, target="aie", pl_budget=0.0)
    assert fleet.net_ids == ["jet_tagger", "jet_tagger#1"]
    assert fleet.tenant("jet_tagger#1").col_offset \
        == fleet.tenant("jet_tagger").cols


def test_fleet_empty_rejected():
    with pytest.raises(ValueError):
        plan_lib.plan_fleet([])


# ---------------------------------------------------------------------------
# FleetPlan artifact: schema v2 round-trip + v1 backward compat
# ---------------------------------------------------------------------------

def test_fleet_json_roundtrip(tmp_path):
    cfgs = [edge.edge_config(n) for n in ("jet_tagger", "tau_select")]
    fleet = plan_lib.plan_fleet(cfgs, target="aie", pl_budget=0.0)
    s = fleet.to_json()
    json.loads(s)                                  # strict JSON
    assert plan_lib.FleetPlan.from_json(s) == fleet
    p = fleet.save(tmp_path / "fleet.json")
    assert plan_lib.FleetPlan.load(p) == fleet


# (v1/v2/v3 schema round-trips — including FleetPlan.load wrapping old
# single-net artifacts — are consolidated in tests/test_plan_compat.py.)


# ---------------------------------------------------------------------------
# Calibration feedback (autotune hook)
# ---------------------------------------------------------------------------

def test_calibration_feedback_updates_cache():
    cfg = edge.edge_config("jet_tagger")
    cache = plan_lib.PlanCache()
    plan = plan_lib.get_or_plan(cfg, target="tpu", cache=cache)
    measured = plan.est_latency_s * 2.0
    cal = plan_lib.feedback(plan, measured, cache=cache)
    assert cal.est_latency_s == pytest.approx(measured)
    assert cal.key == plan.key                    # same question, same key
    # Tile decisions untouched; per-layer costs rescaled by one factor.
    scale = cal.serve["calibration"]["scale"]
    assert scale > 1.0
    for l0, l1 in zip(plan.layers, cal.layers):
        assert l1.api_tile == l0.api_tile and l1.regime == l0.regime
        assert l1.est_latency_s == pytest.approx(scale * l0.est_latency_s)
    # The fixed dispatch overhead is NOT folded into the layers: the total
    # still decomposes as parts + overhead after calibration.
    parts = sum(l.est_latency_s * l.repeat for l in cal.layers) \
        + sum(b.crossing_s for b in cal.boundaries)
    overhead = plan.est_latency_s \
        - sum(l.est_latency_s * l.repeat for l in plan.layers) \
        - sum(b.crossing_s for b in plan.boundaries)
    assert parts + overhead == pytest.approx(measured)
    # The next same-key plan request returns the calibrated costs.
    again = plan_lib.get_or_plan(cfg, target="tpu", cache=cache)
    assert again is cal


def test_fleet_replan_picks_up_calibration():
    """The fleet autotune loop: feedback on a tenant plan, then a re-plan of
    the SAME fleet returns the calibrated costs (and budgets derived from
    them)."""
    cfgs = [edge.edge_config("jet_tagger"), edge.edge_config("tau_select")]
    cache = plan_lib.PlanCache()
    fleet = plan_lib.plan_fleet(cfgs, target="tpu", cache=cache)
    t0 = fleet.tenants[0]
    measured = t0.plan.est_latency_s * 3.0
    plan_lib.feedback(t0.plan, measured, cache=cache)
    again = plan_lib.plan_fleet(cfgs, target="tpu", cache=cache)
    assert again.tenants[0].plan.est_latency_s == pytest.approx(measured)
    assert "calibration" in again.tenants[0].plan.serve
    assert again.tenants[0].latency_budget_s == pytest.approx(
        2.0 * (measured + again.tenants[0].crossing_s))
    # The uncalibrated tenant is unaffected.
    assert again.tenants[1].plan.est_latency_s == pytest.approx(
        fleet.tenants[1].plan.est_latency_s)


def test_fleet_cache_hit_keeps_requested_serve_policy():
    """A calibrated cache hit contributes COSTS only; the serve policy must
    reflect what THIS plan_fleet call asked for (the serve knobs are not
    part of the fleet key)."""
    lm_cfg = configs.get("qwen2_5_3b").smoke
    cache = plan_lib.PlanCache()
    fleet = plan_lib.plan_fleet([lm_cfg], target="tpu", cache=cache,
                                serve_slots_total=8, prefill_chunk=8)
    plan = fleet.tenants[0].plan
    plan_lib.feedback(plan, plan.est_latency_s * 2.0, cache=cache)
    again = plan_lib.plan_fleet([lm_cfg], target="tpu", cache=cache,
                                serve_slots_total=2, prefill_chunk=16)
    t = again.tenants[0]
    assert t.plan.serve["slots"] == 2             # fresh policy wins
    assert t.plan.serve["prefill_chunk"] == 16
    assert "calibration" in t.plan.serve          # calibrated costs kept
    assert t.plan.est_latency_s == pytest.approx(2.0 * plan.est_latency_s)


def test_calibration_feedback_rejects_bad_measurement():
    plan = plan_lib.plan_deployment(edge.edge_config("jet_tagger"),
                                    target="tpu")
    with pytest.raises(ValueError):
        plan_lib.feedback(plan, 0.0, cache=plan_lib.PlanCache())


def test_fleet_tenant_feedback_preserves_latency_decomposition():
    """Regression: ``calibrate.feedback`` on a FLEET tenant's plan (fleet-
    scoped key, serve policy attached) must keep the invariant
    ``est_latency == sum(parts) + overhead`` — the entry-dispatch overhead is
    not folded into the per-layer/boundary parts."""
    cfgs = [edge.edge_config("jet_tagger"), edge.edge_config("tau_select")]
    cache = plan_lib.PlanCache()
    fleet = plan_lib.plan_fleet(cfgs, target="tpu", cache=cache)
    for tp in fleet.tenants:
        plan = tp.plan
        overhead = plan.est_latency_s \
            - sum(l.est_latency_s * l.repeat for l in plan.layers) \
            - sum(b.crossing_s for b in plan.boundaries)
        assert overhead > 0                        # TPU path charges entry
        measured = plan.est_latency_s * 3.0
        cal = plan_lib.feedback(plan, measured, cache=cache)
        parts = sum(l.est_latency_s * l.repeat for l in cal.layers) \
            + sum(b.crossing_s for b in cal.boundaries)
        assert parts + overhead == pytest.approx(cal.est_latency_s)
        assert cal.est_latency_s == pytest.approx(measured)
        assert cal.key == plan.key


def test_edge_engine_record_calibration():
    cfg = edge.edge_config("tau_select")
    cache = plan_lib.PlanCache()
    plan = plan_lib.get_or_plan(cfg, target="tpu", cache=cache)
    eng = engine.EdgeEngine(cfg, plan=plan, x_scale=0.02)
    with pytest.raises(RuntimeError):
        eng.record_calibration(cache=cache)       # nothing measured yet
    x = jax.random.normal(jax.random.PRNGKey(0), (cfg.batch, cfg.dims[0]))
    eng.infer(x)
    cal = eng.record_calibration(cache=cache)
    assert cal.est_latency_s == pytest.approx(eng.measured_mean_s)
    assert plan_lib.get_or_plan(cfg, target="tpu", cache=cache) is cal


# ---------------------------------------------------------------------------
# Router + tenants + metrics
# ---------------------------------------------------------------------------

def _edge_fleet(names=("jet_tagger", "tau_select")):
    return plan_lib.plan_fleet([edge.edge_config(n) for n in names],
                               target="tpu")


def test_router_dispatch_and_metrics():
    fleet = _edge_fleet()
    router = Router.from_fleet(fleet)
    for nid in router.net_ids:
        cfg = edge.edge_config(nid)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (cfg.batch, cfg.dims[0])) * 0.5
        y = router.infer(nid, x)
        assert y.shape == (cfg.batch, cfg.dims[-1])
    rep = router.report()
    for nid in router.net_ids:
        assert rep[nid]["count"] == 1
        assert rep[nid]["mean_s"] > 0
        assert rep[nid]["kind"] == "edge"
    with pytest.raises(KeyError):
        router.infer("no_such_net", None)


def test_router_engine_matches_direct_execution():
    """Routing must not change the math: router output == a directly-built
    EdgeEngine executing the same tenant plan with the same seed."""
    fleet = _edge_fleet(("jet_tagger",))
    router = Router.from_fleet(fleet, seed=0)
    cfg = edge.edge_config("jet_tagger")
    direct = engine.EdgeEngine(cfg, plan=fleet.tenants[0].plan, seed=0)
    x = jax.random.normal(jax.random.PRNGKey(2),
                          (cfg.batch, cfg.dims[0])) * 0.5
    np.testing.assert_allclose(np.asarray(router.infer("jet_tagger", x)),
                               np.asarray(direct.infer(x)),
                               rtol=1e-5, atol=1e-5)


def test_router_budget_violations_and_shedding():
    fleet = _edge_fleet(("jet_tagger",))
    router = Router.from_fleet(fleet, shed_after=2)
    t = router.tenant("jet_tagger")
    t.metrics.latency_budget_s = 1e-12            # impossible budget
    cfg = edge.edge_config("jet_tagger")
    x = jax.random.normal(jax.random.PRNGKey(3), (cfg.batch, cfg.dims[0]))
    router.infer("jet_tagger", x)
    assert not router.over_budget("jet_tagger")
    router.infer("jet_tagger", x)
    assert router.over_budget("jet_tagger")
    assert t.metrics.budget_violations == 2
    with pytest.raises(TenantOverBudget):
        router.infer("jet_tagger", x)             # shed, not served
    router.reset_metrics()                        # re-opens the tenant
    t.metrics.latency_budget_s = 1e9
    router.infer("jet_tagger", x)
    assert not router.over_budget("jet_tagger")


def test_router_shed_tenant_reopens_via_probe():
    """Half-open shedding: after shed_after refusals one probe is admitted,
    and a within-budget probe re-opens the tenant."""
    fleet = _edge_fleet(("tau_select",))
    router = Router.from_fleet(fleet, shed_after=2)
    t = router.tenant("tau_select")
    cfg = edge.edge_config("tau_select")
    x = jax.random.normal(jax.random.PRNGKey(4), (cfg.batch, cfg.dims[0]))
    t.metrics.latency_budget_s = 1e-12
    router.infer("tau_select", x)
    router.infer("tau_select", x)                 # 2 violations -> shed
    for _ in range(2):                            # shed_after refusals
        with pytest.raises(TenantOverBudget):
            router.infer("tau_select", x)
    t.metrics.latency_budget_s = 1e9              # tenant recovered
    router.infer("tau_select", x)                 # the admitted probe
    assert not router.over_budget("tau_select")
    router.infer("tau_select", x)                 # serving normally again


def test_router_lm_tenant_plan_driven_batcher():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    fleet = plan_lib.plan_fleet([cfg], target="tpu", serve_slots_total=2,
                                prefill_chunk=2)
    nid = fleet.net_ids[0]
    router = Router.from_fleet(fleet, lm={nid: (cfg, params)})
    t = router.tenant(nid)
    assert t.kind == "lm" and t.engine.slots == 2
    assert t.engine.policy.prefill_chunk == 2
    reqs = [engine.Request(rid=i, prompt=np.array([3 + i, 5, 7], np.int32),
                           max_new=3) for i in range(3)]
    for r in reqs:
        router.submit(nid, r)
    router.run_until_drained(max_ticks=300)
    for r in reqs:
        assert r.done and len(r.out) == 3
    rep = router.report()[nid]
    assert rep["count"] == 3                      # request latencies recorded
    assert rep["occupancy"] > 0
    assert rep["mean_s"] > 0


def test_tenant_metrics_counters():
    m = TenantMetrics("x", latency_budget_s=1.0)
    assert m.observe_latency(0.5) is True
    assert m.observe_latency(2.0) is False
    assert m.budget_violations == 1 and m.consecutive_violations == 1
    assert m.observe_latency(0.1) is True
    assert m.consecutive_violations == 0          # success resets the streak
    m.observe_occupancy(2, 4)
    m.observe_occupancy(4, 4)
    assert m.occupancy == pytest.approx(0.75)
    assert m.mean_s == pytest.approx((0.5 + 2.0 + 0.1) / 3)
    assert m.p50_s == 0.5
    assert m.p95_s == 2.0
    snap = m.snapshot()
    assert snap["count"] == 3 and snap["budget_violations"] == 1
    m.reset()
    assert m.count == 0 and m.occupancy == 0.0


# ---------------------------------------------------------------------------
# Plan-driven continuous batcher
# ---------------------------------------------------------------------------

def _lm_plan_with_serve(cfg, serve):
    plan = plan_lib.plan_deployment(cfg, target="tpu")
    return plan_lib.DeploymentPlan.from_dict(
        {**plan.to_dict(), "serve": serve})


def test_batcher_reads_policy_from_plan():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    plan = _lm_plan_with_serve(cfg, {"slots": 2, "prefill_chunk": 2,
                                     "admit_per_tick": 1, "max_new_cap": 2})
    b = engine.ContinuousBatcher(cfg, params, plan=plan, max_len=64)
    assert b.slots == 2
    assert b.policy.prefill_chunk == 2 and b.policy.max_new_cap == 2


def test_batcher_chunked_prefill_spreads_over_ticks():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    plan = _lm_plan_with_serve(cfg, {"slots": 1, "prefill_chunk": 2})
    b = engine.ContinuousBatcher(cfg, params, plan=plan, max_len=64)
    req = engine.Request(rid=0, prompt=np.array([3, 5, 7, 11, 13], np.int32),
                         max_new=8)
    b.submit(req)
    b.step()                          # admit + first 2-token chunk
    assert req.filled == 2 and not req.out and b.pos[0] == 2
    b.step()                          # second chunk
    assert req.filled == 4 and not req.out
    b.step()                          # final chunk -> first token + 1 decode
    assert req.filled == 5 and len(req.out) == 2


def test_batcher_chunked_prefill_matches_unchunked_state():
    """Chunking only spreads prefill across ticks; the slot's cache and
    cursor must end up identical to the one-shot path (token-level outputs
    are near-tie argmaxes — assert on state, per the repo convention)."""
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    prompt = np.array([4, 8, 15, 16, 23], np.int32)

    def drained(serve):
        plan = _lm_plan_with_serve(cfg, serve)
        b = engine.ContinuousBatcher(cfg, params, plan=plan, max_len=64)
        b.submit(engine.Request(rid=0, prompt=prompt.copy(), max_new=3))
        b.run_until_drained(max_ticks=50)
        return b

    one_shot = drained({"slots": 1})
    chunked = drained({"slots": 1, "prefill_chunk": 2})
    assert one_shot.pos[0] == chunked.pos[0]
    for a, c in zip(jax.tree.leaves(one_shot.state),
                    jax.tree.leaves(chunked.state)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=1e-2, atol=1e-2)


def test_batcher_max_new_cap_evicts():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    plan = _lm_plan_with_serve(cfg, {"slots": 1, "max_new_cap": 2})
    b = engine.ContinuousBatcher(cfg, params, plan=plan, max_len=64)
    req = engine.Request(rid=0, prompt=np.array([3, 5], np.int32),
                         max_new=50)               # plan cap overrides
    b.submit(req)
    b.run_until_drained(max_ticks=20)
    assert req.done and len(req.out) == 2


def test_batcher_admit_per_tick_limits_admissions():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    plan = _lm_plan_with_serve(cfg, {"slots": 2, "admit_per_tick": 1})
    b = engine.ContinuousBatcher(cfg, params, plan=plan, max_len=64)
    for i in range(2):
        b.submit(engine.Request(rid=i, prompt=np.array([3 + i], np.int32),
                                max_new=8))
    b.step()
    assert b.n_active == 1                         # one admission per tick
    b.step()
    assert b.n_active == 2


def test_batch_policy_rejects_stalling_values():
    with pytest.raises(ValueError):
        engine.BatchPolicy(prefill_chunk=0)       # would never make progress
    with pytest.raises(ValueError):
        engine.BatchPolicy(slots=0)
    with pytest.raises(ValueError):
        engine.BatchPolicy(admit_per_tick=0)
    # An explicit 0 in a plan's serve section must fail validation too, not
    # be coerced to the default by a truthiness check.
    class _P:
        serve = {"slots": 0}
    with pytest.raises(ValueError):
        engine.BatchPolicy.from_plan(_P())


def test_batch_policy_from_plan_rejects_unknown_override():
    """Regression: a typo'd override key must fail loudly with the valid
    key set, not be silently mis-applied."""
    class _P:
        serve = {"slots": 2}
    with pytest.raises(TypeError, match="unknown BatchPolicy override"):
        engine.BatchPolicy.from_plan(_P(), prefill_chunks=2)   # typo'd key
    with pytest.raises(TypeError, match="slot"):
        engine.BatchPolicy.from_plan(_P(), slot=3)
    # Valid overrides still outrank the plan's serve section.
    p = engine.BatchPolicy.from_plan(_P(), slots=3)
    assert p.slots == 3


def test_router_idle_tenant_does_not_stall_busy_cotenant():
    """The router-level idle wait applies only when EVERY LM tenant is
    idle: tenant A being drained must not throttle tenant B's decodes."""
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    fleet = plan_lib.plan_fleet([cfg, cfg], target="tpu",
                                serve_slots_total=2, prefill_chunk=None)
    a, b = fleet.net_ids
    router = Router.from_fleet(fleet, lm={a: (cfg, params),
                                          b: (cfg, params)})
    router.submit(b, engine.Request(rid=0, prompt=np.array([3], np.int32),
                                    max_new=4))
    router.step()                                 # b busy, a idle
    t0 = time.perf_counter()
    router.step(wait_s=30.0)
    assert time.perf_counter() - t0 < 10.0        # no per-tenant parking


def test_batcher_busy_step_does_not_block_on_empty_queue():
    """The blocking idle wait applies only when every slot is empty: a busy
    batcher must keep decoding at full rate."""
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    b = engine.ContinuousBatcher(cfg, params, slots=2, max_len=32)
    b.submit(engine.Request(rid=0, prompt=np.array([3, 5], np.int32),
                            max_new=8))
    b.step()                                      # admit; slot 0 busy
    t0 = time.perf_counter()
    b.step(wait_s=30.0)                           # free slot + empty queue
    assert time.perf_counter() - t0 < 10.0        # decoded, did not park


def test_batcher_idle_blocks_instead_of_spinning():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    b = engine.ContinuousBatcher(cfg, params, slots=1, max_len=32)
    t0 = time.perf_counter()
    assert b.step(wait_s=0.2) == 0                 # idle: parks in the kernel
    assert time.perf_counter() - t0 >= 0.15
    # A queued request is admitted without burning the full wait.
    b.submit(engine.Request(rid=0, prompt=np.array([7], np.int32), max_new=1))
    assert b.step(wait_s=30.0) >= 0
    assert b._steps >= 1                           # it actually decoded


def test_router_queue_depth_aware_admission():
    """The plan-derived depth bound refuses admits BEFORE budget violations:
    a backlog at ``serve["max_queue_depth"]`` raises TenantQueueFull, and
    draining the queue re-opens admission."""
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    fleet = plan_lib.plan_fleet([cfg], target="tpu", serve_slots_total=1,
                                queue_depth_factor=2)
    nid = fleet.net_ids[0]
    assert fleet.tenants[0].plan.serve["max_queue_depth"] == 2
    router = Router.from_fleet(fleet, lm={nid: (cfg, params)})
    assert router.queue_depth_bound(nid) == 2
    reqs = [engine.Request(rid=i, prompt=np.array([3 + i], np.int32),
                           max_new=2) for i in range(3)]
    router.submit(nid, reqs[0])
    router.submit(nid, reqs[1])
    with pytest.raises(TenantQueueFull):           # backlog at the bound
        router.submit(nid, reqs[2])
    assert isinstance(TenantQueueFull("x"), TenantOverBudget)  # same family
    router.step()                                  # admits one -> queue drains
    router.submit(nid, reqs[2])                    # re-opened
    router.run_until_drained(max_ticks=200)
    for r in reqs:
        assert r.done


def test_edge_tenant_has_no_queue_bound():
    fleet = _edge_fleet(("jet_tagger",))
    router = Router.from_fleet(fleet)
    assert router.queue_depth_bound("jet_tagger") is None
    cfg = edge.edge_config("jet_tagger")
    x = jax.random.normal(jax.random.PRNGKey(0), (cfg.batch, cfg.dims[0]))
    router.infer("jet_tagger", x)                  # sync path unaffected


def test_write_serve_snapshots_roundtrip_with_trend(tmp_path):
    fleet = _edge_fleet(("jet_tagger",))
    router = Router.from_fleet(fleet)
    cfg = edge.edge_config("jet_tagger")
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.batch, cfg.dims[0]))
    for _ in range(3):
        router.infer("jet_tagger", x)
    paths = write_serve_snapshots(router.report(), tmp_path,
                                  meta={"run": "test"})
    assert [p.name for p in paths] == ["BENCH_serve_jet_tagger.json"]
    payload = trend.load(paths[0])
    names = {r["name"] for r in payload["rows"]}
    assert {"serve/jet_tagger/p50", "serve/jet_tagger/p95",
            "serve/jet_tagger/mean", "serve/jet_tagger/planned"} <= names
    assert payload["meta"]["run"] == "test"
    # trend diffs serving snapshots exactly like benchmark snapshots.
    slower = {"rows": [{**r, "us_per_call": r["us_per_call"] * 10}
                       for r in payload["rows"]]}
    deltas = {d["name"]: d for d in trend.compare(payload, slower)}
    assert deltas["serve/jet_tagger/p50"]["status"] == "regression"
    # Tenant ids with '#' (duplicate nets) sanitize into safe filenames.
    paths2 = write_serve_snapshots(
        {"jet_tagger#1": router.report()["jet_tagger"]}, tmp_path)
    assert paths2[0].name == "BENCH_serve_jet_tagger_1.json"


# ---------------------------------------------------------------------------
# BENCH trend tracking
# ---------------------------------------------------------------------------

def test_trend_compare_classifies_deltas():
    old = {"rows": [{"name": "a", "us_per_call": 10.0},
                    {"name": "b", "us_per_call": 1.0},
                    {"name": "d", "us_per_call": 5.0}]}
    new = {"rows": [{"name": "a", "us_per_call": 20.0},
                    {"name": "c", "us_per_call": 2.0},
                    {"name": "d", "us_per_call": 5.1}]}
    deltas = {d["name"]: d for d in trend.compare(old, new)}
    assert deltas["a"]["status"] == "regression"
    assert deltas["a"]["delta_pct"] == pytest.approx(100.0)
    assert deltas["b"]["status"] == "gone"
    assert deltas["c"]["status"] == "new"
    assert deltas["d"]["status"] == "steady"


def test_trend_gate_blocks_model_regressions(tmp_path, monkeypatch):
    """The CI gate fails (rc 2) only on model-sourced regressions; measured
    rows jitter with the host and never gate; the override env downgrades
    failures to warnings."""
    old = {"rows": [{"name": "m", "us_per_call": 1.0, "derived": "src=model"},
                    {"name": "w", "us_per_call": 1.0,
                     "derived": "src=measured"}]}
    p_old = tmp_path / "old.json"
    p_old.write_text(json.dumps(old))
    monkeypatch.delenv("TREND_GATE_OVERRIDE", raising=False)

    def run(rows):
        p_new = tmp_path / "new.json"
        p_new.write_text(json.dumps({"rows": rows}))
        return trend.main([str(p_new), "--against", str(p_old), "--gate"])

    # Measured-row regression alone: reported, not gated.
    assert run([{"name": "m", "us_per_call": 1.0, "derived": "src=model"},
                {"name": "w", "us_per_call": 9.0,
                 "derived": "src=measured"}]) == 0
    # Model-row regression: gated.
    bad = [{"name": "m", "us_per_call": 2.0, "derived": "src=model"},
           {"name": "w", "us_per_call": 1.0, "derived": "src=measured"}]
    assert run(bad) == 2
    # Deleting/renaming a model row is not a silent bypass: gated too.
    assert run([{"name": "w", "us_per_call": 1.0,
                 "derived": "src=measured"}]) == 2
    # Override label/env downgrades to a warning.
    monkeypatch.setenv("TREND_GATE_OVERRIDE", "1")
    assert run(bad) == 0


def test_trend_report_roundtrips_files(tmp_path, capsys):
    old = {"meta": {}, "rows": [{"name": "x", "us_per_call": 1.0,
                                "derived": "src=model"}]}
    new = {"meta": {}, "rows": [{"name": "x", "us_per_call": 3.0,
                                "derived": "src=model"}]}
    p_old, p_new = tmp_path / "old.json", tmp_path / "new.json"
    p_old.write_text(json.dumps(old))
    p_new.write_text(json.dumps(new))
    rc = trend.main([str(p_new), "--against", str(p_old)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "SLOWER" in out and "+200.0%" in out
