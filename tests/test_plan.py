"""Deployment-planner tests: graphs, regimes, column/band constraints,
boundary charges, artifact round-trip, cache keying, plan execution, CLI."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import hw as hwlib
from repro import plan as plan_lib
from repro.models import edge
from repro.plan import __main__ as plan_cli


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------

def test_edge_graph_shapes():
    cfg = edge.edge_config("qubit")
    g = plan_lib.edge_graph(cfg)
    assert len(g) == len(cfg.layer_shapes)
    assert [(n.n_in, n.n_out) for n in g] == cfg.layer_shapes
    assert g.macs == cfg.macs
    assert g.nodes[-1].act == "none"          # no activation after the head


def test_model_graph_covers_decode_gemms():
    from repro import configs
    cfg = configs.get("qwen2_5_3b").smoke
    g = plan_lib.model_graph(cfg)
    names = [n.name for n in g]
    assert "attn.wq" in names and "mlp.out" in names and "unemb" in names
    assert all(n.repeat == cfg.num_layers for n in g.nodes
               if n.name.startswith(("attn.", "mlp.")))


# ---------------------------------------------------------------------------
# Planner: every edge net, both targets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(edge.EDGE_NETS))
@pytest.mark.parametrize("target", ["aie", "tpu"])
def test_plan_all_edge_nets(name, target):
    cfg = edge.edge_config(name)
    plan = plan_lib.plan_deployment(cfg, target=target)
    assert plan.network == name and plan.target == target
    assert len(plan.layers) == len(cfg.layer_shapes)
    assert plan.est_latency_s > 0 and plan.est_interval_s > 0
    valid = {"pl", "aie"} if target == "aie" else {"pipeline", "tiled"}
    assert set(plan.regimes()) <= valid
    # Strict JSON (no NaN/Infinity) and lossless round-trip.
    s = plan.to_json()
    json.loads(s)
    assert plan_lib.DeploymentPlan.from_json(s) == plan


def test_plan_tpu_tiles_are_legal_pallas_blocks():
    cfg = edge.edge_config("autoencoder")
    plan = plan_lib.plan_deployment(cfg, target="tpu")
    sub = hwlib.TPU_V5E.sublanes_for(1)
    for l in plan.layers:
        bm, bk, bn = l.api_tile
        assert bm % sub == 0 and bk % 128 == 0 and bn % 128 == 0


def test_plan_aie_column_constraint():
    """All-AIE plans keep band-1 column usage within the usable array."""
    for name in edge.EDGE_NETS:
        plan = plan_lib.plan_deployment(edge.edge_config(name), target="aie",
                                        pl_budget=0.0)
        band1_cols = sum(l.p_k for l in plan.layers if l.band == 1)
        assert band1_cols <= hwlib.AIE_ML.usable_cols
        assert all(l.p_n <= hwlib.AIE_ML.rows for l in plan.layers)


def test_plan_aie_meets_trigger_rate():
    """Planner reproduces the paper's headline: design-rule AIE deployments
    of the Table-I nets beat the 40 MHz level-1 trigger."""
    for name in ("vae", "qubit", "autoencoder"):
        plan = plan_lib.plan_deployment(edge.edge_config(name), target="aie",
                                        pl_budget=0.0)
        assert plan.inferences_per_s / 1e6 >= 40.0, name


def test_plan_mixed_regimes_charge_boundaries():
    cfg = edge.edge_config("qubit")
    plan = plan_lib.plan_deployment(cfg, target="aie", pl_budget=100.0)
    regimes = plan.regimes()
    assert len(set(regimes)) == 2           # budget chosen to mix PL and AIE
    transitions = sum(1 for a, b in zip(regimes, regimes[1:]) if a != b)
    assert len(plan.boundaries) == transitions
    assert all(b.crossing_s > 0 for b in plan.boundaries)
    # Crossings are part of the total.
    assert plan.est_latency_s > sum(l.est_latency_s for l in plan.layers)


def test_plan_budget_monotone():
    """A generous PL budget absorbs every layer; zero budget forces AIE."""
    cfg = edge.edge_config("vae")
    rich = plan_lib.plan_deployment(cfg, target="aie", pl_budget=1e6)
    poor = plan_lib.plan_deployment(cfg, target="aie", pl_budget=0.0)
    assert set(rich.regimes()) == {"pl"}
    assert set(poor.regimes()) == {"aie"}


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def test_plan_cache_roundtrip(tmp_path):
    cfg = edge.edge_config("jet_tagger")
    cache = plan_lib.PlanCache(tmp_path)
    p1 = plan_lib.get_or_plan(cfg, target="tpu", cache=cache)
    p2 = plan_lib.get_or_plan(cfg, target="tpu", cache=cache)
    assert p1 is p2                          # memory hit
    # Disk artifact exists and reloads into a fresh cache.
    cache2 = plan_lib.PlanCache(tmp_path)
    p3 = plan_lib.get_or_plan(cfg, target="tpu", cache=cache2)
    assert p3 == p1 and p3 is not p1


def test_plan_key_sensitivity():
    cfg = edge.edge_config("jet_tagger")
    g8 = plan_lib.as_graph(cfg)
    k_tpu = plan_lib.plan_key(g8, "tpu", (hwlib.TPU_V5E,))
    assert k_tpu != plan_lib.plan_key(g8, "aie", (hwlib.PL_FABRIC,
                                                  hwlib.AIE_ML))
    # Hardware re-parameterization invalidates the key.
    import dataclasses
    slower = dataclasses.replace(hwlib.TPU_V5E, hbm_bw=1e9)
    assert k_tpu != plan_lib.plan_key(g8, "tpu", (slower,))
    # Different batch -> different graph -> different key.
    g16 = plan_lib.edge_graph(dataclasses.replace(cfg, batch=16))
    assert k_tpu != plan_lib.plan_key(g16, "tpu", (hwlib.TPU_V5E,))


# ---------------------------------------------------------------------------
# Plan execution (the consumers)
# ---------------------------------------------------------------------------

def test_edge_forward_planned_matches_explicit_blocks():
    cfg = edge.edge_config("jet_tagger")
    params = edge.init_edge(jax.random.PRNGKey(0), cfg)
    qp = edge.quantize_edge(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.batch, cfg.dims[0]))
    plan = plan_lib.plan_deployment(cfg, target="tpu")
    y_plan = edge.edge_forward_q8(qp, cfg, x, x_scale=0.02, plan=plan)
    y_fixed = edge.edge_forward_q8(qp, cfg, x, x_scale=0.02,
                                   block_m=8, block_k=128, block_n=128)
    # int32 accumulation is exact under any legal blocking.
    np.testing.assert_allclose(np.asarray(y_plan), np.asarray(y_fixed),
                               rtol=1e-5, atol=1e-5)


def test_edge_engine_executes_plan():
    from repro.serve.engine import EdgeEngine
    cfg = edge.edge_config("tau_select")
    eng = EdgeEngine(cfg, x_scale=0.02)
    x = jax.random.normal(jax.random.PRNGKey(2),
                          (cfg.batch, cfg.dims[0])) * 0.5
    y = eng.infer(x)
    assert y.shape == (cfg.batch, cfg.dims[-1])
    assert eng.calls == 1 and eng.measured_mean_s > 0
    assert eng.planned_latency_s == eng.plan.est_latency_s


def test_serve_steps_consume_plan():
    from repro import configs
    from repro.models import api
    from repro.serve import engine
    cfg = configs.get("qwen2_5_3b").smoke
    plan = plan_lib.plan_deployment(cfg, target="tpu")
    assert plan.serve.get("quantize_weights") in (True, False)
    params = api.init(cfg, jax.random.PRNGKey(0))
    prepared = engine.prepare_params(params, plan=plan)
    # The smoke config's GEMMs are small; either way the decision came from
    # the plan, and chunked prefill still works when the plan requests it.
    chunked = plan_lib.DeploymentPlan.from_dict(
        {**plan.to_dict(), "serve": {"prefill_chunk": 4}})
    prefill, decode = engine.build_serve_steps(cfg, max_len=32, plan=chunked)
    state = api.init_decode_state(cfg, 2, 32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    logits, state = prefill(prepared, toks, state)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    # Chunked prefill matches the one-shot path.
    prefill1, _ = engine.build_serve_steps(cfg, max_len=32)
    logits1, _ = prefill1(prepared, toks, api.init_decode_state(cfg, 2, 32))
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(logits1, np.float32),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_emits_artifacts(tmp_path, capsys):
    rc = plan_cli.main(["jet_tagger", "--target", "both",
                        "--out", str(tmp_path)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "jet_tagger [aie]" in out and "jet_tagger [tpu]" in out
    for target in ("aie", "tpu"):
        art = tmp_path / f"jet_tagger_{target}.json"
        assert art.exists()
        plan = plan_lib.DeploymentPlan.load(art)
        assert plan.network == "jet_tagger" and plan.target == target


def test_cli_rejects_unknown_net(tmp_path):
    assert plan_cli.main(["nope", "--out", str(tmp_path)]) == 2
    assert plan_cli.main(["jet_tagger", "nope", "--out", str(tmp_path)]) == 2


def test_cli_artifact_roundtrip(tmp_path):
    """CLI plan -> JSON -> reload is lossless: the reloaded artifact
    re-serializes byte-identically."""
    assert plan_cli.main(["qubit", "--target", "tpu",
                          "--out", str(tmp_path)]) == 0
    art = tmp_path / "qubit_tpu.json"
    plan = plan_lib.DeploymentPlan.load(art)
    assert plan.to_json() + "\n" == art.read_text()
    assert plan_lib.DeploymentPlan.from_json(plan.to_json()) == plan


def test_cli_fleet_emits_artifact(tmp_path, capsys):
    rc = plan_cli.main(["jet_tagger", "tau_select", "--target", "aie",
                        "--pl-budget", "0", "--out", str(tmp_path)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "fleet jet_tagger+tau_select [aie]" in out
    art = tmp_path / "fleet_jet_tagger+tau_select_aie.json"
    fleet = plan_lib.FleetPlan.load(art)
    assert fleet.net_ids == ["jet_tagger", "tau_select"]
    assert fleet.band1_cols_used > 0
    assert plan_lib.FleetPlan.from_json(fleet.to_json()) == fleet
