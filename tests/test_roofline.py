"""Loop-aware HLO analysis + shared roofline terms.

The toy scanned HLO below exercises exactly what ``cost_analysis()`` gets
wrong on scanned layer stacks: a while loop with a static trip count whose
body holds a dot and a GSPMD-style collective — the analyzer must multiply
both by the trip count.  Also covers the dtype byte table, the collective
payload formulas, the serving-executable entry points, and the retired
``launch/roofline.py`` path now running on the shared term math.
"""

import json

import jax
import jax.numpy as jnp
import pytest

from repro import hw as hwlib
from repro.launch import hlo_analysis as ha
from repro.launch import roofline as rl
from repro.obs.profile import roofline_terms

# A hand-written post-optimization-style module: ENTRY wraps a while loop
# with trip count 4; the body runs one (8,16)x(16,16) dot and one
# 4-way all-gather of the f32[8,16] activations.
TOY_HLO = """\
HloModule toy

%cond (arg: (s32[], f32[8,16])) -> pred[] {
  %arg = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %trip = s32[] constant(4)
  ROOT %lt = pred[] compare(%i, %trip), direction=LT
}

%body (arg2: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg2 = (s32[], f32[8,16]) parameter(0)
  %j = s32[] get-tuple-element(%arg2), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%arg2), index=1
  %w = f32[16,16]{1,0} constant({...})
  %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[32,16]{1,0} all-gather(%y), replica_groups=[1,4]<=[4], dimensions={0}
  %one = s32[] constant(1)
  %j1 = s32[] add(%j, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%j1, %y)
}

ENTRY %main (x: f32[8,16]) -> f32[8,16] {
  %x0 = f32[8,16]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%c0, %x0)
  %loop = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
}
"""


# ---------------------------------------------------------------------------
# dtype byte table + shape parsing
# ---------------------------------------------------------------------------

def test_dtype_byte_table():
    assert ha._DTYPE_BYTES["s8"] == 1
    assert ha._DTYPE_BYTES["bf16"] == 2
    assert ha._DTYPE_BYTES["f32"] == 4
    assert ha._DTYPE_BYTES["f64"] == 8
    assert ha._DTYPE_BYTES["f8e4m3fn"] == 1


@pytest.mark.parametrize("text,expected", [
    ("f32[8,16]", 8 * 16 * 4),
    ("bf16[16,16]{1,0}", 16 * 16 * 2),
    ("s8[128]", 128),
    ("(s32[], f32[8,16])", 4 + 8 * 16 * 4),   # tuple: sum of members
    ("pred[]", 1),                            # scalar: one element
])
def test_shape_bytes(text, expected):
    assert ha._shape_bytes(text) == expected


# ---------------------------------------------------------------------------
# while-loop trip counts and multipliers
# ---------------------------------------------------------------------------

def test_while_trip_count_multiplies_body():
    comps = ha.parse_computations(TOY_HLO)
    assert set(comps) == {"cond", "body", "main", "__entry__"}
    mult = ha._multipliers(comps)
    assert mult["main"] == 1.0
    assert mult["body"] == 4.0            # trip count from constant(4)
    assert mult["cond"] == 5.0            # trip + 1 evaluations


def test_dot_flops_scale_with_trip_count():
    out = ha.analyze_hlo(TOY_HLO)
    # one dot per iteration: 2 * (8*16 result) * 16 contracted = 4096
    assert out["flops"] == pytest.approx(4 * 2 * 8 * 16 * 16)
    assert out["n_computations"] == 3


def test_collective_payload_accounting():
    out = ha.analyze_hlo(TOY_HLO)
    coll = out["collectives"]
    assert set(coll) == {"all-gather"}
    ag = coll["all-gather"]
    rb = 32 * 16 * 4                      # f32[32,16] result bytes
    g = 4                                 # replica_groups=[1,4]
    assert ag["count"] == 4.0             # once per loop iteration
    assert ag["operand_bytes"] == 4 * (rb // g)
    assert ag["wire_bytes"] == 4 * (rb * (g - 1) // g)
    assert out["collective_operand_bytes"] == ag["operand_bytes"]
    assert out["collective_wire_bytes"] == ag["wire_bytes"]


def test_loop_once_would_undercount():
    """The failure mode the docstring warns about: dropping the loop
    multiplier (what ``cost_analysis()`` does) undercounts by ~trip x."""
    looped = ha.analyze_hlo(TOY_HLO)
    unrolled_once = ha.analyze_hlo(TOY_HLO.replace("constant(4)",
                                                   "constant(1)"))
    assert looped["flops"] == 4 * unrolled_once["flops"]


# ---------------------------------------------------------------------------
# serving-executable entry points
# ---------------------------------------------------------------------------

def test_analyze_jitted_counts_matmul_flops():
    w = jnp.ones((16, 32), jnp.float32)
    fn = jax.jit(lambda x: x @ w)
    x = jnp.ones((8, 16), jnp.float32)
    out = ha.analyze_jitted(fn, x)
    assert out["flops"] == pytest.approx(2 * 8 * 16 * 32)
    assert out["bytes_est"] > 0


class _FakeEngine:
    def hlo_text(self):
        return TOY_HLO


def test_hlo_overhead_reports_useful_fraction():
    ov = ha.hlo_overhead(2 * 8 * 16 * 16, _FakeEngine())
    assert ov["hlo_flops"] == pytest.approx(4 * 2 * 8 * 16 * 16)
    assert ov["useful_fraction"] == pytest.approx(0.25)
    # no compiled FLOPs -> no fraction, not a ZeroDivisionError
    class _Empty:
        def hlo_text(self):
            return "ENTRY %e (p: f32[2]) -> f32[2] {\n" \
                   "  ROOT %p = f32[2]{0} parameter(0)\n}\n"
    assert ha.hlo_overhead(1.0, _Empty())["useful_fraction"] is None


# ---------------------------------------------------------------------------
# launch/roofline.py on the shared term math
# ---------------------------------------------------------------------------

def _cell(**over):
    cell = {
        "arch": "qwen2_5_3b", "shape": "decode_32k", "phase": "decode",
        "mesh_kind": "single", "flops": 1e12, "hlo_bytes": 1e9,
        "collective_operand_bytes": 0.0, "temp_size_in_bytes": 0,
        "argument_size_in_bytes": 0,
    }
    cell.update(over)
    return cell


def test_analyze_cell_uses_shared_ceilings():
    r = rl.analyze_cell(_cell())
    tpu = hwlib.TPU_V5E
    assert r["t_compute_s"] == pytest.approx(1e12 / tpu.peak_bf16_flops)
    assert r["t_memory_s"] == pytest.approx(1e9 / tpu.hbm_bw)
    assert r["dominant"] in ("compute", "memory", "collective")
    # one ceiling of truth: a substituted hw model moves the terms
    import dataclasses
    fast = dataclasses.replace(tpu, hbm_bw=tpu.hbm_bw * 2)
    r2 = rl.analyze_cell(_cell(), hw=fast)
    assert r2["t_memory_s"] == pytest.approx(r["t_memory_s"] / 2)


def test_resolve_hw_stock_and_fitted(tmp_path):
    assert rl.resolve_hw(None) is hwlib.TPU_V5E
    assert rl.resolve_hw("stock") is hwlib.TPU_V5E


def test_roofline_terms_bound_classification():
    hw = hwlib.TPU_V5E
    t = roofline_terms(1e15, 1.0, 0, hw=hw)
    assert t["bound"] == "compute"
    t = roofline_terms(1.0, 1e12, 0, hw=hw)
    assert t["bound"] == "memory"
    t = roofline_terms(1.0, 1.0, 100, hw=hw)
    assert t["bound"] == "launch"
    t = roofline_terms(1.0, 1.0, 0, hw=hw, collective_bytes=1e12)
    assert t["bound"] == "collective"
    # int8 work prices against the int8 peak
    t8 = roofline_terms(1e12, 1.0, 0, itemsize=1, hw=hw)
    t16 = roofline_terms(1e12, 1.0, 0, itemsize=2, hw=hw)
    assert t8["t_compute_s"] < t16["t_compute_s"]
    assert t8["peak_flops"] == hw.peak_int8_ops
