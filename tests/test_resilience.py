"""Resilience tests: fault taxonomy + injection, circuit breaker FSM,
degradation-ladder bit-exactness, router isolation, crash-safe plan cache,
and recovery under a chaos replay."""

import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, faults
from repro import plan as plan_lib
from repro.models import api, edge
from repro.serve import engine
from repro.serve.resilience import CircuitBreaker, Supervisor
from repro.serve.router import (Router, TenantBreakerOpen, TenantFaulted,
                                TenantOverBudget)


# ---------------------------------------------------------------------------
# Fault plans + injector
# ---------------------------------------------------------------------------

def test_fault_plan_generate_deterministic():
    a = faults.FaultPlan.generate(["x", "y"], seed=7)
    b = faults.FaultPlan.generate(["x", "y"], seed=7)
    assert a == b and a.faults
    assert a != faults.FaultPlan.generate(["x", "y"], seed=8)


def test_fault_plan_json_roundtrip(tmp_path):
    plan = faults.FaultPlan.generate(["jet_tagger"], seed=3)
    assert faults.FaultPlan.from_json(plan.to_json()) == plan
    p = plan.save(tmp_path / "faults.json")
    assert faults.FaultPlan.load(p) == plan
    # strict JSON on disk
    json.loads(p.read_text())


def test_fault_spec_validation_and_default_site():
    with pytest.raises(ValueError):
        faults.FaultSpec(kind="nope")
    with pytest.raises(ValueError):
        faults.FaultSpec(kind="latency_spike", site="nowhere")
    with pytest.raises(ValueError):
        faults.FaultSpec(kind="latency_spike", count=0)
    for kind, site in faults.DEFAULT_SITE.items():
        assert faults.FaultSpec(kind=kind).site == site


def test_injector_fires_by_invocation_count():
    plan = faults.FaultPlan(faults=(
        faults.FaultSpec(kind="engine_exception", tenant="a",
                         after=2, count=2),))
    inj = plan.injector()
    hits = [inj.fire("engine.infer", tenant="a") is not None
            for _ in range(6)]
    assert hits == [False, False, True, True, False, False]
    assert inj.fired(tenant="a") == 2 == plan.scheduled("a")
    # a co-resident tenant's hook counts independently and never fires
    assert all(inj.fire("engine.infer", tenant="b") is None
               for _ in range(6))
    assert inj.fired(tenant="b") == 0
    assert [e["call"] for e in inj.log] == [2, 3]


# ---------------------------------------------------------------------------
# Circuit breaker FSM
# ---------------------------------------------------------------------------

def test_breaker_full_cycle_closed_open_halfopen_closed():
    br = CircuitBreaker(k=2, cooldown=3)
    br.record_failure()
    assert br.state == "closed"
    br.record_failure()                   # k-th consecutive failure
    assert br.state == "open" and br.opens == 1
    assert not br.allow() and not br.allow() and not br.allow()
    assert br.allow()                     # after 3 refusals: the probe
    assert br.state == "half_open"
    br.record_success()
    assert br.state == "closed" and br.recloses == 1
    assert br.time_to_recovery_s is not None


def test_breaker_probe_failure_reopens():
    br = CircuitBreaker(k=1, cooldown=2)
    br.record_failure()
    assert br.state == "open"
    assert not br.allow() and not br.allow()
    assert br.allow()                     # probe after 2 refusals
    br.record_failure()                   # probe failed
    assert br.state == "open" and br.opens == 2
    assert not br.allow() and not br.allow()   # cooldown restarted
    assert br.allow()
    br.record_success()
    assert br.state == "closed" and br.recloses == 1


def test_breaker_success_resets_streak():
    br = CircuitBreaker(k=3)
    br.record_failure()
    br.record_failure()
    br.record_success()
    br.record_failure()
    br.record_failure()
    assert br.state == "closed"           # streak never reached k


# ---------------------------------------------------------------------------
# Degradation ladder: per-layer fallback is bit-exact vs fused
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("net", sorted(edge.EDGE_NETS))
def test_degraded_engine_matches_fused(net):
    cfg = edge.edge_config(net)
    plan = plan_lib.get_or_plan(cfg, target="tpu")
    eng = engine.EdgeEngine(cfg, plan=plan, x_scale=0.02, seed=0)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (cfg.batch, cfg.dims[0])) * 0.5
    fused = np.asarray(eng.infer(x))
    assert eng.degrade() and eng.degrade_level == 1
    assert not eng.degrade()              # one rung only
    degraded = np.asarray(eng.infer(x))
    np.testing.assert_allclose(degraded, fused, rtol=1e-5, atol=1e-6)
    assert eng.restore() and eng.degrade_level == 0
    np.testing.assert_allclose(np.asarray(eng.infer(x)), fused,
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Non-finite output guards
# ---------------------------------------------------------------------------

def test_edge_engine_nonfinite_guard():
    cfg = edge.edge_config("jet_tagger")
    plan = plan_lib.get_or_plan(cfg, target="tpu")
    eng = engine.EdgeEngine(cfg, plan=plan, x_scale=0.02)
    x = jnp.ones((cfg.batch, cfg.dims[0]), jnp.float32)
    eng.infer(x)                          # warm (indices not consumed yet)
    eng.injector = faults.FaultPlan(faults=(
        faults.FaultSpec(kind="non_finite_output",
                         tenant=eng.trace_label, after=0),)).injector()
    with pytest.raises(faults.NonFiniteOutput):
        eng.infer(x)
    assert eng.faults == 1
    eng.infer(x)                          # next call is clean again


def test_batcher_nonfinite_fails_request_not_batch():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    fleet = plan_lib.plan_fleet([cfg], target="tpu", serve_slots_total=2)
    nid = fleet.net_ids[0]
    router = Router.from_fleet(fleet, lm={nid: (cfg, params)},
                               resilience=True)
    t = router.tenant(nid)
    good = engine.Request(rid=0, prompt=np.array([3, 5, 7], np.int32),
                          max_new=3)
    router.submit(nid, good)
    router.run_until_drained(max_ticks=300)   # warm the decode path
    router.arm_faults(faults.FaultPlan(faults=(
        faults.FaultSpec(kind="non_finite_output", site="batcher.decode",
                         tenant=nid, after=0),)).injector())
    bad = engine.Request(rid=1, prompt=np.array([4, 6, 8], np.int32),
                         max_new=3)
    router.submit(nid, bad)
    router.run_until_drained(max_ticks=300)
    assert bad.done and bad.error == "non_finite_output"
    assert t.metrics.failures == 1
    assert t.engine.faults >= 1
    # the slot was freed: a later request still completes
    again = engine.Request(rid=2, prompt=np.array([3, 5, 7], np.int32),
                           max_new=3)
    router.submit(nid, again)
    router.run_until_drained(max_ticks=300)
    assert again.done and again.error is None and len(again.out) == 3


def test_batcher_stall_and_exception_isolated():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    fleet = plan_lib.plan_fleet([cfg], target="tpu", serve_slots_total=2)
    nid = fleet.net_ids[0]
    router = Router.from_fleet(fleet, lm={nid: (cfg, params)},
                               resilience=True)
    req = engine.Request(rid=0, prompt=np.array([3, 5, 7], np.int32),
                         max_new=3)
    router.submit(nid, req)
    router.arm_faults(faults.FaultPlan(faults=(
        faults.FaultSpec(kind="batcher_stall", tenant=nid, after=0),
        faults.FaultSpec(kind="engine_exception", site="batcher.tick",
                         tenant=nid, after=1),)).injector())
    router.step()                         # stalled: tick skipped
    assert not req.done
    router.step()                         # injected engine exception
    assert router.tenant(nid).metrics.failures == 1
    router.run_until_drained(max_ticks=300)   # batch survives the fault
    assert req.done and req.error is None and len(req.out) == 3


# ---------------------------------------------------------------------------
# Router isolation + breaker integration
# ---------------------------------------------------------------------------

def _served_router(**kw):
    fleet = plan_lib.plan_fleet(
        [edge.edge_config(n) for n in ("jet_tagger", "tau_select")],
        target="tpu")
    router = Router.from_fleet(fleet, resilience=True, **kw)
    xs = {nid: jax.random.normal(jax.random.PRNGKey(1),
                                 (edge.edge_config(nid).batch,
                                  edge.edge_config(nid).dims[0])) * 0.5
          for nid in router.net_ids}
    for nid, x in xs.items():
        router.infer(nid, x)              # warm before arming faults
    return router, xs


def test_router_isolates_faulted_tenant():
    router, xs = _served_router()
    router.arm_faults(faults.FaultPlan.burst(
        "jet_tagger", after=0, count=2).injector())
    # retries=1 consumes both scheduled faults in ONE request: the retry
    # hits the next scheduled index, then the burst is exhausted.
    with pytest.raises(TenantFaulted):
        router.infer("jet_tagger", xs["jet_tagger"])
    t = router.tenant("jet_tagger")
    assert t.metrics.failures == 1
    assert router.supervisor.retries["jet_tagger"] == 1
    # co-resident keeps serving; victim recovers once the burst is over
    router.infer("tau_select", xs["tau_select"])
    router.infer("jet_tagger", xs["jet_tagger"])
    assert t.metrics.failures == 1        # no new failures


def test_breaker_opens_and_recloses_through_router():
    router, xs = _served_router()
    sup = router.supervisor
    cfg = sup.cfg("jet_tagger")
    k, cooldown, retries = (cfg["breaker_k"], cfg["breaker_cooldown"],
                            cfg["retries"])
    burst = k * (retries + 1)             # each failed request burns 1+retries
    router.arm_faults(faults.FaultPlan.burst(
        "jet_tagger", after=0, count=burst).injector())
    for _ in range(k):
        with pytest.raises(TenantFaulted):
            router.infer("jet_tagger", xs["jet_tagger"])
    br = sup.breaker("jet_tagger")
    assert br.state == "open" and br.opens == 1
    # the ladder stepped down when the breaker opened
    assert router.tenant("jet_tagger").engine.degrade_level == 1
    health = router.health()
    assert health["tenants"]["jet_tagger"]["state"] == "open"
    assert health["tenants"]["jet_tagger"]["degrade_level"] == 2  # shedding
    for _ in range(cooldown):
        with pytest.raises(TenantBreakerOpen):
            router.infer("jet_tagger", xs["jet_tagger"])
    # co-resident tenant was never gated
    router.infer("tau_select", xs["tau_select"])
    # burst exhausted: the half-open probe succeeds and re-closes
    router.infer("jet_tagger", xs["jet_tagger"])
    assert br.state == "closed" and br.recloses == 1
    assert br.time_to_recovery_s is not None
    # a clean streak one cooldown long restores the fused path
    for _ in range(cooldown + 1):
        router.infer("jet_tagger", xs["jet_tagger"])
    assert router.tenant("jet_tagger").engine.degrade_level == 0
    assert sup.restores["jet_tagger"] == 1


def test_breaker_exception_ordering():
    assert issubclass(TenantBreakerOpen, TenantFaulted)
    assert issubclass(TenantFaulted, TenantOverBudget)


def test_replan_failure_falls_back_to_current_fleet():
    # No warmup here: the compile-heavy first call plus drift_min_samples=1
    # guarantees the drift watcher trips while the replan fault is armed.
    fleet = plan_lib.plan_fleet([edge.edge_config("jet_tagger")],
                                target="tpu")
    router = Router.from_fleet(fleet, resilience=True, drift_threshold=1.5,
                               drift_min_samples=1,
                               cache=plan_lib.PlanCache())
    router.arm_faults(faults.FaultPlan(faults=(
        faults.FaultSpec(kind="replan_failure", tenant="jet_tagger",
                         after=0, count=99),)).injector())
    cfg = edge.edge_config("jet_tagger")
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (cfg.batch, cfg.dims[0])) * 0.5
    # CPU wall-clock vs modeled accelerator latency trips the drift watcher;
    # the injected replan failure must not take down serving.
    for _ in range(4):
        router.infer("jet_tagger", x)
    assert router.replan_failures >= 1
    assert router.fleet is fleet
    router.infer("jet_tagger", x)                  # still serving


# ---------------------------------------------------------------------------
# Crash-safe plan cache
# ---------------------------------------------------------------------------

def test_plan_save_is_atomic(tmp_path):
    cfg = edge.edge_config("jet_tagger")
    plan = plan_lib.get_or_plan(cfg, target="tpu")
    p = plan.save(tmp_path / "plan.json")
    assert json.loads(p.read_text())["network"] == "jet_tagger"
    assert not list(tmp_path.glob("*.tmp.*"))     # no tmp droppings


def test_corrupt_cached_plan_is_a_miss_with_warning(tmp_path):
    cfg = edge.edge_config("tau_select")
    cache = plan_lib.PlanCache(tmp_path)
    plan = plan_lib.get_or_plan(cfg, target="tpu", cache=cache)
    disk = tmp_path / f"{plan.key}.json"
    assert disk.exists()
    disk.write_text(disk.read_text()[:40])        # truncate mid-artifact
    cold = plan_lib.PlanCache(tmp_path)           # fresh memory, bad disk
    with pytest.warns(RuntimeWarning, match="corrupt"):
        assert cold.get(plan.key) is None
    assert cold.corrupt_reads == 1
    # planning again through the cold cache self-heals the artifact
    again = plan_lib.get_or_plan(cfg, target="tpu", cache=cold)
    assert again.key == plan.key
    assert plan_lib.DeploymentPlan.load(disk).key == plan.key


def test_injected_cache_corruption_is_a_miss(tmp_path):
    cfg = edge.edge_config("tau_select")
    cache = plan_lib.PlanCache(tmp_path)
    plan = plan_lib.get_or_plan(cfg, target="tpu", cache=cache)
    cold = plan_lib.PlanCache(tmp_path)
    cold.injector = faults.FaultPlan(faults=(
        faults.FaultSpec(kind="cache_corruption", after=0),)).injector()
    with pytest.warns(RuntimeWarning, match="injected"):
        assert cold.get(plan.key) is None
    assert cold.corrupt_reads == 1
    assert cold.get(plan.key) is not None         # next read is clean


# ---------------------------------------------------------------------------
# Plan artifacts carry the resilience knobs (plan-6)
# ---------------------------------------------------------------------------

def test_fleet_serve_section_has_resilience_knobs():
    fleet = plan_lib.plan_fleet(
        [edge.edge_config(n) for n in ("jet_tagger", "tau_select")],
        target="tpu")
    for tp in fleet.tenants:
        res = tp.plan.serve["resilience"]
        assert res == faults.RESILIENCE_DEFAULTS
    from repro.plan.artifact import PLANNER_VERSION
    assert PLANNER_VERSION == "plan-6"
    # and they survive the artifact round-trip
    again = plan_lib.multinet.FleetPlan.from_json(fleet.to_json())
    assert again.tenants[0].plan.serve["resilience"] == \
        faults.RESILIENCE_DEFAULTS


def test_supervisor_reads_plan_knobs():
    fleet = plan_lib.plan_fleet([edge.edge_config("jet_tagger")],
                                target="tpu")
    sup = Supervisor.from_fleet(fleet)
    cfg = sup.cfg("jet_tagger")
    assert cfg["breaker_k"] == faults.RESILIENCE_DEFAULTS["breaker_k"]
    # deadline derives from the serve-section SLO budget
    p95 = fleet.tenants[0].plan.serve["slo"]["p95_s"]
    assert sup._deadline_s["jet_tagger"] == pytest.approx(
        cfg["deadline_factor"] * p95)


# ---------------------------------------------------------------------------
# Prometheus resilience families
# ---------------------------------------------------------------------------

def test_prometheus_resilience_families_parse():
    from repro.obs.export import parse_prometheus, prometheus_text
    health = {"tenants": {
        "jet_tagger": {"failures": 3, "state": "open", "breaker_opens": 1,
                       "breaker_recloses": 0, "degrade_level": 2,
                       "retries": 2, "deadline_exceeded": 1},
        "tau_select": {"failures": 0, "degrade_level": 0}},
        "replan_failures": 1, "supervised": True}
    text = prometheus_text({}, resilience=health)
    samples = parse_prometheus(text)
    by_name = {}
    for s in samples:
        by_name.setdefault(s["name"], []).append(s)
    fails = {s["labels"]["tenant"]: s["value"]
             for s in by_name["repro_resilience_failures_total"]}
    assert fails == {"jet_tagger": 3.0, "tau_select": 0.0}
    st = by_name["repro_resilience_breaker_state"][0]
    assert st["labels"] == {"tenant": "jet_tagger", "state": "open"}
    levels = {s["labels"]["tenant"]: s["value"]
              for s in by_name["repro_resilience_degrade_level"]}
    assert levels == {"jet_tagger": 2.0, "tau_select": 0.0}
    assert by_name["repro_resilience_replan_failures_total"][0]["value"] == 1


# ---------------------------------------------------------------------------
# Replay under faults: isolation + recovery, end to end
# ---------------------------------------------------------------------------

def test_replay_under_faults_recovers(tmp_path):
    from repro.deploy import Deployment
    dep = Deployment.build(["jet_tagger", "tau_select"], target="tpu",
                           machine_model=None,
                           cache=plan_lib.PlanCache())
    router = dep.serve()
    cfg = router.supervisor.cfg("jet_tagger")
    burst = cfg["breaker_k"] * (cfg["retries"] + 1)
    plan = faults.FaultPlan.burst("jet_tagger", after=4, count=burst)
    inj = plan.injector()
    report = dep.replay("flash_crowd", duration_s=0.15, seed=0,
                        faults=inj, json_dir=tmp_path)
    s = report.summary()
    # the victim faulted and was breaker-gated...
    assert inj.fired(tenant="jet_tagger") == burst
    assert s["jet_tagger"]["fault"] == cfg["breaker_k"]
    assert s["jet_tagger"]["breaker"] >= cfg["breaker_cooldown"]
    # ...but recovered: breaker re-closed and requests completed after it
    vh = router.health()["tenants"]["jet_tagger"]
    assert vh["breaker_opens"] == 1 and vh["breaker_recloses"] == 1
    assert vh["state"] == "closed"
    assert vh["time_to_recovery_s"] is not None
    assert s["jet_tagger"]["ok"] > 0
    # co-resident isolation: tau_select served finite latencies throughout
    assert s["tau_select"]["fault"] == 0 == s["tau_select"]["breaker"]
    assert s["tau_select"]["ok"] == s["tau_select"]["count"]
    assert np.isfinite(s["tau_select"]["p95_s"])
    # snapshots carry the fault/breaker counters, strict-JSON
    snap = json.loads(
        (tmp_path / "BENCH_serve_jet_tagger__flash_crowd.json").read_text())
    derived = snap["rows"][0]["derived"]
    assert f"fault={cfg['breaker_k']}" in derived
    assert "breaker=" in derived


def test_deployment_summary_and_prometheus_health(tmp_path):
    from repro.deploy import Deployment
    dep = Deployment.build(["jet_tagger"], target="tpu", machine_model=None,
                           cache=plan_lib.PlanCache())
    router = dep.serve()
    x = jnp.ones((edge.edge_config("jet_tagger").batch,
                  edge.edge_config("jet_tagger").dims[0]), jnp.float32)
    router.infer("jet_tagger", x)
    router.arm_faults(faults.FaultPlan.burst(
        "jet_tagger", after=0, count=2).injector())
    with pytest.raises(TenantFaulted):
        router.infer("jet_tagger", x)
    assert "health:" in dep.summary()
    p = dep.export_prometheus(tmp_path / "metrics.prom")
    from repro.obs.export import parse_prometheus
    samples = parse_prometheus(p.read_text())
    fails = [s for s in samples
             if s["name"] == "repro_resilience_failures_total"]
    assert fails and fails[0]["value"] == 1.0
