"""repro.deploy facade: staged pipeline, caching, serving, bench, CLI."""

import numpy as np
import pytest

from repro import configs
from repro import plan as plan_lib
from repro.deploy import Deployment, StageContext, resolve_configs, stages
from repro.models import edge
from repro.serve.engine import ContinuousBatcher, EdgeEngine, Request


@pytest.fixture(scope="module")
def lm_cfg():
    return configs.get("qwen2_5_3b").smoke


@pytest.fixture(scope="module")
def built(lm_cfg):
    """One full build shared by the e2e assertions: 2 edge nets + 1 LM,
    planned under the host-calibrated model, engines live."""
    cache = plan_lib.PlanCache()
    dep = Deployment.build(["jet_tagger", "tau_select", lm_cfg],
                           machine_model="auto", cache=cache)
    return dep, cache


# ---------------------------------------------------------------------------
# The e2e smoke the ISSUE asks for
# ---------------------------------------------------------------------------

def test_build_runs_all_stages(built, lm_cfg):
    dep, _ = built
    assert list(dep.stage_results) == ["characterize", "plan", "engines"]
    assert set(dep.plans) == {"jet_tagger", "tau_select", lm_cfg.name}
    assert isinstance(dep.engines["jet_tagger"], EdgeEngine)
    assert isinstance(dep.engines[lm_cfg.name], ContinuousBatcher)
    # The LM tenant's batcher is plan-driven (slots from the serve section).
    lm_plan = dep.plans[lm_cfg.name]
    assert dep.engines[lm_cfg.name].slots == lm_plan.serve["slots"]
    # machine_model="auto" resolved to a host-calibrated TpuV5e.
    from repro import hw as hwlib
    assert isinstance(dep.machine_model, hwlib.TpuV5e)
    assert dep.machine_model.kernel_overhead_s != hwlib.TPU_V5E.kernel_overhead_s


def test_second_build_hits_plan_cache(built, lm_cfg):
    _, cache = built
    dep2 = Deployment.build(["jet_tagger", "tau_select", lm_cfg],
                            machine_model="auto", cache=cache,
                            stop_after="plan")
    assert dep2.stage_results["plan"].cached
    assert dep2.stage_results["characterize"].cached    # process memo
    assert "engines" not in dep2.stage_results          # partial pipeline


def test_serve_drains_request_set(built, lm_cfg):
    dep, _ = built
    router = dep.serve()
    inputs = router.warmup()
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, lm_cfg.vocab_size,
                                        3).astype(np.int32), max_new=3)
            for i in range(3)]
    for r in reqs:
        router.submit(lm_cfg.name, r)
    router.drive(inputs, iters=4)
    router.run_until_drained(max_ticks=200)
    assert all(r.done and len(r.out) == 3 for r in reqs)
    rep = router.report()
    assert rep["jet_tagger"]["count"] >= 4
    assert rep[lm_cfg.name]["count"] == 3


def test_bench_row_shape(built):
    dep, _ = built
    rows = dep.bench(iters=3, warmup=1)
    assert [r.net_id for r in rows] == ["jet_tagger", "tau_select"]  # no LM
    for r in rows:
        rec = r.as_record()
        assert rec["name"] == f"deploy/{r.net_id}/planned-vs-measured"
        assert "src=measured" in rec["derived"]
        assert rec["us_per_call"] > 0


def test_bench_rows_within_2x():
    """A fully-characterized deployment predicts interpret-mode latency
    within the repo-wide 2x band.  Like fig10/fig11, a load shift between
    sweep and measurement is drift, not model error — re-characterize under
    the current load (up to 3 passes) before failing."""
    from repro.characterize import characterize
    for _ in range(3):
        mm = characterize(sweep="quick")
        dep = Deployment.build(["jet_tagger", "tau_select"],
                               machine_model=mm, cache=plan_lib.PlanCache())
        rows = dep.bench(iters=7, warmup=2)
        if all(r.within_2x for r in rows):
            break
    assert all(r.within_2x for r in rows), [r.as_record() for r in rows]


def test_recalibrate_adopts_measured_costs(built):
    dep, cache = built
    before = {t.net_id: t.plan.est_latency_s for t in dep.fleet.tenants}
    new_fleet = dep.recalibrate()
    assert dep.fleet is new_fleet
    for t in new_fleet.tenants:
        if t.plan.kind != "edge":
            continue
        assert "calibration" in t.plan.serve
        assert t.plan.est_latency_s != before[t.net_id]
        # Engines executed the same tiles but adopted the new cost story.
        assert dep.engines[t.net_id].plan is t.plan
        # Calibrated plans landed in the cache under their original keys.
        assert cache.get(t.plan.key).est_latency_s == t.plan.est_latency_s


# ---------------------------------------------------------------------------
# Partial pipelines + spec resolution
# ---------------------------------------------------------------------------

def test_plan_only_builds_no_engines():
    dep = Deployment.build("jet_tagger", machine_model=None,
                           stop_after="plan", cache=plan_lib.PlanCache())
    assert "engines" not in dep.stage_results
    assert dep.ctx.engines == {}
    assert dep.plan.network == "jet_tagger"
    # Stock constants: the characterize stage is an explicit no-op.
    assert dep.stage_results["characterize"].skipped
    # .engines builds lazily when asked.
    assert isinstance(dep.engines["jet_tagger"], EdgeEngine)
    assert "engines" in dep.stage_results


def test_single_net_plan_matches_direct_planner():
    """The facade's single-net plan is the planner's answer (same layers,
    same estimates) — no facade-only cost drift."""
    cfg = edge.edge_config("qubit")
    via_facade = Deployment.build(cfg, machine_model=None,
                                  stop_after="plan",
                                  cache=plan_lib.PlanCache()).plan
    direct = plan_lib.plan_deployment(cfg, target="tpu")
    assert via_facade.layers == direct.layers
    assert via_facade.est_latency_s == pytest.approx(direct.est_latency_s)
    assert via_facade.fusion_groups == direct.fusion_groups


def test_resolve_configs_specs(lm_cfg):
    out = resolve_configs(["jet_tagger", lm_cfg])
    assert out[0].name == "jet_tagger" and out[1] is lm_cfg
    assert resolve_configs("vae")[0].dims == edge.edge_config("vae").dims
    smoke = resolve_configs("lm:qwen2_5_3b")[0]
    assert smoke.family == lm_cfg.family
    with pytest.raises(ValueError):
        resolve_configs(["definitely_not_a_net"])


def test_build_rejects_bad_stop_after():
    with pytest.raises(ValueError):
        Deployment.build("jet_tagger", stop_after="quantize")


def test_artifact_dir_writes_plan(tmp_path):
    dep = Deployment.build("tau_select", machine_model=None,
                           stop_after="plan", artifact_dir=tmp_path,
                           cache=plan_lib.PlanCache())
    art = dep.stage_results["plan"].artifact
    assert art == tmp_path / "tau_select_tpu.json"
    assert plan_lib.DeploymentPlan.load(art).layers == dep.plan.layers


def test_stage_context_individually_invokable():
    """The stages are usable without Deployment: a hand-built context run
    through PlanStage alone is the documented plan-only pipeline."""
    ctx = StageContext(configs=resolve_configs("jet_tagger"),
                       machine_model=None, cache=plan_lib.PlanCache())
    res = stages.PlanStage().run(ctx)
    assert res.stage == "plan" and ctx.fleet is not None
    assert not res.cached
    again = stages.PlanStage()
    ctx2 = StageContext(configs=resolve_configs("jet_tagger"),
                        machine_model=None, cache=ctx.cache)
    assert again.run(ctx2).cached                   # same cache, same question


# ---------------------------------------------------------------------------
# Unified CLI
# ---------------------------------------------------------------------------

def test_cli_plan_subcommand(tmp_path, capsys):
    from repro import cli
    rc = cli.main(["plan", "qubit", "--target", "tpu",
                   "--out", str(tmp_path)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "# qubit [tpu]" in out
    assert (tmp_path / "qubit_tpu.json").exists()


def test_cli_deploy_dry_run(tmp_path, capsys):
    from repro import cli
    rc = cli.main(["deploy", "jet_tagger", "--dry-run",
                   "--machine-model", "stock", "--out", str(tmp_path)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "dry run" in out and "jet_tagger" in out
    assert (tmp_path / "jet_tagger_tpu.json").exists()


def test_cli_legacy_shim_still_works(tmp_path, capsys):
    """python -m repro.plan keeps its exact flags + artifacts (deprecation
    shim over the unified CLI)."""
    from repro.plan import __main__ as plan_cli
    rc = plan_cli.main(["vae", "--target", "tpu", "--out", str(tmp_path)])
    assert rc == 0
    assert (tmp_path / "vae_tpu.json").exists()
    assert plan_cli.main(["nope", "--out", str(tmp_path)]) == 2
