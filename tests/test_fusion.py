"""Fused-group execution tests: the fused_mlp megakernel vs the per-layer
int8 path, plan schema v3 (fusion_groups), calibrated activation scales, and
the stale-plan self-invalidation story."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import hw as hwlib
from repro import plan as plan_lib
from repro.kernels import ops as kops
from repro.models import edge


def _qparams(cfg, *, calibrated=True, seed=0):
    params = edge.init_edge(jax.random.PRNGKey(seed), cfg)
    calib = None
    if calibrated:
        calib = jax.random.normal(jax.random.PRNGKey(seed + 100),
                                  (cfg.batch, cfg.dims[0]), jnp.float32)
    return params, edge.quantize_edge(params, calib_x=calib, act=cfg.act)


# ---------------------------------------------------------------------------
# Numerical equivalence: the megakernel IS the per-layer path, fused
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(edge.EDGE_NETS))
def test_fused_matches_per_layer_all_nets(name):
    """CI acceptance: fused output allclose to the per-layer int8 path for
    every edge net (same plan, same quantized params, same scales)."""
    cfg = edge.edge_config(name)
    _, qp = _qparams(cfg)
    plan = plan_lib.plan_deployment(cfg, target="tpu")
    assert any(len(g) > 1 for g in plan.groups()), "plan must fuse something"
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.batch, cfg.dims[0]))
    y_fused = edge.edge_forward_q8(qp, cfg, x, plan=plan)
    y_layer = edge.edge_forward_q8(qp, cfg, x, plan=plan, fused=False)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_layer),
                               rtol=1e-5, atol=1e-5)


def test_fused_mlp_kernel_vs_dequant_reference():
    """The raw kernel against explicit dequantized-math reference, on odd
    (non-tile-multiple) shapes so the padding paths are exercised."""
    key = jax.random.PRNGKey(3)
    dims = [19, 45, 7]
    m = 5
    ws, scs, bs = [], [], []
    rng = np.random.default_rng(0)
    for a, b in zip(dims[:-1], dims[1:]):
        ws.append(jnp.asarray(rng.integers(-127, 128, (a, b)), jnp.int8))
        scs.append(jnp.asarray(rng.uniform(0.01, 0.1, (b,)), jnp.float32))
        bs.append(jnp.asarray(rng.normal(size=(b,)), jnp.float32))
    xs = jnp.asarray([0.03, 0.07], jnp.float32)
    x = jax.random.normal(key, (m, dims[0]), jnp.float32)

    out = kops.fused_mlp_q8(x, ws, scs, bs, xs, act="relu")
    assert out.shape == (m, dims[-1])

    h = np.asarray(x, np.float64)
    for i, (w, sc, b) in enumerate(zip(ws, scs, bs)):
        hq = np.clip(np.round(h / float(xs[i])), -127, 127)
        y = (hq @ np.asarray(w, np.float64)) * float(xs[i]) \
            * np.asarray(sc, np.float64) + np.asarray(b, np.float64)
        h = np.maximum(y, 0.0) if i == 0 else y
    np.testing.assert_allclose(np.asarray(out, np.float64), h,
                               rtol=1e-5, atol=1e-5)


def test_fused_act_last_for_mid_net_groups():
    """A group that ends mid-network must apply the activation to its last
    layer (the next group quantizes the ACTIVATED output)."""
    cfg = edge.edge_config("vae")
    _, qp = _qparams(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (cfg.batch, cfg.dims[0]))
    # Split the net by hand: fused [0..2] then per-layer [3..] must equal
    # the all-per-layer result.
    scales = jnp.asarray([qp[i]["x_scale"] for i in range(3)], jnp.float32)
    h = kops.fused_mlp_q8(x, [qp[i]["w_q"] for i in range(3)],
                          [qp[i]["w_scale"] for i in range(3)],
                          [qp[i]["b"] for i in range(3)], scales,
                          act="relu", act_last=True, out_dtype=jnp.float32)
    last = len(qp) - 1
    for i in range(3, len(qp)):
        s = qp[i]["x_scale"]
        hq = jnp.clip(jnp.round(h / s), -127, 127).astype(jnp.int8)
        y = kops.gemm_int8(hq, qp[i]["w_q"], qp[i]["w_scale"], s,
                           out_dtype=jnp.float32)
        h = y + qp[i]["b"][None, :]
        if i != last:
            h = jnp.maximum(h, 0.0)
    full = edge.edge_forward_q8(qp, cfg, x, fused=False,
                                plan=plan_lib.plan_deployment(cfg,
                                                              target="tpu"))
    np.testing.assert_allclose(np.asarray(h), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Satellite: explicit block overrides (the falsy-zero fix)
# ---------------------------------------------------------------------------

def test_partial_block_override_beats_plan_tiles():
    """A PARTIAL explicit block override must apply (the old ``block_m or
    bm`` pattern silently kept the plan tile) and force the per-layer path;
    int32 accumulation keeps the result exact under any legal blocking."""
    cfg = edge.edge_config("jet_tagger")
    _, qp = _qparams(cfg)
    plan = plan_lib.plan_deployment(cfg, target="tpu")
    x = jax.random.normal(jax.random.PRNGKey(4), (cfg.batch, cfg.dims[0]))
    y_plan = edge.edge_forward_q8(qp, cfg, x, plan=plan, fused=False)
    y_part = edge.edge_forward_q8(qp, cfg, x, plan=plan, block_m=8)
    y_full = edge.edge_forward_q8(qp, cfg, x, block_m=8, block_k=128,
                                  block_n=128)
    np.testing.assert_allclose(np.asarray(y_part), np.asarray(y_plan),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_plan),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Satellite: calibrated activation scales
# ---------------------------------------------------------------------------

def test_calibrated_scales_beat_fixed_guess():
    """Inputs far outside the 0.05-scale representable range (|x| <= 6.35)
    saturate the hard-coded guess; calibrated per-layer scales track the
    actual activation magnitudes and stay accurate."""
    cfg = edge.edge_config("vae")
    params = edge.init_edge(jax.random.PRNGKey(0), cfg)
    x = 10.0 * jax.random.normal(jax.random.PRNGKey(5),
                                 (cfg.batch, cfg.dims[0]))
    qp_cal = edge.quantize_edge(params, calib_x=x, act=cfg.act)
    qp_fix = edge.quantize_edge(params)
    assert all("x_scale" in p for p in qp_cal)
    assert all("x_scale" not in p for p in qp_fix)
    y_ref = np.asarray(edge.edge_forward(params, cfg, x))
    plan = plan_lib.plan_deployment(cfg, target="tpu")
    err_cal = np.abs(np.asarray(
        edge.edge_forward_q8(qp_cal, cfg, x, plan=plan)) - y_ref).max()
    err_fix = np.abs(np.asarray(
        edge.edge_forward_q8(qp_fix, cfg, x, plan=plan)) - y_ref).max()
    assert err_cal < err_fix


def test_edge_engine_calibrates_and_fuses():
    from repro.serve.engine import EdgeEngine
    cfg = edge.edge_config("tau_select")
    eng = EdgeEngine(cfg)
    assert all("x_scale" in p for p in eng.qparams)
    assert any(len(g) > 1 for g in eng.plan.groups())
    x = jax.random.normal(jax.random.PRNGKey(6), (cfg.batch, cfg.dims[0]))
    y = eng.infer(x)
    assert y.shape == (cfg.batch, cfg.dims[-1])
    legacy = EdgeEngine(cfg, calibrate=False)
    assert all("x_scale" not in p for p in legacy.qparams)


# ---------------------------------------------------------------------------
# Plan schema v3: fusion_groups
# ---------------------------------------------------------------------------

def test_v3_fusion_groups_roundtrip():
    cfg = edge.edge_config("qubit")
    plan = plan_lib.plan_deployment(cfg, target="tpu")
    assert plan.schema == 3 and plan.fusion_groups
    # Groups partition the layers in order.
    flat = [i for g in plan.groups() for i in g]
    assert flat == list(range(len(plan.layers)))
    for g in plan.fusion_groups:
        assert g.est_latency_s > 0
        assert g.vmem_bytes > 0
    s = plan.to_json()
    json.loads(s)                                   # strict JSON
    again = plan_lib.DeploymentPlan.from_json(s)
    assert again == plan
    assert again.fusion_groups == plan.fusion_groups
    # The plan decomposes: groups + crossings + entry dispatch == total.
    parts = sum(g.est_latency_s for g in plan.fusion_groups) \
        + sum(b.crossing_s for b in plan.boundaries)
    assert plan.est_latency_s == pytest.approx(
        parts + hwlib.TPU_V5E.kernel_overhead_s)


# (v1/v2 artifact loading/derivation/execution compat is consolidated in
# tests/test_plan_compat.py.)


def test_aie_plans_fall_back_to_per_layer_groups():
    plan = plan_lib.plan_deployment(edge.edge_config("jet_tagger"),
                                    target="aie", pl_budget=0.0)
    assert plan.fusion_groups == ()                # aie target: no section
    assert plan.groups() == [[i] for i in range(len(plan.layers))]


def test_fusion_respects_vmem_budget():
    """A VMEM too small for the whole net forces multiple groups, each
    within the budget (the per-layer fallback in the limit)."""
    cfg = edge.edge_config("autoencoder")
    tiny = dataclasses.replace(hwlib.TPU_V5E, vmem_bytes=800_000)
    plan = plan_lib.plan_deployment(cfg, target="tpu", tpu=tiny)
    assert len(plan.fusion_groups) > 1
    for g in plan.fusion_groups:
        assert g.vmem_bytes <= int(tiny.vmem_bytes * 0.75)
    # And an expensive fused epilogue splits everything (fuse only when the
    # epilogue undercuts the crossing — DR7').
    slow = dataclasses.replace(hwlib.TPU_V5E, fused_epilogue_s=1.0)
    split = plan_lib.plan_deployment(cfg, target="tpu", tpu=slow)
    assert split.groups() == [[i] for i in range(len(split.layers))]


def test_fused_plan_estimates_beat_per_layer_sum():
    """The planner must predict a win from fusing: the fused-group estimate
    undercuts the same stages priced as per-layer launches."""
    plan = plan_lib.plan_deployment(edge.edge_config("autoencoder"),
                                    target="tpu")
    split = plan_lib.plan_deployment(
        edge.edge_config("autoencoder"), target="tpu",
        tpu=dataclasses.replace(hwlib.TPU_V5E, fused_epilogue_s=1e-3))
    assert len(plan.fusion_groups) < len(split.fusion_groups)
    assert plan.est_latency_s < split.est_latency_s


# ---------------------------------------------------------------------------
# Stale-plan self-invalidation
# ---------------------------------------------------------------------------

def test_stale_planner_version_self_invalidates(tmp_path, monkeypatch):
    """A cached plan keyed under an older PLANNER_VERSION must MISS when the
    planner (search or cost model) changes: the key is derived from the
    version, so stale artifacts self-invalidate instead of silently serving
    pre-fusion decisions."""
    from repro.plan import artifact
    cfg = edge.edge_config("jet_tagger")
    cache = plan_lib.PlanCache(tmp_path)
    p1 = plan_lib.get_or_plan(cfg, target="tpu", cache=cache)
    assert plan_lib.get_or_plan(cfg, target="tpu", cache=cache) is p1
    n_before = len(list(tmp_path.glob("*.json")))
    monkeypatch.setattr(artifact, "PLANNER_VERSION", "plan-999")
    p2 = plan_lib.get_or_plan(cfg, target="tpu", cache=cache)
    assert p2.key != p1.key                        # version keyed
    assert len(list(tmp_path.glob("*.json"))) == n_before + 1
