"""Characterization harness tests: sweeps, fits, the MachineModel artifact,
planner consumption + plan-cache invalidation, and the drift-triggered
fleet replan loop (characterize -> plan -> serve -> replan).

Sweeps run under a SYNTHETIC timer (a known linear cost function) so the
full machinery is exercised deterministically; one smoke test times the real
legacy calibration grid.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro import characterize as ch
from repro import hw as hwlib
from repro import plan as plan_lib
from repro.models import edge
from repro.serve import Router

# Ground-truth constants the synthetic timer encodes; fits must recover them.
_TRUE = {
    "overhead_s": 2e-3,
    "inv_peak_int8": 1e-10,
    "inv_peak_f32": 5e-11,
    "fused_epilogue_s": 3e-4,
    "boundary_const": 1e-5,
    "boundary_dispatch": 5e-5,
    "boundary_per_byte": 1e-9,
    "band2_slope": 0.12,
}


def _synthetic_timer(term, regs):
    if term == "gemm_int8":
        return (_TRUE["overhead_s"] * regs["launches"]
                + _TRUE["inv_peak_int8"] * regs["padded_ops"])
    if term == "gemm_f32":
        return 1e-4 * regs["launches"] + _TRUE["inv_peak_f32"] * regs["ops"]
    if term == "fused_chain":
        return (_TRUE["overhead_s"]
                + _TRUE["inv_peak_int8"] * regs["padded_ops"]
                + _TRUE["fused_epilogue_s"] * regs["inner_layers"])
    if term == "boundary":
        return (_TRUE["boundary_const"]
                + _TRUE["boundary_dispatch"] * regs["launches"]
                + _TRUE["boundary_per_byte"] * regs["launch_bytes"])
    if term == "contention":
        return 1e-6 * (1.0 + _TRUE["band2_slope"] * regs["n_band2"])
    raise AssertionError(term)


def _model(**kw):
    return ch.characterize(sweep="quick", timer=_synthetic_timer, **kw)


def _with_constant(mm, term, name, value):
    """Copy of ``mm`` with one fitted constant replaced."""
    tf = mm.fits[term]
    fits = dict(mm.fits)
    fits[term] = dataclasses.replace(
        tf, constants={**tf.constants, name: value})
    return ch.MachineModel(fits=fits, provenance=mm.provenance)


# ---------------------------------------------------------------------------
# Sweeps + fits
# ---------------------------------------------------------------------------

def test_fit_recovers_synthetic_constants():
    mm = _model()
    g = mm.fits["gemm_int8"]
    assert g.constants["kernel_overhead_s"] == pytest.approx(
        _TRUE["overhead_s"], rel=1e-6)
    assert g.constants["peak_int8_ops"] == pytest.approx(
        1.0 / _TRUE["inv_peak_int8"], rel=1e-6)
    assert g.residual_rel_rms < 1e-9
    assert mm.fits["gemm_f32"].constants["peak_flops"] == pytest.approx(
        1.0 / _TRUE["inv_peak_f32"], rel=1e-6)
    b = mm.fits["boundary"]
    assert b.constants["dispatch_s"] == pytest.approx(
        _TRUE["boundary_dispatch"], rel=1e-6)
    assert b.constants["hbm_bw"] == pytest.approx(
        2.0 / _TRUE["boundary_per_byte"], rel=1e-6)
    c = mm.fits["contention"]
    assert c.constants["band2_penalty_per_layer"] == pytest.approx(
        _TRUE["band2_slope"], rel=1e-6)
    assert c.source == "model"
    assert g.source == "measured"
    fc = mm.fits["fused_chain"]
    assert fc.constants["fused_epilogue_s"] == pytest.approx(
        _TRUE["fused_epilogue_s"], rel=1e-6)
    assert fc.source == "measured"


def test_fit_requires_enough_samples():
    samples = ch.run_term("gemm_int8", sweep="quick",
                          timer=_synthetic_timer)[:1]
    with pytest.raises(ValueError):
        ch.fit_term("gemm_int8", samples)
    with pytest.raises(ValueError):
        ch.run_term("no_such_term")
    with pytest.raises(ValueError):
        ch.run_term("gemm_int8", sweep="no_such_sweep")


def test_real_calibrate_grid_smoke():
    """The legacy 3-point grid, actually timed on this host: sane constants
    (positive overhead/peak) without asserting host-dependent values."""
    samples = ch.run_term("gemm_int8", sweep="calibrate", iters=2)
    tf = ch.fit_term("gemm_int8", samples)
    assert tf.n_samples == 3
    assert tf.constants["kernel_overhead_s"] >= 1e-6
    assert tf.constants["peak_int8_ops"] >= 1e6


# ---------------------------------------------------------------------------
# MachineModel artifact
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_and_provenance(tmp_path):
    mm = _model()
    s = mm.to_json()
    json.loads(s)                                  # strict JSON
    again = ch.MachineModel.from_json(s)
    assert again.version == mm.version
    assert again.fits == mm.fits
    p = mm.save(tmp_path / "model.json")
    loaded = ch.MachineModel.load(p)
    assert loaded.version == mm.version
    prov = loaded.provenance
    for key in ("host", "jax", "sweep", "grids", "python"):
        assert key in prov
    assert prov["timer"] == "synthetic"
    assert set(prov["grids"]) == set(ch.TERMS)
    assert len(mm.version) == 64                   # sha256 hex


def test_artifact_rejects_tampered_version(tmp_path):
    mm = _model()
    d = mm.to_dict()
    d["fits"]["gemm_int8"]["constants"]["kernel_overhead_s"] *= 2
    with pytest.raises(ValueError):                # content/version mismatch
        ch.MachineModel.from_dict(d)
    with pytest.raises(ValueError):
        ch.MachineModel.from_dict({"schema": 99, "fits": {}})


def test_version_tracks_constants_not_provenance():
    mm = _model()
    # Same constants, different provenance -> same version.
    other = ch.MachineModel(fits=mm.fits,
                            provenance={**mm.provenance, "host": "elsewhere"})
    assert other.version == mm.version
    # Same constants, different residuals/coefficients (two wall-clock runs
    # landing on identical clamped constants) -> same version, so a no-op
    # re-characterization does not invalidate every cached plan.
    tf = mm.fits["gemm_int8"]
    noisy = dict(mm.fits)
    noisy["gemm_int8"] = dataclasses.replace(
        tf, residual_rel_rms=tf.residual_rel_rms + 0.1,
        coefficients=tuple(c * 1.001 for c in tf.coefficients))
    assert ch.MachineModel(fits=noisy,
                           provenance=mm.provenance).version == mm.version
    # Any constant change -> new version.
    bumped = _with_constant(mm, "gemm_int8", "kernel_overhead_s", 1.0)
    assert bumped.version != mm.version


def test_hardware_model_substitution():
    mm = _model()
    tpu = mm.tpu()
    assert tpu.kernel_overhead_s == pytest.approx(_TRUE["overhead_s"])
    assert tpu.peak_int8_ops == pytest.approx(1.0 / _TRUE["inv_peak_int8"])
    assert tpu.peak_bf16_flops == pytest.approx(1.0 / _TRUE["inv_peak_f32"])
    assert tpu.hbm_bw == pytest.approx(2.0 / _TRUE["boundary_per_byte"])
    assert tpu.fused_epilogue_s == pytest.approx(_TRUE["fused_epilogue_s"])
    # Un-fitted constants stay at the base model's values.
    assert tpu.vmem_bytes == hwlib.TPU_V5E.vmem_bytes
    aie = mm.aie()
    assert aie.band2_penalty_per_layer == pytest.approx(_TRUE["band2_slope"])
    assert aie.cols == hwlib.AIE_ML.cols


def test_characterize_cli_roundtrip(tmp_path, capsys):
    from repro.characterize.__main__ import main
    out = tmp_path / "m.json"
    rc = main(["--sweep", "calibrate", "--terms", "contention",
               "--out", str(out)])
    assert rc == 0
    mm = ch.MachineModel.load(out)
    assert mm.fits["contention"].constants[
        "band2_penalty_per_layer"] == pytest.approx(
        hwlib.AIE_ML.band2_penalty_per_layer)
    assert "contention" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Planner consumption + plan-cache invalidation
# ---------------------------------------------------------------------------

def test_planner_consumes_machine_model():
    mm = _model()
    cfg = edge.edge_config("jet_tagger")
    stock = plan_lib.plan_deployment(cfg, target="tpu")
    fitted = plan_lib.plan_deployment(cfg, target="tpu", machine_model=mm)
    assert fitted.key != stock.key
    # The fitted overhead (2ms/launch) dwarfs the stock 2.2us: the planned
    # latency must reflect the substituted constants, not the datasheet.
    assert fitted.est_latency_s > stock.est_latency_s * 10
    # machine_model overrides an explicitly-passed tpu model too.
    explicit = plan_lib.plan_deployment(cfg, target="tpu", machine_model=mm,
                                        tpu=hwlib.TPU_V5E)
    assert explicit.key == fitted.key


def test_planner_aie_path_consumes_machine_model():
    mm = _with_constant(_model(), "contention",
                        "band2_penalty_per_layer", 5.0)
    cfg = edge.edge_config("autoencoder")
    stock = plan_lib.plan_deployment(cfg, target="aie", pl_budget=0.0)
    fitted = plan_lib.plan_deployment(cfg, target="aie", pl_budget=0.0,
                                      machine_model=mm)
    assert fitted.key != stock.key


def test_plan_cache_invalidation_on_any_constant_change():
    """Changing ANY fitted constant changes the cache key -> forced re-plan."""
    mm = _model()
    cfg = edge.edge_config("jet_tagger")
    cache = plan_lib.PlanCache()
    plan_lib.get_or_plan(cfg, target="tpu", cache=cache, machine_model=mm)
    assert len(cache) == 1
    # Same model again: cache hit, no new entry.
    plan_lib.get_or_plan(cfg, target="tpu", cache=cache, machine_model=mm)
    assert len(cache) == 1
    mutations = [("gemm_int8", "kernel_overhead_s", 1e-3),
                 ("gemm_int8", "peak_int8_ops", 123e9),
                 ("gemm_f32", "peak_flops", 77e9),
                 ("fused_chain", "fused_epilogue_s", 9e-4),
                 ("boundary", "hbm_bw", 5e8)]
    for n, (term, name, value) in enumerate(mutations, start=2):
        plan_lib.get_or_plan(cfg, target="tpu", cache=cache,
                             machine_model=_with_constant(mm, term, name,
                                                          value))
        assert len(cache) == n, f"mutating {term}.{name} must force a re-plan"


def test_fleet_planner_consumes_machine_model():
    mm = _model()
    cfgs = [edge.edge_config("jet_tagger"), edge.edge_config("tau_select")]
    cache = plan_lib.PlanCache()
    stock = plan_lib.plan_fleet(cfgs, target="tpu", cache=cache)
    fitted = plan_lib.plan_fleet(cfgs, target="tpu", cache=cache,
                                 machine_model=mm)
    assert fitted.key != stock.key
    for t in fitted.tenants:
        assert t.plan.est_latency_s > 0


# ---------------------------------------------------------------------------
# Drift-triggered fleet replanning (the closed loop)
# ---------------------------------------------------------------------------

def _drift_ratio(router, nid):
    r = router.drift(nid)
    return max(r, 1.0 / r)                         # symmetric badness


def test_drift_triggers_recalibration_and_replan():
    """A fleet planned under stock datasheet constants drifts wildly on the
    interpret-mode host; the router's watcher must recalibrate + replan and
    the planned-vs-measured ratio must improve."""
    cfg = edge.edge_config("jet_tagger")
    cache = plan_lib.PlanCache()
    fleet = plan_lib.plan_fleet([cfg], target="tpu", cache=cache)
    router = Router.from_fleet(fleet, drift_threshold=2.0,
                               drift_min_samples=3, cache=cache)
    x = jnp.ones((cfg.batch, cfg.dims[0]), jnp.float32)
    router.infer("jet_tagger", x)                  # jit warmup
    router.reset_metrics()
    before = None
    for _ in range(3):
        router.infer("jet_tagger", x)
        if before is None:
            before = _drift_ratio(router, "jet_tagger")
    assert before > 2.0                            # datasheet plan is way off
    assert router.replans >= 1
    after = _drift_ratio(router, "jet_tagger")
    assert after < before                          # ratio improved...
    assert after == pytest.approx(1.0, abs=0.5)    # ...to ~1 post-replan
    # The replanned fleet is live everywhere: tenant, engine, budget, cache.
    t = router.tenant("jet_tagger")
    assert t.plan.est_latency_s == router.fleet.tenant(
        "jet_tagger").plan.est_latency_s
    assert t.engine.plan is t.plan
    assert t.metrics.latency_budget_s == pytest.approx(
        router.fleet.tenant("jet_tagger").latency_budget_s)
    assert "calibration" in t.plan.serve
    assert cache.get(t.plan.key).est_latency_s == t.plan.est_latency_s


def test_no_replan_within_threshold():
    """A fleet whose plan already matches measurement must not churn."""
    cfg = edge.edge_config("jet_tagger")
    cache = plan_lib.PlanCache()
    fleet = plan_lib.plan_fleet([cfg], target="tpu", cache=cache,
                                tpu=plan_lib.calibrated_cpu_model())
    router = Router.from_fleet(fleet, drift_threshold=50.0,
                               drift_min_samples=3, cache=cache)
    x = jnp.ones((cfg.batch, cfg.dims[0]), jnp.float32)
    router.infer("jet_tagger", x)
    router.reset_metrics()
    for _ in range(4):
        router.infer("jet_tagger", x)
    assert router.replans == 0


def test_lm_tenant_drift_uses_decode_step_not_request_latency():
    """LM request latency includes queue wait, which is not the quantity the
    plan estimates, so it must never feed recalibration.  With the span
    decomposition, LM tenants join the drift loop through the batcher's
    measured DECODE-STEP p50 instead: the same quantity-vs-quantity
    comparison the edge path has (an LM plan's graph models one decode
    step)."""
    import numpy as np
    from repro import configs
    from repro.models import api
    from repro.serve import engine
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    cache = plan_lib.PlanCache()
    fleet = plan_lib.plan_fleet([cfg], target="tpu", cache=cache,
                                serve_slots_total=2)
    nid = fleet.net_ids[0]
    router = Router.from_fleet(fleet, lm={nid: (cfg, params)},
                               drift_threshold=1.5, drift_min_samples=1,
                               cache=cache)
    for i in range(3):
        router.submit(nid, engine.Request(
            rid=i, prompt=np.array([3 + i], np.int32), max_new=2))
    router.run_until_drained(max_ticks=200)
    t = router.tenant(nid)
    assert t.metrics.count == 3
    # The drift ratio is decode-step-based: queue-polluted request p50 (the
    # metrics window) never enters it.
    decode_p50 = t.engine.measured_decode_p50_s
    assert decode_p50 > 0
    assert decode_p50 < t.metrics.p50_s           # request latency >> step
    planned = router.fleet.tenant(nid).plan.est_latency_s
    assert router.drift(nid) == pytest.approx(decode_p50 / planned)
    # The interpret-mode step is wildly off the datasheet plan, so the
    # watcher tripped and replanned DURING serving — from the decode step.
    assert router.replans >= 1
    recal = router.tenant(nid).plan.est_latency_s
    assert recal == pytest.approx(decode_p50, rel=0.5)
    assert recal < t.metrics.p50_s / 10           # not the queue-wait number
    assert "calibration" in router.tenant(nid).plan.serve


def test_router_rejects_bad_drift_threshold():
    fleet = plan_lib.plan_fleet([edge.edge_config("jet_tagger")],
                                target="tpu", cache=plan_lib.PlanCache())
    with pytest.raises(ValueError):
        Router.from_fleet(fleet, drift_threshold=0.5)


def test_recalibrate_fleet_preserves_unmeasured_tenants():
    cfgs = [edge.edge_config("jet_tagger"), edge.edge_config("tau_select")]
    cache = plan_lib.PlanCache()
    fleet = plan_lib.plan_fleet(cfgs, target="tpu", cache=cache)
    t0 = fleet.tenants[0]
    measured = t0.plan.est_latency_s * 4.0
    again = plan_lib.recalibrate_fleet(fleet, {"jet_tagger": measured},
                                       cache=cache)
    assert again.tenants[0].plan.est_latency_s == pytest.approx(measured)
    # Budget re-derived with the fleet's original headroom factor (2x).
    assert again.tenants[0].latency_budget_s == pytest.approx(
        2.0 * (measured + again.tenants[0].crossing_s))
    # Unmeasured tenant untouched.
    assert again.tenants[1] == fleet.tenants[1]
    assert again.est_latency_s >= again.tenants[0].total_latency_s - 1e-18
    # The calibrated plan landed in the cache under its original key.
    assert cache.get(t0.plan.key).est_latency_s == pytest.approx(measured)
