"""Optional-``hypothesis`` shim.

Property tests import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly; when the package is absent the decorators degrade to
a clean per-test skip so the rest of the suite still collects and runs
(tier-1 must not fail on an optional dev dependency).
"""

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # A fresh zero-arg function (not functools.wraps) so pytest does
            # not try to resolve the property parameters as fixtures.
            def stub():
                pytest.skip("hypothesis not installed")
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Accepts any strategy constructor call; values are never drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
