"""Traffic replay + SLO observability tests: trace format + scenario
generators (determinism, spike density, JSONL round-trip), the open-loop
replay driver (fake-router unit level + a real edge fleet), the SLO
monitor (edge-triggered violations, burn rates, re-arm), priority-aware
deferral in the router, serve-metrics percentile edges, and the new
Prometheus families."""

import json
import math

import pytest

from repro.obs import (SloBudget, SloMonitor, Tracer, parse_prometheus,
                       priority_rank, prometheus_text, workload)
from repro.obs.workload import TraceRequest
from repro.serve import TenantMetrics

TENANTS = {"jet_tagger": "edge", "tau_select": "edge", "lm0": "lm"}


# ---------------------------------------------------------------------------
# Scenario generators + trace format
# ---------------------------------------------------------------------------

def test_scenarios_deterministic_and_nonempty():
    for name in workload.SCENARIOS:
        kw = dict(duration_s=0.1, lm_rate_hz=120.0, seed=7)
        a = workload.make_scenario(name, TENANTS, **kw)
        b = workload.make_scenario(name, TENANTS, **kw)
        assert a == b, name                     # same seed, same trace
        assert a, name
        c = workload.make_scenario(name, TENANTS, **{**kw, "seed": 8})
        assert a != c, name                     # seed actually matters
        # rids are sequential in arrival order (the merge-sort contract).
        assert [r.rid for r in a] == list(range(len(a)))
        arrivals = [r.arrival_s for r in a]
        assert arrivals == sorted(arrivals)
        assert all(0 <= t < 0.1 for t in arrivals)
        # Every tenant with a positive rate offers something at these knobs.
        assert {r.tenant for r in a} == set(TENANTS)


def test_flash_crowd_spike_density():
    """The spike window must be much denser than the baseline around it."""
    reqs = workload.flash_crowd({"n": "edge"}, duration_s=1.0, rate_hz=300.0,
                                seed=3, spike_factor=8.0, spike_start=0.4,
                                spike_frac=0.2)
    in_spike = sum(1 for r in reqs if 0.4 <= r.arrival_s < 0.6)
    before = sum(1 for r in reqs if 0.0 <= r.arrival_s < 0.2)
    assert in_spike > 3 * max(1, before)


def test_trace_jsonl_roundtrip(tmp_path):
    reqs = workload.make_scenario("bursty", TENANTS, duration_s=0.05, seed=1)
    p = workload.save_trace(reqs, tmp_path / "trace.jsonl")
    # Strict JSON, one object per line.
    for line in p.read_text().splitlines():
        json.loads(line, parse_constant=lambda c: 1 / 0)
    assert workload.load_trace(p) == reqs


def test_trace_request_validation():
    with pytest.raises(ValueError, match="kind"):
        TraceRequest(arrival_s=0.0, tenant="x", kind="gpu")
    with pytest.raises(ValueError, match="arrival_s"):
        TraceRequest(arrival_s=-1.0, tenant="x")
    with pytest.raises(ValueError, match="unknown scenario"):
        workload.make_scenario("tsunami", TENANTS)
    with pytest.raises(ValueError, match="duration_s"):
        workload.steady(TENANTS, duration_s=0.0)


def test_smoke_trace_shape():
    reqs = workload.smoke_trace(TENANTS, edge_iters=4, lm_requests=2)
    by_tenant = {}
    for r in reqs:
        by_tenant.setdefault(r.tenant, []).append(r)
    assert len(by_tenant["jet_tagger"]) == 4
    assert len(by_tenant["lm0"]) == 2
    assert all(r.kind == "lm" for r in by_tenant["lm0"])
    assert [r.rid for r in reqs] == list(range(len(reqs)))


# ---------------------------------------------------------------------------
# Open-loop replay driver (fake router: no jax, no engines)
# ---------------------------------------------------------------------------

class _FakeRouter:
    """Edge-only router stub: records calls, optionally refuses."""

    def __init__(self, refuse=None):
        self.calls = []
        self.refuse = refuse or {}

    def default_inputs(self):
        return {t: None for t in TENANTS}

    def infer(self, nid, x):
        self.calls.append(nid)
        exc = self.refuse.get(nid)
        if exc is not None:
            raise exc
        return x

    def step(self, wait_s=0.0):
        return 0

    def run_until_drained(self, max_ticks=0):
        return 0


def test_replay_fake_router_records_and_lag():
    reqs = [TraceRequest(arrival_s=i * 1e-3, tenant="jet_tagger", rid=i)
            for i in range(5)]
    router = _FakeRouter()
    report = workload.replay(router, reqs)
    assert len(report.records) == 5
    assert router.calls == ["jet_tagger"] * 5
    for r in report.records:
        assert r.status == "ok"
        assert r.e2e_s is not None and r.e2e_s >= 0
        assert r.lag_s >= 0                 # fired at-or-after schedule
    s = report.summary()["jet_tagger"]
    assert s["ok"] == 5 and s["shed"] == 0
    assert math.isfinite(s["p99_s"]) and math.isfinite(s["lag_p95_s"])


def test_replay_records_refusals_as_data():
    """Open loop: back-pressure must be recorded, never raised."""
    from repro.serve.router import TenantOverBudget, TenantQueueFull
    reqs = [TraceRequest(arrival_s=0.0, tenant="jet_tagger", rid=0),
            TraceRequest(arrival_s=0.0, tenant="tau_select", rid=1)]
    router = _FakeRouter(refuse={
        "jet_tagger": TenantOverBudget("jet_tagger shed"),
        "tau_select": TenantQueueFull("tau_select full")})
    report = workload.replay(router, reqs)
    by = {r.tenant: r for r in report.records}
    assert by["jet_tagger"].status == "shed"
    assert by["tau_select"].status == "queue_full"
    assert by["jet_tagger"].e2e_s is None
    s = report.summary()
    assert s["jet_tagger"]["shed"] == 1
    assert s["tau_select"]["queue_full"] == 1
    assert s["jet_tagger"]["p95_s"] == 0.0  # empty ok-window reads 0, not NaN


def test_replay_speed_validation():
    with pytest.raises(ValueError, match="speed"):
        workload.replay(_FakeRouter(), [], speed=0.0)


def test_replay_real_edge_fleet():
    """The driver against a live router: every smoke request serves ok and
    the router's own metrics agree with the replay record count."""
    from repro import plan as plan_lib
    from repro.models import edge
    from repro.serve import Router
    fleet = plan_lib.plan_fleet([edge.edge_config("jet_tagger")],
                                target="tpu")
    router = Router.from_fleet(fleet)
    inputs = router.warmup()
    trace = workload.smoke_trace({"jet_tagger": "edge"}, edge_iters=6)
    report = workload.replay(router, trace, inputs=inputs)
    assert [r.status for r in report.records] == ["ok"] * 6
    assert router.report()["jet_tagger"]["count"] == 6


def test_write_replay_snapshots_rows(tmp_path):
    reqs = [TraceRequest(arrival_s=i * 1e-3, tenant="jet_tagger", rid=i)
            for i in range(4)]
    report = workload.replay(_FakeRouter(), reqs)
    report.scenario = "steady"
    slo = SloMonitor([SloBudget("jet_tagger", p95_s=0.5, p99_s=0.75)])
    paths = workload.write_replay_snapshots(report, tmp_path, slo=slo)
    assert [p.name for p in paths] == \
        ["BENCH_serve_jet_tagger__steady.json"]
    doc = json.loads(paths[0].read_text(), parse_constant=lambda c: 1 / 0)
    rows = {r["name"]: r for r in doc["rows"]}
    assert rows["serve/jet_tagger/steady/offered"]["us_per_call"] == 4.0
    assert "src=model" in rows["serve/jet_tagger/steady/offered"]["derived"]
    assert rows["serve/jet_tagger/steady/slo_p95_budget"]["us_per_call"] \
        == pytest.approx(0.5e6)
    for pct in ("p50", "p95", "p99", "max"):
        r = rows[f"serve/jet_tagger/steady/{pct}"]
        assert "src=measured" in r["derived"]
        assert math.isfinite(r["us_per_call"])
    assert "serve/jet_tagger/steady/lag/p95" in rows
    # The human report renders without a monitor and with one.
    assert "jet_tagger" in workload.format_replay(report)
    assert "slo:" in workload.format_replay(report, slo=slo)


# ---------------------------------------------------------------------------
# SLO monitor
# ---------------------------------------------------------------------------

def _mon(**kw):
    kw.setdefault("window", 32)
    kw.setdefault("min_samples", 5)
    kw.setdefault("fast_window", 8)
    kw.setdefault("slow_window", 16)
    return SloMonitor([SloBudget("a", p95_s=1e-3, p99_s=2e-3,
                                 priority="critical"),
                       SloBudget("b", p95_s=1.0, p99_s=2.0,
                                 priority="batch")], **kw)


def test_slo_violation_edge_triggered_and_rearm():
    m = _mon()
    for _ in range(10):
        m.observe("a", 5e-3)                  # 5x over the p95 budget
    counts = m.violation_counts()
    assert counts["a"] >= 1 and counts["b"] == 0
    n = len(m.violations)
    for _ in range(5):
        m.observe("a", 5e-3)                  # still violating: no new event
    assert len(m.violations) == n
    for _ in range(64):
        m.observe("a", 1e-5)                  # back under budget: re-arm
    assert not m.snapshot()["a"]["in_violation"]
    for _ in range(64):
        m.observe("a", 5e-3)                  # second violation episode
    assert len(m.violations) > n


def test_slo_burn_rate_and_pressure():
    m = _mon()
    for _ in range(20):
        m.observe("a", 5e-3)
    assert m.burn_rate("a", "fast") == pytest.approx(1 / 0.05)
    assert m.at_risk("a")
    assert not m.at_risk("b")
    assert m.pressure_rank() == priority_rank("critical") == 0
    m.reset()                                 # budgets survive a reset
    assert m.pressure_rank() is None
    assert m.budgets["a"].p95_s == 1e-3


def test_slo_observe_ignores_unknown_and_nonfinite():
    m = _mon()
    m.observe("nobody", 1.0)
    m.observe("a", float("nan"))
    m.observe("a", float("inf"))
    assert m.snapshot()["a"]["count"] == 0


def test_slo_set_budget_and_validation():
    m = _mon()
    m.set_budget("b", p95_s=1e-9, p99_s=1e-9)
    for _ in range(10):
        m.observe("b", 1e-3)
    assert m.violation_counts()["b"] >= 1
    with pytest.raises(ValueError, match="> 0"):
        SloBudget("x", p95_s=0.0)
    with pytest.raises(ValueError, match="priority"):
        SloBudget("x", priority="mega")
    with pytest.raises(ValueError, match="duplicate"):
        SloMonitor([SloBudget("x"), SloBudget("x")])


def test_slo_budget_from_plan_fallback():
    """Plans without a serve['slo'] section fall back to the mean-style
    latency budget (p99 = 1.5x), so old cached artifacts keep a contract."""
    class _Plan:
        serve = {}
        kind = "edge"
    b = SloBudget.from_plan("t", _Plan(), latency_budget_s=2e-3)
    assert b.p95_s == pytest.approx(2e-3)
    assert b.p99_s == pytest.approx(3e-3)
    assert b.priority == "critical"

    class _LmPlan:
        serve = {"slo": {"p95_s": 0.5, "p99_s": 0.9},
                 "priority": "standard"}
        kind = "lm"
    b = SloBudget.from_plan("t", _LmPlan())
    assert (b.p95_s, b.p99_s, b.priority) == (0.5, 0.9, "standard")


def test_fleet_plans_carry_slo_section():
    """The fleet planner writes serve['slo'] + serve['priority'] so the
    monitor needs no side channel."""
    from repro import configs
    from repro import plan as plan_lib
    from repro.models import edge
    fleet = plan_lib.plan_fleet(
        [edge.edge_config("jet_tagger"), configs.get("qwen2_5_3b").smoke],
        target="tpu")
    edge_t, lm_t = fleet.tenants
    assert edge_t.plan.serve["priority"] == "critical"
    assert lm_t.plan.serve["priority"] == "standard"
    for t in fleet.tenants:
        slo = t.plan.serve["slo"]
        assert 0 < slo["p95_s"] < slo["p99_s"]
        assert slo["p95_s"] == pytest.approx(t.latency_budget_s)
    mon = SloMonitor.from_fleet(fleet)
    assert mon.budgets[edge_t.net_id].priority == "critical"
    assert mon.budgets[lm_t.net_id].rank == 1


def test_slo_violation_audit_span():
    tracer = Tracer(enabled=True)
    m = SloMonitor([SloBudget("a", p95_s=1e-6, p99_s=2e-6)],
                   min_samples=3, tracer=tracer)
    for _ in range(5):
        m.observe("a", 1e-3)
    spans = [s for s in tracer.spans if s.name == "slo/violation"]
    assert spans and spans[0].attrs["tenant"] == "a"
    assert spans[0].dur_s == 0.0              # an event, not an interval


# ---------------------------------------------------------------------------
# Priority-aware deferral in the router
# ---------------------------------------------------------------------------

def _lm_router(tracer=None, slo=None, defer_limit=4):
    import jax

    from repro import configs
    from repro import plan as plan_lib
    from repro.models import api
    from repro.serve import Router
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    fleet = plan_lib.plan_fleet([cfg], target="tpu", serve_slots_total=2,
                                prefill_chunk=2)
    nid = fleet.net_ids[0]
    router = Router.from_fleet(fleet, lm={nid: (cfg, params)},
                               tracer=tracer, slo=slo,
                               defer_limit=defer_limit)
    return router, nid


def test_router_defers_lower_priority_under_pressure_but_never_starves():
    """With a critical tenant at risk, a standard LM tenant's admissions
    are deferred (sched/defer audit spans) — but aging admits it within
    defer_limit ticks, so the queue still drains."""
    from repro.serve import engine
    tracer = Tracer(enabled=True)
    slo = SloMonitor([SloBudget("edge0", p95_s=1e-6, p99_s=2e-6,
                                priority="critical")],
                     min_samples=5, fast_window=8, slow_window=16,
                     tracer=tracer)
    router, nid = _lm_router(tracer=tracer, slo=slo, defer_limit=3)
    slo.budgets[nid] = SloBudget(nid, p95_s=1.0, p99_s=2.0,
                                 priority="standard")
    for _ in range(20):                       # critical tenant burning
        slo.observe("edge0", 1e-3)
    assert slo.pressure_rank() == 0
    req = engine.Request(rid=0, prompt=__import__("numpy").array(
        [3, 5, 7], "int32"), max_new=3)
    router.submit(nid, req)
    router.run_until_drained(max_ticks=300)
    assert req.done                           # aging beat starvation
    defers = [s for s in tracer.spans if s.name == "sched/defer"]
    assert defers, "no sched/defer audit span under pressure"
    assert defers[0].attrs["tenant"] == nid
    assert defers[0].attrs["pressure_rank"] == 0


def test_router_no_deferral_without_pressure():
    from repro.serve import engine
    tracer = Tracer(enabled=True)
    router, nid = _lm_router(tracer=tracer)
    req = engine.Request(rid=0, prompt=__import__("numpy").array(
        [3, 5, 7], "int32"), max_new=3)
    router.submit(nid, req)
    router.run_until_drained(max_ticks=300)
    assert req.done
    assert not [s for s in tracer.spans if s.name == "sched/defer"]


def test_router_slo_fed_by_edge_traffic():
    """router.infer feeds the monitor; report() carries the slo block."""
    import jax

    from repro import plan as plan_lib
    from repro.models import edge
    from repro.serve import Router
    fleet = plan_lib.plan_fleet([edge.edge_config("jet_tagger")],
                                target="tpu")
    slo = SloMonitor.from_fleet(fleet, min_samples=3)
    router = Router.from_fleet(fleet, slo=slo)
    cfg = edge.edge_config("jet_tagger")
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.batch, cfg.dims[0]))
    for _ in range(5):
        router.infer("jet_tagger", x)
    snap = slo.snapshot()["jet_tagger"]
    assert snap["count"] == 5
    rep = router.report()["jet_tagger"]
    assert rep["priority"] == "critical"
    assert rep["slo"]["count"] == 5
    router.reset_metrics()                    # clears observations too
    assert slo.snapshot()["jet_tagger"]["count"] == 0


def test_router_rejects_bad_defer_limit():
    from repro import plan as plan_lib
    from repro.models import edge
    from repro.serve import Router
    fleet = plan_lib.plan_fleet([edge.edge_config("jet_tagger")],
                                target="tpu")
    with pytest.raises(ValueError, match="defer_limit"):
        Router.from_fleet(fleet, defer_limit=0)


# ---------------------------------------------------------------------------
# Serve-metrics percentile edges + Prometheus families (satellites)
# ---------------------------------------------------------------------------

def test_tenant_metrics_percentile_edges():
    m = TenantMetrics("x", latency_budget_s=1.0)
    m.observe_latency(3e-3)                   # n=1: all quantiles collapse
    assert m.p50_s == m.p95_s == m.p99_s == pytest.approx(3e-3)
    for _ in range(9):
        m.observe_latency(3e-3)               # all-equal window
    assert m.p95_s == m.p99_s == pytest.approx(3e-3)
    snap = m.snapshot()
    assert snap["p99_s"] == pytest.approx(3e-3)


def test_tenant_metrics_window_rollover():
    m = TenantMetrics("x", latency_budget_s=1.0, window=8)
    for _ in range(8):
        m.observe_latency(1.0)
    for _ in range(8):                        # rolls the slow epoch out
        m.observe_latency(1e-3)
    assert m.p99_s == pytest.approx(1e-3)
    assert m.p50_s == pytest.approx(1e-3)


def test_prometheus_tracer_dropped_and_slo_roundtrip():
    from repro.obs import aggregate
    tracer = Tracer(enabled=True, maxlen=4)
    for i in range(9):                        # saturate the ring buffer
        tracer.add(f"k{i % 2}", 0.0, 1e-3, tenant="t")
    assert tracer.dropped == 5
    m = _mon()
    for _ in range(10):
        m.observe("a", 5e-3)
    text = prometheus_text(aggregate(tracer.spans), dropped=tracer.dropped,
                           slo=m.snapshot())
    samples = parse_prometheus(text)
    by_name = {}
    for s in samples:
        by_name.setdefault(s["name"], []).append(s)
    assert by_name["repro_tracer_dropped_total"][0]["value"] == 5.0
    assert {s["labels"]["tenant"] for s in
            by_name["repro_slo_budget_seconds"]} == {"a", "b"}
    assert any(s["labels"] == {"tenant": "a", "window": "fast"}
               for s in by_name["repro_slo_burn_rate"])
    viol = {s["labels"]["tenant"]: s["value"]
            for s in by_name["repro_slo_violations_total"]}
    assert viol["a"] >= 1.0 and viol["b"] == 0.0
    lat = [s for s in by_name["repro_slo_latency_seconds"]
           if s["labels"]["tenant"] == "a"]
    assert {s["labels"]["quantile"] for s in lat} == {"0.95", "0.99"}
