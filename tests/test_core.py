"""Core-library tests: tiling planner, LARE, boundary cost — including
hypothesis property tests on the planner/metric invariants."""

import math

import pytest

from _hyp import given, settings, st

from repro import hw as hwlib
from repro.core import boundary, lare, tiling


# ---------------------------------------------------------------------------
# Two-level tiling planner
# ---------------------------------------------------------------------------

def test_plan_api_legal_blocks():
    p = tiling.plan_api(8, 4608, 36864, itemsize=2)
    assert p.block_k % 128 == 0 and p.block_n % 128 == 0
    assert p.block_m % hwlib.TPU_V5E.sublanes_for(2) == 0
    assert p.vmem_bytes <= hwlib.TPU_V5E.vmem_bytes


@given(st.integers(1, 64), st.sampled_from([128, 192, 256, 1024, 4608]),
       st.sampled_from([128, 256, 2048, 11008]))
@settings(max_examples=30, deadline=None)
def test_plan_api_covers_workload(m, k, n):
    """Property: block x repeat covers the (padded) workload exactly."""
    p = tiling.plan_api(m, k, n, itemsize=2)
    assert p.block_m * p.r_m >= m
    assert p.block_k * p.r_k >= k
    assert p.block_n * p.r_n >= n
    assert p.vmem_bytes <= hwlib.TPU_V5E.vmem_bytes


@given(st.sampled_from([1, 2, 4]), st.sampled_from([2048, 4096, 8192]),
       st.sampled_from([2048, 8192, 32768]))
@settings(max_examples=20, deadline=None)
def test_plan_spatial_respects_floor(m_exp, k, n):
    m = 8 * m_exp
    sp = tiling.plan_spatial(m, k, n, axis_sizes=(16,))
    if sp.tiles > 1:
        assert sp.q_k >= 512 and sp.q_n >= 512       # DR5'
    assert sp.p_k * sp.q_k >= k and sp.p_n * sp.q_n >= n


def test_plan_gemm_rules_annotated():
    p = tiling.plan_gemm(8, 8192, 8192, axis_sizes=(16,))
    assert any("DR1'" in r for r in p.rules)
    assert p.est_s > 0


def test_aie_api_ordering_matches_paper():
    """Paper Fig. 4: (4,8,8) and (4,16,8) outperform the other legal tiles."""
    t = {s: tiling.aie_tile_latency(8, 128, 128, s)
         for s in hwlib.AIE_ML.legal_api_tiles_i8}
    best2 = sorted(t, key=t.get)[:2]
    assert set(best2) == {(4, 8, 8), (4, 16, 8)}


def test_aie_asymmetry_favors_n():
    """Paper Fig. 4 / DR2: Q_N-larger beats Q_K-larger at equal MACs."""
    fast = tiling.aie_tile_latency(8, 64, 256)
    slow = tiling.aie_tile_latency(8, 256, 64)
    assert fast < slow


def test_aie_spatial_k_expansion_beats_n():
    """Paper Fig. 5 / DR3: for fixed P, more columns (K) is faster."""
    t_k = tiling.aie_spatial_latency(8, 128, 128, p_k=4, p_n=1)
    t_n = tiling.aie_spatial_latency(8, 128, 128, p_k=1, p_n=4)
    assert t_k < t_n


def test_aie_band_spill_penalty():
    """Paper Fig. 6 / DR6: spilling layers into a second band costs latency."""
    base = tiling.aie_spatial_latency(8, 192, 192, 3, 4)
    spilled = tiling.aie_spatial_latency(8, 192, 192, 4, 3, layers_in_band_2=1)
    assert spilled > base * 1.0


# ---------------------------------------------------------------------------
# LARE
# ---------------------------------------------------------------------------

@given(st.sampled_from([16, 32, 64, 128, 192, 256]),
       st.sampled_from([16, 32, 64, 128, 192, 256]))
@settings(max_examples=25, deadline=None)
def test_lare_invariants(n_in, n_out):
    r = lare.lare(n_in, n_out)
    assert r.lare >= 0
    assert r.rf_eq >= 1
    # decision boundary is monotone in the budget
    assert r.decide(r.lare * 2) == "pl"
    assert r.decide(r.lare * 0.4) == "aie"
    # PL curve: interval nondecreasing in rf, resource nonincreasing
    ivals = [p.interval_s for p in r.pl_curve]
    res = [p.resource for p in r.pl_curve]
    assert all(a <= b + 1e-12 for a, b in zip(ivals, ivals[1:]))
    assert all(a >= b - 1e-9 for a, b in zip(res, res[1:]))


def test_lare_grows_with_layer_size():
    """Bigger layers need more PL resource to match the AIE point."""
    small = lare.lare(32, 32)
    big = lare.lare(192, 192)
    assert big.lare > small.lare


def test_lare_tpu_core_equivalence():
    r = lare.lare_tpu(4096, 14336)
    assert r.core_eq >= 1
    # pipeline curve latency decreases with cores
    lat = [t for _, t in r.pipeline_curve]
    assert lat[0] > lat[-1]


# ---------------------------------------------------------------------------
# Boundary cost / fusion planner
# ---------------------------------------------------------------------------

def test_fusion_groups_small_chain():
    st_ = [boundary.Stage("gemm", 1e-5, 8 * 4096 * 2, 4 << 20),
           boundary.Stage("bias", 1e-7, 8 * 4096 * 2, 1 << 16),
           boundary.Stage("gelu", 2e-7, 8 * 4096 * 2, 1 << 16)]
    groups = boundary.plan_fusion(st_)
    assert groups == [0, 0, 0]      # everything fuses under VMEM budget


def test_fusion_splits_on_vmem():
    big = boundary.Stage("a", 1e-5, 1 << 20, 90 << 20)
    big2 = boundary.Stage("b", 1e-5, 1 << 20, 90 << 20)
    groups = boundary.plan_fusion([big, big2])
    assert groups == [0, 1]         # cannot co-reside in VMEM


def test_chain_latency_monotone_in_crossings():
    st_ = [boundary.Stage(f"s{i}", 1e-6, 1 << 20, 1 << 16) for i in range(6)]
    fused = boundary.chain_latency(st_, [0] * 6)
    split = boundary.chain_latency(st_, list(range(6)))
    assert split > fused


def test_hybrid_split_dp():
    stages = [
        boundary.Stage("gemm1", 0, 0, domain_s={"aie": 1e-6, "pl": 3e-6}),
        boundary.Stage("bitrev", 0, 0, domain_s={"aie": 5e-6, "pl": 1e-6}),
        boundary.Stage("gemm2", 0, 0, domain_s={"aie": 1e-6, "pl": 3e-6}),
    ]
    # Cheap crossings: split wins.
    assign, cost = boundary.plan_hybrid_split(stages, ["aie", "pl"],
                                              crossing_s=1e-8)
    assert assign == ["aie", "pl", "aie"]
    # Expensive crossings (DR7): stay in one domain.
    assign2, _ = boundary.plan_hybrid_split(stages, ["aie", "pl"],
                                            crossing_s=1e-4)
    assert len(set(assign2)) == 1


def test_crossing_cost_aie_calibration():
    """DR7: ~3.9% of a baseline latency per crossing."""
    base = 10e-6
    c = boundary.crossing_cost_aie(8 * 192, base)
    assert abs(c - 0.039 * base) / (0.039 * base) < 0.2
