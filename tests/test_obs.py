"""Observability tests: span/trace API, exporters, plan attribution,
span-decomposed serving reconciliation, and the tracing-off overhead guard.
"""

import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro import plan as plan_lib
from repro.models import api, edge
from repro.obs import (NULL_TRACER, Tracer, aggregate, attribution,
                       format_attribution, parse_prometheus, percentile,
                       prometheus_text, reconcile, summarize, to_chrome,
                       write_chrome, write_prometheus)
from repro.serve import (Router, TenantMetrics, TenantQueueFull, engine,
                         write_serve_snapshots)
from repro.serve.metrics import _safe_net_name


# ---------------------------------------------------------------------------
# Tracer primitives (no jax)
# ---------------------------------------------------------------------------

def test_span_ctx_records_interval():
    tr = Tracer()
    with tr.span("work", trace=7, tenant="a"):
        time.sleep(0.002)
    (s,) = tr.spans
    assert s.name == "work" and s.trace_id == 7
    assert s.attrs["tenant"] == "a"
    assert s.dur_s >= 0.002
    assert s.t1_s == pytest.approx(s.t0_s + s.dur_s)


def test_disabled_tracer_returns_shared_noop_ctx():
    tr = Tracer(enabled=False)
    a = tr.span("x")
    b = tr.span("y", trace=1, tenant="t")
    assert a is b                        # no per-call allocation when off
    with a:
        pass
    tr.add("x", 0.0, 1.0)
    assert len(tr) == 0


def test_tracer_maxlen_drops_and_counts():
    tr = Tracer(maxlen=3)
    for i in range(5):
        tr.add("s", float(i), float(i) + 0.5)
    assert len(tr) == 3 and tr.dropped == 2
    payload = to_chrome(tr.spans, dropped=tr.dropped)
    assert payload["otherData"]["dropped"] == 2
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_null_tracer_cannot_be_enabled():
    NULL_TRACER.enabled = True           # write is silently refused
    assert NULL_TRACER.enabled is False
    assert not NULL_TRACER
    NULL_TRACER.add("x", 0.0, 1.0)
    assert len(NULL_TRACER) == 0


def test_add_clamps_negative_duration():
    tr = Tracer()
    tr.add("backwards", 2.0, 1.0)
    assert tr.spans[0].dur_s == 0.0


def test_percentile_and_summarize_conventions():
    assert percentile([], 0.95) == 0.0
    assert percentile([3.0], 0.95) == 3.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.0) == 1.0
    agg = summarize([])
    assert agg["count"] == 0 and agg["p50_s"] == 0.0 and agg["p95_s"] == 0.0
    assert not any(math.isnan(v) for v in agg.values())
    # Same nearest-rank convention as TenantMetrics.
    m = TenantMetrics("x")
    for v in (1.0, 2.0, 3.0, 4.0):
        m.observe_latency(v)
    assert m.p95_s == percentile([1.0, 2.0, 3.0, 4.0], 0.95)


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

def _sample_tracer() -> Tracer:
    tr = Tracer()
    tr.add("queue", 0.0, 0.001, trace=1, tenant="lm0")
    tr.add("decode_step", 0.001, 0.003, trace=1, tenant="lm0", tokens=1)
    tr.add("infer", 0.0, 0.0005, trace=1, tenant="edge0")
    return tr


def test_chrome_payload_shape_and_strict_json(tmp_path):
    tr = _sample_tracer()
    payload = to_chrome(tr.spans)
    names = {e["name"] for e in payload["traceEvents"]}
    assert {"queue", "decode_step", "infer", "thread_name"} <= names
    meta = [e for e in payload["traceEvents"] if e["ph"] == "M"]
    assert {m["args"]["name"] for m in meta} == {"tenant:lm0", "tenant:edge0"}
    x = [e for e in payload["traceEvents"]
         if e["ph"] == "X" and e["name"] == "decode_step"][0]
    assert x["ts"] == pytest.approx(1000.0)        # microseconds
    assert x["dur"] == pytest.approx(2000.0)
    assert x["args"]["trace_id"] == 1
    # Spans from one tenant share a row; different tenants do not.
    tids = {e["cat"]: e["tid"] for e in payload["traceEvents"]
            if e["ph"] == "X"}
    assert tids["lm0"] != tids["edge0"]
    p = write_chrome(tr.spans, tmp_path / "trace.json")
    json.loads(p.read_text(), parse_constant=lambda _: 1 / 0)  # strict


def test_prometheus_roundtrip():
    tr = _sample_tracer()
    text = prometheus_text(aggregate(tr.spans))
    samples = parse_prometheus(text)
    by_name = {}
    for s in samples:
        by_name.setdefault(s["name"], []).append(s)
    assert "repro_span_seconds" in by_name
    counts = {(s["labels"]["tenant"], s["labels"]["kind"]): s["value"]
              for s in by_name["repro_span_seconds_count"]}
    assert counts[("lm0", "queue")] == 1
    assert counts[("edge0", "infer")] == 1
    q = [s for s in by_name["repro_span_seconds"]
         if s["labels"] == {"tenant": "lm0", "kind": "decode_step",
                            "quantile": "0.5"}]
    assert q and q[0]["value"] == pytest.approx(0.002)


def test_prometheus_parser_is_strict(tmp_path):
    with pytest.raises(ValueError, match="malformed"):
        parse_prometheus('metric{unterminated 1.0\n')
    with pytest.raises(ValueError, match="non-numeric"):
        parse_prometheus('metric{a="b"} not_a_float\n')
    with pytest.raises(ValueError, match="non-finite"):
        parse_prometheus('metric{a="b"} nan\n')
    with pytest.raises(ValueError, match="no samples"):
        parse_prometheus("# HELP only comments\n")
    # The writer never trips its own parser, non-finite aggregates included.
    stats = {("t", "k"): {"count": 1, "total_s": float("inf"),
                          "p50_s": float("nan"), "p95_s": 0.5}}
    p = write_prometheus(stats, tmp_path / "m.prom")
    samples = parse_prometheus(p.read_text())
    assert all(math.isfinite(s["value"]) for s in samples)


# ---------------------------------------------------------------------------
# Attribution
# ---------------------------------------------------------------------------

class _FakePlan:
    def __init__(self, est):
        self.est_latency_s = est


def test_aggregate_groups_by_tenant_kind_and_sums_tokens():
    tr = Tracer()
    tr.add("prefill_chunk", 0.0, 0.1, trace=1, tenant="lm", tokens=4)
    tr.add("prefill_chunk", 0.1, 0.3, trace=2, tenant="lm", tokens=2)
    tr.add("prefill_chunk", 0.0, 0.1, trace=3, tenant="other", tokens=8)
    agg = aggregate(tr.spans)
    assert agg[("lm", "prefill_chunk")]["count"] == 2
    assert agg[("lm", "prefill_chunk")]["tokens"] == 6
    assert agg[("other", "prefill_chunk")]["tokens"] == 8


def test_attribution_planned_analogue_per_kind():
    tr = Tracer()
    tr.add("decode_step", 0.0, 0.002, trace=1, tenant="lm")
    tr.add("queue", 0.0, 0.5, trace=1, tenant="lm")
    tr.add("prefill_chunk", 0.0, 0.006, trace=1, tenant="lm", tokens=3)
    rows = {(r.tenant, r.kind): r
            for r in attribution({"lm": _FakePlan(0.002)}, tr.spans)}
    dec = rows[("lm", "decode_step")]
    assert dec.planned_s == 0.002 and dec.ratio == pytest.approx(1.0)
    assert dec.within_2x is True
    # prefill prices per token: est x mean tokens/chunk = 0.002 * 3.
    pre = rows[("lm", "prefill_chunk")]
    assert pre.planned_s == pytest.approx(0.006)
    # Queue wait is exactly what the plan does NOT price.
    q = rows[("lm", "queue")]
    assert q.planned_s is None and q.ratio is None and q.within_2x is None
    table = format_attribution(list(rows.values()))
    assert "decode_step" in table and "queue" in table
    # Unknown tenants degrade to unplanned rows, not KeyError.
    rows2 = attribution({}, tr.spans)
    assert all(r.planned_s is None for r in rows2)


def test_reconcile_excludes_request_envelope():
    tr = Tracer()
    tr.add("request", 0.0, 1.0, trace=9, tenant="lm")   # the e2e envelope
    tr.add("queue", 0.0, 0.4, trace=9, tenant="lm")
    tr.add("decode_step", 0.4, 0.9, trace=9, tenant="lm")
    tr.add("decode_step", 0.0, 0.5, trace=8, tenant="lm")  # other trace
    rec = reconcile(tr.spans, 9, 1.0)
    assert rec["sum_s"] == pytest.approx(0.9)
    assert rec["coverage"] == pytest.approx(0.9)
    assert set(rec["by_kind"]) == {"queue", "decode_step"}


# ---------------------------------------------------------------------------
# Metrics satellites: NaN-free snapshots, filename hardening
# ---------------------------------------------------------------------------

def test_tenant_metrics_snapshot_strict_json_on_empty_window():
    m = TenantMetrics("x")                    # latency_budget_s = inf
    snap = m.snapshot()
    assert snap["p95_s"] == 0.0 and snap["p50_s"] == 0.0
    assert snap["latency_budget_s"] is None   # inf -> null, not "Infinity"
    json.dumps(snap, allow_nan=False)


def test_tenant_metrics_rejects_nonfinite_observations():
    m = TenantMetrics("x", latency_budget_s=1.0)
    for bad in (float("nan"), float("inf"), float("-inf")):
        assert m.observe_latency(bad) is False
    m.observe_latency(0.5)
    assert m.count == 1 and m.invalid_observations == 3
    assert m.p95_s == 0.5 and not math.isnan(m.mean_s)
    json.dumps(m.snapshot(), allow_nan=False)


def test_safe_net_name_hardening():
    # The established mapping (test_fleet relies on the '#'->'_' filenames).
    assert _safe_net_name("jet_tagger#1") == "jet_tagger_1"
    assert _safe_net_name("a/b\\c") == "a_b_c"
    # Degenerate ids fall back to a stable content hash, never "" or "..".
    for bad in ("", "..", ".", "___", "//", "--"):
        safe = _safe_net_name(bad)
        assert safe.startswith("net_") and len(safe) > 4, (bad, safe)
    assert _safe_net_name("..") != _safe_net_name(".")


def test_write_serve_snapshots_hostile_id_and_cold_tenant(tmp_path):
    report = {
        "../evil": {"net_id": "../evil", "count": 0, "mean_s": 0.0,
                    "p50_s": 0.0, "p95_s": 0.0, "budget_violations": 0,
                    "kind": "edge", "planned_latency_s": 1e-6},
    }
    (p,) = write_serve_snapshots(report, tmp_path)
    assert p.parent == tmp_path               # no traversal out of json_dir
    rows = json.loads(p.read_text())["rows"]
    names = [r["name"] for r in rows]
    # Cold tenant: no 0.0 percentile rows (they would read as a regression
    # to zero in the trend diff) — only the model-sourced planned row.
    assert names == ["serve/../evil/planned"]


def test_write_serve_snapshots_span_kind_rows(tmp_path):
    report = {
        "lm0": {"net_id": "lm0", "count": 2, "mean_s": 1.0, "p50_s": 1.0,
                "p95_s": 1.2, "budget_violations": 0, "kind": "lm",
                "planned_latency_s": 2e-5,
                "spans": {"decode_step": summarize([1e-3, 2e-3]),
                          "queue": summarize([0.5]),
                          "cold": summarize([])}},
    }
    (p,) = write_serve_snapshots(report, tmp_path)
    rows = {r["name"]: r for r in json.loads(p.read_text())["rows"]}
    assert rows["serve/lm0/decode_step/p50"]["us_per_call"] == \
        pytest.approx(2000.0)                     # upper-median convention
    assert "span=decode_step" in rows["serve/lm0/decode_step/p50"]["derived"]
    assert "serve/lm0/queue/p95" in rows
    assert "serve/lm0/cold/p50" not in rows   # empty window: no rows
    # The LM decode-step planned analogue rides along as a model row.
    planned = rows["serve/lm0/decode_step/planned"]
    assert planned["derived"] == "src=model"
    assert planned["us_per_call"] == pytest.approx(20.0)


# ---------------------------------------------------------------------------
# Span-decomposed serving: reconciliation, shed/evict, decode-step drift
# ---------------------------------------------------------------------------

def _smoke_batcher(tracer=None, serve=None, max_len=64):
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    plan = plan_lib.plan_deployment(cfg, target="tpu")
    if serve:
        plan = plan_lib.DeploymentPlan.from_dict(
            {**plan.to_dict(), "serve": serve})
    return engine.ContinuousBatcher(cfg, params, plan=plan, max_len=max_len,
                                    tracer=tracer)


def _warm(b):
    """One throwaway request: jit compile + slot-reset dispatch, so traced
    requests measure steady-state service, not compilation."""
    b.submit(engine.Request(rid=-1, prompt=np.array([2, 3], np.int32),
                            max_new=2))
    b.run_until_drained(max_ticks=50)
    if b.tracer.enabled:
        b.tracer.clear()


def test_solo_request_spans_reconcile_with_e2e_latency():
    tr = Tracer()
    b = _smoke_batcher(tracer=tr, serve={"slots": 2, "prefill_chunk": 2})
    _warm(b)
    # The coverage bound is a host-timing property: a scheduler hiccup in
    # the drain loop inflates the untraced inter-tick gap.  Resample up to
    # three times; the bound itself never loosens.
    rec = None
    for _ in range(3):
        tr.clear()
        req = engine.Request(rid=42, prompt=np.array([3, 5, 7], np.int32),
                             max_new=4)
        b.submit(req)
        b.run_until_drained(max_ticks=50)
        assert req.done
        mine = tr.by_trace(42)
        kinds = {s.name for s in mine}
        assert {"queue", "prefill_chunk", "decode_step", "request"} <= kinds
        (envelope,) = [s for s in mine if s.name == "request"]
        assert envelope.attrs["tokens_out"] == 4
        e2e = envelope.dur_s
        assert e2e == pytest.approx(req.t_done - req.t_submit)
        # Components are consistent: decode steps = generated tokens - the
        # one emitted by the prefill finish.
        n_dec = sum(1 for s in mine if s.name == "decode_step")
        assert n_dec == 3
        assert sum(s.attrs["tokens"] for s in mine
                   if s.name == "prefill_chunk") == len(req.prompt)
        rec = reconcile(tr.spans, 42, e2e)
        if 0.7 <= rec["coverage"] <= 1.05:
            break
    # A solo request's spans tile its end-to-end latency: the only
    # uncovered wall time is inter-tick bookkeeping (slot reset, the drain
    # loop), the only overlap none.  Far below 1 would mean the request
    # spent time no span accounts for.
    assert 0.7 <= rec["coverage"] <= 1.05, rec


def test_concurrent_request_spans_keep_trace_ids_apart():
    tr = Tracer()
    b = _smoke_batcher(tracer=tr, serve={"slots": 2})
    _warm(b)
    # Coverage is a host-timing property (see the solo test): resample up
    # to three times on a scheduler hiccup, bound unchanged.
    recs = {}
    for _ in range(3):
        tr.clear()
        reqs = [engine.Request(rid=100 + i,
                               prompt=np.array([3 + i, 5], np.int32),
                               max_new=3)
                for i in range(3)]
        for r in reqs:
            b.submit(r)
        b.run_until_drained(max_ticks=100)
        for r in reqs:
            mine = tr.by_trace(r.rid)
            kinds = {s.name for s in mine}
            assert {"queue", "prefill_chunk", "decode_step",
                    "request"} <= kinds
            assert len([s for s in mine if s.name == "request"]) == 1
        recs = {r.rid: reconcile(tr.spans, r.rid, r.t_done - r.t_submit)
                for r in reqs}
        if all(rec["coverage"] > 0.5 for rec in recs.values()):
            break
    # Batched decode: per-request spans share the step interval, so
    # coverage can exceed 1 (legit overlap) but never collapse.
    for rid, rec in recs.items():
        assert rec["coverage"] > 0.5, (rid, rec)
    # No span leaked onto another request's trace id.
    all_ids = {s.trace_id for s in tr.spans if s.trace_id is not None}
    assert all_ids == {100, 101, 102}


def test_trace_survives_max_new_cap_eviction():
    tr = Tracer()
    b = _smoke_batcher(tracer=tr, serve={"slots": 1, "max_new_cap": 2})
    _warm(b)
    req = engine.Request(rid=7, prompt=np.array([3, 5], np.int32),
                         max_new=50)              # plan cap evicts at 2
    b.submit(req)
    b.run_until_drained(max_ticks=20)
    assert req.done and len(req.out) == 2
    (envelope,) = [s for s in tr.by_trace(7) if s.name == "request"]
    assert envelope.attrs["tokens_out"] == 2      # the evicted trace closed
    assert req.t_done is not None
    assert envelope.dur_s == pytest.approx(req.t_done - req.t_submit)


def test_trace_survives_queue_full_shedding():
    """A refused submit (TenantQueueFull) must neither emit spans for the
    refused request nor corrupt the admitted requests' traces."""
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    fleet = plan_lib.plan_fleet([cfg], target="tpu", serve_slots_total=1,
                                queue_depth_factor=2,
                                cache=plan_lib.PlanCache())
    nid = fleet.net_ids[0]
    tr = Tracer()
    router = Router.from_fleet(fleet, lm={nid: (cfg, params)}, tracer=tr)
    reqs = [engine.Request(rid=i, prompt=np.array([3 + i], np.int32),
                           max_new=2) for i in range(3)]
    router.submit(nid, reqs[0])
    router.submit(nid, reqs[1])
    with pytest.raises(TenantQueueFull):
        router.submit(nid, reqs[2])
    assert tr.by_trace(2) == []                   # refused: no spans
    router.run_until_drained(max_ticks=200)
    for r in reqs[:2]:
        assert r.done
        mine = tr.by_trace(r.rid)
        assert [s for s in mine if s.name == "request"]
        # Spans are labeled with the ROUTER's net id, not cfg.name.
        assert {s.attrs["tenant"] for s in mine} == {nid}
    # The shed request can be resubmitted later and traces normally.
    router.submit(nid, reqs[2])
    router.run_until_drained(max_ticks=200)
    assert reqs[2].done and tr.by_trace(2)


def test_decode_step_window_is_always_on():
    """Drift needs decode-step p50 with tracing DISABLED: the batcher's
    windows are maintained unconditionally."""
    b = _smoke_batcher()                          # no tracer
    assert not b.tracer.enabled
    b.submit(engine.Request(rid=0, prompt=np.array([3, 5], np.int32),
                            max_new=4))
    b.run_until_drained(max_ticks=50)
    assert b.measured_decode_p50_s > 0
    assert b.decode_steps_observed == 3
    stats = b.span_stats()
    assert {"queue", "prefill_chunk", "decode_step"} <= set(stats)
    assert stats["decode_step"]["total_count"] == 3


def test_router_report_carries_span_stats():
    cfg = edge.edge_config("jet_tagger")
    fleet = plan_lib.plan_fleet([cfg], target="tpu",
                                cache=plan_lib.PlanCache())
    router = Router.from_fleet(fleet)
    x = jnp.ones((cfg.batch, cfg.dims[0]), jnp.float32)
    router.warmup({"jet_tagger": x})
    router.drive({"jet_tagger": x}, iters=3)
    snap = router.report()["jet_tagger"]
    assert snap["spans"]["infer"]["count"] == 3
    assert snap["spans"]["infer"]["p50_s"] > 0


# ---------------------------------------------------------------------------
# Deployment + stage spans
# ---------------------------------------------------------------------------

def test_traced_build_emits_stage_spans(tmp_path):
    from repro.deploy import Deployment
    dep = Deployment.build("jet_tagger", machine_model=None,
                           stop_after="plan", trace=True,
                           cache=plan_lib.PlanCache())
    by_name = {s.name: s for s in dep.tracer.spans}
    assert set(by_name) == {"stage/characterize", "stage/plan"}
    assert by_name["stage/characterize"].attrs["skipped"] is True
    assert by_name["stage/plan"].attrs["skipped"] is False
    assert "tracing:" in dep.summary()
    p = dep.export_trace(tmp_path / "trace.json")
    json.loads(p.read_text(), parse_constant=lambda _: 1 / 0)
    samples = parse_prometheus(
        dep.export_prometheus(tmp_path / "m.prom").read_text())
    assert samples


def test_tracer_saturation_surfaces_in_summary_and_prometheus(tmp_path):
    """Regression: once the span ring buffer fills, the dropped count must
    surface in BOTH reporting sinks (``summary()`` and the Prometheus
    snapshot) — a truncated trace that looks complete is the failure
    mode."""
    from repro.deploy import Deployment
    dep = Deployment.build("jet_tagger", machine_model=None,
                           stop_after="plan", trace=True,
                           cache=plan_lib.PlanCache())
    dep.tracer.maxlen = len(dep.tracer.spans) + 2
    for i in range(10):                        # saturate past maxlen
        dep.tracer.add("probe", 0.0, 1e-6, tenant="t")
    assert dep.tracer.dropped == 8
    assert "(8 dropped)" in dep.summary()
    samples = parse_prometheus(
        dep.export_prometheus(tmp_path / "m.prom").read_text())
    (drop,) = [s for s in samples
               if s["name"] == "repro_tracer_dropped_total"]
    assert drop["value"] == 8.0


def test_untraced_build_uses_null_tracer():
    from repro.deploy import Deployment
    dep = Deployment.build("jet_tagger", machine_model=None,
                           stop_after="plan", cache=plan_lib.PlanCache())
    assert dep.tracer is NULL_TRACER
    assert len(dep.tracer.spans) == 0


# ---------------------------------------------------------------------------
# Overhead guard: tracing-off dispatch must stay in the noise
# ---------------------------------------------------------------------------

def test_tracing_disabled_adds_under_2pct_to_edge_dispatch():
    """EdgeEngine.infer with the (disabled) tracer branch vs the raw guarded
    dispatch: the median must agree within 2%.  The baseline includes the
    always-on non-finite output guard — that check is part of infer's
    contract (a poisoned output fails the call instead of returning
    garbage), so the 2% bound isolates exactly what this test is about:
    the cost of the disabled tracer/injector branches.  Retries absorb
    scheduler noise — the guard is against a systematic regression (e.g.
    span allocation on the disabled path), not against a noisy host."""
    cfg = edge.edge_config("jet_tagger")
    eng = engine.EdgeEngine(cfg)
    assert eng.tracer is NULL_TRACER
    x = jnp.ones((cfg.batch, cfg.dims[0]), jnp.float32)
    for _ in range(10):
        eng.infer(x)                               # jit + cache warm
    n = 50
    for _ in range(3):
        # Interleave the two populations so scheduler/load noise hits both
        # equally — back-to-back phases would bias whichever ran during a
        # background spike.
        raw = []
        eng.reset_measurements()
        for _ in range(n):
            t0 = time.perf_counter()
            y = jax.block_until_ready(eng._fwd(x))
            assert bool(np.isfinite(np.asarray(y)).all())
            raw.append(time.perf_counter() - t0)
            eng.infer(x)
        if eng.measured_p50_s <= percentile(raw, 0.5) * 1.02:
            return
    pytest.fail(f"traced-off dispatch overhead > 2%: "
                f"infer p50 {eng.measured_p50_s * 1e6:.1f}us vs "
                f"raw p50 {percentile(raw, 0.5) * 1e6:.1f}us")
