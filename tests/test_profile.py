"""Roofline-attributed profiling: plan work accounting, profile math,
snapshot determinism, Prometheus families, and trend forensics."""

import dataclasses
import json
import math

import pytest

from benchmarks import trend
from repro.models import edge
from repro.obs import (format_attribution, format_profile, parse_prometheus,
                       profile, prometheus_text, write_profile_snapshots)
from repro.plan import get_or_plan


@pytest.fixture(scope="module")
def jet_plan():
    return get_or_plan(edge.edge_config("jet_tagger"), target="tpu")


def _stats(p50=1e-4, count=10, kind="infer", tenant="jet_tagger", tokens=0):
    return {(tenant, kind): {"count": count, "total_s": p50 * count,
                             "mean_s": p50, "p50_s": p50, "p95_s": p50 * 2,
                             "tokens": tokens}}


# ---------------------------------------------------------------------------
# plan work accounting
# ---------------------------------------------------------------------------

def test_plan_work_matches_graph_accounting(jet_plan):
    w = jet_plan.work()
    assert w["itemsize"] == 1            # edge deploys int8
    flops = sum(2.0 * jet_plan.batch * l.n_in * l.n_out * l.repeat
                for l in jet_plan.layers)
    assert w["flops"] == pytest.approx(flops)
    assert w["weight_bytes"] == sum(l.n_in * l.n_out * l.repeat
                                    for l in jet_plan.layers)
    assert w["bytes"] == w["weight_bytes"] + w["act_bytes"]
    assert w["launches"] == len(jet_plan.groups()) or w["launches"] >= 1
    assert sum(g["flops"] for g in w["per_group"]) == pytest.approx(flops)


def test_plan_work_without_fusion_groups(jet_plan):
    """v1/v2 plans load with no fusion_groups section — work() must fall
    back to the derived per-layer groups, same totals."""
    legacy = dataclasses.replace(jet_plan, fusion_groups=())
    w_new, w_old = jet_plan.work(), legacy.work()
    assert w_old["flops"] == pytest.approx(w_new["flops"])
    assert w_old["bytes"] == w_new["bytes"]
    assert w_old["launches"] >= 1
    rows = profile({"jet_tagger": legacy}, _stats())
    assert rows and rows[0].bound in ("compute", "memory", "launch")


# ---------------------------------------------------------------------------
# profile math
# ---------------------------------------------------------------------------

def test_profile_row_fraction_in_unit_interval(jet_plan):
    rows = profile({"jet_tagger": jet_plan}, _stats(p50=1e-4))
    (r,) = [x for x in rows if x.group is None]
    assert 0.0 < r.roofline_fraction <= 1.0
    assert r.achieved_flops == pytest.approx(r.flops / 1e-4)
    assert r.bound in ("compute", "memory", "launch")
    assert r.measured_lare is not None and math.isfinite(r.measured_lare)
    assert r.measured_lare > 0


def test_profile_fraction_clamps_at_one(jet_plan):
    """A measured window faster than the model ceiling clamps to 1.0
    (timer jitter), never reads as >100% of roofline."""
    rows = profile({"jet_tagger": jet_plan}, _stats(p50=1e-9))
    (r,) = [x for x in rows if x.group is None]
    assert r.roofline_fraction == 1.0


def test_profile_zero_duration_window(jet_plan):
    rows = profile({"jet_tagger": jet_plan}, _stats(p50=0.0))
    (r,) = [x for x in rows if x.group is None]
    assert r.roofline_fraction is None
    assert r.achieved_flops is None
    assert r.measured_lare is None
    assert r.ceiling_s > 0               # the model side still prices it


def test_profile_no_measured_spans(jet_plan):
    assert profile({"jet_tagger": jet_plan}, {}) == []
    # unprofiled kinds (queue/admit) produce no rows either
    assert profile({"jet_tagger": jet_plan}, _stats(kind="queue")) == []


def test_profile_skips_duck_typed_plans():
    class _FakePlan:
        est_latency_s = 1e-4
    assert profile({"jet_tagger": _FakePlan()}, _stats()) == []


def test_profile_prefill_scales_by_tokens(jet_plan):
    lm_like = _stats(kind="prefill_chunk", tokens=40, count=10)
    rows = profile({"jet_tagger": jet_plan}, lm_like)
    (r,) = rows
    assert r.flops == pytest.approx(jet_plan.work()["flops"] * 4.0)


def test_format_profile_and_attribution_block(jet_plan):
    rows = profile({"jet_tagger": jet_plan}, _stats())
    txt = format_profile(rows)
    assert "bound" in txt and "jet_tagger" in txt
    assert format_profile([]).startswith("profile: no measured windows")
    attr_txt = format_attribution([], profile=rows)
    assert "roofline:" in attr_txt


# ---------------------------------------------------------------------------
# snapshots: determinism + trend gating shape
# ---------------------------------------------------------------------------

def test_profile_snapshot_model_rows_byte_identical(tmp_path, jet_plan):
    outs = []
    for sub in ("a", "b"):
        rows = profile({"jet_tagger": jet_plan}, _stats())
        (p,) = write_profile_snapshots(rows, tmp_path / sub)
        outs.append(p.read_bytes())
    assert outs[0] == outs[1]
    payload = json.loads(outs[0])
    names = {r["name"] for r in payload["rows"]}
    assert "profile/jet_tagger/infer/ceiling" in names
    model_rows = [r for r in payload["rows"] if "src=model" in r["derived"]]
    assert model_rows and all("t_compute_us=" in r["derived"]
                              for r in model_rows
                              if "ceiling" in r["name"])


def test_profile_snapshot_skips_zero_measured(tmp_path, jet_plan):
    rows = profile({"jet_tagger": jet_plan}, _stats(p50=0.0))
    (p,) = write_profile_snapshots(rows, tmp_path)
    payload = json.loads(p.read_text())
    names = [r["name"] for r in payload["rows"]]
    assert "profile/jet_tagger/infer/ceiling" in names
    assert not any(n.endswith("/p50") for n in names)


# ---------------------------------------------------------------------------
# Prometheus round-trip
# ---------------------------------------------------------------------------

def test_profile_prometheus_roundtrip(jet_plan):
    rows = profile({"jet_tagger": jet_plan}, _stats())
    text = prometheus_text(_stats(), profile=rows)
    samples = parse_prometheus(text)     # strict: rejects non-finite
    by_name = {}
    for s in samples:
        by_name.setdefault(s["name"], []).append(s)
    assert "repro_profile_roofline_fraction" in by_name
    assert "repro_profile_achieved_flops" in by_name
    assert "repro_profile_bound_info" in by_name
    assert "repro_profile_measured_lare" in by_name
    (frac,) = [s for s in by_name["repro_profile_roofline_fraction"]
               if s["labels"].get("group") is None]
    assert 0.0 < frac["value"] <= 1.0
    (bound,) = [s for s in by_name["repro_profile_bound_info"]
                if "group" not in s["labels"]]
    assert bound["labels"]["bound"] in ("compute", "memory", "launch")


def test_profile_prometheus_skips_zero_windows(jet_plan):
    rows = profile({"jet_tagger": jet_plan}, _stats(p50=0.0))
    text = prometheus_text(_stats(p50=0.0), profile=rows)
    samples = parse_prometheus(text)
    names = {s["name"] for s in samples}
    assert "repro_profile_roofline_fraction" not in names
    assert "repro_profile_bound_info" in names


# ---------------------------------------------------------------------------
# trend forensics: --explain + malformed snapshots
# ---------------------------------------------------------------------------

def _payload(ceiling_us, compute_us, memory_us, launch_us):
    return {"meta": {}, "rows": [{
        "name": "profile/jet_tagger/infer/ceiling",
        "us_per_call": ceiling_us,
        "derived": (f"src=model;bound=launch;t_compute_us={compute_us};"
                    f"t_memory_us={memory_us};t_launch_us={launch_us}"),
    }]}


def test_trend_explain_names_worst_moved_term(capsys):
    old = _payload(2.2, 0.5, 0.4, 2.2)
    new = _payload(4.4, 0.5, 4.4, 2.2)   # memory term blew up
    verdict = trend.explain(old, new)
    assert verdict["term"] == "t_memory_us"
    assert verdict["span_kind"] == "infer"
    assert verdict["tenant"] == "jet_tagger"
    assert verdict["term_delta_us"] == pytest.approx(4.0)
    out = capsys.readouterr().out
    assert "t_memory_us" in out and "worst mover" in out


def test_trend_explain_no_breakdown(capsys):
    old = {"rows": [{"name": "serve/jet/p50", "us_per_call": 1.0,
                     "derived": "src=measured"}]}
    new = {"rows": [{"name": "serve/jet/p50", "us_per_call": 2.0,
                     "derived": "src=measured"}]}
    verdict = trend.explain(old, new)
    assert verdict["term"] is None
    assert "attribution stops" in capsys.readouterr().out


def test_trend_explain_nothing_changed(capsys):
    p = _payload(2.2, 0.5, 0.4, 2.2)
    assert trend.explain(p, p) is None


def test_trend_malformed_snapshot_one_line_error(tmp_path, capsys):
    bad = tmp_path / "BENCH_truncated.json"
    bad.write_text('{"rows": [{"name": "x", "us_per_c')   # truncated
    rc = trend.main([str(bad)])
    assert rc == 2
    err = capsys.readouterr().err
    assert "malformed snapshot JSON" in err
    assert len(err.strip().splitlines()) == 1


def test_trend_malformed_rows_shape(tmp_path, capsys):
    bad = tmp_path / "BENCH_shape.json"
    bad.write_text(json.dumps({"rows": [{"nam": "x"}]}))
    rc = trend.main([str(bad)])
    assert rc == 2
    assert "rows" in capsys.readouterr().err


def test_trend_missing_snapshot_file(tmp_path, capsys):
    rc = trend.main([str(tmp_path / "nope.json")])
    assert rc == 2
    assert capsys.readouterr().err.startswith("trend:")


def test_trend_explain_cli_flag(tmp_path, capsys):
    old_p, new_p = tmp_path / "old.json", tmp_path / "new.json"
    old_p.write_text(json.dumps(_payload(2.2, 0.5, 0.4, 2.2)))
    new_p.write_text(json.dumps(_payload(4.4, 0.5, 4.4, 2.2)))
    rc = trend.main([str(new_p), "--against", str(old_p), "--explain"])
    assert rc == 0
    assert "[explain]" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# engine integration: always-on windows -> profile, real executables -> HLO
# ---------------------------------------------------------------------------

def test_edge_engine_profile_integration(jet_plan):
    import jax.numpy as jnp
    from repro.launch.hlo_analysis import analyze_engine
    from repro.serve.engine import EdgeEngine

    cfg = edge.edge_config("jet_tagger")
    eng = EdgeEngine(cfg, plan=jet_plan)
    x = jnp.ones((cfg.batch, cfg.dims[0]), jnp.float32)
    for _ in range(3):
        eng.infer(x)
    stats = {("jet_tagger", k): agg for k, agg in eng.span_stats().items()}
    rows = profile({"jet_tagger": jet_plan}, stats)
    (r,) = [x for x in rows if x.group is None]
    assert 0.0 < r.roofline_fraction <= 1.0
    assert r.count == 3
    hlo = analyze_engine(eng)            # the ACTUAL jitted forward
    assert hlo["flops"] > 0
    assert eng.hlo_text() is eng.hlo_text()   # compiled once, cached
