"""Distribution-layer tests: sharding rules, partitioner, pipeline
parallelism, HLO analyzer (loop multipliers), mesh builders."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs, partition, sharding as shlib
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.train import pipeline_par


def test_shard_noop_without_context():
    x = jnp.ones((4, 4))
    assert shlib.shard(x, "batch", "embed") is x


def test_rules_divisibility_fallback():
    mesh = make_host_mesh(model=1)
    with shlib.use_rules(mesh, {"batch": "data", "heads": "model"}):
        # 3 does not divide the data axis (1 divides everything -> kept)
        x = jnp.ones((3, 8))
        y = shlib.shard(x, "batch", None)
        assert y.shape == x.shape


def test_param_specs_structure():
    mesh = make_host_mesh(model=1)
    cfg = configs.get("gemma2_2b").smoke
    params = jax.eval_shape(lambda k: api.init(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = partition.param_specs(params, cfg, mesh, regime="train")
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    # Specs never exceed the leaf rank.
    for p, s in zip(flat_p, flat_s):
        assert len(s) <= len(p.shape)


def test_param_specs_moe_layouts():
    """EP layout when experts divide the model axis, TP layout otherwise."""
    import os
    mesh = make_host_mesh(model=1)
    ds = configs.get("deepseek_v3_671b")
    params = jax.eval_shape(lambda k: api.init(ds.smoke, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = partition.param_specs(params, ds.smoke, mesh, regime="train")
    assert jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))


def test_serve_regime_drops_fsdp():
    mesh = make_host_mesh(model=1)
    cfg = configs.get("gemma2_2b").smoke
    params = jax.eval_shape(lambda k: api.init(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    tr = partition.param_specs(params, cfg, mesh, regime="train")
    sv = partition.param_specs(params, cfg, mesh, regime="serve")
    # serve specs never reference the data axis
    for s in jax.tree.leaves(sv, is_leaf=lambda x: isinstance(x, P)):
        for e in s:
            axes = (e,) if isinstance(e, str) else (e or ())
            assert "data" not in axes


def test_cache_specs_cover_state():
    mesh = make_host_mesh(model=1)
    for name in ("gemma2_2b", "deepseek_v3_671b", "rwkv6_7b",
                 "recurrentgemma_2b"):
        cfg = configs.get(name).smoke
        st = api.decode_state_specs(cfg, 2, 16)
        specs = partition.cache_specs(st, mesh)
        assert len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))) \
            == len(jax.tree.leaves(st))


# ---------------------------------------------------------------------------
# Pipeline parallelism (1-stage degenerate case on a single CPU device)
# ---------------------------------------------------------------------------

def test_pipeline_apply_single_stage_exact():
    from repro.launch import mesh as mesh_lib
    # a 1-device mesh whose axis is named "pod"
    mesh = mesh_lib.make_mesh((1,), ("pod",))
    L, D = 4, 8
    ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.3

    def layer_fn(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.PRNGKey(1), (4, D))
    out = pipeline_par.pipeline_apply(layer_fn, ws, x, mesh=mesh,
                                      axis="pod", microbatches=2)
    ref = x
    for i in range(L):
        ref = jnp.tanh(ref @ ws[i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

def test_analyzer_scales_scan_bodies():
    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    txt = jax.jit(scanned).lower(x, ws).compile().as_text()
    r = analyze_hlo(txt)
    expect = 2 * 128**3 * 7
    assert abs(r["flops"] - expect) / expect < 0.01


def test_analyzer_nested_scan():
    def nested(x, ws):
        def outer(c, _):
            def body(cc, w):
                return cc @ w, None
            y, _ = jax.lax.scan(body, c, ws)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    txt = jax.jit(nested).lower(x, ws).compile().as_text()
    r = analyze_hlo(txt)
    expect = 2 * 64**3 * 5 * 3
    assert abs(r["flops"] - expect) / expect < 0.01


def test_analyzer_counts_collectives_with_groups():
    mesh = make_host_mesh(data=1, model=1)

    def f(x):
        return jax.lax.psum(x, "data")

    x = jnp.ones((8, 128))
    from repro import compat
    txt = jax.jit(compat.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),

                                check_vma=False)).lower(x).compile().as_text()
    r = analyze_hlo(txt)
    # group size 1: wire bytes 0, but op counted
    assert "all-reduce" in r["collectives"] or r["collective_wire_bytes"] == 0


def test_production_mesh_shapes():
    """make_production_mesh only works under the 512-device dry-run env; here
    we check the pure logic via mock devices count requirement."""
    import repro.launch.mesh as meshmod
    n = len(jax.devices())
    if n < 512:
        with pytest.raises(Exception):
            meshmod.make_production_mesh()
    host = meshmod.make_host_mesh(model=1)
    assert set(host.axis_names) == {"data", "model"}
