"""Serving tests: engine, continuous batcher, int8 quantized weights,
edge low-latency path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api, edge
from repro.serve import engine


def test_quantize_params_marks_big_weights():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    q = engine.quantize_params(params, min_size=1024)
    # embeddings stay bf16 (index-gathered); attention weights quantize
    assert not engine.runtime.is_q8(q["emb"])
    wq = q["blocks"]["slot0"]["attn"]["wq"]
    assert isinstance(wq, dict) and wq["q8"].dtype == jnp.int8
    before, after = engine.quantized_bytes(q)
    assert after < 0.85 * before


def test_quantized_forward_close_to_float():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    full = api.forward(params, cfg, {"tokens": toks})["logits"]
    qp = engine.quantize_params(params, min_size=1024)
    qlg = api.forward(qp, cfg, {"tokens": toks})["logits"]
    # int8 weights: logits correlate strongly with the float path
    a = np.asarray(full[..., :cfg.vocab_size], np.float32).reshape(-1)
    b = np.asarray(qlg[..., :cfg.vocab_size], np.float32).reshape(-1)
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.98, corr


def test_continuous_batcher_drains():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    b = engine.ContinuousBatcher(cfg, params, slots=2, max_len=64)
    reqs = [engine.Request(rid=i,
                           prompt=np.array([3 + i, 5, 7], np.int32),
                           max_new=4) for i in range(5)]
    for r in reqs:
        b.submit(r)
    b.run_until_drained(max_ticks=200)
    for r in reqs:
        assert r.done and len(r.out) == 4
        assert all(0 <= t < cfg.padded_vocab for t in r.out)


def test_serve_steps_builder():
    cfg = configs.get("gemma2_2b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    prefill, decode = engine.build_serve_steps(cfg, max_len=32)
    state = api.init_decode_state(cfg, 2, 32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    logits, state = prefill(params, toks, state)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    lg2, state = decode(params, toks[:, :1], state, 8)
    assert lg2.shape == (2, 1, cfg.padded_vocab)


# ---------------------------------------------------------------------------
# Edge path (the paper's own serving regime)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(edge.EDGE_NETS))
def test_edge_nets_float_forward(name):
    cfg = edge.edge_config(name)
    params = edge.init_edge(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.batch, cfg.dims[0]))
    y = edge.edge_forward(params, cfg, x)
    assert y.shape == (cfg.batch, cfg.dims[-1])
    assert bool(jnp.isfinite(y).all())


def test_edge_int8_close_to_float():
    cfg = edge.edge_config("jet_tagger")
    params = edge.init_edge(jax.random.PRNGKey(0), cfg)
    qp = edge.quantize_edge(params)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (cfg.batch, cfg.dims[0])) * 0.5
    yf = edge.edge_forward(params, cfg, x)
    yq = edge.edge_forward_q8(qp, cfg, x, x_scale=0.02)
    # classification argmax agreement
    agree = float(jnp.mean((jnp.argmax(yf, -1) == jnp.argmax(yq, -1))
                           .astype(jnp.float32)))
    assert agree >= 0.75, agree


def test_edge_mac_counts_match_paper():
    assert abs(edge.edge_config("vae").macs - 34_800) / 34_800 < 0.05
    assert abs(edge.edge_config("qubit").macs - 82_900) / 82_900 < 0.05
    assert abs(edge.edge_config("autoencoder").macs - 116_700) / 116_700 < 0.05
