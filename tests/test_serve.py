"""Serving tests: engine, continuous batcher, int8 quantized weights,
edge low-latency path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api, edge
from repro.serve import engine


def test_quantize_params_marks_big_weights():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    q = engine.quantize_params(params, min_size=1024)
    # embeddings stay bf16 (index-gathered); attention weights quantize
    assert not engine.runtime.is_q8(q["emb"])
    wq = q["blocks"]["slot0"]["attn"]["wq"]
    assert isinstance(wq, dict) and wq["q8"].dtype == jnp.int8
    before, after = engine.quantized_bytes(q)
    assert after < 0.85 * before


def test_quantize_params_roundtrip_error_bound():
    """Per-channel symmetric int8: |w - dequant(q8)| <= scale/2 elementwise."""
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (128, 768), jnp.float32) * 0.3
    q = engine.quantize_params({"w": w}, min_size=1024)["w"]
    assert engine.runtime.is_q8(q)
    deq = engine.runtime.dequant(q, jnp.float32)
    err = np.abs(np.asarray(w) - np.asarray(deq))
    bound = np.asarray(q["scale"]) / 2 + 1e-7
    assert (err <= bound).all()
    # Per-output-channel scales: one scale per trailing-dim column.
    assert q["scale"].shape == (1, 768)


def test_quantize_params_exclusions_and_small_leaves():
    params = {
        "emb": jnp.ones((256, 512), jnp.float32),        # excluded by name
        "scale": jnp.ones((512, 512), jnp.float32),      # excluded by name
        "tiny": jnp.ones((4, 4), jnp.float32),           # below min_size
        "vec": jnp.ones((1 << 18,), jnp.float32),        # 1-D: never quantized
        "big": jnp.ones((512, 512), jnp.float32),
    }
    q = engine.quantize_params(params, min_size=1024)
    for name in ("emb", "scale", "tiny", "vec"):
        assert not engine.runtime.is_q8(q[name]), name
        assert q[name].dtype == jnp.float32
    assert engine.runtime.is_q8(q["big"])


def test_quantized_bytes_accounting():
    params = {"big": jnp.ones((512, 512), jnp.float32),
              "small": jnp.ones((8, 8), jnp.float32)}
    q = engine.quantize_params(params, min_size=1024)
    before, after = engine.quantized_bytes(q)
    # before: everything priced at bf16. after: int8 leaves cost 1 B/elem,
    # the f32-kept leaf and the scales still price at 2 B/elem.
    n_big, n_small = 512 * 512, 8 * 8
    n_scale = 512
    assert before == 2 * (n_big + n_small + n_scale)
    assert after == n_big + 2 * (n_small + n_scale)


def test_quantized_forward_close_to_float():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    full = api.forward(params, cfg, {"tokens": toks})["logits"]
    qp = engine.quantize_params(params, min_size=1024)
    qlg = api.forward(qp, cfg, {"tokens": toks})["logits"]
    # int8 weights: logits correlate strongly with the float path
    a = np.asarray(full[..., :cfg.vocab_size], np.float32).reshape(-1)
    b = np.asarray(qlg[..., :cfg.vocab_size], np.float32).reshape(-1)
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.98, corr


def test_continuous_batcher_drains():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    b = engine.ContinuousBatcher(cfg, params, slots=2, max_len=64)
    reqs = [engine.Request(rid=i,
                           prompt=np.array([3 + i, 5, 7], np.int32),
                           max_new=4) for i in range(5)]
    for r in reqs:
        b.submit(r)
    b.run_until_drained(max_ticks=200)
    for r in reqs:
        assert r.done and len(r.out) == 4
        assert all(0 <= t < cfg.padded_vocab for t in r.out)


def _slot_state(batcher, slot):
    """Slice one slot's decode state (per-leaf batch axis comes from the
    batcher's axis map)."""
    return jax.tree.map(lambda v, ax: np.asarray(jnp.take(v, slot, axis=ax),
                                                 np.float32),
                        batcher.state, batcher._axes)


def test_continuous_batcher_staggered_admission():
    """Regression: slots admitted at different ticks must decode at their OWN
    positions — a shared max-position cursor (the old ``max(self.pos)``)
    writes a late-admitted slot's KV at the earlier slot's offsets and
    corrupts its cache.  Token-level outputs are argmax over the random smoke
    model's near-tie logits (not stable across hosts), so the assertion is on
    cache state, which is where the bug lived."""
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    prompt_a = np.array([3, 5, 7, 11, 13], np.int32)
    prompt_b = np.array([2, 9], np.int32)

    # Reference: B served alone (prefill + first token), in slot 0.
    ref = engine.ContinuousBatcher(cfg, params, slots=2, max_len=64)
    rb = engine.Request(rid=0, prompt=prompt_b, max_new=1)
    ref.submit(rb)
    ref.run_until_drained(max_ticks=10)
    assert rb.done

    # B admitted two ticks after A (longer prompt -> staggered positions).
    bat = engine.ContinuousBatcher(cfg, params, slots=2, max_len=64)
    ra = engine.Request(rid=1, prompt=prompt_a, max_new=8)
    bat.submit(ra)
    bat.step()
    bat.step()
    rb2 = engine.Request(rid=2, prompt=prompt_b, max_new=1)
    bat.submit(rb2)
    bat.step()                       # admits B into slot 1, done after prefill
    assert rb2.done and not ra.done
    assert bat.pos[0] != bat.pos[1]  # genuinely staggered cursors

    # B's prefill cache must match the B-alone reference exactly: same
    # tokens written at the same per-slot positions.
    ref_b = _slot_state(ref, 0)
    stag_b = _slot_state(bat, 1)
    for a, b in zip(jax.tree.leaves(ref_b), jax.tree.leaves(stag_b)):
        np.testing.assert_allclose(a, b, rtol=1e-2, atol=1e-2)


def test_continuous_batcher_slot_reuse_isolated():
    """A slot re-used by a later request starts from a clean cache (no stale
    KV from the previous occupant)."""
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    prompt = np.array([4, 8, 15], np.int32)

    ref = engine.ContinuousBatcher(cfg, params, slots=1, max_len=64)
    r0 = engine.Request(rid=0, prompt=prompt, max_new=1)
    ref.submit(r0)
    ref.run_until_drained(max_ticks=10)

    bat = engine.ContinuousBatcher(cfg, params, slots=1, max_len=64)
    warm = engine.Request(rid=1, prompt=np.array([30, 31, 32, 33], np.int32),
                          max_new=7)
    r1 = engine.Request(rid=2, prompt=prompt, max_new=1)
    bat.submit(warm)
    bat.submit(r1)
    bat.run_until_drained(max_ticks=40)
    assert warm.done and r1.done
    assert bat.pos[0] == ref.pos[0]  # position cursor restarted from zero
    for a, b in zip(jax.tree.leaves(_slot_state(ref, 0)),
                    jax.tree.leaves(_slot_state(bat, 0))):
        np.testing.assert_allclose(a, b, rtol=1e-2, atol=1e-2)


def test_serve_steps_builder():
    cfg = configs.get("gemma2_2b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    prefill, decode = engine.build_serve_steps(cfg, max_len=32)
    state = api.init_decode_state(cfg, 2, 32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    logits, state = prefill(params, toks, state)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    lg2, state = decode(params, toks[:, :1], state, 8)
    assert lg2.shape == (2, 1, cfg.padded_vocab)


# ---------------------------------------------------------------------------
# Edge path (the paper's own serving regime)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(edge.EDGE_NETS))
def test_edge_nets_float_forward(name):
    cfg = edge.edge_config(name)
    params = edge.init_edge(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.batch, cfg.dims[0]))
    y = edge.edge_forward(params, cfg, x)
    assert y.shape == (cfg.batch, cfg.dims[-1])
    assert bool(jnp.isfinite(y).all())


def test_edge_int8_close_to_float():
    cfg = edge.edge_config("jet_tagger")
    params = edge.init_edge(jax.random.PRNGKey(0), cfg)
    qp = edge.quantize_edge(params)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (cfg.batch, cfg.dims[0])) * 0.5
    yf = edge.edge_forward(params, cfg, x)
    yq = edge.edge_forward_q8(qp, cfg, x, x_scale=0.02)
    # classification argmax agreement
    agree = float(jnp.mean((jnp.argmax(yf, -1) == jnp.argmax(yq, -1))
                           .astype(jnp.float32)))
    assert agree >= 0.75, agree


def test_edge_mac_counts_match_paper():
    assert abs(edge.edge_config("vae").macs - 34_800) / 34_800 < 0.05
    assert abs(edge.edge_config("qubit").macs - 82_900) / 82_900 < 0.05
    assert abs(edge.edge_config("autoencoder").macs - 116_700) / 116_700 < 0.05
