"""repro.check: the static design-rule verifier (plan rules, kernel
contracts, jax-hazard lint) and its deploy/CLI surfaces."""

import dataclasses
import json
import pathlib
import subprocess
import sys

import pytest

from _hyp import given, settings, st
from repro.check import (ArtifactError, CheckReport, Finding,
                         PlanVerificationError, check_artifact, check_fleet,
                         check_snapshot, check_tree, kernel_contracts,
                         plan_rules)
from repro.check.lint import lint_source
from repro.models import edge
from repro.plan.artifact import BoundaryPlan, DeploymentPlan
from repro.plan.multinet import FleetPlan, plan_fleet
from repro.plan.planner import plan_deployment

REPO = pathlib.Path(__file__).resolve().parent.parent


def _tpu_plan(name="jet_tagger"):
    return plan_deployment(edge.edge_config(name), target="tpu")


def _aie_plan(name="jet_tagger"):
    return plan_deployment(edge.edge_config(name), target="aie")


def _rules(findings, severity="error"):
    return {f.rule for f in findings if f.severity == severity}


# ---------------------------------------------------------------------------
# layer 1: plan rules
# ---------------------------------------------------------------------------

def test_planner_output_is_clean():
    spatial = plan_deployment(edge.edge_config("jet_tagger"), target="aie",
                              pl_budget=0.0)      # force aie-regime layers
    for target, plan in (("tpu", _tpu_plan()), ("aie", _aie_plan()),
                         ("aie-spatial", spatial)):
        findings = check_fleet(FleetPlan.from_plan(plan))
        assert not [f for f in findings if f.severity == "error"], (
            target, findings)


def test_rule_tile_divides_and_legal():
    plan = _tpu_plan()
    bad = dataclasses.replace(
        plan, layers=(dataclasses.replace(plan.layers[0],
                                          api_tile=(33, 100, 100)),)
        + plan.layers[1:])
    rules = _rules(plan_rules.verify_plan(bad))
    assert "plan.tile-legal" in rules
    assert "plan.tile-divides" in rules


def test_rule_vmem_budget():
    plan = _tpu_plan()
    over = tuple(dataclasses.replace(g, vmem_bytes=1 << 30)
                 for g in plan.fusion_groups)
    bad = dataclasses.replace(plan, fusion_groups=over)
    assert "plan.vmem-budget" in _rules(plan_rules.verify_plan(bad))


def test_rule_serve_keys_illegal_resilience():
    plan = _tpu_plan()
    serve = dict(plan.serve)
    serve["resilience"] = {"breaker_k": 0, "retries": -1}
    bad = dataclasses.replace(plan, serve=serve)
    findings = plan_rules.verify_plan(bad)
    assert "plan.serve-keys" in _rules(findings)
    # both illegal knobs reported, not just the first
    assert sum(f.rule == "plan.serve-keys" and f.severity == "error"
               for f in findings) >= 2


def test_rule_serve_keys_vocabulary():
    plan = _tpu_plan()
    for serve in ({"priority": "urgent"},
                  {"slo": {"p95_s": -1.0}},
                  {"slo": {"p95_s": 1.0, "p99_s": 0.5}},
                  {"decode_regime": "warp"}):
        bad = dataclasses.replace(plan, serve=serve)
        assert "plan.serve-keys" in _rules(plan_rules.verify_plan(bad)), serve


def test_rule_boundary_structure():
    plan = _tpu_plan()
    if plan.boundaries:
        bad = dataclasses.replace(plan, boundaries=())
    else:
        l0 = plan.layers[0]
        bad = dataclasses.replace(plan, boundaries=(BoundaryPlan(
            after_layer=l0.index, from_regime=l0.regime,
            to_regime=l0.regime, crossing_s=1e-6),))
    assert "plan.boundary-structure" in _rules(plan_rules.verify_plan(bad))


def test_rule_fusion_groups_id_mismatch():
    plan = _tpu_plan()
    bumped = (dataclasses.replace(plan.fusion_groups[0],
                                  id=plan.fusion_groups[0].id + 101),) \
        + plan.fusion_groups[1:]
    bad = dataclasses.replace(plan, fusion_groups=bumped)
    assert "plan.fusion-groups" in _rules(plan_rules.verify_plan(bad))


def test_rule_latency_invariant():
    plan = _tpu_plan()
    bad = dataclasses.replace(plan, est_latency_s=plan.est_latency_s / 10)
    assert "plan.latency-invariant" in _rules(plan_rules.verify_plan(bad))


def test_rule_aie_tile_and_spatial_budget():
    # pl_budget=0 forces every layer onto the array (aie regime).
    plan = plan_deployment(edge.edge_config("jet_tagger"), target="aie",
                           pl_budget=0.0)
    aie_layers = [l for l in plan.layers if l.regime == "aie"]
    assert aie_layers, "expected AIE-regime layers with pl_budget=0"
    bad_layers = tuple(
        dataclasses.replace(l, api_tile=(5, 5, 5), p_k=7, p_n=4)
        if l.index == aie_layers[0].index else l for l in plan.layers)
    rules = _rules(plan_rules.verify_plan(
        dataclasses.replace(plan, layers=bad_layers)))
    assert "plan.tile-legal" in rules
    assert "plan.spatial-budget" in rules


def test_rule_fleet_columns():
    plan = _aie_plan()
    fleet = FleetPlan.from_plan(plan)
    t = fleet.tenants[0]
    lying = dataclasses.replace(t, cols=t.cols + 3)
    bad = dataclasses.replace(fleet, tenants=(lying,))
    assert "fleet.columns-overlap" in _rules(plan_rules.verify_fleet(bad))


def test_fleet_budget_warning():
    fleet = FleetPlan.from_plan(_tpu_plan())
    t = fleet.tenants[0]
    starved = dataclasses.replace(t, latency_budget_s=t.total_latency_s / 100)
    bad = dataclasses.replace(fleet, tenants=(starved,))
    assert "fleet.budget" in _rules(plan_rules.verify_fleet(bad), "warning")


# ---------------------------------------------------------------------------
# layer 2: kernel contracts
# ---------------------------------------------------------------------------

def test_kernel_block_divisibility():
    plan = _tpu_plan()
    bad_layers = (dataclasses.replace(plan.layers[0],
                                      api_tile=(8, 128, 128)),) \
        + plan.layers[1:]
    bad = dataclasses.replace(plan, layers=bad_layers)
    findings = kernel_contracts.verify_plan_kernels(bad, tenant="t")
    assert "kernel.block-divisibility" in _rules(findings)


def test_kernel_vmem_scratch_overflow():
    plan = _tpu_plan()
    wide = next((g for g in plan.fusion_groups if len(g.layers) >= 2), None)
    if wide is None:
        pytest.skip("no multi-layer fusion group in this plan")
    members = set(wide.layers)
    bad_layers = tuple(
        dataclasses.replace(l, n_in=30_000, n_out=30_000)
        if l.index in members else l for l in plan.layers)
    bad = dataclasses.replace(plan, layers=bad_layers)
    findings = kernel_contracts.verify_plan_kernels(bad, tenant="t")
    assert "kernel.vmem-scratch" in _rules(findings)


def test_kernel_contracts_clean_on_planner_output():
    findings = kernel_contracts.verify_plan_kernels(_tpu_plan(), tenant="t")
    assert not [f for f in findings if f.severity == "error"], findings


def test_kernel_library_self_check():
    findings = kernel_contracts.verify_kernel_library()
    assert not [f for f in findings if f.severity == "error"], findings


def test_group_vmem_accounting_matches_fused_mlp():
    # The checker's formula must mirror the kernel's padding exactly.
    b = kernel_contracts.group_vmem_bytes([16, 64, 32, 5], batch=8)
    pm, pads = 32, [128, 128, 128, 128]
    want = (pm * pads[0] * 4
            + sum(a * b2 + 2 * b2 * 4 for a, b2 in zip(pads, pads[1:]))
            + pm * pads[-1] * 4 + pm * max(pads[:-1]))
    assert b == want


# ---------------------------------------------------------------------------
# layer 3: jax-hazard lint
# ---------------------------------------------------------------------------

def test_lint_host_sync_and_suppression():
    src = """
class EdgeEngine:
    def infer(self, x):
        y = self._fwd(x)
        return np.asarray(y)
"""
    findings = lint_source(src, "m.py")
    assert _rules(findings) == {"lint.host-sync"}
    ok = src.replace("np.asarray(y)",
                     "np.asarray(y)  # repro: check-ok(lint.host-sync)")
    assert lint_source(ok, "m.py") == []
    # bare check-ok suppresses every rule on the line
    bare = src.replace("np.asarray(y)", "np.asarray(y)  # repro: check-ok")
    assert lint_source(bare, "m.py") == []


def test_lint_host_sync_follows_call_graph():
    src = """
class ContinuousBatcher:
    def step(self, wait_s=0.0):
        self._drain()
    def _drain(self):
        return self.logits.item()
    def unrelated(self):
        return np.asarray(self.logits)   # not reachable from a hot root
"""
    findings = lint_source(src, "m.py")
    assert len(findings) == 1 and findings[0].rule == "lint.host-sync"
    assert "_drain" in findings[0].detail


def test_lint_traced_if():
    src = """
import jax

@jax.jit
def f(x, n):
    if x > 0:
        return x
    return x + n
"""
    findings = lint_source(src, "m.py")
    assert _rules(findings) == {"lint.traced-if"}


def test_lint_traced_if_respects_static_argnames():
    src = """
import functools, jax

@functools.partial(jax.jit, static_argnames=("n",))
def f(x, n):
    if n > 0:
        return x
    return x * 2
"""
    assert lint_source(src, "m.py") == []


def test_lint_time_in_jit():
    src = """
import jax, time

@jax.jit
def f(x):
    t = time.perf_counter()
    r = np.random.uniform()
    return x * t * r
"""
    rules = [f.rule for f in lint_source(src, "m.py")]
    assert rules.count("lint.time-in-jit") == 2


def test_lint_unlocked_shared_state():
    src = """
import threading

class Store:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0
    def bump(self):
        self.n += 1
    def safe_bump(self):
        with self._lock:
            self.n += 1
"""
    findings = lint_source(src, "m.py")
    assert len(findings) == 1
    assert findings[0].rule == "lint.unlocked-shared-state"
    assert "bump" in findings[0].detail


def test_lint_dict_order_hash():
    src = """
import hashlib, json

def key(d):
    return hashlib.sha256(json.dumps(d).encode()).hexdigest()

def stable_key(d):
    return hashlib.sha256(
        json.dumps(d, sort_keys=True).encode()).hexdigest()
"""
    findings = lint_source(src, "m.py")
    assert len(findings) == 1 and findings[0].rule == "lint.dict-order-hash"


def test_lint_committed_tree_is_clean():
    from repro.check import lint as lint_mod
    src = REPO / "src" / "repro"
    findings = lint_mod.lint_paths(sorted(src.rglob("*.py")))
    assert findings == [], findings


# ---------------------------------------------------------------------------
# findings / report plumbing
# ---------------------------------------------------------------------------

def test_finding_severity_validated():
    with pytest.raises(ValueError):
        Finding(rule="r", severity="fatal", detail="d")


def test_report_exit_codes_and_json():
    rep = CheckReport()
    assert rep.exit_code == 0
    rep.extend([Finding(rule="r", severity="warning", detail="w")])
    assert rep.exit_code == 0
    rep.extend([Finding(rule="r2", severity="error", detail="e")])
    assert rep.exit_code == 1
    d = json.loads(rep.to_json())
    assert d["counts"] == {"error": 1, "warning": 1, "info": 0}
    assert {f["rule"] for f in d["findings"]} == {"r", "r2"}


# ---------------------------------------------------------------------------
# artifacts: loading, unknown keys, snapshots
# ---------------------------------------------------------------------------

def test_committed_artifacts_verify_clean():
    for p in sorted((REPO / "deployments").glob("*.json")):
        findings = check_artifact(p)
        assert not [f for f in findings if f.severity == "error"], (p,
                                                                    findings)


def test_check_artifact_undecodable(tmp_path):
    p = tmp_path / "broken.json"
    p.write_text('{"schema": 3, "tenants": [')
    with pytest.raises(ArtifactError):
        check_artifact(p)


def test_check_artifact_unsupported_schema(tmp_path):
    plan = _tpu_plan()
    d = plan.to_dict()
    d["schema"] = 99
    p = tmp_path / "future.json"
    p.write_text(json.dumps(d))
    with pytest.raises(ArtifactError):
        check_artifact(p)


def test_unknown_key_warning_and_info_finding(tmp_path):
    plan = _tpu_plan()
    d = plan.to_dict()
    d["serv"] = {"oops": 1}              # the typo the rule exists for
    p = tmp_path / "typo.json"
    p.write_text(json.dumps(d))
    with pytest.warns(RuntimeWarning, match="unknown top-level key"):
        fleet, load_findings = plan_rules.load_artifact(p)
    assert fleet.tenants[0].plan.network == plan.network
    infos = [f for f in load_findings if f.rule == "plan.unknown-key"]
    assert infos and infos[0].severity == "info"
    assert "serv" in infos[0].detail


def test_fleet_unknown_key_warns():
    fleet = FleetPlan.from_plan(_tpu_plan())
    d = fleet.to_dict()
    d["extra_section"] = []
    with pytest.warns(RuntimeWarning, match="extra_section"):
        FleetPlan.from_dict(d)


def test_snapshot_validation(tmp_path):
    good = tmp_path / "BENCH_ok.json"
    good.write_text(json.dumps(
        {"rows": [{"name": "a", "us_per_call": 1.5}]}))
    assert check_snapshot(good) == []
    bad_val = tmp_path / "BENCH_neg.json"
    bad_val.write_text(json.dumps(
        {"rows": [{"name": "a", "us_per_call": -2}]}))
    assert _rules(check_snapshot(bad_val)) == {"snapshot.row-value"}
    malformed = tmp_path / "BENCH_broken.json"
    malformed.write_text("{nope")
    with pytest.raises(ArtifactError):
        check_snapshot(malformed)
    shapeless = tmp_path / "BENCH_shape.json"
    shapeless.write_text(json.dumps({"rows": [{"name": "a"}]}))
    with pytest.raises(ArtifactError):
        check_snapshot(shapeless)


def test_check_tree_on_repo_is_clean():
    report = check_tree(REPO, kernels=False)
    assert report.errors() == [], report.errors()
    assert any(c.startswith("lint:") for c in report.checked)
    assert any(c.startswith("plan:") for c in report.checked)
    assert any(c.startswith("snapshot:") for c in report.checked)


# ---------------------------------------------------------------------------
# the deploy gate
# ---------------------------------------------------------------------------

def test_build_refuses_failing_plan():
    plan = _tpu_plan()
    bad_layers = (dataclasses.replace(plan.layers[0],
                                      api_tile=(33, 100, 100)),) \
        + plan.layers[1:]
    bad = FleetPlan.from_plan(dataclasses.replace(plan, layers=bad_layers))
    from repro.deploy import Deployment
    with pytest.raises(PlanVerificationError) as ei:
        Deployment.build(plan=bad)
    assert "plan.tile-legal" in str(ei.value)


def test_build_check_false_skips_gate():
    from repro.deploy import Deployment
    dep = Deployment.build("jet_tagger", machine_model=None,
                           stop_after="verify", check=False)
    res = dep.stage_results["verify"]
    assert res.skipped and dep.findings == []


def test_build_verify_stage_runs_clean():
    from repro.deploy import Deployment
    dep = Deployment.build("jet_tagger", machine_model=None,
                           stop_after="verify")
    res = dep.stage_results["verify"]
    assert not res.skipped and res.detail == "clean"
    assert "check: clean" in dep.summary()


def test_verify_stage_fault_injectable():
    from repro.deploy import Deployment
    from repro.faults import FaultSpec, InjectedFault
    spec = FaultSpec(kind="engine_exception", site="build", tenant="verify")
    with pytest.raises(InjectedFault, match="verify stage"):
        Deployment.build("jet_tagger", machine_model=None,
                         stop_after="verify", faults=[spec])


# ---------------------------------------------------------------------------
# CLI: exit codes, JSON shape (trend.py conventions)
# ---------------------------------------------------------------------------

def _run_cli(*args, cwd=None):
    env_src = str(REPO / "src")
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = env_src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro", "check", *args],
        capture_output=True, text=True, cwd=cwd or REPO, env=env)


def test_cli_corrupt_artifact_exits_2_one_line_stderr(tmp_path):
    p = tmp_path / "seeded_corrupt.json"
    p.write_text('{"schema": 3, "network": "x"')     # truncated JSON
    res = _run_cli(str(p))
    assert res.returncode == 2, res.stderr
    lines = [l for l in res.stderr.strip().splitlines() if l]
    assert len(lines) == 1 and lines[0].startswith("check: "), res.stderr
    assert "malformed" in lines[0]


def test_cli_json_artifact_check(tmp_path):
    art = sorted((REPO / "deployments").glob("*.json"))[0]
    res = _run_cli(str(art), "--json")
    assert res.returncode == 0, res.stderr
    d = json.loads(res.stdout)
    assert set(d) == {"version", "checked", "counts", "findings"}
    assert d["counts"]["error"] == 0


def test_cli_error_findings_exit_1(tmp_path):
    plan = _tpu_plan()
    bad_layers = (dataclasses.replace(plan.layers[0],
                                      api_tile=(33, 100, 100)),) \
        + plan.layers[1:]
    p = tmp_path / "bad_plan.json"
    p.write_text(dataclasses.replace(plan, layers=bad_layers).to_json())
    res = _run_cli(str(p))
    assert res.returncode == 1, (res.stdout, res.stderr)
    assert "plan.tile-legal" in res.stdout


# ---------------------------------------------------------------------------
# property: every plan the planner emits passes the checker
# ---------------------------------------------------------------------------

_EDGE_NETS = sorted(edge.EDGE_NETS)


def test_all_edge_configs_and_lm_smoke_check_clean():
    for name in _EDGE_NETS:
        for target in ("tpu", "aie"):
            fleet = FleetPlan.from_plan(
                plan_deployment(edge.edge_config(name), target=target))
            errs = [f for f in check_fleet(fleet)
                    if f.severity == "error"]
            assert errs == [], (name, target, errs)
    from repro import configs
    smoke = configs.get("qwen2_5_3b").smoke
    fleet = plan_fleet([smoke], target="tpu")
    errs = [f for f in check_fleet(fleet) if f.severity == "error"]
    assert errs == [], errs


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(_EDGE_NETS), st.sampled_from(["tpu", "aie"]),
       st.sampled_from([1, 2, 4, 8, 16]))
def test_property_planned_fleets_round_trip_clean(name, target, batch):
    """plan -> serialize -> load -> verify: zero error findings, for any
    edge net x target x batch the planner accepts."""
    plan = plan_deployment(edge.edge_config(name), target=target,
                           batch=batch)
    fleet = FleetPlan.from_plan(plan)
    reloaded = FleetPlan.from_json(fleet.to_json())
    errs = [f for f in check_fleet(reloaded) if f.severity == "error"]
    assert errs == [], (name, target, batch, errs)
