"""Distributed MoE equivalence: the three dispatch implementations (local /
gather_psum EP / SP+all-to-all 2D-EP) must agree numerically.

Runs in a SUBPROCESS with 8 forced host devices (the parent pytest process
has already locked jax to 1 device; forcing must precede any jax import)."""

import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import sharding as shlib
    from repro.models import moe
    from repro.models.config import ModelConfig, MoEConfig

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    base = ModelConfig(
        name="t", family="transformer", num_layers=1, d_model=32,
        num_heads=4, num_kv_heads=4, head_dim=8, d_ff=64, vocab_size=64,
        dtype="float32",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=48,
                      num_shared_experts=1, capacity_factor=8.0))

    p = moe.init_moe(jax.random.PRNGKey(0), base)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)

    # 1. local reference (no mesh).
    y_ref, _ = moe.moe_block(p, x, base)

    # 2. gather_psum EP on the mesh.
    with mesh, shlib.use_rules(mesh, shlib.train_rules(mesh)):
        y_ep, _ = jax.jit(lambda pp, xx: moe.moe_block(pp, xx, base))(p, x)

    # 3. SP + a2a (2D-EP kicks in: 8 experts over 8 devices).
    cfg_a2a = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, impl="a2a"))
    with mesh, shlib.use_rules(mesh, shlib.train_rules(mesh)):
        y_a2a, _ = jax.jit(lambda pp, xx: moe.moe_block(pp, xx, cfg_a2a))(p, x)

    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    print("MOE-DISTRIBUTED-OK")
""")


def test_moe_dispatch_impls_agree_on_8_devices():
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "MOE-DISTRIBUTED-OK" in res.stdout, (res.stdout[-2000:],
                                                res.stderr[-4000:])
