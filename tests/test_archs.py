"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs one forward + one train step + one decode step on
CPU, asserting shapes and finiteness (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import synth_batch
from repro.models import api, encdec
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib

ARCHS = configs.all_archs()


def _batch(cfg, b=2, s=16, step=0):
    return {k: jnp.asarray(v)
            for k, v in synth_batch(cfg, batch=b, seq=s, step=step).items()}


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_forward(name):
    arch = configs.get(name)
    cfg = arch.smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    out = api.forward(params, cfg, batch)
    assert out["logits"].shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(out["logits"][..., :cfg.vocab_size]
                             .astype(jnp.float32)).all())


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_train_step(name):
    arch = configs.get(name)
    cfg = arch.smoke
    opt = opt_lib.make("adamw", lr=1e-3)
    init_fn, step_fn = step_lib.build_train_step(
        cfg, opt, step_lib.TrainOptions(remat="block"))
    state = jax.jit(init_fn)(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    state, metrics = jax.jit(step_fn)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_decode_step(name):
    arch = configs.get(name)
    cfg = arch.smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    extras = {}
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(1),
                                   (2, cfg.encdec.encoder_len, cfg.d_model))
        state = encdec.whisper_init_cache(params, cfg, frames, 32)
    else:
        state = api.init_decode_state(cfg, 2, 32)
    if cfg.mrope_sections is not None:
        p1 = jnp.zeros((3, 2, 1), jnp.int32)
        extras["mrope_positions"] = p1
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, state = api.decode_step(params, cfg, tok, state, 0, extras=extras)
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.isfinite(
        jnp.asarray(logits[..., :cfg.vocab_size], jnp.float32)).all())


@pytest.mark.parametrize("name", ARCHS)
def test_exact_config_matches_assignment(name):
    """The FULL configs carry the exact published hyper-parameters."""
    spec = {
        "gemma2_27b": (46, 4608, 32, 16, 36864, 256000),
        "gemma2_9b": (42, 3584, 16, 8, 14336, 256000),
        "gemma2_2b": (26, 2304, 8, 4, 9216, 256000),
        "qwen2_5_3b": (36, 2048, 16, 2, 11008, 151936),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "deepseek_v3_671b": (61, 7168, 128, 128, 18432, 129280),
        "rwkv6_7b": (32, 4096, 64, 64, 14336, 65536),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen2_vl_72b": (80, 8192, 64, 8, 29568, 152064),
    }[name]
    cfg = configs.get(name).config
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, (got, spec)


def test_param_counts_close_to_published():
    published = {"gemma2_27b": 27.2e9, "gemma2_9b": 9.2e9,
                 "mixtral_8x22b": 141e9, "deepseek_v3_671b": 671e9,
                 "qwen2_vl_72b": 72.7e9}
    for name, want in published.items():
        got = configs.get(name).config.param_count()
        assert abs(got - want) / want < 0.08, (name, got, want)


def test_moe_dispatch_exact_vs_dense():
    """Scatter dispatch == dense per-expert loop at ample capacity."""
    from repro.models import moe
    cfg = configs.get("mixtral_8x22b").smoke
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x2d = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model),
                            jnp.float32)
    mo = cfg.moe
    ys, _ = moe._moe_math(p, x2d, mo, e_start=0, e_count=mo.num_experts,
                          capacity=64)
    logits = x2d @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    w, i = jax.lax.top_k(probs, mo.top_k)
    w = w / w.sum(-1, keepdims=True)
    dense = jnp.zeros_like(x2d)
    for e in range(mo.num_experts):
        h = jax.nn.silu(x2d @ p["w_gate"][e]) * (x2d @ p["w_up"][e])
        ye = h @ p["w_down"][e]
        m = ((i == e) * w).sum(-1)
        dense = dense + m[:, None] * ye
    np.testing.assert_allclose(np.asarray(ys), np.asarray(dense),
                               rtol=2e-2, atol=2e-2)


def test_mla_decode_matches_prefill_tail():
    """Absorbed-decode logits == naive full-forward logits at the last pos."""
    cfg = configs.get("deepseek_v3_671b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    full = api.forward(params, cfg, {"tokens": toks})["logits"]
    # Prefill first 7 tokens, then decode token 8.
    state = api.init_decode_state(cfg, 2, 16)
    _, state = api.decode_step(params, cfg, toks[:, :7], state, 0)
    logits, _ = api.decode_step(params, cfg, toks[:, 7:8], state, 7)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0, :cfg.vocab_size], np.float32),
        np.asarray(full[:, 7, :cfg.vocab_size], np.float32),
        rtol=3e-2, atol=3e-1)


def test_gemma_decode_matches_forward():
    """KV-cache decode == teacher-forced forward (local+global pattern)."""
    cfg = configs.get("gemma2_2b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                              cfg.vocab_size)
    full = api.forward(params, cfg, {"tokens": toks})["logits"]
    state = api.init_decode_state(cfg, 2, 16)
    logits = None
    for t in range(10):
        logits, state = api.decode_step(params, cfg, toks[:, t:t + 1],
                                        state, t)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0, :cfg.vocab_size], np.float32),
        np.asarray(full[:, 9, :cfg.vocab_size], np.float32),
        rtol=3e-2, atol=3e-1)


def test_rwkv_decode_matches_forward():
    cfg = configs.get("rwkv6_7b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0,
                              cfg.vocab_size)
    full = api.forward(params, cfg, {"tokens": toks})["logits"]
    state = api.init_decode_state(cfg, 2, 16)
    logits = None
    for t in range(9):
        logits, state = api.decode_step(params, cfg, toks[:, t:t + 1],
                                        state, t)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0, :cfg.vocab_size], np.float32),
        np.asarray(full[:, 8, :cfg.vocab_size], np.float32),
        rtol=3e-2, atol=3e-1)


def test_griffin_decode_matches_forward():
    cfg = configs.get("recurrentgemma_2b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0,
                              cfg.vocab_size)
    full = api.forward(params, cfg, {"tokens": toks})["logits"]
    state = api.init_decode_state(cfg, 2, 16)
    logits = None
    for t in range(9):
        logits, state = api.decode_step(params, cfg, toks[:, t:t + 1],
                                        state, t)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0, :cfg.vocab_size], np.float32),
        np.asarray(full[:, 8, :cfg.vocab_size], np.float32),
        rtol=3e-2, atol=3e-1)


def test_gemma_ring_local_decode_matches_forward():
    """Ring local-layer KV caches are lossless past the window (the §Perf
    decode memory lever)."""
    from repro.models import transformer
    cfg = configs.get("gemma2_2b").smoke          # window 16
    params = api.init(cfg, jax.random.PRNGKey(0))
    T = 28                                        # > window
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, T), 0,
                              cfg.vocab_size)
    full = api.forward(params, cfg, {"tokens": toks})["logits"]
    cache = transformer.lm_init_cache(cfg, 2, 32, ring_local=True)
    lg = None
    for t in range(T):
        lg, cache = transformer.lm_decode_step(params, cfg, toks[:, t:t + 1],
                                               cache, t)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0, :cfg.vocab_size], np.float32),
        np.asarray(full[:, T - 1, :cfg.vocab_size], np.float32),
        rtol=3e-2, atol=3e-1)
