"""Plan-artifact schema compatibility — the consolidated coverage.

One parametrized round-trip replaces the per-file ad-hoc compat tests that
used to live in test_plan.py / test_fleet.py / test_fusion.py: every
supported schema (v1, v2, v3) must load through ``DeploymentPlan.load``,
wrap through ``FleetPlan.load``, serve through the facade's
``Deployment.build(plan=...)`` path, and execute through the group-driven
int8 path unchanged.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import plan as plan_lib
from repro.deploy import Deployment
from repro.models import edge

SCHEMAS = (1, 2, 3)


def _downgrade(d: dict, schema: int) -> dict:
    """Re-create an artifact as an older PR would have written it."""
    d = dict(d)
    if schema <= 2:
        d.pop("fusion_groups", None)       # v3 addition
    if schema == 1:
        d.pop("kind", None)                # v2 addition
    d["schema"] = schema
    return d


@pytest.fixture(scope="module")
def v3_plan():
    return plan_lib.plan_deployment(edge.edge_config("vae"), target="tpu")


def _artifact(tmp_path, v3_plan, schema):
    p = tmp_path / f"v{schema}.json"
    p.write_text(json.dumps(_downgrade(v3_plan.to_dict(), schema)))
    return p


@pytest.mark.parametrize("schema", SCHEMAS)
def test_schema_roundtrips_everywhere(tmp_path, v3_plan, schema):
    art = _artifact(tmp_path, v3_plan, schema)

    # DeploymentPlan.load: normalized to the current schema, nothing lost.
    loaded = plan_lib.DeploymentPlan.load(art)
    assert loaded.schema == plan_lib.artifact.PLAN_SCHEMA_VERSION
    assert loaded.kind == "edge"                   # v1 default
    assert loaded.layers == v3_plan.layers
    assert loaded.groups() == v3_plan.groups()
    if schema == 3:
        assert loaded == v3_plan
        assert loaded.fusion_groups == v3_plan.fusion_groups
    else:
        # Pre-v3 artifacts derive groups from their per-layer fuse_group ids
        # with the legacy per-launch accounting (no invented fused-epilogue
        # discount for plans whose planner never priced one).
        for g in loaded.fusion_groups:
            assert g.est_latency_s == pytest.approx(
                sum(loaded.layer(i).est_latency_s * loaded.layer(i).repeat
                    for i in g.layers))
    # Reloaded artifacts re-serialize losslessly under the current schema.
    assert plan_lib.DeploymentPlan.from_json(loaded.to_json()) == loaded

    # FleetPlan.load: any single-net artifact wraps as a one-tenant fleet.
    fleet = plan_lib.FleetPlan.load(art)
    assert fleet.net_ids == ["vae"]
    t = fleet.tenants[0]
    assert t.plan.layers == v3_plan.layers
    assert t.latency_budget_s == pytest.approx(2.0 * v3_plan.est_latency_s)

    # The facade: serve-from-a-committed-plan is first-class for every
    # schema — the plan stage adopts the artifact instead of re-planning.
    dep = Deployment.build(plan=art, stop_after="plan")
    assert dep.plan.layers == v3_plan.layers
    assert dep.stage_results["plan"].cached
    assert "characterize" not in dep.stage_results \
        or dep.stage_results["characterize"].skipped


def test_v1_artifact_executes_through_group_path(tmp_path, v3_plan):
    """A v1 artifact drives the SAME fused execution as the v3 plan: the
    facade builds engines from it and the outputs agree bit-for-bit."""
    art = _artifact(tmp_path, v3_plan, 1)
    cfg = edge.edge_config("vae")
    dep_v1 = Deployment.build(plan=art, machine_model=None)
    dep_v3 = Deployment.build(plan=v3_plan, machine_model=None)
    x = jax.random.normal(jax.random.PRNGKey(7), (cfg.batch, cfg.dims[0]))
    np.testing.assert_allclose(
        np.asarray(dep_v1.engines["vae"].infer(x)),
        np.asarray(dep_v3.engines["vae"].infer(x)),
        rtol=1e-5, atol=1e-5)


def test_fleet_artifact_roundtrips_through_facade(tmp_path):
    """A committed FleetPlan JSON serves as-is through the facade."""
    cfgs = [edge.edge_config(n) for n in ("jet_tagger", "tau_select")]
    fleet = plan_lib.plan_fleet(cfgs, target="tpu",
                                cache=plan_lib.PlanCache())
    p = fleet.save(tmp_path / "fleet.json")
    dep = Deployment.build(plan=p, stop_after="plan")
    assert dep.fleet.net_ids == ["jet_tagger", "tau_select"]
    assert dep.fleet == fleet


def test_unknown_schema_rejected():
    with pytest.raises(ValueError):
        plan_lib.DeploymentPlan.from_dict({"schema": 99})
    with pytest.raises(ValueError):
        plan_lib.FleetPlan.from_dict({"schema": 99, "tenants": []})


def test_stale_plan_key_mismatch_is_loadable(tmp_path, v3_plan):
    """Loading never validates the key (plans are data); staleness is the
    CACHE's concern — a key mixed over PLANNER_VERSION misses on change."""
    d = v3_plan.to_dict()
    d["key"] = "0" * 64
    p = tmp_path / "stale.json"
    p.write_text(json.dumps(d))
    assert plan_lib.DeploymentPlan.load(p).key == "0" * 64
