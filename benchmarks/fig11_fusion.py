"""Fig. 11 (ours): DR7' fusion EXECUTED — per-layer launches vs the fused
megakernel, across all five edge nets.

The planner has always charged for un-fused launch boundaries (DR7'); since
``kernels/fused_mlp`` the executor also ELIMINATES them: one Pallas launch
per fusion group, epilogue requantize between layers, activations in VMEM
scratch.  This benchmark measures both executions of the SAME plan:

  * ``fig11/<net>/per-layer`` — ``edge_forward_q8(..., fused=False)``: one
    ``gemm_int8`` launch per layer + host-level quantize ops (the pre-fusion
    pipeline);
  * ``fig11/<net>/fused`` — the plan's fusion groups through the megakernel,
    judged against the planned latency under the fitted ``MachineModel``
    (the ``fused_chain`` sweep prices the epilogue, ``gemm_int8`` the launch
    overhead — the fuse-vs-split decision is fitted, not hand-tuned);
  * ``fig11/<net>/planned-model`` — the deterministic stock-model plan
    (group structure + planned latency), the trend-gated row.

Plans come through the facade (``Deployment.build(..., stop_after="plan")``);
the A/B execution stays on ``edge_forward_q8`` directly because the per-layer
arm is exactly the path the facade no longer takes.  The
re-characterize-on-miss retry loop is :func:`benchmarks.common.
characterize_retry` (shared with fig10).

Acceptance (asserted): the fused path wins on >= 3 of the 5 nets, and
planned-vs-measured for the fused path stays within 2x under the fitted
model.

Net selection: ``REPRO_FIG11_NETS=jet_tagger,tau_select`` (default: all).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import (characterize_retry, emit, judge_row, strict,
                               time_call)
from repro.deploy import Deployment
from repro.models import edge
from repro.plan import PlanCache

_ITERS = 10
_MAX_ATTEMPTS = 3


def _measure(names, mm):
    """(emit rows, wins, 2x-failures) for one characterization attempt."""
    rows, failures = [], []
    wins = 0
    for name in names:
        cfg = edge.edge_config(name)
        plan = Deployment.build(cfg, machine_model=mm, stop_after="plan",
                                cache=PlanCache()).plan
        params = edge.init_edge(jax.random.PRNGKey(0), cfg)
        calib = jax.random.normal(jax.random.PRNGKey(9),
                                  (cfg.batch, cfg.dims[0]), jnp.float32)
        qp = edge.quantize_edge(params, calib_x=calib, act=cfg.act)
        x = jnp.ones((cfg.batch, cfg.dims[0]), jnp.float32)
        f_layer = jax.jit(lambda xx, p=qp, c=cfg, pl=plan:
                          edge.edge_forward_q8(p, c, xx, plan=pl,
                                               fused=False))
        f_fused = jax.jit(lambda xx, p=qp, c=cfg, pl=plan:
                          edge.edge_forward_q8(p, c, xx, plan=pl))
        t_layer = time_call(f_layer, x, iters=_ITERS, warmup=2)
        t_fused = time_call(f_fused, x, iters=_ITERS, warmup=2)
        speedup = t_layer / t_fused if t_fused > 0 else float("inf")
        won = t_fused < t_layer
        wins += won
        groups = plan.groups()
        rows.append((f"fig11/{name}/per-layer", t_layer * 1e6,
                     f"launches={len(plan.layers)};src=measured"))
        row, failure = judge_row(
            f"fig11/{name}/fused", plan.est_latency_s, t_fused,
            extra=f"speedup={speedup:.2f}x;won={won};"
                  f"groups={len(groups)};")
        rows.append(row)
        if failure:
            failures.append(failure)
    return rows, wins, failures


def run():
    print("# fig11: fused-group execution — name,us_per_call,derived")
    names = tuple(n.strip() for n in os.environ.get(
        "REPRO_FIG11_NETS", ",".join(edge.EDGE_NETS)).split(",")
        if n.strip())

    # Deterministic rows first: the stock-model plan's fusion decision (what
    # the trend gate watches — any change in group structure or planned cost
    # is a planner change, not host jitter).
    for name in names:
        plan = Deployment.build(name, machine_model=None,
                                stop_after="plan").plan
        groups = plan.groups()
        emit(f"fig11/{name}/planned-model", plan.est_latency_s * 1e6,
             f"groups={len(groups)};layers={len(plan.layers)};"
             f"whole_net={len(groups) == 1};src=model")

    min_wins = min(3, len(names))
    mm, (rows, wins, failures), attempts = characterize_retry(
        lambda m: _measure(names, m),
        ok=lambda res: res[1] >= min_wins and not res[2],
        max_attempts=_MAX_ATTEMPTS)

    emit("fig11/model-version", 0.0,
         f"version={mm.version[:16]};attempts={attempts};src=measured")
    for row in rows:
        emit(*row)
    emit("fig11/fused-wins", 0.0,
         f"wins={wins}/{len(names)};src=measured")
    if not strict():
        return
    assert wins >= min_wins, (
        f"fused-group execution won on only {wins}/{len(names)} nets "
        f"(need >= {min_wins}) after {attempts} attempt(s)")
    assert not failures, (
        "fused planned-vs-measured missed the 2x band even after "
        "re-characterization: " + "; ".join(failures))


if __name__ == "__main__":
    run()
