"""Paper Fig. 2: HLS4ML performance scalability vs AIE reference.

Synthetic dense workloads of growing size; the PL interval stays flat while
resources last (rf=1), then climbs as the reuse factor is forced up —
Latency strategy hits the wall first, Resource scales further; the naive
1-layer-per-tile AIE mapping stays flat in this regime (paper Section III-A).
"""

from __future__ import annotations

import math

from benchmarks.common import emit
from repro import hw as hwlib
from repro.core import tiling


def min_feasible_rf(layers: list, pl: hwlib.PlFabric, strategy: str) -> int | None:
    """Smallest common rf whose total resource vector fits the device."""
    for rf_target in sorted({rf for (i, o) in layers
                             for rf in pl.legal_reuse_factors(i, o)}):
        total = {"dsp": 0, "lut": 0, "bram_bits": 0}
        ok = True
        for n_in, n_out in layers:
            legal = [r for r in pl.legal_reuse_factors(n_in, n_out)
                     if r >= rf_target]
            rf = legal[0] if legal else pl.legal_reuse_factors(n_in, n_out)[-1]
            res = pl.resources(n_in, n_out, rf, strategy=strategy)
            for k in total:
                total[k] += res[k]
        if pl.fits(total):
            return rf_target
    return None


def run():
    pl = hwlib.PL_FABRIC
    print("# fig2: workload scaling — name,us_per_call,derived")
    for width in (32, 64, 96, 128, 192, 256, 320):
        layers = [(width, width)] * 8
        macs = sum(i * o for i, o in layers)
        for strategy in ("latency", "resource"):
            rf = min_feasible_rf(layers, pl, strategy)
            if rf is None:
                emit(f"fig2/pl-{strategy}/w{width}", float("nan"),
                     f"macs={macs};status=UNROUTABLE;src=model")
                continue
            interval = pl.interval_s(rf)
            emit(f"fig2/pl-{strategy}/w{width}", interval * 1e6,
                 f"macs={macs};rf={rf};src=model")
        # AIE naive: one layer per tile; interval = slowest tile.
        t_aie = max(tiling.aie_tile_interval(8, i, o) for i, o in layers)
        emit(f"fig2/aie-naive/w{width}", t_aie * 1e6,
             f"macs={macs};tiles={len(layers)};src=model")


if __name__ == "__main__":
    run()
