"""Paper Fig. 3 / Alg. 1: reuse-factor sweeps + the LARE crossover point per
dense-layer shape, plus the TPU core-equivalence analogue."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import lare


def run():
    print("# fig3: LARE — name,us_per_call,derived")
    shapes = [(32, 32), (64, 64), (64, 128), (128, 128), (128, 64),
              (192, 192), (256, 128)]
    for n_in, n_out in shapes:
        r = lare.lare(n_in, n_out)
        # a few points of the PL trade-off curve (rf, interval, resource)
        pts = [p for p in r.pl_curve[:: max(1, len(r.pl_curve) // 6)]]
        curve = "|".join(f"rf{p.rf}:r{p.resource:.0f}" for p in pts)
        emit(f"fig3/lare/{n_in}x{n_out}", r.aie_interval_s * 1e6,
             f"lare={r.lare:.1f};rf_eq={r.rf_eq:.1f};"
             f"eff={r.aie_efficiency:.2f};curve={curve};src=model")
    # TPU analogue: core-equivalence for LM-scale layers.
    for n_in, n_out in [(2048, 11008), (4096, 14336), (4608, 36864)]:
        rt = lare.lare_tpu(n_in, n_out)
        emit(f"fig3/lare-tpu/{n_in}x{n_out}", rt.tiled_latency_s * 1e6,
             f"core_eq={rt.core_eq:.2f};src=tpu-model")


if __name__ == "__main__":
    run()
