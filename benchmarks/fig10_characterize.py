"""Fig. 10 (ours): the characterization harness, end to end.

Runs the quick microbenchmark sweep on THIS host (``repro.characterize``),
reports every fitted cost term with its relative-RMS residual
(fitted-vs-measured), then judges the fitted ``MachineModel`` the same way
fig8/fig9 judge the hand-tuned-then-rescaled one:

  * fig8-style: each edge net deployed under the fitted model through the
    facade (``Deployment.build(machine_model=mm)``) and EXECUTED through
    its planned Pallas blocks — planned-vs-measured within 2x is asserted
    (the acceptance bar the paper's characterization methodology exists to
    meet);
  * fig9-style: a two-net fleet deployed under the fitted model, served
    through the multi-tenant router, per-tenant planned-vs-measured p50.

The re-characterize-on-miss retry loop lives in
:func:`benchmarks.common.characterize_retry` (shared with fig11): a load
shift between sweep and measurement is drift, not model error.

Net selection: ``REPRO_FIG10_NETS=jet_tagger,tau_select`` (default: the two
tiniest nets, CI-sized).
"""

from __future__ import annotations

import os

from benchmarks.common import characterize_retry, emit, judge_row, strict
from repro.deploy import Deployment
from repro.plan import PlanCache

DEFAULT_NETS = ("jet_tagger", "tau_select")
_ITERS = 10
_MAX_ATTEMPTS = 3      # re-characterize under current load on a missed band


def _acceptance_rows(names, mm):
    """Deploy + execute every net (solo and as a fleet) under ``mm``.
    Returns (emit rows, failure messages); nothing is emitted here so a
    noisy first attempt can be discarded wholesale."""
    rows, failures = [], []

    def judge(row_name, planned, measured, extra=""):
        row, failure = judge_row(row_name, planned, measured, extra=extra)
        rows.append(row)
        if failure:
            failures.append(failure)

    # fig8-style: per-net planned-vs-measured under the fitted model.
    for name in names:
        dep = Deployment.build(name, machine_model=mm, cache=PlanCache())
        for r in dep.bench(iters=5, warmup=1):
            judge(f"fig10/{name}/planned-vs-measured", r.planned_s,
                  r.measured_s, extra=f"model={mm.version[:12]};")

    # fig9-style: the fitted fleet through the router.
    dep = Deployment.build(list(names), machine_model=mm, cache=PlanCache())
    router = dep.serve()
    inputs = router.warmup()
    rep = router.drive(inputs, iters=_ITERS)
    for t in dep.fleet.tenants:
        judge(f"fig10/{t.net_id}/fleet-planned-vs-measured",
              t.plan.est_latency_s, rep[t.net_id]["p50_s"])
    return rows, failures


def run():
    print("# fig10: characterization — name,us_per_call,derived")
    names = tuple(n.strip() for n in os.environ.get(
        "REPRO_FIG10_NETS", ",".join(DEFAULT_NETS)).split(",") if n.strip())

    # Each attempt re-fits the model under the CURRENT load, so a load
    # shift between sweep and measurement reads as transient drift, not
    # a model failure.
    mm, (rows, failures), attempts = characterize_retry(
        lambda m: _acceptance_rows(names, m),
        ok=lambda res: not res[1], max_attempts=_MAX_ATTEMPTS)

    emit("fig10/model-version", 0.0,
         f"version={mm.version[:16]};sweep=quick;attempts={attempts};"
         f"src=measured")
    for term, f in mm.fits.items():
        # Value column: the term's most latency-like constant, in us.
        us = (f.constants.get("kernel_overhead_s")
              or f.constants.get("dispatch_s")
              or f.constants.get("band2_penalty_per_layer", 0.0)) * 1e6
        consts = ";".join(f"{k}={v:.4g}" for k, v in f.constants.items())
        emit(f"fig10/fit/{term}", us,
             f"residual_rel_rms={f.residual_rel_rms:.3f};{consts};"
             f"src={f.source}")
    for row in rows:
        emit(*row)
    assert not failures or not strict(), (
        "fitted-model plans missed the 2x acceptance band even after "
        "re-characterization: " + "; ".join(failures))


if __name__ == "__main__":
    run()
