"""Fig. 10 (ours): the characterization harness, end to end.

Runs the quick microbenchmark sweep on THIS host (``repro.characterize``),
reports every fitted cost term with its relative-RMS residual
(fitted-vs-measured), then judges the fitted ``MachineModel`` the same way
fig8/fig9 judge the hand-tuned-then-rescaled one:

  * fig8-style: each edge net planned under the fitted model and EXECUTED
    through its planned Pallas blocks — planned-vs-measured within 2x is
    asserted (the acceptance bar the paper's characterization methodology
    exists to meet);
  * fig9-style: a two-net fleet planned under the fitted model, served
    through the multi-tenant router, per-tenant planned-vs-measured p50.

On a shared host the load can shift between the sweep and the measurement,
which is drift, not model error — so a failed acceptance pass triggers a
re-characterization under the current load (up to ``_MAX_ATTEMPTS`` total
passes) before the assert fires: exactly the drift-replan story, applied to
the benchmark itself.

Net selection: ``REPRO_FIG10_NETS=jet_tagger,tau_select`` (default: the two
tiniest nets, CI-sized).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit, strict, time_call
from repro.characterize import characterize
from repro.models import edge
from repro.plan import PlanCache, plan_deployment, plan_fleet

DEFAULT_NETS = ("jet_tagger", "tau_select")
_ITERS = 10
_MAX_ATTEMPTS = 3      # re-characterize under current load on a missed band


def _acceptance_rows(names, mm):
    """Plan + execute every net (solo and as a fleet) under ``mm``.
    Returns (emit rows, failure messages); nothing is emitted here so a
    noisy first attempt can be discarded wholesale."""
    from repro.serve import Router

    rows, failures = [], []

    def judge(row_name, planned, measured, extra=""):
        ratio = planned / measured if measured > 0 else float("inf")
        within = 0.5 <= ratio <= 2.0
        rows.append((row_name, measured * 1e6,
                     f"planned_us={planned * 1e6:.1f};ratio={ratio:.2f};"
                     f"within_2x={within};{extra}src=measured"))
        if not within:
            failures.append(f"{row_name}: planned={planned * 1e6:.1f}us "
                            f"measured={measured * 1e6:.1f}us "
                            f"(ratio {ratio:.2f})")

    # fig8-style: per-net planned-vs-measured under the fitted model.
    for name in names:
        cfg = edge.edge_config(name)
        plan = plan_deployment(cfg, target="tpu", machine_model=mm)
        params = edge.init_edge(jax.random.PRNGKey(0), cfg)
        qp = edge.quantize_edge(params)
        x = jnp.ones((cfg.batch, cfg.dims[0]), jnp.float32)
        f = jax.jit(lambda xx, p=qp, c=cfg, pl=plan:
                    edge.edge_forward_q8(p, c, xx, plan=pl))
        t_meas = time_call(f, x, iters=5, warmup=1)
        judge(f"fig10/{name}/planned-vs-measured", plan.est_latency_s,
              t_meas, extra=f"model={mm.version[:12]};")

    # fig9-style: the fitted fleet through the router.
    cfgs = [edge.edge_config(n) for n in names]
    cache = PlanCache()
    fleet = plan_fleet(cfgs, target="tpu", machine_model=mm, cache=cache)
    router = Router.from_fleet(fleet, cache=cache)
    inputs = {t.net_id: jnp.ones((cfg.batch, cfg.dims[0]), jnp.float32)
              for cfg, t in zip(cfgs, fleet.tenants)}
    for nid, x in inputs.items():          # jit warmup per tenant
        router.infer(nid, x)
    router.reset_metrics()
    for _ in range(_ITERS):
        for nid, x in inputs.items():
            router.infer(nid, x)
    rep = router.report()
    for t in fleet.tenants:
        judge(f"fig10/{t.net_id}/fleet-planned-vs-measured",
              t.plan.est_latency_s, rep[t.net_id]["p50_s"])
    return rows, failures


def run():
    print("# fig10: characterization — name,us_per_call,derived")
    names = tuple(n.strip() for n in os.environ.get(
        "REPRO_FIG10_NETS", ",".join(DEFAULT_NETS)).split(",") if n.strip())

    attempts = 0
    while True:
        # Each attempt re-fits the model under the CURRENT load, so a load
        # shift between sweep and measurement reads as transient drift, not
        # a model failure.
        mm = characterize(sweep="quick")
        rows, failures = _acceptance_rows(names, mm)
        attempts += 1
        if not failures or attempts >= _MAX_ATTEMPTS:
            break

    emit("fig10/model-version", 0.0,
         f"version={mm.version[:16]};sweep=quick;attempts={attempts};"
         f"src=measured")
    for term, f in mm.fits.items():
        # Value column: the term's most latency-like constant, in us.
        us = (f.constants.get("kernel_overhead_s")
              or f.constants.get("dispatch_s")
              or f.constants.get("band2_penalty_per_layer", 0.0)) * 1e6
        consts = ";".join(f"{k}={v:.4g}" for k, v in f.constants.items())
        emit(f"fig10/fit/{term}", us,
             f"residual_rel_rms={f.residual_rel_rms:.3f};{consts};"
             f"src={f.source}")
    for row in rows:
        emit(*row)
    assert not failures or not strict(), (
        "fitted-model plans missed the 2x acceptance band even after "
        "re-characterization: " + "; ".join(failures))


if __name__ == "__main__":
    run()
