"""Shared benchmark utilities.  Output convention (scaffold requirement):
every benchmark prints ``name,us_per_call,derived`` CSV rows.

Numbers are labeled by source:
  * ``model``     — calibrated AIE/PL analytical machine model (hw.py),
                    reproducing the paper's published curves;
  * ``measured``  — wall-clock on THIS host (CPU; jitted XLA or interpret-
                    mode Pallas), for trend sanity only;
  * ``tpu-model`` — TPU v5e roofline estimate from the tiling planner.
"""

from __future__ import annotations

import json
import os
import time

import jax

# Rows emitted since the last reset — the runner snapshots these into
# machine-readable BENCH_<name>.json files so the perf trajectory is
# trackable across PRs without scraping stdout.
_RECORDS: list[dict] = []


def reset_records() -> None:
    _RECORDS.clear()


def get_records() -> list[dict]:
    return list(_RECORDS)


def write_records(path: str, *, meta: dict | None = None) -> None:
    payload = {"meta": meta or {}, "rows": get_records()}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def time_call(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-time per call in seconds (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def strict() -> bool:
    """Whether measured acceptance asserts should fire.

    ``REPRO_BENCH_STRICT=0`` downgrades them to reported rows — used by the
    CI trend-gate job, which only judges DETERMINISTIC model-sourced rows
    and must not fail on host jitter in the measured ones (the bench-smoke
    job runs the same benchmarks strict)."""
    return os.environ.get("REPRO_BENCH_STRICT", "1") != "0"


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")
    _RECORDS.append({"name": name, "us_per_call": round(us_per_call, 3),
                     "derived": derived})
