"""Shared benchmark utilities.  Output convention (scaffold requirement):
every benchmark prints ``name,us_per_call,derived`` CSV rows.

Numbers are labeled by source:
  * ``model``     — calibrated AIE/PL analytical machine model (hw.py),
                    reproducing the paper's published curves;
  * ``measured``  — wall-clock on THIS host (CPU; jitted XLA or interpret-
                    mode Pallas), for trend sanity only;
  * ``tpu-model`` — TPU v5e roofline estimate from the tiling planner.
"""

from __future__ import annotations

import json
import os
import time

import jax

# Rows emitted since the last reset — the runner snapshots these into
# machine-readable BENCH_<name>.json files so the perf trajectory is
# trackable across PRs without scraping stdout.
_RECORDS: list[dict] = []


def reset_records() -> None:
    _RECORDS.clear()


def get_records() -> list[dict]:
    return list(_RECORDS)


def write_records(path: str, *, meta: dict | None = None) -> None:
    payload = {"meta": meta or {}, "rows": get_records()}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def time_call(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-time per call in seconds (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def strict() -> bool:
    """Whether measured acceptance asserts should fire.

    ``REPRO_BENCH_STRICT=0`` downgrades them to reported rows — used by the
    CI trend-gate job, which only judges DETERMINISTIC model-sourced rows
    and must not fail on host jitter in the measured ones (the bench-smoke
    job runs the same benchmarks strict)."""
    return os.environ.get("REPRO_BENCH_STRICT", "1") != "0"


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")
    _RECORDS.append({"name": name, "us_per_call": round(us_per_call, 3),
                     "derived": derived})


# ---------------------------------------------------------------------------
# Planned-vs-measured judging + the re-characterize retry loop (shared by
# fig8/fig9/fig10/fig11 — one implementation, four thin callers)
# ---------------------------------------------------------------------------

def judge_row(name: str, planned_s: float, measured_s: float,
              extra: str = ""):
    """One planned-vs-measured judgement in the repo-wide 2x acceptance
    band.  Returns ``(emit_args, failure)`` where ``emit_args`` is the
    ``(name, us_per_call, derived)`` row and ``failure`` is a message when
    the ratio left ``[0.5, 2.0]`` (None otherwise)."""
    ratio = planned_s / measured_s if measured_s > 0 else float("inf")
    within = 0.5 <= ratio <= 2.0
    row = (name, measured_s * 1e6,
           f"planned_us={planned_s * 1e6:.1f};ratio={ratio:.2f};"
           f"within_2x={within};{extra}src=measured")
    failure = None if within else (
        f"{name}: planned={planned_s * 1e6:.1f}us "
        f"measured={measured_s * 1e6:.1f}us (ratio {ratio:.2f})")
    return row, failure


def characterize_retry(measure, ok, *, max_attempts: int = 3,
                       sweep: str = "quick"):
    """Fit a ``MachineModel`` and measure under it, re-characterizing under
    the CURRENT load when the acceptance predicate fails (up to
    ``max_attempts`` total passes) — the drift-replan story applied to the
    benchmarks themselves: a load shift between sweep and measurement reads
    as transient drift, not a model failure.

    ``measure(mm)`` returns an arbitrary result; ``ok(result)`` decides
    whether it passed.  Returns ``(mm, result, attempts)`` — the LAST
    attempt's model and result, so a noisy early pass is discarded
    wholesale."""
    from repro.characterize import characterize
    attempts = 0
    while True:
        mm = characterize(sweep=sweep)
        result = measure(mm)
        attempts += 1
        if ok(result) or attempts >= max_attempts:
            return mm, result, attempts
