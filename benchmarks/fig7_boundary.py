"""Paper Fig. 7 / DR7: latency penalty per PL<->AIE boundary crossing.

16-layer dense model, 8 layers per domain, crossings swept 2..14 stride 2.
The AIE-side model reproduces the ~3.9%/crossing slope; the TPU analogue
MEASURES the kernel-boundary cost on this host by running the same edge net
as one fused jit vs per-layer jits (each extra dispatch + HBM round trip is
the DR7' crossing)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import boundary, tiling
from repro.models import edge


def run():
    print("# fig7: boundary crossing — name,us_per_call,derived")
    layers, feat, batch = 16, 192, 8
    t_layer = tiling.aie_tile_latency(batch, feat, feat)
    base = layers * t_layer + 2 * boundary.crossing_cost_aie(
        batch * feat, layers * t_layer)
    act_bytes = batch * feat
    xs, ys = [], []
    for crossings in range(2, 15, 2):
        t = layers * t_layer + crossings * boundary.crossing_cost_aie(
            act_bytes, layers * t_layer)
        xs.append(crossings)
        ys.append(t)
        emit(f"fig7/aie/crossings{crossings}", t * 1e6,
             f"rel={(t/base - 1)*100:.1f}%;src=model")
    slope = np.polyfit(xs, ys, 1)[0] / (layers * t_layer) * 100
    emit("fig7/aie/slope", 0.0, f"pct_per_crossing={slope:.2f};src=model")

    # TPU DR7' measured: fused single-jit chain vs per-layer jit dispatches.
    cfg = edge.EdgeConfig("fig7", tuple([feat] * 9))
    params = edge.init_edge(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((batch, feat), jnp.float32)

    fused = jax.jit(lambda xx: edge.edge_forward(params, cfg, xx))
    layer_fns = [jax.jit(lambda xx, p=p: jnp.maximum(xx @ p["w"] + p["b"], 0))
                 for p in params]

    def split(xx):
        for f in layer_fns:
            xx = f(xx)
        return xx

    t_fused = time_call(fused, x)
    t_split = time_call(split, x)
    n_cross = len(params) - 1
    per_cross = max(t_split - t_fused, 0.0) / max(n_cross, 1)
    emit("fig7/tpu-measured/fused", t_fused * 1e6, "src=measured")
    emit("fig7/tpu-measured/split", t_split * 1e6,
         f"crossings={n_cross};us_per_crossing={per_cross*1e6:.2f};src=measured")
    emit("fig7/tpu-model/crossing", boundary.crossing_cost_tpu(act_bytes * 4)
         * 1e6, "src=tpu-model")


if __name__ == "__main__":
    run()
