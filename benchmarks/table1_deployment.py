"""Paper Table I: full NN deployment — VAE / Qubit / Autoencoder.

Per workload:
  * paper's published numbers (MACs, min HLS4ML rf, PL/naive-AIE/optimized MHz),
  * our PL model at its min feasible rf,
  * our AIE naive mapping (1 layer / 1 tile),
  * our AIE optimized mapping (Section-IV design rules via the spatial planner),
  * the TPU extreme-edge path: int8 fused kernels, measured on CPU interpret
    (wall time, trend only) + v5e model latency from the tiling planner.

Acceptance: optimized AIE exceeds the 40 MHz LHC trigger rate; PL does not.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro import hw as hwlib
from repro.core import tiling
from repro.models import edge

PAPER = {  # MACs, min rf, PL MHz, naive AIE MHz, optimized MHz (Table I)
    "vae": (34_800, 8, 20.8, 22.7, 97.9),
    "qubit": (82_900, 16, 12.5, 14.4, 58.9),
    "autoencoder": (116_700, 32, 8.4, 15.9, 58.8),
}


def run():
    print("# table1: full NN deployment — name,us_per_call,derived")
    pl = hwlib.PL_FABRIC
    tpu = hwlib.TPU_V5E
    for name, (macs_pub, rf_pub, pl_pub, naive_pub, opt_pub) in PAPER.items():
        cfg = edge.edge_config(name)
        emit(f"table1/{name}/macs", 0.0,
             f"ours={cfg.macs};paper={macs_pub};"
             f"delta={abs(cfg.macs-macs_pub)/macs_pub*100:.1f}%")
        # PL at the paper's min rf (MHz = batch/interval, batch streams
        # through the rf-cycle initiation interval per sample).
        t_pl = pl.interval_s(rf_pub) * cfg.batch / cfg.batch   # per-sample II
        mhz_pl = 1 / pl.interval_s(rf_pub) / 1e6
        emit(f"table1/{name}/pl", t_pl * 1e6,
             f"mhz={mhz_pl:.1f};paper_mhz={pl_pub};rf={rf_pub};src=model")
        # AIE naive: 1 layer per tile; steady-state interval = slowest layer.
        t_naive = max(tiling.aie_tile_interval(cfg.batch, i, o)
                      for i, o in cfg.layer_shapes)
        mhz_naive = cfg.batch / t_naive / 1e6        # inferences/s (batch=8)
        emit(f"table1/{name}/aie-naive", t_naive * 1e6,
             f"mhz={mhz_naive:.1f};paper_mhz={naive_pub};src=model")
        # AIE optimized with the design rules.
        t_opt = tiling.aie_optimized_interval(cfg.layer_shapes, cfg.batch)
        mhz_opt = cfg.batch / t_opt / 1e6
        meets = mhz_opt >= 40.0
        emit(f"table1/{name}/aie-optimized", t_opt * 1e6,
             f"mhz={mhz_opt:.1f};paper_mhz={opt_pub};"
             f"meets_40mhz={meets};speedup_vs_naive={t_naive/t_opt:.2f}x;src=model")
        # TPU edge path: per-layer int8 fused kernels, weights-stationary.
        t_tpu = sum(tpu.matmul_time(cfg.batch, i, o, itemsize=1)
                    + tpu.kernel_overhead_s for i, o in cfg.layer_shapes)
        emit(f"table1/{name}/tpu-v5e-per-layer", t_tpu * 1e6,
             f"mhz={cfg.batch/t_tpu/1e6:.2f};src=tpu-model")
        # Whole-net single-kernel fusion (DR7'-minimal: ONE dispatch).
        t_fused = tpu.kernel_overhead_s + sum(
            tpu.matmul_time(cfg.batch, i, o, itemsize=1)
            for i, o in cfg.layer_shapes)
        emit(f"table1/{name}/tpu-v5e-fused", t_fused * 1e6,
             f"mhz={cfg.batch/t_fused/1e6:.2f};src=tpu-model")
        # Measured interpret-mode int8 path (correctness witness; CPU wall
        # time is NOT a TPU latency claim).
        params = edge.init_edge(jax.random.PRNGKey(0), cfg)
        qp = edge.quantize_edge(params)
        x = jnp.ones((cfg.batch, cfg.dims[0]), jnp.float32)
        f = jax.jit(lambda xx: edge.edge_forward_q8(qp, cfg, xx))
        t_meas = time_call(f, x, iters=5, warmup=1)
        emit(f"table1/{name}/int8-interpret", t_meas * 1e6, "src=measured")


if __name__ == "__main__":
    run()
