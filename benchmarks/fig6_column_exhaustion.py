"""Paper Fig. 6 / DR6: the cost of exhausting AIE columns.  8-layer model,
(8,192,192) per layer, P_K*P_N = 12 tiles/layer, sweeping asymmetry; layers
spill into a second band once 8 * P_K exceeds the 31-column limit."""

from __future__ import annotations

import math

from benchmarks.common import emit
from repro import hw as hwlib
from repro.core import tiling


def run():
    print("# fig6: column exhaustion — name,us_per_call,derived")
    aie = hwlib.AIE_ML
    layers, feat = 8, 192
    for p_k, p_n in ((2, 6), (3, 4), (4, 3), (6, 2)):
        cols_needed = layers * p_k
        in_band2 = 0
        if cols_needed > aie.usable_cols:
            fit = aie.usable_cols // p_k
            in_band2 = layers - fit
        t = tiling.aie_spatial_latency(8, feat, feat, p_k, p_n,
                                       layers_in_band_2=in_band2)
        emit(f"fig6/pk{p_k}-pn{p_n}", t * 1e6,
             f"cols={cols_needed};band2_layers={in_band2};src=model")

    # TPU DR6' analogue: K-sharding past one mesh axis wraps onto the slow
    # axis — the planner's band penalty.
    for p_k in (8, 16, 32):
        sp = tiling.collective_time(8 * 1152 * 4, p_k,
                                    axis_bw=hwlib.TPU_V5E.ici_bw * 2)
        bands = math.ceil(p_k / 16)
        t = sp * (1.0 + 0.5 * (bands - 1))
        emit(f"fig6/tpu-kshard{p_k}", t * 1e6,
             f"bands={bands};src=tpu-model")


if __name__ == "__main__":
    run()
