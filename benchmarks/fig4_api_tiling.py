"""Paper Fig. 4: API-level tiling sweep (DR1/DR2) — GOP/s per legal
aie::mmul shape over growing, asymmetric single-tile workloads; plus the
TPU DR1' block choices from the planner and a measured CPU trend check."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro import hw as hwlib
from repro.core import tiling


def run():
    print("# fig4: API tiling — name,us_per_call,derived")
    aie = hwlib.AIE_ML
    # Two workloads per ops-group, Q_K-larger vs Q_N-larger (paper x-axis).
    for ops_k in (16384, 32768, 65536):
        qk_big = (8, ops_k // (8 * 32), 32)        # K-heavy
        qn_big = (8, 32, ops_k // (8 * 32))        # N-heavy
        for tag, (m, qk, qn) in (("Qk-larger", qk_big), ("Qn-larger", qn_big)):
            for s in aie.legal_api_tiles_i8:
                t = tiling.aie_tile_interval(m, qk, qn, s)
                gops = 2 * m * qk * qn / t / 1e9
                emit(f"fig4/api{s}/{tag}/ops{ops_k}", t * 1e6,
                     f"gops={gops:.1f};src=model")
    # DR2 asymmetry factor:
    fast = tiling.aie_tile_interval(8, 32, 256)
    slow = tiling.aie_tile_interval(8, 256, 32)
    emit("fig4/asymmetry-ratio", 0.0, f"qn_over_qk_speedup={slow/fast:.2f};src=model")

    # TPU DR1': planner block choices for the same workloads.
    for m, k, n in [(8, 512, 512), (8, 2048, 2048), (256, 4096, 4096)]:
        p = tiling.plan_api(m, k, n, itemsize=2)
        emit(f"fig4/tpu-plan/{m}x{k}x{n}", p.est_s * 1e6,
             f"blocks={p.blocks};vmem_mib={p.vmem_bytes/2**20:.1f};src=tpu-model")

    # Measured CPU trend: N-heavy vs K-heavy matmul wall time (sanity).
    import jax
    f = jax.jit(lambda a, b: a @ b)
    a1 = jnp.ones((8, 2048), jnp.float32)
    b1 = jnp.ones((2048, 128), jnp.float32)
    a2 = jnp.ones((8, 128), jnp.float32)
    b2 = jnp.ones((128, 2048), jnp.float32)
    t_k = time_call(f, a1, b1)
    t_n = time_call(f, a2, b2)
    emit("fig4/measured-cpu/k-heavy", t_k * 1e6, "src=measured")
    emit("fig4/measured-cpu/n-heavy", t_n * 1e6, "src=measured")


if __name__ == "__main__":
    run()
