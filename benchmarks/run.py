"""Benchmark runner: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig4 table1
  PYTHONPATH=src python -m benchmarks.run fig8 --json-dir out/

Every row is ``name,us_per_call,derived`` on stdout (see benchmarks/common.py
for the model/measured/tpu-model source labels), and each module also writes
a machine-readable ``BENCH_<name>.json`` snapshot so the perf trajectory is
tracked across PRs.  When a previous snapshot exists at the output path,
``benchmarks/trend.py`` prints per-metric deltas against it after each run.
"""

from __future__ import annotations

import argparse
import pathlib
import platform
import time

from benchmarks import (common, fig2_scalability, fig3_lare, fig4_api_tiling,
                        fig5_spatial, fig6_column_exhaustion, fig7_boundary,
                        fig8_planner, fig9_coresidency, fig10_characterize,
                        fig11_fusion, table1_deployment, trend)

ALL = {
    "fig2": fig2_scalability.run,
    "fig3": fig3_lare.run,
    "fig4": fig4_api_tiling.run,
    "fig5": fig5_spatial.run,
    "fig6": fig6_column_exhaustion.run,
    "fig7": fig7_boundary.run,
    "fig8": fig8_planner.run,
    "fig9": fig9_coresidency.run,
    "fig10": fig10_characterize.run,
    "fig11": fig11_fusion.run,
    "table1": table1_deployment.run,
}


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.run")
    ap.add_argument("which", nargs="*", choices=[*ALL, []],
                    help="benchmarks to run (default: all)")
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_<name>.json snapshots")
    args = ap.parse_args(argv)

    json_dir = pathlib.Path(args.json_dir)
    json_dir.mkdir(parents=True, exist_ok=True)
    for name in args.which or list(ALL):
        print(f"\n## {name}")
        common.reset_records()
        t0 = time.perf_counter()
        ALL[name]()
        path = json_dir / f"BENCH_{name}.json"
        try:
            previous = trend.load(path) if path.exists() else None
        except (ValueError, OSError):       # truncated/corrupt old snapshot
            previous = None
        common.write_records(str(path), meta={
            "benchmark": name,
            "wall_s": round(time.perf_counter() - t0, 3),
            "host": platform.machine(),
            "python": platform.python_version(),
        })
        print(f"[wrote {path}]")
        if previous is not None:
            trend.report(previous, trend.load(path))


if __name__ == "__main__":
    main()
