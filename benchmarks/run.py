"""Benchmark runner: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig4 table1

Every row is ``name,us_per_call,derived`` (see benchmarks/common.py for the
model/measured/tpu-model source labels).
"""

from __future__ import annotations

import sys

from benchmarks import (fig2_scalability, fig3_lare, fig4_api_tiling,
                        fig5_spatial, fig6_column_exhaustion, fig7_boundary,
                        table1_deployment)

ALL = {
    "fig2": fig2_scalability.run,
    "fig3": fig3_lare.run,
    "fig4": fig4_api_tiling.run,
    "fig5": fig5_spatial.run,
    "fig6": fig6_column_exhaustion.run,
    "fig7": fig7_boundary.run,
    "table1": table1_deployment.run,
}


def main() -> None:
    which = sys.argv[1:] or list(ALL)
    for name in which:
        print(f"\n## {name}")
        ALL[name]()


if __name__ == "__main__":
    main()
