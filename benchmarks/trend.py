"""BENCH trend tracking: diff benchmark snapshots across PRs.

``benchmarks/run.py`` writes a machine-readable ``BENCH_<name>.json`` per
benchmark.  This module compares the freshly-written snapshot against the
previously committed one and prints per-metric deltas, so a perf regression
shows up in the run log instead of silently replacing the old numbers.

  PYTHONPATH=src python -m benchmarks.trend bench/BENCH_fig8.json
      # vs the committed version (git show HEAD:<path>)
  PYTHONPATH=src python -m benchmarks.trend new.json --against old.json
  PYTHONPATH=src python -m benchmarks.trend bench/BENCH_fig8.json --gate
      # CI regression gate: exit 2 when a model-sourced metric regressed

``run.py`` calls :func:`report` automatically whenever a previous snapshot
exists at the output path.  ``--gate`` turns the diff into a CI check: any
``src=model`` row (deterministic, host-independent) slower than the
committed baseline by more than ``REGRESSION_PCT`` fails the build.
Measured rows jitter with the host and are reported but never gate.  To
land an intentional perf trade-off, set ``TREND_GATE_OVERRIDE=1`` — the CI
workflow maps the ``perf-regression-ok`` PR label onto it — and update the
committed baseline in the same PR.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

# |delta| beyond this fraction of the old value is flagged.  Model-sourced
# rows are deterministic, so ANY drift there is worth a look; measured rows
# jitter with the host.
REGRESSION_PCT = 25.0


def load(path: str | pathlib.Path) -> dict:
    return json.loads(pathlib.Path(path).read_text())


def load_committed(path: str | pathlib.Path) -> dict | None:
    """The snapshot as last committed (``git show HEAD:<relpath>``), or None
    when the file is new to the repo / we are not in a work tree."""
    p = pathlib.Path(path).resolve()
    try:
        root = pathlib.Path(subprocess.check_output(
            ["git", "rev-parse", "--show-toplevel"], cwd=p.parent,
            text=True, stderr=subprocess.DEVNULL).strip())
        blob = subprocess.check_output(
            ["git", "show", f"HEAD:{p.relative_to(root).as_posix()}"],
            cwd=root, text=True, stderr=subprocess.DEVNULL)
    except (subprocess.CalledProcessError, OSError, ValueError):
        return None
    return json.loads(blob)


def compare(old_payload: dict, new_payload: dict) -> list[dict]:
    """Per-metric deltas between two snapshots, keyed by row name."""
    old = {r["name"]: r for r in old_payload.get("rows", [])}
    new = {r["name"]: r for r in new_payload.get("rows", [])}
    out = []
    for name in sorted(set(old) | set(new)):
        o, n = old.get(name), new.get(name)
        if o is None:
            out.append({"name": name, "status": "new",
                        "new_us": n["us_per_call"]})
            continue
        if n is None:
            out.append({"name": name, "status": "gone",
                        "old_us": o["us_per_call"],
                        "derived": o.get("derived", "")})
            continue
        ou, nu = o["us_per_call"], n["us_per_call"]
        pct = 100.0 * (nu - ou) / ou if ou else (0.0 if nu == ou else 100.0)
        status = ("regression" if pct > REGRESSION_PCT
                  else "improvement" if pct < -REGRESSION_PCT else "steady")
        out.append({"name": name, "status": status, "old_us": ou,
                    "new_us": nu, "delta_pct": round(pct, 1),
                    "derived": n.get("derived", "")})
    return out


def gate(deltas: list[dict], *, print_fn=print) -> int:
    """CI regression gate over a diff: 0 = clean, 2 = gated regression.

    Only ``src=model`` rows gate — they are deterministic functions of the
    code, so any slowdown is a real cost-model/planner change, not host
    jitter.  A DISAPPEARED model row gates too: deleting or renaming a
    metric must not be a silent way around the check.
    ``TREND_GATE_OVERRIDE=1`` downgrades failures to warnings (the CI
    workflow sets it from the ``perf-regression-ok`` PR label)."""
    gated = [d for d in deltas if d["status"] in ("regression", "gone")
             and "src=model" in d.get("derived", "")]
    if not gated:
        return 0
    for d in gated:
        what = "vanished metric" if d["status"] == "gone" else "regression"
        print_fn(f"[gate] model-sourced {what}: {format_delta(d).strip()}")
    if os.environ.get("TREND_GATE_OVERRIDE"):
        print_fn(f"[gate] {len(gated)} regression(s) overridden "
                 f"(TREND_GATE_OVERRIDE set)")
        return 0
    print_fn(f"[gate] FAIL: {len(gated)} model-sourced metric(s) regressed "
             f">{REGRESSION_PCT:.0f}% vs the committed baseline; apply the "
             f"perf-regression-ok label (or set TREND_GATE_OVERRIDE=1) and "
             f"refresh the baseline to land this intentionally")
    return 2


def format_delta(d: dict) -> str:
    if d["status"] == "new":
        return f"  NEW        {d['name']}: {d['new_us']:.3f}us"
    if d["status"] == "gone":
        return f"  GONE       {d['name']} (was {d['old_us']:.3f}us)"
    arrow = {"regression": "SLOWER", "improvement": "FASTER",
             "steady": "~"}[d["status"]]
    return (f"  {arrow:<10} {d['name']}: {d['old_us']:.3f} -> "
            f"{d['new_us']:.3f}us ({d['delta_pct']:+.1f}%)")


def report(old_payload: dict, new_payload: dict, *,
           print_fn=print) -> list[dict]:
    """Print per-metric deltas; returns the structured rows."""
    deltas = compare(old_payload, new_payload)
    if not deltas:
        print_fn("[trend] no rows to compare")
        return deltas
    flagged = sum(1 for d in deltas
                  if d["status"] in ("regression", "gone"))
    print_fn(f"[trend] {len(deltas)} metrics vs previous snapshot"
             + (f", {flagged} flagged" if flagged else ""))
    for d in deltas:
        if d["status"] != "steady":
            print_fn(format_delta(d))
    return deltas


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.trend",
                                 description=__doc__)
    ap.add_argument("snapshot", nargs="+",
                    help="current BENCH_<name>.json (several snapshots "
                         "diff/gate independently; worst exit code wins)")
    ap.add_argument("--against", default=None,
                    help="previous snapshot (default: committed version "
                         "via git show HEAD:<path>)")
    ap.add_argument("--gate", action="store_true",
                    help="exit 2 when a model-sourced metric regressed more "
                         f"than {REGRESSION_PCT:.0f}%% vs the baseline "
                         "(override: TREND_GATE_OVERRIDE=1 / the "
                         "perf-regression-ok PR label)")
    args = ap.parse_args(argv)
    if args.against and len(args.snapshot) > 1:
        print("--against pairs with exactly one snapshot", file=sys.stderr)
        return 2
    rc = 0
    for snap in args.snapshot:
        if len(args.snapshot) > 1:
            print(f"== {snap}")
        new_payload = load(snap)
        old_payload = (load(args.against) if args.against
                       else load_committed(snap))
        if old_payload is None:
            if args.gate:   # a brand-new snapshot has nothing to regress
                print(f"[gate] no committed baseline for {snap}; "
                      f"nothing to gate")
                continue
            print(f"no committed baseline for {snap}; nothing to diff",
                  file=sys.stderr)
            rc = max(rc, 1)
            continue
        deltas = report(old_payload, new_payload)
        for d in deltas:
            if d["status"] == "steady":
                print(format_delta(d))
        if args.gate:
            rc = max(rc, gate(deltas))
    return rc


if __name__ == "__main__":
    sys.exit(main())
