"""BENCH trend tracking: diff benchmark snapshots across PRs.

``benchmarks/run.py`` writes a machine-readable ``BENCH_<name>.json`` per
benchmark.  This module compares the freshly-written snapshot against the
previously committed one and prints per-metric deltas, so a perf regression
shows up in the run log instead of silently replacing the old numbers.

  PYTHONPATH=src python -m benchmarks.trend bench/BENCH_fig8.json
      # vs the committed version (git show HEAD:<path>)
  PYTHONPATH=src python -m benchmarks.trend new.json --against old.json

``run.py`` calls :func:`report` automatically whenever a previous snapshot
exists at the output path.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys

# |delta| beyond this fraction of the old value is flagged.  Model-sourced
# rows are deterministic, so ANY drift there is worth a look; measured rows
# jitter with the host.
REGRESSION_PCT = 25.0


def load(path: str | pathlib.Path) -> dict:
    return json.loads(pathlib.Path(path).read_text())


def load_committed(path: str | pathlib.Path) -> dict | None:
    """The snapshot as last committed (``git show HEAD:<relpath>``), or None
    when the file is new to the repo / we are not in a work tree."""
    p = pathlib.Path(path).resolve()
    try:
        root = pathlib.Path(subprocess.check_output(
            ["git", "rev-parse", "--show-toplevel"], cwd=p.parent,
            text=True, stderr=subprocess.DEVNULL).strip())
        blob = subprocess.check_output(
            ["git", "show", f"HEAD:{p.relative_to(root).as_posix()}"],
            cwd=root, text=True, stderr=subprocess.DEVNULL)
    except (subprocess.CalledProcessError, OSError, ValueError):
        return None
    return json.loads(blob)


def compare(old_payload: dict, new_payload: dict) -> list[dict]:
    """Per-metric deltas between two snapshots, keyed by row name."""
    old = {r["name"]: r for r in old_payload.get("rows", [])}
    new = {r["name"]: r for r in new_payload.get("rows", [])}
    out = []
    for name in sorted(set(old) | set(new)):
        o, n = old.get(name), new.get(name)
        if o is None:
            out.append({"name": name, "status": "new",
                        "new_us": n["us_per_call"]})
            continue
        if n is None:
            out.append({"name": name, "status": "gone",
                        "old_us": o["us_per_call"]})
            continue
        ou, nu = o["us_per_call"], n["us_per_call"]
        pct = 100.0 * (nu - ou) / ou if ou else (0.0 if nu == ou else 100.0)
        status = ("regression" if pct > REGRESSION_PCT
                  else "improvement" if pct < -REGRESSION_PCT else "steady")
        out.append({"name": name, "status": status, "old_us": ou,
                    "new_us": nu, "delta_pct": round(pct, 1)})
    return out


def format_delta(d: dict) -> str:
    if d["status"] == "new":
        return f"  NEW        {d['name']}: {d['new_us']:.3f}us"
    if d["status"] == "gone":
        return f"  GONE       {d['name']} (was {d['old_us']:.3f}us)"
    arrow = {"regression": "SLOWER", "improvement": "FASTER",
             "steady": "~"}[d["status"]]
    return (f"  {arrow:<10} {d['name']}: {d['old_us']:.3f} -> "
            f"{d['new_us']:.3f}us ({d['delta_pct']:+.1f}%)")


def report(old_payload: dict, new_payload: dict, *,
           print_fn=print) -> list[dict]:
    """Print per-metric deltas; returns the structured rows."""
    deltas = compare(old_payload, new_payload)
    if not deltas:
        print_fn("[trend] no rows to compare")
        return deltas
    flagged = sum(1 for d in deltas
                  if d["status"] in ("regression", "gone"))
    print_fn(f"[trend] {len(deltas)} metrics vs previous snapshot"
             + (f", {flagged} flagged" if flagged else ""))
    for d in deltas:
        if d["status"] != "steady":
            print_fn(format_delta(d))
    return deltas


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.trend",
                                 description=__doc__)
    ap.add_argument("snapshot", help="current BENCH_<name>.json")
    ap.add_argument("--against", default=None,
                    help="previous snapshot (default: committed version "
                         "via git show HEAD:<path>)")
    args = ap.parse_args(argv)
    new_payload = load(args.snapshot)
    old_payload = (load(args.against) if args.against
                   else load_committed(args.snapshot))
    if old_payload is None:
        print(f"no committed baseline for {args.snapshot}; nothing to diff",
              file=sys.stderr)
        return 1
    deltas = report(old_payload, new_payload)
    for d in deltas:
        if d["status"] == "steady":
            print(format_delta(d))
    return 0


if __name__ == "__main__":
    sys.exit(main())
