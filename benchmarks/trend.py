"""BENCH trend tracking: diff benchmark snapshots across PRs.

``benchmarks/run.py`` writes a machine-readable ``BENCH_<name>.json`` per
benchmark.  This module compares the freshly-written snapshot against the
previously committed one and prints per-metric deltas, so a perf regression
shows up in the run log instead of silently replacing the old numbers.

  PYTHONPATH=src python -m benchmarks.trend bench/BENCH_fig8.json
      # vs the committed version (git show HEAD:<path>)
  PYTHONPATH=src python -m benchmarks.trend new.json --against old.json
  PYTHONPATH=src python -m benchmarks.trend bench/BENCH_fig8.json --gate
      # CI regression gate: exit 2 when a model-sourced metric regressed
  PYTHONPATH=src python -m benchmarks.trend new.json --against old.json --explain
      # forensics: name the span kind + roofline term that moved most

``run.py`` calls :func:`report` automatically whenever a previous snapshot
exists at the output path.  ``--gate`` turns the diff into a CI check: any
``src=model`` row (deterministic, host-independent) slower than the
committed baseline by more than ``REGRESSION_PCT`` fails the build.
Measured rows jitter with the host and are reported but never gate.  To
land an intentional perf trade-off, set ``TREND_GATE_OVERRIDE=1`` — the CI
workflow maps the ``perf-regression-ok`` PR label onto it — and update the
committed baseline in the same PR.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

# |delta| beyond this fraction of the old value is flagged.  Model-sourced
# rows are deterministic, so ANY drift there is worth a look; measured rows
# jitter with the host.
REGRESSION_PCT = 25.0


class SnapshotError(Exception):
    """A snapshot that cannot be read as BENCH JSON (malformed/truncated).

    Raised instead of letting ``json.JSONDecodeError`` stack-trace out of
    the CLI: a half-written snapshot is an input error the gate should
    report in one line with a nonzero exit, not a crash."""


def _parse_snapshot(text: str, origin: str) -> dict:
    try:
        payload = json.loads(text)
    except json.JSONDecodeError as e:
        raise SnapshotError(f"{origin}: malformed snapshot JSON "
                            f"({e.msg} at line {e.lineno})") from None
    if not isinstance(payload, dict):
        raise SnapshotError(f"{origin}: snapshot must be a JSON object, "
                            f"got {type(payload).__name__}")
    rows = payload.get("rows", [])
    if not isinstance(rows, list) or any(
            not isinstance(r, dict) or "name" not in r
            or "us_per_call" not in r for r in rows):
        raise SnapshotError(f"{origin}: 'rows' must be a list of "
                            f"{{name, us_per_call}} objects")
    return payload


def load(path: str | pathlib.Path) -> dict:
    p = pathlib.Path(path)
    try:
        text = p.read_text()
    except OSError as e:
        raise SnapshotError(f"{p}: {e.strerror or e}") from None
    return _parse_snapshot(text, str(p))


def load_committed(path: str | pathlib.Path) -> dict | None:
    """The snapshot as last committed (``git show HEAD:<relpath>``), or None
    when the file is new to the repo / we are not in a work tree."""
    p = pathlib.Path(path).resolve()
    try:
        root = pathlib.Path(subprocess.check_output(
            ["git", "rev-parse", "--show-toplevel"], cwd=p.parent,
            text=True, stderr=subprocess.DEVNULL).strip())
        blob = subprocess.check_output(
            ["git", "show", f"HEAD:{p.relative_to(root).as_posix()}"],
            cwd=root, text=True, stderr=subprocess.DEVNULL)
    except (subprocess.CalledProcessError, OSError, ValueError):
        return None
    return _parse_snapshot(blob, f"HEAD:{p.name}")


def compare(old_payload: dict, new_payload: dict) -> list[dict]:
    """Per-metric deltas between two snapshots, keyed by row name."""
    old = {r["name"]: r for r in old_payload.get("rows", [])}
    new = {r["name"]: r for r in new_payload.get("rows", [])}
    out = []
    for name in sorted(set(old) | set(new)):
        o, n = old.get(name), new.get(name)
        if o is None:
            out.append({"name": name, "status": "new",
                        "new_us": n["us_per_call"]})
            continue
        if n is None:
            out.append({"name": name, "status": "gone",
                        "old_us": o["us_per_call"],
                        "derived": o.get("derived", "")})
            continue
        ou, nu = o["us_per_call"], n["us_per_call"]
        pct = 100.0 * (nu - ou) / ou if ou else (0.0 if nu == ou else 100.0)
        status = ("regression" if pct > REGRESSION_PCT
                  else "improvement" if pct < -REGRESSION_PCT else "steady")
        out.append({"name": name, "status": status, "old_us": ou,
                    "new_us": nu, "delta_pct": round(pct, 1),
                    "derived": n.get("derived", "")})
    return out


def gate(deltas: list[dict], *, print_fn=print) -> int:
    """CI regression gate over a diff: 0 = clean, 2 = gated regression.

    Only ``src=model`` rows gate — they are deterministic functions of the
    code, so any slowdown is a real cost-model/planner change, not host
    jitter.  A DISAPPEARED model row gates too: deleting or renaming a
    metric must not be a silent way around the check.
    ``TREND_GATE_OVERRIDE=1`` downgrades failures to warnings (the CI
    workflow sets it from the ``perf-regression-ok`` PR label)."""
    gated = [d for d in deltas if d["status"] in ("regression", "gone")
             and "src=model" in d.get("derived", "")]
    if not gated:
        return 0
    for d in gated:
        what = "vanished metric" if d["status"] == "gone" else "regression"
        print_fn(f"[gate] model-sourced {what}: {format_delta(d).strip()}")
    if os.environ.get("TREND_GATE_OVERRIDE"):
        print_fn(f"[gate] {len(gated)} regression(s) overridden "
                 f"(TREND_GATE_OVERRIDE set)")
        return 0
    print_fn(f"[gate] FAIL: {len(gated)} model-sourced metric(s) regressed "
             f">{REGRESSION_PCT:.0f}% vs the committed baseline; apply the "
             f"perf-regression-ok label (or set TREND_GATE_OVERRIDE=1) and "
             f"refresh the baseline to land this intentionally")
    return 2


def format_delta(d: dict) -> str:
    if d["status"] == "new":
        return f"  NEW        {d['name']}: {d['new_us']:.3f}us"
    if d["status"] == "gone":
        return f"  GONE       {d['name']} (was {d['old_us']:.3f}us)"
    arrow = {"regression": "SLOWER", "improvement": "FASTER",
             "steady": "~"}[d["status"]]
    return (f"  {arrow:<10} {d['name']}: {d['old_us']:.3f} -> "
            f"{d['new_us']:.3f}us ({d['delta_pct']:+.1f}%)")


def _parse_derived(derived: str) -> dict:
    """``k=v;k=v`` derived-field pairs as a dict (floats where they parse).

    The profile snapshots embed their roofline-term breakdown here
    (``t_compute_us=..;t_memory_us=..;t_launch_us=..``) precisely so this
    forensics pass can attribute a regression to the term that moved."""
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, _, v = part.partition("=")
        try:
            out[k.strip()] = float(v)
        except ValueError:
            out[k.strip()] = v.strip()
    return out


def _span_kind_of(name: str) -> str:
    """The span-kind component of a BENCH row name: row names follow
    ``<family>/<net>/<kind...>/<metric>`` (``serve/jet/infer/p50``,
    ``profile/jet/decode_step/ceiling``); two-component names have no
    kind."""
    parts = name.split("/")
    return "/".join(parts[2:-1]) if len(parts) >= 4 else "-"


def explain(old_payload: dict, new_payload: dict, *,
            print_fn=print) -> dict | None:
    """Regression forensics: name the row, span kind, and roofline term
    that moved most between two snapshots.

    Ranks changed rows by ``|delta_pct|`` with regressions first, then
    diffs the term breakdown embedded in the ``derived`` strings
    (``t_*_us`` keys) of the worst mover and reports the single term whose
    change explains the most of it.  Returns the structured verdict (None
    when nothing changed)."""
    deltas = [d for d in compare(old_payload, new_payload)
              if "old_us" in d and "new_us" in d and d["old_us"]]
    movers = [d for d in deltas if abs(d.get("delta_pct", 0.0)) > 0]
    if not movers:
        print_fn("[explain] no changed rows between the two snapshots")
        return None
    movers.sort(key=lambda d: (d.get("delta_pct", 0.0) <= 0,
                               -abs(d.get("delta_pct", 0.0))))
    worst = movers[0]
    tenant = (worst["name"].split("/") + ["-"])[1]
    kind = _span_kind_of(worst["name"])
    print_fn(f"[explain] worst mover: {worst['name']} "
             f"{worst['old_us']:.3f} -> {worst['new_us']:.3f}us "
             f"({worst['delta_pct']:+.1f}%)")
    print_fn(f"[explain] tenant={tenant} span_kind={kind}")
    old_rows = {r["name"]: r for r in old_payload.get("rows", [])}
    old_terms = _parse_derived(old_rows.get(worst["name"], {})
                               .get("derived", ""))
    new_terms = _parse_derived(worst.get("derived", ""))
    term_deltas = {
        k: new_terms[k] - old_terms[k]
        for k in new_terms
        if k.startswith("t_") and k in old_terms
        and isinstance(new_terms[k], float)
        and isinstance(old_terms[k], float)
    }
    verdict = {"name": worst["name"], "tenant": tenant, "span_kind": kind,
               "delta_pct": worst["delta_pct"], "term": None,
               "term_delta_us": None}
    if term_deltas:
        term = max(term_deltas, key=lambda k: abs(term_deltas[k]))
        verdict["term"] = term
        verdict["term_delta_us"] = term_deltas[term]
        bound_note = ""
        ob, nb = old_terms.get("bound"), new_terms.get("bound")
        if ob is not None and nb is not None and ob != nb:
            bound_note = f"; bound {ob} -> {nb}"
        print_fn(f"[explain] roofline term moved most: {term} "
                 f"{old_terms[term]:.4f} -> {new_terms[term]:.4f}us "
                 f"({term_deltas[term]:+.4f}us){bound_note}")
    else:
        print_fn("[explain] no roofline-term breakdown in the derived "
                 "fields of the worst mover (measured row or pre-profile "
                 "snapshot) — attribution stops at the span kind")
    return verdict


def report(old_payload: dict, new_payload: dict, *,
           print_fn=print) -> list[dict]:
    """Print per-metric deltas; returns the structured rows."""
    deltas = compare(old_payload, new_payload)
    if not deltas:
        print_fn("[trend] no rows to compare")
        return deltas
    flagged = sum(1 for d in deltas
                  if d["status"] in ("regression", "gone"))
    print_fn(f"[trend] {len(deltas)} metrics vs previous snapshot"
             + (f", {flagged} flagged" if flagged else ""))
    for d in deltas:
        if d["status"] != "steady":
            print_fn(format_delta(d))
    return deltas


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.trend",
                                 description=__doc__)
    ap.add_argument("snapshot", nargs="+",
                    help="current BENCH_<name>.json (several snapshots "
                         "diff/gate independently; worst exit code wins)")
    ap.add_argument("--against", default=None,
                    help="previous snapshot (default: committed version "
                         "via git show HEAD:<path>)")
    ap.add_argument("--gate", action="store_true",
                    help="exit 2 when a model-sourced metric regressed more "
                         f"than {REGRESSION_PCT:.0f}%% vs the baseline "
                         "(override: TREND_GATE_OVERRIDE=1 / the "
                         "perf-regression-ok PR label)")
    ap.add_argument("--explain", action="store_true",
                    help="regression forensics: name the row, span kind "
                         "and roofline term that moved most between the "
                         "two snapshots")
    args = ap.parse_args(argv)
    if args.against and len(args.snapshot) > 1:
        print("--against pairs with exactly one snapshot", file=sys.stderr)
        return 2
    rc = 0
    for snap in args.snapshot:
        if len(args.snapshot) > 1:
            print(f"== {snap}")
        try:
            new_payload = load(snap)
            old_payload = (load(args.against) if args.against
                           else load_committed(snap))
        except SnapshotError as e:
            print(f"trend: {e}", file=sys.stderr)
            rc = max(rc, 2)
            continue
        if old_payload is None:
            if args.gate:   # a brand-new snapshot has nothing to regress
                print(f"[gate] no committed baseline for {snap}; "
                      f"nothing to gate")
                continue
            print(f"no committed baseline for {snap}; nothing to diff",
                  file=sys.stderr)
            rc = max(rc, 1)
            continue
        deltas = report(old_payload, new_payload)
        for d in deltas:
            if d["status"] == "steady":
                print(format_delta(d))
        if args.explain:
            explain(old_payload, new_payload)
        if args.gate:
            rc = max(rc, gate(deltas))
    return rc


if __name__ == "__main__":
    sys.exit(main())
