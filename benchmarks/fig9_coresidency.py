"""Fig. 9 (ours): multi-network co-residency (paper Section V-C).

Two or more edge nets share ONE array through a joint :class:`FleetPlan`:

  * the paper-faithful all-AIE fleet placement: joint column packing under
    the shared ``usable_cols`` budget (non-overlapping column ranges, the
    Fig.-6 shrink-vs-spill rule applied fleet-wide), each net's off-array
    hand-off charged the DR7 crossing — planned intervals vs each net's SOLO
    plan quantify the co-residency cost;
  * the executable path: the same fleet deployed through the facade
    (``Deployment.build`` -> CPU-calibrated plan -> engines -> ``serve()``),
    every tenant served through the multi-tenant :class:`Router` under its
    plan-derived latency budget — per-net planned-vs-measured latency within
    2x is the acceptance bar;
  * the autotune loop: measured latencies are fed back into the plan cache
    (``calibrate.feedback``) and the calibrated ratio is reported.

Net selection: ``REPRO_FIG9_NETS=jet_tagger,tau_select`` (the CI smoke uses
the two tiniest nets).
"""

from __future__ import annotations

import os

from benchmarks.common import emit, judge_row
from repro import hw as hwlib
from repro.deploy import Deployment

DEFAULT_NETS = ("jet_tagger", "tau_select")
_ITERS = 10


def run():
    print("# fig9: co-residency — name,us_per_call,derived")
    names = tuple(n.strip() for n in os.environ.get(
        "REPRO_FIG9_NETS", ",".join(DEFAULT_NETS)).split(",") if n.strip())

    # ---- paper-faithful joint AIE placement (all-AIE: pl_budget=0) ------
    fleet_aie = Deployment.build(list(names), target="aie",
                                 machine_model=None, stop_after="plan",
                                 pl_budget=0.0).fleet
    emit("fig9/aie-fleet", fleet_aie.est_latency_s * 1e6,
         f"nets={len(names)};band1_cols={fleet_aie.band1_cols_used}"
         f"/{hwlib.AIE_ML.usable_cols};src=model")
    for name, t in zip(names, fleet_aie.tenants):
        solo = Deployment.build(name, target="aie", machine_model=None,
                                stop_after="plan", pl_budget=0.0).plan
        slowdown = (t.plan.est_interval_s / solo.est_interval_s
                    if solo.est_interval_s else float("inf"))
        cols = (f"{t.col_offset}-{t.col_offset + t.cols - 1}"
                if t.cols else "none")
        emit(f"fig9/{t.net_id}/aie-colocated", t.plan.est_interval_s * 1e6,
             f"cols={cols};mhz={t.plan.inferences_per_s / 1e6:.1f};"
             f"vs_solo={slowdown:.2f}x;src=model")

    # ---- executable co-residency: calibrated fleet through the router ---
    dep = Deployment.build(list(names), machine_model="auto")
    router = dep.serve()
    inputs = router.warmup()               # jit compile + zero counters
    rep = router.drive(inputs, iters=_ITERS)   # interleaved traffic
    for t in dep.fleet.tenants:
        m = rep[t.net_id]
        # Median, not mean: one scheduler spike on a shared host must not
        # swing the planned-vs-measured acceptance.
        row, _ = judge_row(f"fig9/{t.net_id}/planned-vs-measured",
                           t.plan.est_latency_s, m["p50_s"],
                           extra=f"budget_violations="
                                 f"{m['budget_violations']};")
        emit(*row)

    # ---- autotune feedback: measured times land back in the plan cache --
    for t in dep.fleet.tenants:
        calibrated = dep.engines[t.net_id].record_calibration()
        emit(f"fig9/{t.net_id}/calibrated", calibrated.est_latency_s * 1e6,
             f"scale={calibrated.serve['calibration']['scale']:.2f};"
             f"src=measured")


if __name__ == "__main__":
    run()
