"""Fig. 9 (ours): multi-network co-residency (paper Section V-C).

Two or more edge nets share ONE array through a joint :class:`FleetPlan`:

  * the paper-faithful all-AIE fleet placement: joint column packing under
    the shared ``usable_cols`` budget (non-overlapping column ranges, the
    Fig.-6 shrink-vs-spill rule applied fleet-wide), each net's off-array
    hand-off charged the DR7 crossing — planned intervals vs each net's SOLO
    plan quantify the co-residency cost;
  * the executable path: the same fleet planned for this host with the
    CPU-calibrated machine model, every tenant served through the
    multi-tenant :class:`Router` under its plan-derived latency budget —
    per-net planned-vs-measured latency within 2x is the acceptance bar;
  * the autotune loop: measured latencies are fed back into the plan cache
    (``calibrate.feedback``) and the calibrated ratio is reported.

Net selection: ``REPRO_FIG9_NETS=jet_tagger,tau_select`` (the CI smoke uses
the two tiniest nets).
"""

from __future__ import annotations

import os

import jax.numpy as jnp

from benchmarks.common import emit
from repro import hw as hwlib
from repro.models import edge
from repro.plan import calibrated_cpu_model, plan_deployment, plan_fleet

DEFAULT_NETS = ("jet_tagger", "tau_select")
_ITERS = 10


def run():
    from repro.serve import Router

    print("# fig9: co-residency — name,us_per_call,derived")
    names = tuple(n.strip() for n in os.environ.get(
        "REPRO_FIG9_NETS", ",".join(DEFAULT_NETS)).split(",") if n.strip())
    cfgs = [edge.edge_config(n) for n in names]

    # ---- paper-faithful joint AIE placement (all-AIE: pl_budget=0) ------
    fleet_aie = plan_fleet(cfgs, target="aie", pl_budget=0.0)
    emit("fig9/aie-fleet", fleet_aie.est_latency_s * 1e6,
         f"nets={len(names)};band1_cols={fleet_aie.band1_cols_used}"
         f"/{hwlib.AIE_ML.usable_cols};src=model")
    for cfg, t in zip(cfgs, fleet_aie.tenants):
        solo = plan_deployment(cfg, target="aie", pl_budget=0.0)
        slowdown = (t.plan.est_interval_s / solo.est_interval_s
                    if solo.est_interval_s else float("inf"))
        cols = (f"{t.col_offset}-{t.col_offset + t.cols - 1}"
                if t.cols else "none")
        emit(f"fig9/{t.net_id}/aie-colocated", t.plan.est_interval_s * 1e6,
             f"cols={cols};mhz={t.plan.inferences_per_s / 1e6:.1f};"
             f"vs_solo={slowdown:.2f}x;src=model")

    # ---- executable co-residency: calibrated fleet through the router ---
    cpu_hw = calibrated_cpu_model()
    fleet = plan_fleet(cfgs, target="tpu", tpu=cpu_hw)
    router = Router.from_fleet(fleet)
    inputs = {t.net_id: jnp.ones((cfg.batch, cfg.dims[0]), jnp.float32)
              for cfg, t in zip(cfgs, fleet.tenants)}
    for nid, x in inputs.items():          # jit warmup per tenant
        router.infer(nid, x)
    router.reset_metrics()
    for t in fleet.tenants:
        router.tenant(t.net_id).engine.reset_measurements()

    # Interleaved multi-tenant traffic (not one net at a time).
    for _ in range(_ITERS):
        for nid, x in inputs.items():
            router.infer(nid, x)

    rep = router.report()
    for t in fleet.tenants:
        m = rep[t.net_id]
        # Median, not mean: one scheduler spike on a shared host must not
        # swing the planned-vs-measured acceptance.
        planned, measured = t.plan.est_latency_s, m["p50_s"]
        ratio = planned / measured if measured > 0 else float("inf")
        within = 0.5 <= ratio <= 2.0
        emit(f"fig9/{t.net_id}/planned-vs-measured", measured * 1e6,
             f"planned_us={planned * 1e6:.1f};ratio={ratio:.2f};"
             f"within_2x={within};budget_violations={m['budget_violations']};"
             f"src=measured")

    # ---- autotune feedback: measured times land back in the plan cache --
    for t in fleet.tenants:
        eng = router.tenant(t.net_id).engine
        calibrated = eng.record_calibration()
        emit(f"fig9/{t.net_id}/calibrated", calibrated.est_latency_s * 1e6,
             f"scale={calibrated.serve['calibration']['scale']:.2f};"
             f"src=measured")


if __name__ == "__main__":
    run()
