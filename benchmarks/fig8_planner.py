"""Fig. 8 (ours): Table I end-to-end THROUGH THE DEPLOYMENT PLANNER.

Per edge network (all five ``EDGE_NETS``, superset of the paper's Table I):

  * the paper-faithful AIE plan (``pl_budget=0`` -> all-AIE, the design-rule
    deployment) with its planned interval vs the paper's optimized MHz;
  * the LARE mixed plan at the paper's PL budget (regime string + crossings);
  * the TPU-path plan executed on this host (Pallas interpret): planned
    latency from a CPU-CALIBRATED machine model vs measured wall time — the
    planner is judged on prediction, not just selection.

Everything routes through the facade: ``repro.deploy.Deployment`` builds
the plan-only AIE deployments AND the executable TPU one (plan + quantize +
calibrate + jit behind ``build``; planned-vs-measured via ``bench``).

Acceptance: planned/measured within 2x on the CPU smoke path.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.deploy import Deployment
from repro.models import edge
from repro.plan import calibrated_cpu_model

PAPER_OPT_MHZ = {"vae": 97.9, "qubit": 58.9, "autoencoder": 58.8}


def run():
    print("# fig8: planner — name,us_per_call,derived")
    cpu_hw = calibrated_cpu_model()        # memoized; "auto" resolves to it
    emit("fig8/calibration", cpu_hw.kernel_overhead_s * 1e6,
         f"peak_int8={cpu_hw.peak_int8_ops:.3g}ops/s;src=measured")
    for name in edge.EDGE_NETS:
        # Paper-faithful all-AIE plan (the design-rule deployment).
        aie_plan = Deployment.build(name, target="aie", machine_model=None,
                                    stop_after="plan", pl_budget=0.0).plan
        mhz = aie_plan.inferences_per_s / 1e6
        paper = PAPER_OPT_MHZ.get(name)
        emit(f"fig8/{name}/aie-planned", aie_plan.est_interval_s * 1e6,
             f"mhz={mhz:.1f}"
             + (f";paper_mhz={paper}" if paper else "")
             + f";meets_40mhz={mhz >= 40.0};src=model")

        # LARE mixed plan at the paper's PL budget: regimes + crossings.
        mixed = Deployment.build(name, target="aie", machine_model=None,
                                 stop_after="plan", pl_budget=100.0).plan
        emit(f"fig8/{name}/lare-mixed", mixed.est_latency_s * 1e6,
             f"regimes={'/'.join(mixed.regimes())};"
             f"crossings={len(mixed.boundaries)};src=model")

        # TPU-path deployment, planned with the CPU-calibrated model, then
        # EXECUTED through the planned Pallas blocks on this host.
        dep = Deployment.build(name, machine_model="auto")
        for row in dep.bench(iters=5, warmup=1):
            emit(f"fig8/{name}/tpu-planned-vs-measured",
                 row.measured_s * 1e6, row.derived)


if __name__ == "__main__":
    run()
