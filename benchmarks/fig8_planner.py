"""Fig. 8 (ours): Table I end-to-end THROUGH THE DEPLOYMENT PLANNER.

Per edge network (all five ``EDGE_NETS``, superset of the paper's Table I):

  * the paper-faithful AIE plan (``pl_budget=0`` -> all-AIE, the design-rule
    deployment) with its planned interval vs the paper's optimized MHz;
  * the LARE mixed plan at the paper's PL budget (regime string + crossings);
  * the TPU-path plan executed on this host (Pallas interpret): planned
    latency from a CPU-CALIBRATED machine model vs measured wall time — the
    planner is judged on prediction, not just selection.

Acceptance: planned/measured within 2x on the CPU smoke path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.models import edge
from repro.plan import calibrated_cpu_model, plan_deployment

PAPER_OPT_MHZ = {"vae": 97.9, "qubit": 58.9, "autoencoder": 58.8}


def run():
    print("# fig8: planner — name,us_per_call,derived")
    cpu_hw = calibrated_cpu_model()
    emit("fig8/calibration", cpu_hw.kernel_overhead_s * 1e6,
         f"peak_int8={cpu_hw.peak_int8_ops:.3g}ops/s;src=measured")
    for name in edge.EDGE_NETS:
        cfg = edge.edge_config(name)

        # Paper-faithful all-AIE plan (the design-rule deployment).
        aie_plan = plan_deployment(cfg, target="aie", pl_budget=0.0)
        mhz = aie_plan.inferences_per_s / 1e6
        paper = PAPER_OPT_MHZ.get(name)
        emit(f"fig8/{name}/aie-planned", aie_plan.est_interval_s * 1e6,
             f"mhz={mhz:.1f}"
             + (f";paper_mhz={paper}" if paper else "")
             + f";meets_40mhz={mhz >= 40.0};src=model")

        # LARE mixed plan at the paper's PL budget: regimes + crossings.
        mixed = plan_deployment(cfg, target="aie", pl_budget=100.0)
        emit(f"fig8/{name}/lare-mixed", mixed.est_latency_s * 1e6,
             f"regimes={'/'.join(mixed.regimes())};"
             f"crossings={len(mixed.boundaries)};src=model")

        # TPU-path plan, planned with the CPU-calibrated model, then
        # EXECUTED through the planned Pallas blocks on this host.
        plan = plan_deployment(cfg, target="tpu", tpu=cpu_hw)
        params = edge.init_edge(jax.random.PRNGKey(0), cfg)
        qp = edge.quantize_edge(params)
        x = jnp.ones((cfg.batch, cfg.dims[0]), jnp.float32)
        f = jax.jit(lambda xx: edge.edge_forward_q8(qp, cfg, xx, plan=plan))
        t_meas = time_call(f, x, iters=5, warmup=1)
        ratio = plan.est_latency_s / t_meas if t_meas > 0 else float("inf")
        within = 0.5 <= ratio <= 2.0
        emit(f"fig8/{name}/tpu-planned-vs-measured", t_meas * 1e6,
             f"planned_us={plan.est_latency_s * 1e6:.1f};"
             f"ratio={ratio:.2f};within_2x={within};"
             f"fuse_groups={len(set(l.fuse_group for l in plan.layers))};"
             f"src=measured")


if __name__ == "__main__":
    run()
