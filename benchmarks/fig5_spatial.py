"""Paper Fig. 5: spatial tiling of a (8,128,128) GEMM across P_K x P_N
compute tiles (DR3/DR4/DR5), plus the TPU spatial planner's choices."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import tiling


def run():
    print("# fig5: spatial tiling — name,us_per_call,derived")
    m, k, n = 8, 128, 128
    base = None
    for p_k in (1, 2, 4, 8):
        for p_n in (1, 2, 4, 8):
            if p_k * p_n > 16 or k // p_k < 8 or n // p_n < 8:
                continue
            t = tiling.aie_spatial_latency(m, k, n, p_k, p_n)
            if base is None:
                base = t
            emit(f"fig5/aie/pk{p_k}-pn{p_n}", t * 1e6,
                 f"tiles={p_k*p_n};speedup={base/t:.2f};src=model")
    # DR4 knee check: per-tile workload at the measured optimum.
    best = min(((p_k, p_n) for p_k in (1, 2, 4, 8) for p_n in (1, 2, 4, 8)
                if k // p_k >= 8 and n // p_n >= 8),
               key=lambda pq: tiling.aie_spatial_latency(m, k, n, *pq))
    emit("fig5/aie/optimum", 0.0,
         f"pk={best[0]};pn={best[1]};qk={k//best[0]};qn={n//best[1]};src=model")

    # TPU spatial plans for LM-scale GEMMs on a 16-way axis.
    for mm, kk, nn in [(8, 4096, 14336), (8, 7168, 18432), (1024, 8192, 29568)]:
        sp = tiling.plan_spatial(mm, kk, nn, axis_sizes=(16,))
        emit(f"fig5/tpu-plan/{mm}x{kk}x{nn}", sp.est_collective_s * 1e6,
             f"pk={sp.p_k};pn={sp.p_n};bands={sp.bands};src=tpu-model")


if __name__ == "__main__":
    run()
