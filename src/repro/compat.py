"""jax version-compatibility aliases.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` in 0.5 and
renamed its replication-check kwarg ``check_rep`` -> ``check_vma``; this
wrapper presents the new-style surface on either jax.
"""

import inspect

import jax

try:
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = inspect.signature(_shard_map).parameters


def shard_map(f, **kwargs):
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(f, **kwargs)
