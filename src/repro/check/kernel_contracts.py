"""Layer 2: kernel contract checking via ``jax.eval_shape``.

A plan that passes every layer-1 rule can still die inside a device launch:
the Pallas entry points carry their own contracts (dtype asserts, block
divisibility Mosaic enforces at compile time, VMEM working sets the
megakernel actually allocates).  This module abstract-evaluates the repo's
``pallas_call`` entry points against the shapes a plan implies — tracing
only, zero compilation, zero device work — so those failures surface at
check time as structured findings.

Rules:

* ``kernel.block-divisibility`` — the plan's block shapes are Mosaic-legal
  tile multiples for the kernel's operand dtypes.
* ``kernel.eval-shape`` — the entry point abstract-evaluates on the
  plan-implied shapes and returns the shape/dtype the engine will consume.
* ``kernel.dtype-contract`` — int8 in / int32 accumulate / float
  requantized out: the quantized path rejects non-int8 operands and emits
  the requested float dtype.
* ``kernel.vmem-scratch`` — re-derive the fused megakernel's actual VMEM
  working set (padded operands + int8 activation scratch) and compare it
  against both the hardware budget (error) and the plan's
  ``fusion_groups[].vmem_bytes`` estimate (warning when the plan
  under-states what the launch will allocate).
"""

from __future__ import annotations

import functools

from repro import hw as hwlib
from repro.check import Finding

# Must match core/tiling.plan_api's search budget and fused_mlp's padding.
_VMEM_BUDGET_FRACTION = 0.75
_INT8_SUBLANE = 32
_LANE = 128


def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


def _jax():
    """Import jax lazily so `repro check` still lints and verifies plans on
    a host without the accelerator toolchain (kernel layer degrades to an
    info finding instead of an ImportError)."""
    try:
        import jax
        import jax.numpy as jnp
        return jax, jnp
    except Exception:                                    # pragma: no cover
        return None, None


def group_vmem_bytes(widths, batch: int) -> int:
    """The fused megakernel's real VMEM working set for a group whose
    activation widths (input first) are ``widths`` at batch ``batch``.

    Mirrors :func:`repro.kernels.fused_mlp.fused_mlp_q8` exactly: f32 input
    and output buffers, int8 weights, one f32 scale row + one f32 bias row
    per layer, and the shared int8 activation scratch."""
    pm = _ceil_to(batch, _INT8_SUBLANE)
    pads = [_ceil_to(d, _LANE) for d in widths]
    total = pm * pads[0] * 4                             # x, f32
    for kin, kout in zip(pads, pads[1:]):
        total += kin * kout                              # int8 weight
        total += 2 * kout * 4                            # scale + bias rows
    total += pm * pads[-1] * 4                           # out, f32
    total += pm * max(pads[:-1])                         # int8 act scratch
    return total


def verify_plan_kernels(plan, *, tenant: str | None = None,
                        tpu=None) -> list:
    """Abstract-evaluate the kernels a TPU plan will launch, with the
    plan's own block shapes and fusion groups."""
    tenant = tenant if tenant is not None else plan.network
    tpu = tpu if tpu is not None else hwlib.TPU_V5E
    if plan.target != "tpu" or plan.kind != "edge":
        # LM plans drive the attention/scan kernels with runtime-dependent
        # sequence shapes; those entry points are covered by the canonical
        # library self-check instead.
        return []
    jax, jnp = _jax()
    if jax is None:                                      # pragma: no cover
        return [Finding(rule="kernel.eval-shape", severity="info",
                        tenant=tenant,
                        detail="jax unavailable; kernel contracts skipped")]
    from repro.kernels.gemm_int8 import gemm_int8

    fs: list = []
    sub = tpu.sublanes_for(1)            # quantized path: int8 operands
    for l in plan.layers:
        bm, bk, bn = l.api_tile
        if bm % sub or bk % _LANE or bn % _LANE:
            fs.append(Finding(
                rule="kernel.block-divisibility", severity="error",
                tenant=tenant, layer=l.index,
                detail=f"block {l.api_tile} on {l.name!r} is not a "
                       f"({sub}, {_LANE}, {_LANE}) multiple - Mosaic "
                       f"rejects the int8 BlockSpec at compile time"))
            continue                     # eval_shape would fail for the same
        x = jax.ShapeDtypeStruct((plan.batch, l.n_in), jnp.int8)
        w = jax.ShapeDtypeStruct((l.n_in, l.n_out), jnp.int8)
        ws = jax.ShapeDtypeStruct((l.n_out,), jnp.float32)
        fn = functools.partial(gemm_int8, block_m=bm, block_k=bk,
                               block_n=bn, out_dtype=jnp.float32)
        try:
            out = jax.eval_shape(fn, x, w, ws)
        except Exception as e:
            fs.append(Finding(
                rule="kernel.eval-shape", severity="error", tenant=tenant,
                layer=l.index,
                detail=f"gemm_int8 fails to trace {l.name!r} "
                       f"(M={plan.batch}, K={l.n_in}, N={l.n_out}, "
                       f"blocks={l.api_tile}): {e.__class__.__name__}: "
                       f"{str(e).splitlines()[0][:160]}"))
            continue
        if tuple(out.shape) != (plan.batch, l.n_out) \
                or out.dtype != jnp.float32:
            fs.append(Finding(
                rule="kernel.dtype-contract", severity="error",
                tenant=tenant, layer=l.index,
                detail=f"gemm_int8 on {l.name!r} returns "
                       f"{out.shape}/{out.dtype}, engine expects "
                       f"({plan.batch}, {l.n_out})/float32"))
    fs += _verify_fused_groups(plan, tenant, tpu, jax, jnp)
    fs += _verify_int8_rejects_float(tenant, jax, jnp)
    return fs


def _verify_fused_groups(plan, tenant, tpu, jax, jnp) -> list:
    """Fusion groups launch as ONE megakernel: re-derive the working set it
    allocates and abstract-evaluate the fused entry point."""
    from repro.kernels.fused_mlp import fused_mlp_q8
    fs = []
    by_index = {l.index: l for l in plan.layers}
    budget = int(tpu.vmem_bytes * _VMEM_BUDGET_FRACTION)
    for g in plan.fusion_groups:
        ls = [by_index[i] for i in g.layers if i in by_index]
        if len(ls) < 2 or len(ls) != len(g.layers):
            continue                     # single-layer: the gemm path above
        widths = [ls[0].n_in] + [l.n_out for l in ls]
        actual = group_vmem_bytes(widths, plan.batch)
        if actual > budget:
            fs.append(Finding(
                rule="kernel.vmem-scratch", severity="error", tenant=tenant,
                layer=g.layers[0],
                detail=f"group {g.id} megakernel allocates {actual} B of "
                       f"VMEM (widths {widths}, batch {plan.batch}) - over "
                       f"the {budget} B budget; the launch OOMs"))
        elif actual > max(g.vmem_bytes, 1) * 4:
            fs.append(Finding(
                rule="kernel.vmem-scratch", severity="warning",
                tenant=tenant, layer=g.layers[0],
                detail=f"group {g.id} megakernel allocates {actual} B but "
                       f"the plan budgeted vmem_bytes={g.vmem_bytes} B - "
                       f"the fusion DP is charging far too little"))
        x = jax.ShapeDtypeStruct((plan.batch, ls[0].n_in), jnp.float32)
        weights = tuple(jax.ShapeDtypeStruct((a, b), jnp.int8)
                        for a, b in zip(widths, widths[1:]))
        w_scales = tuple(jax.ShapeDtypeStruct((n,), jnp.float32)
                         for n in widths[1:])
        biases = w_scales
        xs = jax.ShapeDtypeStruct((len(ls),), jnp.float32)
        try:
            out = jax.eval_shape(fused_mlp_q8, x, weights, w_scales,
                                 biases, xs)
        except Exception as e:
            fs.append(Finding(
                rule="kernel.eval-shape", severity="error", tenant=tenant,
                layer=g.layers[0],
                detail=f"fused_mlp_q8 fails to trace group {g.id} "
                       f"(widths {widths}): {e.__class__.__name__}: "
                       f"{str(e).splitlines()[0][:160]}"))
            continue
        if tuple(out.shape) != (plan.batch, widths[-1]):
            fs.append(Finding(
                rule="kernel.eval-shape", severity="error", tenant=tenant,
                layer=g.layers[0],
                detail=f"fused_mlp_q8 group {g.id} returns {out.shape}, "
                       f"engine expects ({plan.batch}, {widths[-1]})"))
    return fs


def _verify_int8_rejects_float(tenant, jax, jnp) -> list:
    """The quantized path's input contract: non-int8 operands must be
    rejected at trace time, not silently up-cast (which would run the f32
    MXU path at half the int8 peak and skip requantization)."""
    from repro.kernels.gemm_int8 import gemm_int8
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.int8)
    ws = jax.ShapeDtypeStruct((128,), jnp.float32)
    try:
        jax.eval_shape(functools.partial(gemm_int8, block_m=32,
                                         block_k=128, block_n=128), x, w, ws)
    except AssertionError:
        return []
    except Exception:
        return []                        # rejected, just not via assert
    return [Finding(
        rule="kernel.dtype-contract", severity="error", tenant=tenant,
        detail="gemm_int8 accepted a float32 activation operand - the "
               "int8-in contract is no longer enforced at trace time")]


# Canonical shapes exercising every library entry point the LM engine uses.
_LIBRARY_CASES = (
    ("tiled_gemm", "repro.kernels.tiled_gemm", "tiled_gemm",
     lambda jax, jnp: ((jax.ShapeDtypeStruct((64, 256), jnp.bfloat16),
                        jax.ShapeDtypeStruct((256, 512), jnp.bfloat16)),
                       {}, (64, 512))),
    ("flash_attention", "repro.kernels.flash_attention", "flash_attention",
     lambda jax, jnp: ((jax.ShapeDtypeStruct((1, 8, 256, 64), jnp.bfloat16),
                        jax.ShapeDtypeStruct((1, 2, 256, 64), jnp.bfloat16),
                        jax.ShapeDtypeStruct((1, 2, 256, 64), jnp.bfloat16)),
                       {"causal": True}, (1, 8, 256, 64))),
    ("rwkv6_scan", "repro.kernels.rwkv6", "rwkv6_scan",
     lambda jax, jnp: (tuple(
         [jax.ShapeDtypeStruct((4, 128, 64), jnp.float32)] * 4
         + [jax.ShapeDtypeStruct((64,), jnp.float32)]),
                       {}, (4, 128, 64))),
    ("linear_scan", "repro.kernels.rglru", "linear_scan",
     lambda jax, jnp: ((jax.ShapeDtypeStruct((2, 256, 128), jnp.float32),
                        jax.ShapeDtypeStruct((2, 256, 128), jnp.float32)),
                       {}, (2, 256, 128))),
)


def verify_kernel_library() -> list:
    """Self-check: every library entry point abstract-evaluates on a
    canonical shape and returns what its docstring promises.  Run by
    ``repro check`` so a contract-breaking kernel edit fails CI even when
    no committed plan exercises that kernel."""
    jax, jnp = _jax()
    if jax is None:                                      # pragma: no cover
        return [Finding(rule="kernel.eval-shape", severity="info",
                        tenant="library",
                        detail="jax unavailable; kernel self-check skipped")]
    import importlib
    fs = []
    for name, mod_name, attr, build in _LIBRARY_CASES:
        fn = getattr(importlib.import_module(mod_name), attr)
        argses, kwargs, want = build(jax, jnp)
        try:
            out = jax.eval_shape(functools.partial(fn, **kwargs), *argses)
        except Exception as e:
            fs.append(Finding(
                rule="kernel.eval-shape", severity="error", tenant="library",
                detail=f"{name} fails to trace its canonical shape: "
                       f"{e.__class__.__name__}: "
                       f"{str(e).splitlines()[0][:160]}"))
            continue
        if tuple(out.shape) != want:
            fs.append(Finding(
                rule="kernel.eval-shape", severity="error", tenant="library",
                detail=f"{name} returns shape {tuple(out.shape)} on its "
                       f"canonical case, contract says {want}"))
    return fs
