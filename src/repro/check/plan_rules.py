"""Layer 1: the plan verifier — every design rule a committed artifact must
prove, with zero execution.

Rules (stable ids; DR references per PAPER.md / EXPERIMENTS.md):

=======================  ==========================================  ========
rule                     invariant                                   paper
=======================  ==========================================  ========
plan.schema              known schema version, required sections     —
plan.unknown-key         no unrecognized top-level artifact keys     —
plan.layer-chain         edge graphs chain n_out -> n_in, indices    —
plan.tile-legal          api tiles legal for the target (DR1/DR1')   DR1
plan.tile-divides        blocks divide the padded layer shapes       DR1'
plan.spatial-budget      P_K*P_N cap, DR5 floors, band legality      DR3/DR5
plan.column-budget       fleet-wide band-1 columns fit usable_cols   DR6
plan.vmem-budget         fusion-group scratch fits the VMEM budget   DR7'
plan.latency-invariant   est == sum(parts) + crossings + overhead    DR7
plan.fusion-groups       groups consecutive, uniform, exhaustive     DR7'
plan.boundary-structure  boundaries exactly at group/regime changes  DR7
plan.serve-keys          slo/priority/resilience/batch policy legal  —
fleet.columns-overlap    tenant column ranges disjoint, in budget    DR6
fleet.budget             budgets cover planned latency + crossing    —
=======================  ==========================================  ========

``load_artifact`` decodes any supported artifact schema (v1..v6 planner
lineage — artifact schema versions 1/2/3 under planner versions plan-1..
plan-6) WITHOUT executing it; undecodable input raises
:class:`repro.check.ArtifactError` so the CLI exits 2 in one line.
"""

from __future__ import annotations

import json
import math
import pathlib

from repro import hw as hwlib
from repro.check import ArtifactError, Finding

# Relative slack for float identities that calibration rescales under.
_REL_TOL = 5e-3
# Fraction of VMEM the planner budgets for kernel working sets (the same
# constant core/tiling.plan_api searches under).
_VMEM_BUDGET_FRACTION = 0.75

_PLAN_KEYS = {"schema", "kind", "network", "target", "batch", "key",
              "layers", "boundaries", "fusion_groups", "totals", "serve"}
_FLEET_KEYS = {"schema", "kind", "name", "target", "key", "tenants",
               "totals"}
_TENANT_KEYS = {"net_id", "col_offset", "cols", "crossing_s",
                "latency_budget_s", "plan"}

_PRIORITIES = ("critical", "standard", "batch")
_RESILIENCE_KEYS = {"breaker_k", "breaker_cooldown", "retries", "backoff_s",
                    "deadline_factor"}
_DECODE_REGIMES = ("pipeline", "tiled")


def _close(a: float, b: float, *, rel: float = _REL_TOL,
           abs_tol: float = 1e-9) -> bool:
    return math.isclose(a, b, rel_tol=rel, abs_tol=abs_tol)


def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


# ---------------------------------------------------------------------------
# Artifact decoding (schema-tolerant, execution-free)
# ---------------------------------------------------------------------------

def unknown_key_findings(d: dict, known: set, *, what: str,
                         tenant: str | None = None) -> list:
    """Info findings for top-level keys the current schema does not define.

    Forward-compat is preserved (the loaders keep accepting them); the
    verifier surfaces them so a typo'd section (``"serv"``) is seen before
    it silently does nothing."""
    return [Finding(rule="plan.unknown-key", severity="info", tenant=tenant,
                    detail=f"{what} artifact carries unknown top-level key "
                           f"{k!r} (ignored by the loader)")
            for k in sorted(set(d) - known)]


def load_artifact(path):
    """Decode a committed plan artifact (DeploymentPlan or FleetPlan, any
    supported schema) into a FleetPlan + the load-time findings.

    Never executes the plan.  Undecodable/unsupported input raises
    :class:`ArtifactError` (the exit-2 path)."""
    from repro.plan.artifact import DeploymentPlan
    from repro.plan.multinet import FleetPlan
    p = pathlib.Path(path)
    try:
        text = p.read_text()
    except OSError as e:
        raise ArtifactError(f"{p}: {e.strerror or e}") from None
    try:
        d = json.loads(text)
    except json.JSONDecodeError as e:
        raise ArtifactError(f"{p}: malformed plan JSON "
                            f"({e.msg} at line {e.lineno})") from None
    if not isinstance(d, dict):
        raise ArtifactError(f"{p}: plan artifact must be a JSON object, "
                            f"got {type(d).__name__}")
    findings = []
    try:
        if "tenants" in d:
            findings += unknown_key_findings(d, _FLEET_KEYS, what="fleet",
                                             tenant=d.get("name"))
            for t in d.get("tenants", ()):
                if isinstance(t, dict):
                    findings += unknown_key_findings(
                        t, _TENANT_KEYS, what="tenant",
                        tenant=t.get("net_id"))
                    if isinstance(t.get("plan"), dict):
                        findings += unknown_key_findings(
                            t["plan"], _PLAN_KEYS, what="plan",
                            tenant=t.get("net_id"))
            fleet = FleetPlan.from_dict(d)
        else:
            findings += unknown_key_findings(d, _PLAN_KEYS, what="plan",
                                             tenant=d.get("network"))
            fleet = FleetPlan.from_plan(DeploymentPlan.from_dict(d))
    except (KeyError, TypeError, ValueError) as e:
        raise ArtifactError(
            f"{p}: undecodable plan artifact "
            f"({e.__class__.__name__}: {e})") from None
    return fleet, findings


# ---------------------------------------------------------------------------
# Single-plan rules
# ---------------------------------------------------------------------------

def verify_plan(plan, *, tenant: str | None = None, tpu=None,
                aie=None) -> list:
    """All layer-1 findings for one DeploymentPlan."""
    tpu = tpu if tpu is not None else hwlib.TPU_V5E
    aie = aie if aie is not None else hwlib.AIE_ML
    tenant = tenant if tenant is not None else plan.network
    fs: list = []
    fs += _rule_layer_chain(plan, tenant)
    if plan.target == "tpu":
        fs += _rule_tiles_tpu(plan, tenant, tpu)
    elif plan.target == "aie":
        fs += _rule_tiles_aie(plan, tenant, aie)
    fs += _rule_fusion_groups(plan, tenant, tpu)
    fs += _rule_boundaries(plan, tenant)
    fs += _rule_latency_invariant(plan, tenant)
    fs += _rule_serve_section(plan, tenant)
    return fs


def _rule_layer_chain(plan, tenant) -> list:
    fs = []
    idx = [l.index for l in plan.layers]
    if idx != sorted(set(idx)):
        fs.append(Finding(
            rule="plan.layer-chain", severity="error", tenant=tenant,
            detail=f"layer indices must be unique and ascending, got {idx}"))
    # Shape chaining only holds for edge graphs (LM attention fans one
    # activation into wq/wk/wv, all with the same n_in).
    if plan.kind == "edge":
        for prev, nxt in zip(plan.layers, plan.layers[1:]):
            if prev.n_out != nxt.n_in:
                fs.append(Finding(
                    rule="plan.layer-chain", severity="error", tenant=tenant,
                    layer=nxt.index,
                    detail=f"layer {nxt.name!r} consumes n_in={nxt.n_in} but "
                           f"{prev.name!r} produces n_out={prev.n_out}"))
    return fs


def _rule_tiles_tpu(plan, tenant, tpu) -> list:
    """DR1' legality: Pallas block shapes must be lane/sublane multiples
    and divide the padded layer extents the kernels run on."""
    fs = []
    lane = tpu.vreg_lane
    sub = tpu.sublanes_for(plan.itemsize)
    for l in plan.layers:
        bm, bk, bn = l.api_tile
        if bm <= 0 or bk <= 0 or bn <= 0:
            fs.append(Finding(
                rule="plan.tile-legal", severity="error", tenant=tenant,
                layer=l.index,
                detail=f"non-positive block {l.api_tile} on {l.name!r}"))
            continue
        if bm % sub or bk % lane or bn % lane:
            fs.append(Finding(
                rule="plan.tile-legal", severity="error", tenant=tenant,
                layer=l.index,
                detail=f"block {l.api_tile} on {l.name!r} is not a "
                       f"({sub}, {lane}, {lane}) multiple (itemsize "
                       f"{plan.itemsize})"))
        for extent, block, dim in ((plan.batch, bm, "M"),
                                   (l.n_in, bk, "K"), (l.n_out, bn, "N")):
            mult = sub if dim == "M" else lane
            if _ceil_to(extent, mult) % block:
                fs.append(Finding(
                    rule="plan.tile-divides", severity="error", tenant=tenant,
                    layer=l.index,
                    detail=f"{dim}-block {block} does not divide padded "
                           f"extent {_ceil_to(extent, mult)} "
                           f"({dim}={extent}) on {l.name!r}"))
    return fs


def _rule_tiles_aie(plan, tenant, aie) -> list:
    """DR1 (legal aie::mmul shapes), DR3/DR5 (split caps and floors),
    band legality for the paper-faithful target."""
    fs = []
    max_tiles = 12                       # planner._AIE_MAX_TILES_PER_LAYER
    for l in plan.layers:
        if l.regime == "pl":
            continue
        if tuple(l.api_tile) not in aie.legal_api_tiles_i8:
            fs.append(Finding(
                rule="plan.tile-legal", severity="error", tenant=tenant,
                layer=l.index,
                detail=f"api tile {tuple(l.api_tile)} on {l.name!r} is not "
                       f"a legal aie::mmul i8 shape"))
        if l.p_k * l.p_n > max_tiles or l.p_n > aie.rows \
                or l.p_k > aie.usable_cols:
            fs.append(Finding(
                rule="plan.spatial-budget", severity="error", tenant=tenant,
                layer=l.index,
                detail=f"split {l.p_k}x{l.p_n} on {l.name!r} exceeds the "
                       f"per-layer tile cap ({max_tiles}) or array dims"))
        q_k = math.ceil(l.n_in / max(l.p_k, 1))
        q_n = math.ceil(l.n_out / max(l.p_n, 1))
        if (l.p_k > 1 and q_k < 16) or (l.p_n > 1 and q_n < 32):
            fs.append(Finding(
                rule="plan.spatial-budget", severity="error", tenant=tenant,
                layer=l.index,
                detail=f"DR5 floor violated on {l.name!r}: split "
                       f"{l.p_k}x{l.p_n} leaves q_k={q_k}, q_n={q_n} "
                       f"(need q_k>=16 when P_K>1, q_n>=32 when P_N>1)"))
        if l.band not in (1, 2):
            fs.append(Finding(
                rule="plan.spatial-budget", severity="error", tenant=tenant,
                layer=l.index,
                detail=f"band {l.band} on {l.name!r} (AIE layers sit in "
                       f"band 1 or the spill band 2)"))
    return fs


def _rule_fusion_groups(plan, tenant, tpu) -> list:
    """DR7' structure: groups partition the layers into consecutive runs,
    each repeat- and regime-uniform, matching per-layer fuse_group ids;
    multi-layer group working sets fit the VMEM budget."""
    fs = []
    by_index = {l.index: l for l in plan.layers}
    seen: list = []
    for g in plan.fusion_groups:
        members = list(g.layers)
        if members != sorted(members) or \
                members != list(range(members[0], members[-1] + 1)):
            fs.append(Finding(
                rule="plan.fusion-groups", severity="error", tenant=tenant,
                layer=members[0] if members else None,
                detail=f"group {g.id} layers {members} are not consecutive"))
        missing = [i for i in members if i not in by_index]
        if missing:
            fs.append(Finding(
                rule="plan.fusion-groups", severity="error", tenant=tenant,
                detail=f"group {g.id} names layer indices {missing} the "
                       f"plan does not have"))
            continue
        ls = [by_index[i] for i in members]
        if len({l.repeat for l in ls}) > 1 or len({l.regime for l in ls}) > 1:
            fs.append(Finding(
                rule="plan.fusion-groups", severity="error", tenant=tenant,
                layer=members[0],
                detail=f"group {g.id} mixes repeats/regimes "
                       f"({[(l.repeat, l.regime) for l in ls]}) - a fused "
                       f"launch executes all members together"))
        bad_ids = [l.index for l in ls if l.fuse_group != g.id]
        if bad_ids:
            fs.append(Finding(
                rule="plan.fusion-groups", severity="error", tenant=tenant,
                layer=bad_ids[0],
                detail=f"layers {bad_ids} carry fuse_group != group id "
                       f"{g.id}"))
        seen += members
    if plan.fusion_groups and sorted(seen) != sorted(by_index):
        fs.append(Finding(
            rule="plan.fusion-groups", severity="error", tenant=tenant,
            detail=f"fusion groups cover layers {sorted(seen)} but the plan "
                   f"has {sorted(by_index)} (must partition exactly)"))
    budget = int(tpu.vmem_bytes * _VMEM_BUDGET_FRACTION)
    if plan.target == "tpu":
        for g in plan.fusion_groups:
            if g.vmem_bytes > budget:
                fs.append(Finding(
                    rule="plan.vmem-budget", severity="error", tenant=tenant,
                    layer=g.layers[0] if g.layers else None,
                    detail=f"group {g.id} working set {g.vmem_bytes} B "
                           f"exceeds the VMEM budget {budget} B "
                           f"({_VMEM_BUDGET_FRACTION:.0%} of "
                           f"{tpu.vmem_bytes} B)"))
    return fs


def _rule_boundaries(plan, tenant) -> list:
    """DR7 structure: a boundary charge exists exactly where the graph
    says one is crossed — after every fuse-group or regime change (TPU) /
    regime change (AIE) — and its regimes match the adjacent layers."""
    fs = []
    by_after = {b.after_layer: b for b in plan.boundaries}
    if len(by_after) != len(plan.boundaries):
        fs.append(Finding(
            rule="plan.boundary-structure", severity="error", tenant=tenant,
            detail="duplicate boundary after_layer entries"))
    expected = {}
    for prev, nxt in zip(plan.layers, plan.layers[1:]):
        crossed = (prev.regime != nxt.regime if plan.target == "aie"
                   else prev.fuse_group != nxt.fuse_group
                   or prev.regime != nxt.regime)
        if crossed:
            expected[prev.index] = (prev, nxt)
    for after, (prev, nxt) in expected.items():
        b = by_after.get(after)
        if b is None:
            fs.append(Finding(
                rule="plan.boundary-structure", severity="error",
                tenant=tenant, layer=after,
                detail=f"missing boundary after layer {after} "
                       f"({prev.name!r} -> {nxt.name!r} crosses a "
                       f"group/regime edge but charges nothing)"))
            continue
        if b.from_regime != prev.regime or b.to_regime != nxt.regime:
            fs.append(Finding(
                rule="plan.boundary-structure", severity="error",
                tenant=tenant, layer=after,
                detail=f"boundary after layer {after} says "
                       f"{b.from_regime}->{b.to_regime} but the layers are "
                       f"{prev.regime}->{nxt.regime}"))
        if b.crossing_s < 0:
            fs.append(Finding(
                rule="plan.boundary-structure", severity="error",
                tenant=tenant, layer=after,
                detail=f"negative crossing charge {b.crossing_s} after "
                       f"layer {after}"))
    for after in set(by_after) - set(expected):
        fs.append(Finding(
            rule="plan.boundary-structure", severity="error", tenant=tenant,
            layer=after,
            detail=f"boundary after layer {after} charges a crossing no "
                   f"group/regime change justifies"))
    return fs


def _rule_latency_invariant(plan, tenant) -> list:
    """The parts+overhead decomposition calibration rescales under:
    ``est_latency == sum(layer est x repeat) + sum(crossings) + overhead``
    with ``overhead >= 0``; and the fusion-group estimates must sum to the
    per-layer parts (the amortized shares, TPU plans)."""
    fs = []
    # The AIE totals sum un-repeated layer estimates (edge pipelines are
    # repeat-1; the spatial path has no per-launch dispatch to amortize).
    parts = sum(l.est_latency_s * (l.repeat if plan.target == "tpu" else 1)
                for l in plan.layers)
    crossings = sum(b.crossing_s for b in plan.boundaries)
    overhead = plan.est_latency_s - parts - crossings
    tol = _REL_TOL * max(plan.est_latency_s, 1e-12)
    if overhead < -tol:
        fs.append(Finding(
            rule="plan.latency-invariant", severity="error", tenant=tenant,
            detail=f"est_latency_s={plan.est_latency_s:.3e} is less than "
                   f"its parts (layers {parts:.3e} + crossings "
                   f"{crossings:.3e}): overhead {overhead:.3e} < 0"))
    if plan.est_latency_s <= 0 or plan.est_interval_s <= 0:
        fs.append(Finding(
            rule="plan.latency-invariant", severity="error", tenant=tenant,
            detail=f"totals must be positive (est_latency_s="
                   f"{plan.est_latency_s}, est_interval_s="
                   f"{plan.est_interval_s})"))
    if plan.target == "tpu" and plan.fusion_groups:
        group_sum = sum(g.est_latency_s for g in plan.fusion_groups)
        if not _close(group_sum, parts, abs_tol=tol):
            fs.append(Finding(
                rule="plan.latency-invariant", severity="error",
                tenant=tenant,
                detail=f"fusion-group estimates sum to {group_sum:.3e} but "
                       f"the amortized per-layer parts sum to {parts:.3e} "
                       f"(shares no longer decompose the group costs)"))
    return fs


def _rule_serve_section(plan, tenant) -> list:
    """Serve-section vocabulary: the knobs the router/batcher/supervisor
    read must be legal AND mutually consistent."""
    fs = []
    serve = plan.serve or {}

    def bad(detail, layer=None, severity="error"):
        fs.append(Finding(rule="plan.serve-keys", severity=severity,
                          tenant=tenant, layer=layer, detail=detail))

    if not isinstance(serve, dict):
        bad(f"serve section must be an object, got {type(serve).__name__}")
        return fs
    slo = serve.get("slo")
    if slo is not None:
        if not isinstance(slo, dict):
            bad(f"serve.slo must be an object, got {type(slo).__name__}")
        else:
            p95, p99 = slo.get("p95_s"), slo.get("p99_s")
            if not isinstance(p95, (int, float)) or p95 <= 0:
                bad(f"serve.slo.p95_s must be a positive number, got {p95!r}")
            if p99 is not None and (not isinstance(p99, (int, float))
                                    or (isinstance(p95, (int, float))
                                        and p99 < p95)):
                bad(f"serve.slo.p99_s={p99!r} must be >= p95_s={p95!r} "
                    f"(a p99 tighter than p95 is unsatisfiable)")
    prio = serve.get("priority")
    if prio is not None and prio not in _PRIORITIES:
        bad(f"serve.priority={prio!r} is not one of {_PRIORITIES}")
    res = serve.get("resilience")
    if res is not None:
        if not isinstance(res, dict):
            bad(f"serve.resilience must be an object, "
                f"got {type(res).__name__}")
        else:
            for k in sorted(set(res) - _RESILIENCE_KEYS):
                bad(f"serve.resilience carries unknown knob {k!r} "
                    f"(known: {sorted(_RESILIENCE_KEYS)})",
                    severity="warning")
            checks = (("breaker_k", 1), ("breaker_cooldown", 0),
                      ("retries", 0))
            for k, floor in checks:
                v = res.get(k)
                if v is not None and (not isinstance(v, int)
                                      or isinstance(v, bool) or v < floor):
                    bad(f"serve.resilience.{k}={v!r} must be an int "
                        f">= {floor}")
            for k, floor in (("backoff_s", 0.0), ("deadline_factor", 0.0)):
                v = res.get(k)
                if v is not None and (not isinstance(v, (int, float))
                                      or isinstance(v, bool) or v < floor
                                      or (k == "deadline_factor"
                                          and v <= 0)):
                    bad(f"serve.resilience.{k}={v!r} must be a number "
                        f"{'> 0' if k == 'deadline_factor' else '>= 0'}")
    dr = serve.get("decode_regime")
    if dr is not None and dr not in _DECODE_REGIMES:
        bad(f"serve.decode_regime={dr!r} is not one of {_DECODE_REGIMES}")
    qw = serve.get("quantize_weights")
    if qw is not None and not isinstance(qw, bool):
        bad(f"serve.quantize_weights must be a bool, got {qw!r}")
    # LM continuous-batching policy.
    for k, floor in (("slots", 1), ("admit_per_tick", 1),
                     ("max_queue_depth", 1), ("prefill_chunk", 1)):
        v = serve.get(k)
        if v is None:
            continue
        if not isinstance(v, int) or isinstance(v, bool) or v < floor:
            bad(f"serve.{k}={v!r} must be an int >= {floor} (or null)")
    slots = serve.get("slots")
    depth = serve.get("max_queue_depth")
    if isinstance(slots, int) and isinstance(depth, int) and depth < slots:
        bad(f"serve.max_queue_depth={depth} < slots={slots}: admission "
            f"would refuse requests the batcher has free slots for",
            severity="warning")
    if plan.kind == "lm" and slo is not None and slots is None:
        bad("LM tenant has an SLO but no batch policy (slots) - the "
            "batcher falls back to built-in defaults", severity="warning")
    return fs


# ---------------------------------------------------------------------------
# Fleet rules
# ---------------------------------------------------------------------------

def verify_fleet(fleet, *, tpu=None, aie=None) -> list:
    """All layer-1 findings for a FleetPlan: per-tenant plan rules plus the
    fleet-wide column-budget and latency-budget invariants."""
    tpu = tpu if tpu is not None else hwlib.TPU_V5E
    aie = aie if aie is not None else hwlib.AIE_ML
    fs: list = []
    for t in fleet.tenants:
        fs += verify_plan(t.plan, tenant=t.net_id, tpu=tpu, aie=aie)
        if t.crossing_s < 0:
            fs.append(Finding(
                rule="fleet.budget", severity="error", tenant=t.net_id,
                detail=f"negative crossing charge {t.crossing_s}"))
        planned = t.plan.est_latency_s + t.crossing_s
        if t.latency_budget_s < planned * (1 - _REL_TOL):
            fs.append(Finding(
                rule="fleet.budget", severity="warning", tenant=t.net_id,
                detail=f"latency budget {t.latency_budget_s:.3e}s is below "
                       f"the planned latency {planned:.3e}s - every request "
                       f"starts in violation"))
    if fleet.target == "aie":
        fs += _rule_fleet_columns(fleet, aie)
    if fleet.tenants:
        worst = max(t.total_latency_s for t in fleet.tenants)
        if not _close(fleet.est_latency_s, worst,
                      abs_tol=_REL_TOL * max(worst, 1e-12)):
            fs.append(Finding(
                rule="fleet.budget", severity="error", tenant=fleet.name,
                detail=f"fleet est_latency_s={fleet.est_latency_s:.3e} != "
                       f"worst tenant total {worst:.3e} (spatially "
                       f"concurrent nets are judged by the slowest)"))
    return fs


def _rule_fleet_columns(fleet, aie) -> list:
    """DR6 fleet-wide: band-1 columns across ALL tenants fit usable_cols,
    tenant ranges are disjoint, and each tenant's `cols` matches the
    band-1 column sum of its own plan."""
    fs = []
    total = 0
    spans = []
    for t in fleet.tenants:
        declared = sum(l.p_k for l in t.plan.layers
                       if l.regime == "aie" and l.band == 1)
        if t.cols != declared:
            fs.append(Finding(
                rule="fleet.columns-overlap", severity="error",
                tenant=t.net_id,
                detail=f"tenant declares cols={t.cols} but its plan's "
                       f"band-1 layers occupy {declared}"))
        if t.cols:
            spans.append((t.col_offset, t.col_offset + t.cols, t.net_id))
        total += t.cols
    if total > aie.usable_cols:
        fs.append(Finding(
            rule="plan.column-budget", severity="error", tenant=fleet.name,
            detail=f"fleet band-1 columns {total} exceed usable_cols="
                   f"{aie.usable_cols} (DR6: spill must go to band 2, not "
                   f"off the array)"))
    spans.sort()
    for (a0, a1, na), (b0, b1, nb) in zip(spans, spans[1:]):
        if b0 < a1:
            fs.append(Finding(
                rule="fleet.columns-overlap", severity="error", tenant=nb,
                detail=f"column range [{b0}, {b1}) overlaps {na!r}'s "
                       f"[{a0}, {a1})"))
    return fs
