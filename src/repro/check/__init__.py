"""``repro.check`` — the static design-rule verifier.

The paper's contribution is a set of *design rules*; until this package the
repo only enforced them dynamically (an over-budget fusion group was found
when an engine crashed mid-deploy).  ``repro.check`` proves plans and code
against the rules with ZERO execution, in three layers:

* **plan rules** (:mod:`repro.check.plan_rules`) — decode any
  DeploymentPlan/FleetPlan artifact and verify every invariant the planner
  is supposed to respect: tile legality, column/band budgets, fusion-group
  VMEM fit, the parts+overhead latency decomposition, serve-section knobs,
  DR7 boundary structure.
* **kernel contracts** (:mod:`repro.check.kernel_contracts`) — abstract-
  evaluate the repo's Pallas entry points via ``jax.eval_shape`` against
  the shapes a plan implies: block divisibility, dtype contracts, scratch
  accounting vs the plan's ``fusion_groups[].vmem_bytes`` estimate.
* **jax-hazard lint** (:mod:`repro.check.lint`) — stdlib-``ast`` rules over
  ``src/repro`` catching the bug classes earlier PRs fixed by hand: host
  syncs in serving hot paths, Python ``if`` on traced values,
  ``time``/RNG inside jitted functions, shared state mutated outside the
  lock, dict-order-dependent hashing near cache keys.

Every violation is a structured :class:`Finding`; the CLI surface is
``python -m repro check`` and the deploy gate is
:class:`repro.deploy.stages.VerifyStage` (fail-closed before engines).

Exit-code contract (matching ``benchmarks/trend.py``):

* ``0`` — clean (warnings and info findings do not fail the check);
* ``1`` — at least one error-severity finding;
* ``2`` — an artifact that cannot be decoded at all
  (:class:`ArtifactError`, reported as one line on stderr).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

SEVERITIES = ("error", "warning", "info")

#: Exit codes, trend.py style.
EXIT_CLEAN = 0
EXIT_FINDINGS = 1
EXIT_UNDECODABLE = 2


class ArtifactError(Exception):
    """An artifact that cannot be decoded as a plan/snapshot at all
    (malformed JSON, unsupported schema, missing required sections).

    Raised instead of letting ``json.JSONDecodeError`` stack-trace out of
    the CLI — the check reports it in one line with exit code 2, exactly
    like ``benchmarks.trend.SnapshotError``."""


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation (or advisory), machine-readable.

    ``rule`` is the stable dotted rule id (``plan.vmem-budget``,
    ``lint.host-sync``, …); ``tenant`` the fleet tenant (or file path for
    lint findings); ``layer`` the layer index / line number when the
    finding is that specific."""

    rule: str
    severity: str                       # "error" | "warning" | "info"
    detail: str
    tenant: str | None = None
    layer: int | None = None

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}, "
                             f"got {self.severity!r}")

    def to_dict(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "tenant": self.tenant, "layer": self.layer,
                "detail": self.detail}

    def __str__(self) -> str:
        where = self.tenant or "-"
        if self.layer is not None:
            where += f":{self.layer}"
        return f"[{self.severity:<7}] {self.rule:<24} {where:<28} {self.detail}"


@dataclasses.dataclass
class CheckReport:
    """All findings from one check run, plus the exit-code logic."""

    findings: list = dataclasses.field(default_factory=list)
    checked: list = dataclasses.field(default_factory=list)  # what was seen

    def extend(self, findings) -> "CheckReport":
        self.findings.extend(findings)
        return self

    def errors(self) -> list:
        return [f for f in self.findings if f.severity == "error"]

    def counts(self) -> dict:
        out = {s: 0 for s in SEVERITIES}
        for f in self.findings:
            out[f.severity] += 1
        return out

    @property
    def exit_code(self) -> int:
        return EXIT_FINDINGS if self.errors() else EXIT_CLEAN

    def to_dict(self) -> dict:
        return {"version": 1,
                "checked": list(self.checked),
                "counts": self.counts(),
                "findings": [f.to_dict() for f in self.findings]}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    def __str__(self) -> str:
        if not self.findings:
            return "check: clean (no findings)"
        lines = [str(f) for f in self.findings]
        c = self.counts()
        lines.append(f"check: {len(self.findings)} finding(s) "
                     f"({c['error']} error, {c['warning']} warning, "
                     f"{c['info']} info)")
        return "\n".join(lines)


class PlanVerificationError(Exception):
    """A plan failed verification at deploy time (the fail-closed gate in
    :class:`repro.deploy.stages.VerifyStage`).  Carries the findings."""

    def __init__(self, findings):
        self.findings = list(findings)
        errs = [f for f in self.findings if f.severity == "error"]
        super().__init__(
            f"{len(errs)} design-rule violation(s): "
            + "; ".join(f"{f.rule} ({f.tenant or '-'})" for f in errs[:4])
            + ("; ..." if len(errs) > 4 else ""))


# ---------------------------------------------------------------------------
# Aggregation entry points
# ---------------------------------------------------------------------------

def check_fleet(fleet, *, tpu=None, aie=None, kernels: bool = True) -> list:
    """All plan-layer + kernel-layer findings for one FleetPlan (or a bare
    DeploymentPlan, wrapped as a single-tenant fleet)."""
    from repro.check import kernel_contracts, plan_rules
    from repro.plan.multinet import FleetPlan
    if not isinstance(fleet, FleetPlan):
        fleet = FleetPlan.from_plan(fleet)
    findings = plan_rules.verify_fleet(fleet, tpu=tpu, aie=aie)
    if kernels:
        for t in fleet.tenants:
            findings.extend(kernel_contracts.verify_plan_kernels(
                t.plan, tenant=t.net_id, tpu=tpu))
    return findings


def check_artifact(path, *, tpu=None, aie=None, kernels: bool = True) -> list:
    """Decode one committed plan artifact (any supported schema) and verify
    it.  Undecodable input raises :class:`ArtifactError` (exit code 2)."""
    from repro.check import plan_rules
    fleet, load_findings = plan_rules.load_artifact(path)
    return load_findings + check_fleet(fleet, tpu=tpu, aie=aie,
                                       kernels=kernels)


def check_snapshot(path) -> list:
    """Validate one committed BENCH snapshot through the same strict shape
    ``benchmarks.trend`` enforces.  Undecodable -> :class:`ArtifactError`."""
    p = pathlib.Path(path)
    try:
        text = p.read_text()
    except OSError as e:
        raise ArtifactError(f"{p}: {e.strerror or e}") from None
    try:
        payload = json.loads(text)
    except json.JSONDecodeError as e:
        raise ArtifactError(f"{p}: malformed snapshot JSON "
                            f"({e.msg} at line {e.lineno})") from None
    if not isinstance(payload, dict):
        raise ArtifactError(f"{p}: snapshot must be a JSON object, "
                            f"got {type(payload).__name__}")
    rows = payload.get("rows", [])
    if not isinstance(rows, list) or any(
            not isinstance(r, dict) or "name" not in r
            or "us_per_call" not in r for r in rows):
        raise ArtifactError(f"{p}: 'rows' must be a list of "
                            f"{{name, us_per_call}} objects")
    findings = []
    if not rows:
        findings.append(Finding(
            rule="snapshot.empty", severity="warning", tenant=str(p),
            detail="snapshot has no rows - nothing to trend-gate"))
    for i, r in enumerate(rows):
        v = r["us_per_call"]
        if not isinstance(v, (int, float)) or isinstance(v, bool) \
                or v != v or v < 0:
            findings.append(Finding(
                rule="snapshot.row-value", severity="error", tenant=str(p),
                layer=i,
                detail=f"row {r['name']!r}: us_per_call must be a "
                       f"non-negative number, got {v!r}"))
    return findings


def check_tree(root=".", *, kernels: bool = True,
               lint: bool = True) -> CheckReport:
    """The full repo check: lint ``src/repro``, verify every committed
    artifact under ``deployments/``, validate every BENCH snapshot under
    ``bench/``.  This is what ``python -m repro check`` and CI run."""
    from repro.check import lint as lint_mod
    root = pathlib.Path(root)
    report = CheckReport()
    if lint:
        src = root / "src" / "repro"
        if src.is_dir():
            files = sorted(src.rglob("*.py"))
            report.extend(lint_mod.lint_paths(files))
            report.checked.append(f"lint:{len(files)} files")
    plans = sorted((root / "deployments").glob("*.json")) \
        if (root / "deployments").is_dir() else []
    for p in plans:
        report.extend(check_artifact(p, kernels=kernels))
        report.checked.append(f"plan:{p.name}")
    snaps = sorted((root / "bench").rglob("BENCH_*.json")) \
        if (root / "bench").is_dir() else []
    for p in snaps:
        report.extend(check_snapshot(p))
        report.checked.append(f"snapshot:{p.name}")
    return report
