"""Layer 3: the jax-hazard lint — stdlib-``ast`` rules over ``src/repro``.

Each rule encodes a bug class an earlier PR fixed by hand; the lint keeps
it fixed.  Findings reuse :class:`repro.check.Finding` with ``tenant`` set
to the file path and ``layer`` to the line number.

Rules:

* ``lint.host-sync`` — ``.item()``, ``np.asarray``/``np.array``, and
  ``block_until_ready`` inside the serving hot paths (the intra-module
  call graphs rooted at ``ContinuousBatcher.step``/``.tick`` and
  ``EdgeEngine.infer``).  Each of these blocks the host on the device and
  serializes the dispatch pipeline mid-request.
* ``lint.traced-if`` — a Python ``if`` on a non-static parameter of a
  ``jax.jit``-decorated function: the branch runs on a tracer and raises
  ``TracerBoolConversionError`` at the first real call.
* ``lint.time-in-jit`` — ``time.time()``/``perf_counter()`` or host RNG
  (``random.*``, ``np.random.*``) inside a jitted function: the value is
  baked in at trace time and never changes again.
* ``lint.unlocked-shared-state`` — a class that guards itself with
  ``self._lock`` (``Tracer``-style) mutating an attribute outside a
  ``with self._lock:`` block in a non-``__init__`` method.
* ``lint.dict-order-hash`` — feeding ``json.dumps`` without
  ``sort_keys=True`` into a function that also hashes (``hashlib``):
  plan-cache keys must not depend on dict insertion order.

Per-line suppression::

    y = np.asarray(logits)  # repro: check-ok(lint.host-sync)

A bare ``# repro: check-ok`` suppresses every rule on that line.  The
suppression must name the finding's rule (or be bare) and sit on the
flagged line itself.
"""

from __future__ import annotations

import ast
import pathlib
import re

from repro.check import Finding

#: (class name, method name) roots of the serving hot paths.
HOT_PATH_ROOTS = (("ContinuousBatcher", "step"),
                  ("ContinuousBatcher", "tick"),
                  ("EdgeEngine", "infer"))

_SUPPRESS_RE = re.compile(r"#\s*repro:\s*check-ok(?:\(([^)]*)\))?")
_NP_NAMES = {"np", "numpy", "onp"}
_CLOCK_ATTRS = {"time", "perf_counter", "perf_counter_ns", "monotonic",
                "monotonic_ns"}


def _suppressions(source: str) -> dict:
    """line number -> set of suppressed rules (empty set == all rules)."""
    out = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            rules = m.group(1)
            out[i] = {r.strip() for r in rules.split(",")} if rules else set()
    return out


def _dotted(node) -> str | None:
    """'np.random.default_rng' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_decorated(fn) -> tuple[bool, set]:
    """(jitted?, static parameter names) from the decorator list."""
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = _dotted(target) or ""
        statics = set()
        call = dec if isinstance(dec, ast.Call) else None
        if name.endswith("partial") and call and call.args:
            inner = _dotted(call.args[0]) or ""
            if inner in ("jax.jit", "jit"):
                for kw in call.keywords:
                    if kw.arg == "static_argnames":
                        statics |= {e.value
                                    for e in ast.walk(kw.value)
                                    if isinstance(e, ast.Constant)
                                    and isinstance(e.value, str)}
                return True, statics
        elif name in ("jax.jit", "jit"):
            if call:
                for kw in call.keywords:
                    if kw.arg == "static_argnames":
                        statics |= {e.value
                                    for e in ast.walk(kw.value)
                                    if isinstance(e, ast.Constant)
                                    and isinstance(e.value, str)}
            return True, statics
    return False, set()


def lint_source(source: str, path: str) -> list:
    """All lint findings for one module's source text."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(rule="lint.syntax", severity="error", tenant=path,
                        layer=e.lineno,
                        detail=f"file does not parse: {e.msg}")]
    suppress = _suppressions(source)
    findings = []

    def emit(rule, lineno, detail, severity="error"):
        rules = suppress.get(lineno)
        if rules is not None and (not rules or rule in rules):
            return
        findings.append(Finding(rule=rule, severity=severity, tenant=path,
                                layer=lineno, detail=detail))

    _lint_host_sync(tree, emit)
    _lint_jit_bodies(tree, emit)
    _lint_unlocked_state(tree, emit)
    _lint_dict_order_hash(tree, emit)
    return findings


def lint_paths(paths) -> list:
    findings = []
    for p in paths:
        p = pathlib.Path(p)
        findings += lint_source(p.read_text(), p.as_posix())
    return findings


# ---------------------------------------------------------------------------
# lint.host-sync
# ---------------------------------------------------------------------------

def _lint_host_sync(tree, emit) -> None:
    """Walk the intra-module call graph from the hot-path roots and flag
    host-synchronizing calls anywhere reachable."""
    module_funcs = {}                    # name -> FunctionDef (module level)
    methods = {}                         # (class, method) -> FunctionDef
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            module_funcs[node.name] = node
        elif isinstance(node, ast.ClassDef):
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    methods[(node.name, item.name)] = item

    def callees(owner_class, fn):
        out = []
        for call in ast.walk(fn):
            if not isinstance(call, ast.Call):
                continue
            f = call.func
            if isinstance(f, ast.Attribute) and \
                    isinstance(f.value, ast.Name) and f.value.id == "self" \
                    and (owner_class, f.attr) in methods:
                out.append((owner_class, f.attr))
            elif isinstance(f, ast.Name) and f.id in module_funcs:
                out.append((None, f.id))
        return out

    roots = [(c, m) for (c, m) in HOT_PATH_ROOTS if (c, m) in methods]
    seen, queue = set(), list(roots)
    while queue:
        key = queue.pop()
        if key in seen:
            continue
        seen.add(key)
        fn = methods[key] if key[0] else module_funcs[key[1]]
        for nxt in callees(key[0], fn):
            if nxt not in seen:
                queue.append(nxt)

    for cls, name in seen:
        fn = methods[(cls, name)] if cls else module_funcs[name]
        where = f"{cls}.{name}" if cls else name
        for call in ast.walk(fn):
            if not isinstance(call, ast.Call):
                continue
            f = call.func
            sync = None
            if isinstance(f, ast.Attribute):
                dotted = _dotted(f) or ""
                if f.attr == "item" and not call.args:
                    sync = ".item()"
                elif f.attr == "block_until_ready" \
                        or dotted == "jax.block_until_ready":
                    sync = "block_until_ready"
                elif dotted.split(".")[0] in _NP_NAMES \
                        and f.attr in ("asarray", "array"):
                    sync = dotted
            if sync:
                emit("lint.host-sync", call.lineno,
                     f"{sync} in serving hot path (reachable from "
                     f"{where}, rooted at "
                     f"{'/'.join(f'{c}.{m}' for c, m in roots)}): blocks "
                     f"the host on the device mid-request")


# ---------------------------------------------------------------------------
# lint.traced-if / lint.time-in-jit
# ---------------------------------------------------------------------------

def _lint_jit_bodies(tree, emit) -> None:
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        jitted, statics = _is_jit_decorated(fn)
        if not jitted:
            continue
        params = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                  + fn.args.kwonlyargs)} - statics - {"self"}
        for node in ast.walk(fn):
            if isinstance(node, ast.If):
                names = {n.id for n in ast.walk(node.test)
                         if isinstance(n, ast.Name)}
                traced = sorted(names & params)
                if traced:
                    emit("lint.traced-if", node.lineno,
                         f"Python `if` on traced parameter(s) "
                         f"{', '.join(traced)} inside jitted "
                         f"{fn.name!r}: raises TracerBoolConversionError "
                         f"at call time (use lax.cond / mark static)")
            elif isinstance(node, ast.Call):
                dotted = _dotted(node.func) or ""
                parts = dotted.split(".")
                if dotted.startswith("time.") and parts[-1] in _CLOCK_ATTRS:
                    emit("lint.time-in-jit", node.lineno,
                         f"{dotted}() inside jitted {fn.name!r}: the clock "
                         f"reads once at trace time and is constant "
                         f"thereafter")
                elif parts[0] == "random" or (len(parts) >= 2
                                              and parts[0] in _NP_NAMES
                                              and parts[1] == "random"):
                    emit("lint.time-in-jit", node.lineno,
                         f"host RNG {dotted}() inside jitted {fn.name!r}: "
                         f"the draw is baked in at trace time (thread a "
                         f"jax.random key instead)")


# ---------------------------------------------------------------------------
# lint.unlocked-shared-state
# ---------------------------------------------------------------------------

def _under_lock(node, parents) -> bool:
    n = parents.get(id(node))
    while n is not None:
        if isinstance(n, ast.With):
            for item in n.items:
                for sub in ast.walk(item.context_expr):
                    if isinstance(sub, ast.Attribute) and \
                            sub.attr.endswith("_lock"):
                        return True
        n = parents.get(id(n))
    return False


def _lint_unlocked_state(tree, emit) -> None:
    """Classes that allocate ``self._lock`` in ``__init__`` have declared
    their mutable state shared; every other method must mutate it under
    the lock."""
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        init = next((m for m in cls.body
                     if isinstance(m, ast.FunctionDef)
                     and m.name == "__init__"), None)
        if init is None or not any(
                isinstance(t, ast.Attribute) and t.attr == "_lock"
                for a in ast.walk(init) if isinstance(a, ast.Assign)
                for t in a.targets):
            continue
        for m in cls.body:
            if not isinstance(m, ast.FunctionDef) or m.name == "__init__":
                continue
            parents = {id(child): parent
                       for parent in ast.walk(m)
                       for child in ast.iter_child_nodes(parent)}
            for node in ast.walk(m):
                if isinstance(node, (ast.Assign, ast.AugAssign)):
                    targets = node.targets if isinstance(node, ast.Assign) \
                        else [node.target]
                    for t in targets:
                        if isinstance(t, ast.Attribute) and \
                                isinstance(t.value, ast.Name) and \
                                t.value.id == "self" and \
                                not _under_lock(node, parents):
                            emit("lint.unlocked-shared-state", node.lineno,
                                 f"{cls.name}.{m.name} mutates "
                                 f"self.{t.attr} outside `with "
                                 f"self._lock:` - {cls.name} declared its "
                                 f"state shared by allocating the lock")


# ---------------------------------------------------------------------------
# lint.dict-order-hash
# ---------------------------------------------------------------------------

def _lint_dict_order_hash(tree, emit) -> None:
    """A function that both hashes and serializes must serialize
    deterministically: ``json.dumps`` without ``sort_keys=True`` next to a
    ``hashlib`` call makes cache keys depend on dict insertion order."""
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        hashes = any(
            (_dotted(c.func) or "").startswith("hashlib.")
            for c in ast.walk(fn) if isinstance(c, ast.Call))
        if not hashes:
            continue
        for c in ast.walk(fn):
            if not isinstance(c, ast.Call):
                continue
            if (_dotted(c.func) or "") != "json.dumps":
                continue
            sorted_kw = any(
                kw.arg == "sort_keys" and
                isinstance(kw.value, ast.Constant) and kw.value.value is True
                for kw in c.keywords)
            if not sorted_kw:
                emit("lint.dict-order-hash", c.lineno,
                     f"json.dumps without sort_keys=True inside hashing "
                     f"function {fn.name!r}: the digest depends on dict "
                     f"insertion order")
