"""Serving: engine, continuous batcher, int8 path, multi-tenant router.

``engine`` holds the step builders, the plan-driven :class:`ContinuousBatcher`
and the :class:`EdgeEngine` plan executor; ``router``/``tenant``/``metrics``
form the multi-tenant runtime over a :class:`repro.plan.FleetPlan` —
co-resident networks dispatched by net id under per-tenant latency budgets.
"""

from repro.serve.metrics import TenantMetrics, write_serve_snapshots
from repro.serve.router import Router, TenantOverBudget, TenantQueueFull
from repro.serve.tenant import Tenant, edge_tenant, lm_tenant, plan_priority

__all__ = ["Router", "Tenant", "TenantMetrics", "TenantOverBudget",
           "TenantQueueFull", "edge_tenant", "lm_tenant", "plan_priority",
           "write_serve_snapshots"]
