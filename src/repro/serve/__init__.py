"""Serving: engine, continuous batcher, int8 path, multi-tenant router.

``engine`` holds the step builders, the plan-driven :class:`ContinuousBatcher`
and the :class:`EdgeEngine` plan executor; ``router``/``tenant``/``metrics``
form the multi-tenant runtime over a :class:`repro.plan.FleetPlan` —
co-resident networks dispatched by net id under per-tenant latency budgets.
``resilience`` supervises it all: per-tenant circuit breakers, bounded
retries, deadlines and the fused → per-layer → shed degradation ladder
(fault taxonomy + deterministic injection live in :mod:`repro.faults`).
"""

from repro.serve.metrics import TenantMetrics, write_serve_snapshots
from repro.serve.resilience import CircuitBreaker, Supervisor
from repro.serve.router import (Router, TenantBreakerOpen, TenantFaulted,
                                TenantOverBudget, TenantQueueFull)
from repro.serve.tenant import Tenant, edge_tenant, lm_tenant, plan_priority

__all__ = ["CircuitBreaker", "Router", "Supervisor", "Tenant",
           "TenantMetrics", "TenantBreakerOpen", "TenantFaulted",
           "TenantOverBudget", "TenantQueueFull", "edge_tenant", "lm_tenant",
           "plan_priority", "write_serve_snapshots"]
