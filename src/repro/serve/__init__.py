"""Serving: engine, continuous batcher, int8 path."""
