"""Request router: multi-tenant dispatch over a co-residency FleetPlan.

The router is the runtime half of :func:`repro.plan.plan_fleet`: one
:class:`~repro.serve.tenant.Tenant` (engine + metrics + budget) per
co-resident network, dispatch by net id, and per-tenant latency-budget
enforcement.

Two dispatch surfaces, matching the two serving paths:

* **edge** — :meth:`infer` is synchronous: route to the tenant's
  :class:`EdgeEngine`, time the call, record it against the tenant's budget.
* **lm** — :meth:`submit` enqueues a request on the tenant's plan-driven
  :class:`ContinuousBatcher`; :meth:`step` ticks every LM tenant once
  (round-robin, so one tenant's burst cannot starve another) and completes
  request latencies as they drain.  The idle path blocks in
  ``queue.get(timeout=...)`` instead of spinning.

Budget enforcement is two-level: every over-budget request increments the
tenant's violation counters, and with ``shed_after=k`` the router starts
REFUSING (:class:`TenantOverBudget`) a tenant's traffic after ``k``
consecutive violations — shedding one misbehaving tenant instead of letting
it drag every co-resident net past its deadline.  Shedding is a half-open
circuit: after ``k`` consecutive refusals one probe request is admitted; a
within-budget probe resets the violation streak and re-opens the tenant, an
over-budget probe keeps it shed.  :meth:`reset_metrics` re-opens
unconditionally.

Two further plan-driven controls:

* **Queue-depth admission** — an LM tenant whose pending queue has reached
  its plan's ``serve["max_queue_depth"]`` bound is refused
  (:class:`TenantQueueFull`) at submit time, BEFORE the backlog grows past
  the point where the tail request could still meet any latency budget —
  back-pressure at admission instead of shedding after the damage.

* **Drift watcher** — with ``drift_threshold=r`` the router compares a
  tenant's measured service time against its planned latency after every
  completed request; when the ratio leaves ``[1/r, r]`` (and
  ``drift_min_samples`` observations exist) it triggers a FLEET-WIDE
  recalibration: :func:`repro.plan.calibrate.recalibrate_fleet` feeds the
  measured latencies back into the plan cache and replans the ``FleetPlan``
  in place (costs + budgets move; tiles and column assignments stay), and
  the router swaps the replanned fleet into its live tenants.  This closes
  the characterize -> plan -> serve -> drift -> replan loop fleet-wide.
  The measured quantity is chosen per tenant kind so it is the SAME
  quantity the plan estimates: edge tenants feed request p50 (their request
  IS the planned pipeline), LM tenants feed the batcher's decomposed
  **decode-step** p50 (an LM plan's graph models one decode step; an LM
  request's end-to-end latency includes queue wait, so recalibrating from
  it under a burst would bake transient load into the cost model).  The
  decode-step windows are maintained by the batcher unconditionally —
  LM drift works with tracing disabled.

* **SLO-aware priority scheduling** — with ``slo=`` (a
  :class:`repro.obs.slo.SloMonitor`) the router feeds every completed
  request into the monitor and turns its burn-rate signal into scheduling:
  LM tenants tick priority-first, and while any tenant is actively burning
  its p95 budget, strictly lower-priority tenants admit nothing
  (``admit_cap=0`` — live slots keep decoding) and have their queue-depth
  bound halved.  Deferral ages out after ``defer_limit`` consecutive ticks
  so a backlog is slowed, never starved; shedding remains the last resort.
  Every deferral lands as a ``sched/defer`` audit span.

* **Fault isolation & the supervisor** — engine exceptions during
  :meth:`infer` or an LM tick are CAUGHT: the failure is booked against
  that tenant (``TenantMetrics.failures``, a ``fault/<kind>`` audit span)
  and surfaced as :class:`TenantFaulted`, while every co-resident tenant
  keeps draining.  With ``resilience=True`` (what ``Deployment.serve``
  passes) a :class:`~repro.serve.resilience.Supervisor` additionally gives
  each tenant bounded retry-with-backoff, per-request deadlines from the
  plan's ``serve["slo"]`` budget, a circuit breaker
  (:class:`TenantBreakerOpen` while open; deterministic half-open probe),
  and the fused → per-layer → shed degradation ladder.  A drift-watcher
  replan that FAILS falls back to the current fleet plan with a
  ``degrade/replan`` audit span instead of propagating; explicit
  :meth:`replan_fleet` calls still raise.  :meth:`arm_faults` threads a
  deterministic :class:`repro.faults.FaultInjector` through every engine
  hook for chaos testing.

Pass ``tracer=`` (a :class:`repro.obs.Tracer`) to thread request-grain
spans through every tenant engine: edge requests emit ``infer`` +
``request`` spans, LM requests decompose into ``queue`` / ``prefill_chunk``
/ ``decode_step`` / ``request`` spans keyed by the request id as trace id.
``report()`` attaches each engine's per-kind service-time aggregates under
``"spans"`` regardless of tracing, so snapshots carry the decomposition.
"""

from __future__ import annotations

import time
from typing import Iterable

from repro.faults import InjectedFault, fault_kind
from repro.obs import NULL_TRACER
from repro.obs.slo import priority_rank
from repro.serve.resilience import Supervisor
from repro.serve.tenant import Tenant, edge_tenant, lm_tenant


class TenantOverBudget(RuntimeError):
    """Raised when a shedding router refuses a persistently late tenant."""


class TenantQueueFull(TenantOverBudget):
    """Raised when a tenant's backlog hits its plan's queue-depth bound."""


class TenantFaulted(TenantOverBudget):
    """Raised when a tenant's request FAILED (engine exception, non-finite
    output) rather than ran late.  The failure is already booked against
    the tenant; co-resident tenants are unaffected."""


class TenantBreakerOpen(TenantFaulted):
    """Raised while a tenant's circuit breaker refuses traffic (open state,
    between half-open probes)."""


class Router:
    def __init__(self, tenants: Iterable[Tenant], *,
                 shed_after: int | None = None, fleet=None,
                 drift_threshold: float | None = None,
                 drift_min_samples: int = 5, cache=None, tracer=None,
                 slo=None, defer_limit: int = 4, resilience=None):
        self._tenants: dict[str, Tenant] = {}
        for t in tenants:
            if t.net_id in self._tenants:
                raise ValueError(f"duplicate tenant id {t.net_id!r}")
            self._tenants[t.net_id] = t
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if tracer is not None:
            # Retrofit the shared tracer onto every tenant engine, labeled
            # by NET ID (the engine's own cfg.name default can collide when
            # duplicate nets carry a '#index').
            for t in self._tenants.values():
                t.engine.tracer = tracer
                t.engine.trace_label = t.net_id
        self.shed_after = shed_after
        self.fleet = fleet
        if drift_threshold is not None and drift_threshold <= 1.0:
            raise ValueError(f"drift_threshold must be > 1 (a measured/"
                             f"planned ratio band), got {drift_threshold}")
        self.drift_threshold = drift_threshold
        self.drift_min_samples = drift_min_samples
        self._cache = cache
        self.replans = 0
        self._inflight: dict[str, list[tuple]] = {
            nid: [] for nid in self._tenants}
        self._refused: dict[str, int] = {nid: 0 for nid in self._tenants}
        # SLO-aware scheduling (see repro.obs.slo): the monitor is fed
        # every completed request and read by the tick/admission policy.
        self.slo = slo
        if defer_limit < 1:
            raise ValueError(f"defer_limit must be >= 1, got {defer_limit}")
        self.defer_limit = defer_limit
        self._defer_streak: dict[str, int] = {
            nid: 0 for nid in self._tenants}
        # Supervised dispatch (repro.serve.resilience): True builds a
        # Supervisor from each tenant's plan knobs; a Supervisor instance
        # is adopted as-is; None/False keeps raw dispatch (failures are
        # still isolated and counted — only breaker/retry/deadline/ladder
        # need the supervisor).
        if resilience is True:
            sup = Supervisor(tracer=self.tracer)
            for t in self._tenants.values():
                sup.register(t.net_id, t.plan)
        else:
            sup = resilience or None
        self.supervisor = sup
        self.replan_failures = 0

    # -- construction -----------------------------------------------------
    @classmethod
    def from_fleet(cls, fleet, *, engines: dict | None = None,
                   lm: dict | None = None, shed_after: int | None = None,
                   drift_threshold: float | None = None,
                   drift_min_samples: int = 5, cache=None, tracer=None,
                   slo=None, defer_limit: int = 4, resilience=None,
                   x_scale: float = 0.05, seed: int = 0) -> "Router":
        """Build a router from a :class:`FleetPlan`.

        Edge tenants get an :class:`EdgeEngine` automatically (fresh params
        unless ``engines[net_id]`` supplies a pre-built engine).  LM tenants
        need weights, so pass ``lm={net_id: (cfg, params)}`` (batcher built
        plan-driven) or a ready engine via ``engines``.  With
        ``drift_threshold`` set the router watches measured/planned drift and
        recalibrates + replans the fleet when it trips (see module doc);
        ``cache`` is the plan cache the recalibration writes through.
        """
        tenants = []
        for tp in fleet.tenants:
            if engines and tp.net_id in engines:
                tenants.append(Tenant(
                    net_id=tp.net_id, plan=tp.plan,
                    engine=engines[tp.net_id],
                    latency_budget_s=tp.latency_budget_s))
            elif tp.plan.kind == "lm":
                if not lm or tp.net_id not in lm:
                    raise ValueError(
                        f"LM tenant {tp.net_id!r} needs (cfg, params) via "
                        f"lm= or a pre-built engine via engines=")
                cfg, params = lm[tp.net_id]
                tenants.append(lm_tenant(tp, cfg, params))
            else:
                tenants.append(edge_tenant(tp, x_scale=x_scale, seed=seed))
        return cls(tenants, shed_after=shed_after, fleet=fleet,
                   drift_threshold=drift_threshold,
                   drift_min_samples=drift_min_samples, cache=cache,
                   tracer=tracer, slo=slo, defer_limit=defer_limit,
                   resilience=resilience)

    def arm_faults(self, injector) -> "Router":
        """Thread a :class:`repro.faults.FaultInjector` through every hook
        this router owns (each tenant engine + the supervisor's replan
        hook).  Arm AFTER warmup, so compile-time traffic doesn't consume
        scheduled fault indices.  Builds a default supervisor if none is
        attached — injected faults without a breaker would just be noise.
        Returns self for chaining."""
        if self.supervisor is None:
            sup = Supervisor(tracer=self.tracer)
            for t in self._tenants.values():
                sup.register(t.net_id, t.plan)
            self.supervisor = sup
        self.supervisor.injector = injector
        for t in self._tenants.values():
            if hasattr(t.engine, "injector"):
                t.engine.injector = injector
        return self

    # -- lookup -----------------------------------------------------------
    def tenant(self, net_id: str) -> Tenant:
        try:
            return self._tenants[net_id]
        except KeyError:
            raise KeyError(f"unknown net id {net_id!r}; tenants: "
                           f"{sorted(self._tenants)}") from None

    @property
    def net_ids(self) -> list[str]:
        return list(self._tenants)

    def over_budget(self, net_id: str) -> bool:
        """True when the tenant is currently shed (consecutive violations
        reached ``shed_after``)."""
        t = self.tenant(net_id)
        return (self.shed_after is not None
                and t.metrics.consecutive_violations >= self.shed_after)

    def queue_depth_bound(self, net_id: str) -> int | None:
        """The tenant plan's pending-queue bound (None = unbounded).  The
        fleet planner derives it from the serve policy (``queue_depth_factor
        x slots``): a backlog deeper than a few full slot generations cannot
        land within any budget derived from the planned latency."""
        t = self.tenant(net_id)
        serve = getattr(t.plan, "serve", None) or {}
        return serve.get("max_queue_depth")

    def _admission_check(self, t: Tenant):
        # Queue-depth-aware admission (LM path): refuse BEFORE the backlog
        # outgrows the plan's depth bound, not only after budget violations.
        bound = self.queue_depth_bound(t.net_id)
        if bound is not None and t.kind == "lm":
            # SLO pressure halves a lower-priority tenant's depth bound
            # while a higher-priority tenant is burning budget: its backlog
            # will drain slower under deferral, so the same depth would
            # mean strictly worse tail latency for its own requests.
            pressure = (self.slo.pressure_rank()
                        if self.slo is not None else None)
            if pressure is not None and priority_rank(t.priority) > pressure:
                bound = max(1, bound // 2)
            if t.engine.queue.qsize() >= bound:
                raise TenantQueueFull(
                    f"tenant {t.net_id!r} queue at plan depth bound "
                    f"({t.engine.queue.qsize()}/{bound}); retry after a tick")
        if self.shed_after is None \
                or t.metrics.consecutive_violations < self.shed_after:
            return
        # Half-open: after shed_after consecutive refusals, admit one probe.
        # Its measured latency decides whether the tenant re-opens (streak
        # reset on a within-budget observation) or stays shed.
        if self._refused[t.net_id] >= self.shed_after:
            self._refused[t.net_id] = 0
            return
        self._refused[t.net_id] += 1
        raise TenantOverBudget(
            f"tenant {t.net_id!r} shed: "
            f"{t.metrics.consecutive_violations} consecutive requests "
            f"over the {t.metrics.latency_budget_s * 1e6:.1f}us budget")

    # -- measurement loop (shared by benchmarks / facade / examples) ------
    def default_inputs(self) -> dict:
        """One representative input batch per edge tenant (ones at the
        plan's batch/width) — the probe traffic ``warmup``/``drive`` use
        when the caller has no real inputs."""
        import jax.numpy as jnp
        from repro.models import edge as edge_lib
        out = {}
        for nid, t in self._tenants.items():
            if t.kind != "edge":
                continue
            cfg = getattr(t.engine, "cfg", None) or \
                edge_lib.edge_config(t.plan.network)
            out[nid] = jnp.ones((cfg.batch, cfg.dims[0]), jnp.float32)
        return out

    def warmup(self, inputs: dict | None = None) -> dict:
        """One inference per edge tenant (jit compile + first dispatch),
        then zero every metric and engine measurement, so what follows is
        steady-state.  Returns the inputs used (handy for ``drive``)."""
        inputs = inputs if inputs is not None else self.default_inputs()
        for nid, x in inputs.items():
            self.infer(nid, x)
        self.reset_metrics()
        for t in self._tenants.values():
            if hasattr(t.engine, "reset_measurements"):
                t.engine.reset_measurements()
        return inputs

    def drive(self, inputs: dict | None = None, *, iters: int = 10) -> dict:
        """Interleaved multi-tenant traffic (not one net at a time): ``iters``
        rounds of one inference per edge tenant, then :meth:`report`.  The
        fig9/fig10-style measurement loop, hoisted out of the benchmarks."""
        inputs = inputs if inputs is not None else self.default_inputs()
        for _ in range(iters):
            for nid, x in inputs.items():
                self.infer(nid, x)
        return self.report()

    def _breaker_gate(self, t: Tenant):
        """Refuse while the tenant's circuit is open (half-open probes are
        admitted by the breaker itself)."""
        sup = self.supervisor
        if sup is not None and not sup.admit(t.net_id):
            br = sup.breaker(t.net_id)
            raise TenantBreakerOpen(
                f"tenant {t.net_id!r} circuit open after "
                f"{br.consecutive_failures} consecutive failures; a probe "
                f"is admitted after {br.cooldown} refusals")

    def _record_failure(self, t: Tenant, exc: BaseException,
                        t0: float | None = None):
        """Book one failed request/tick against its tenant: the failure
        counter, the breaker (when supervised), and a ``fault/<kind>``
        audit span.  Non-finite faults already emitted their span at the
        engine that detected them — don't double-report those."""
        t.metrics.observe_failure()
        if self.tracer.enabled and fault_kind(exc) != "non_finite":
            now = time.perf_counter()
            self.tracer.add(f"fault/{fault_kind(exc)}",
                            t0 if t0 is not None else now, now,
                            tenant=t.net_id, error=str(exc)[:160])
        if self.supervisor is not None:
            self.supervisor.record_failure(t)

    # -- edge path (synchronous) ------------------------------------------
    def infer(self, net_id: str, x):
        """Route one edge inference; measured against the tenant's budget.
        A failing engine raises :class:`TenantFaulted` (after the
        supervisor's bounded retries, when one is attached) — the fault is
        booked against THIS tenant and co-residents are untouched."""
        t = self.tenant(net_id)
        self._admission_check(t)
        self._breaker_gate(t)
        sup = self.supervisor
        t0 = time.perf_counter()
        try:
            y = sup.call_edge(t, x) if sup is not None else t.engine.infer(x)
        except Exception as exc:
            self._record_failure(t, exc, t0)
            raise TenantFaulted(
                f"tenant {net_id!r} request failed: {exc}") from exc
        t1 = time.perf_counter()
        t.metrics.observe_latency(t1 - t0)
        if sup is not None:
            sup.record_success(t, t1 - t0)
        if self.slo is not None:
            self.slo.observe(net_id, t1 - t0)
        if self.tracer.enabled:
            # The router-grain envelope around the engine's own ``infer``
            # span; the engine numbered this call, so reuse its counter as
            # the trace id and the two spans join on it.
            self.tracer.add("request", t0, t1,
                            trace=getattr(t.engine, "calls", None),
                            tenant=net_id)
        self._maybe_replan(t)
        return y

    # -- lm path (continuous batching) ------------------------------------
    def submit(self, net_id: str, request):
        """Enqueue an LM request on its tenant's batcher."""
        t = self.tenant(net_id)
        self._admission_check(t)
        self._breaker_gate(t)
        self._inflight[net_id].append((request, time.perf_counter()))
        t.engine.submit(request)
        return request

    def lm_pending(self) -> bool:
        """True while any LM tenant holds queued or in-slot work — the
        open-loop replay driver's "should I tick or sleep" predicate."""
        return any(not t.engine.queue.empty() or t.engine.n_active
                   for t in self._tenants.values() if t.kind == "lm")

    def _deferrals(self, lm_order: list[Tenant]) -> set[str]:
        """SLO-aware tick policy: while any tenant is actively burning its
        p95 budget (``slo.at_risk``), strictly LOWER-priority LM tenants
        with queued work admit nothing this tick (``admit_cap=0``) — their
        live slots keep decoding, but free capacity goes to the pressured
        class first.  Deferral is bounded: after ``defer_limit`` consecutive
        deferred ticks the tenant admits anyway (aging), so a permanently
        at-risk tenant can slow a batch-class backlog but never starve it.
        Every deferral is emitted as a zero-duration ``sched/defer`` audit
        span, so priority decisions are inspectable in the trace."""
        if self.slo is None:
            return set()
        pressure = self.slo.pressure_rank()
        if pressure is None:
            for nid in self._defer_streak:
                self._defer_streak[nid] = 0
            return set()
        deferred = set()
        for t in lm_order:
            nid = t.net_id
            if priority_rank(t.priority) <= pressure \
                    or t.engine.queue.empty():
                self._defer_streak[nid] = 0
                continue
            streak = self._defer_streak[nid]
            if streak >= self.defer_limit:
                self._defer_streak[nid] = 0      # aged out: admit this tick
                continue
            self._defer_streak[nid] = streak + 1
            deferred.add(nid)
            if self.tracer.enabled:
                now = time.perf_counter()
                self.tracer.add("sched/defer", now, now, tenant=nid,
                                priority=t.priority, pressure_rank=pressure,
                                streak=streak + 1)
        return deferred

    def step(self, wait_s: float = 0.0) -> int:
        """Tick every LM tenant's batcher once; returns total active slots.
        The blocking idle wait ``wait_s`` is applied only when EVERY LM
        tenant is idle, and at most once per router tick — one idle tenant
        must not stall a busy co-tenant's decodes.

        Tick order is priority-first (burn-rate breaks ties inside a
        class), and with an SLO monitor attached lower-priority tenants may
        have their admissions deferred for this tick — see
        :meth:`_deferrals`."""
        lm = [t for t in self._tenants.values() if t.kind == "lm"]
        if self.slo is not None:
            lm.sort(key=lambda t: (priority_rank(t.priority),
                                   -self.slo.burn_rate(t.net_id)))
        else:
            lm.sort(key=lambda t: priority_rank(t.priority))
        deferred = self._deferrals(lm)
        all_idle = all(t.engine.n_active == 0 and t.engine.queue.empty()
                       for t in lm)
        remaining_wait = wait_s if all_idle else 0.0
        total = 0
        for t in lm:
            nid = t.net_id
            steps_before = getattr(t.engine, "decode_steps_observed", 0)
            try:
                n = t.engine.step(wait_s=remaining_wait,
                                  admit_cap=0 if nid in deferred else None)
            except Exception as exc:
                # Isolation: one tenant's tick failure is booked against
                # that tenant; every co-resident keeps draining.
                n = t.engine.n_active
                self._record_failure(t, exc)
            remaining_wait = 0.0
            t.metrics.observe_occupancy(t.engine.n_active, t.slots)
            total += n
            # Complete latencies for drained requests; a request the
            # batcher FAILED (req.error, e.g. non-finite logits) books a
            # failure instead of a latency — garbage never enters the
            # window or the SLO monitor.
            now = time.perf_counter()
            still = []
            for req, t0 in self._inflight[nid]:
                if req.done:
                    if getattr(req, "error", None):
                        t.metrics.observe_failure()
                        if self.supervisor is not None:
                            self.supervisor.record_failure(t)
                    else:
                        t.metrics.observe_latency(now - t0)
                        if self.slo is not None:
                            self.slo.observe(nid, now - t0)
                        if self.supervisor is not None:
                            self.supervisor.record_success(t, now - t0)
                else:
                    still.append((req, t0))
            self._inflight[nid] = still
            # Drift check per tick that actually decoded (n_active can be 0
            # when every stepped request completed within the tick).
            if getattr(t.engine, "decode_steps_observed", 0) > steps_before:
                self._maybe_replan(t)
        return total

    def run_until_drained(self, max_ticks: int = 10_000,
                          wait_s: float = 0.0):
        """Drive all LM tenants until every queue and slot is empty."""
        for _ in range(max_ticks):
            pending = any(
                not t.engine.queue.empty() or t.engine.n_active
                for t in self._tenants.values() if t.kind == "lm")
            if not pending:
                return
            self.step(wait_s=wait_s)

    # -- drift watcher (characterize -> plan -> serve -> replan loop) -----
    def _drift_measurement(self, t: Tenant) -> tuple[float, int]:
        """(measured seconds, sample count) of the plan-comparable service
        time for one tenant: request p50 for edge (the request IS the
        planned pipeline), decode-step p50 for LM (the plan's graph models
        one decode step; request latency would fold queue wait into the
        cost model)."""
        if t.kind == "lm":
            return (getattr(t.engine, "measured_decode_p50_s", 0.0),
                    getattr(t.engine, "decode_steps_observed", 0))
        return t.metrics.p50_s, t.metrics.count

    def drift(self, net_id: str) -> float:
        """Measured/planned service-time ratio for one tenant (p50 over the
        kind-appropriate window vs the tenant plan's estimate); 1.0 when
        either side has no signal yet."""
        t = self.tenant(net_id)
        planned = getattr(t.plan, "est_latency_s", 0.0)
        measured, _ = self._drift_measurement(t)
        if planned <= 0 or measured <= 0:
            return 1.0
        return measured / planned

    def _tenant_drifted(self, t: Tenant) -> bool:
        _, samples = self._drift_measurement(t)
        if samples < self.drift_min_samples:
            return False
        r = self.drift(t.net_id)
        return r > self.drift_threshold or r < 1.0 / self.drift_threshold

    def drifted(self) -> list[str]:
        """Tenants whose drift ratio left ``[1/threshold, threshold]``
        with at least ``drift_min_samples`` observations."""
        if self.drift_threshold is None:
            return []
        return [nid for nid, t in self._tenants.items()
                if self._tenant_drifted(t)]

    def _maybe_replan(self, t: Tenant):
        """Fire the fleet replan when the tenant that just reported a
        latency has drifted past the threshold.  Checking only that tenant
        keeps the per-request cost at one percentile computation.

        A drift-triggered replan that FAILS degrades instead of
        propagating: the router keeps serving under the CURRENT fleet plan,
        counts the failure, and emits a ``degrade/replan`` audit span — the
        request that happened to trip the drift check must not die because
        the planner did.  Explicit :meth:`replan_fleet` calls still raise.
        """
        if self.drift_threshold is None or self.fleet is None \
                or not self._tenant_drifted(t):
            return None
        try:
            sup = self.supervisor
            if sup is not None and sup.injector is not None:
                spec = sup.injector.fire("replan", tenant=t.net_id)
                if spec is not None and spec.kind == "replan_failure":
                    raise InjectedFault(
                        f"injected replan failure ({t.net_id})")
            return self.replan_fleet()
        except Exception as exc:
            self.replan_failures += 1
            if self.tracer.enabled:
                now = time.perf_counter()
                self.tracer.add("degrade/replan", now, now, tenant=t.net_id,
                                error=str(exc)[:160])
            return None

    def replan_fleet(self, *, budget_factor: float | None = None):
        """Fleet-wide recalibration: feed every measured tenant's
        plan-comparable p50 (edge request / LM decode step) back into the
        plan cache (:func:`repro.plan.calibrate.recalibrate_fleet`) and
        swap the replanned :class:`FleetPlan` into the live tenants — cost
        annotations and budgets move; engines keep their compiled tiles.
        ``budget_factor`` overrides each tenant's original headroom factor
        when re-deriving budgets.  Returns the replanned fleet."""
        from repro.plan import calibrate
        measurements = {}
        for nid, t in self._tenants.items():
            measured, samples = self._drift_measurement(t)
            if samples and measured > 0:
                measurements[nid] = measured
        new_fleet = calibrate.recalibrate_fleet(self.fleet, measurements,
                                                cache=self._cache,
                                                budget_factor=budget_factor)
        self.adopt_fleet(new_fleet)
        self.replans += 1
        return new_fleet

    def adopt_fleet(self, new_fleet):
        """Swap a replanned fleet into the live tenants: plans, budgets and
        engine plan annotations move; engines keep their compiled tiles.
        Used by :meth:`replan_fleet` and by ``Deployment.recalibrate`` when
        the recalibration was driven from engine measurements."""
        for tp in new_fleet.tenants:
            t = self._tenants[tp.net_id]
            t.plan = tp.plan
            t.latency_budget_s = tp.latency_budget_s
            t.metrics.latency_budget_s = tp.latency_budget_s
            # The recalibrated budget reflects measured reality; stale
            # violation streaks (from the mis-planned budget) must not keep
            # the tenant shed under the corrected one.
            t.metrics.consecutive_violations = 0
            if hasattr(t.engine, "plan"):
                t.engine.plan = tp.plan
        self.fleet = new_fleet

    # -- reporting --------------------------------------------------------
    def health(self) -> dict:
        """Per-tenant resilience state + fleet-level counters — what
        ``Deployment.summary()`` prints as its health block and the
        ``repro_resilience_*`` Prometheus families export.  Breaker fields
        appear only when a supervisor is attached."""
        tenants = {}
        for nid, t in self._tenants.items():
            h = {"failures": t.metrics.failures,
                 "engine_faults": getattr(t.engine, "faults", 0),
                 "degrade_level": getattr(t.engine, "degrade_level", 0)}
            if self.supervisor is not None:
                h.update(self.supervisor.snapshot(nid))
                # The ladder's bottom rung is the open breaker itself:
                # while open, even the per-layer path only runs as probes.
                if h["state"] != "closed":
                    h["degrade_level"] = 2
            tenants[nid] = h
        return {"tenants": tenants, "replans": self.replans,
                "replan_failures": self.replan_failures,
                "supervised": self.supervisor is not None}

    def report(self) -> dict:
        """Per-tenant metrics + planned-vs-budget context."""
        out = {}
        slo_snap = self.slo.snapshot() if self.slo is not None else {}
        for nid, t in self._tenants.items():
            snap = t.metrics.snapshot()
            snap["planned_latency_s"] = t.plan.est_latency_s
            snap["kind"] = t.kind
            snap["priority"] = t.priority
            snap["shed"] = self.over_budget(nid)
            snap["drift"] = self.drift(nid)
            if hasattr(t.engine, "span_stats"):
                snap["spans"] = t.engine.span_stats()
            if nid in slo_snap:
                snap["slo"] = slo_snap[nid]
            out[nid] = snap
        return out

    def reset_metrics(self):
        """Zero every tenant's counters (e.g. after jit warmup)."""
        for t in self._tenants.values():
            t.metrics.reset()
        self._refused = {nid: 0 for nid in self._tenants}
        self._defer_streak = {nid: 0 for nid in self._tenants}
        if self.slo is not None:
            # Warmup samples (jit compile) must not pre-burn the budget.
            self.slo.reset()
