"""Per-tenant serving metrics: latency, occupancy, budget accounting.

Counters are plain host-side state (no jax) updated by the router on every
dispatch; :meth:`TenantMetrics.snapshot` is what the router's ``report()``
surfaces and what the benchmarks/tests assert on.  Latencies are kept in a
bounded window so a long-lived router's percentiles track recent behavior.

:func:`write_serve_snapshots` exports a router report as per-tenant
``BENCH_serve_<net>.json`` files in the exact snapshot format
``benchmarks/run.py`` writes, so ``benchmarks/trend.py`` diffs SERVING
latency across runs the same way it diffs benchmark runs.
"""

from __future__ import annotations

import collections
import hashlib
import json
import math
import pathlib
import re

from repro.obs.trace import percentile


def _finite(x, default=None):
    """JSON-strict value: finite floats pass through, NaN/inf become
    ``default`` (None serializes as null — parseable everywhere, unlike the
    bare ``Infinity``/``NaN`` tokens ``json.dumps`` emits by default)."""
    if isinstance(x, (int, float)) and not math.isfinite(x):
        return default
    return x


class TenantMetrics:
    """Latency/occupancy/budget counters for one tenant."""

    def __init__(self, net_id: str, *,
                 latency_budget_s: float = math.inf, window: int = 256):
        self.net_id = net_id
        self.latency_budget_s = latency_budget_s
        self.window = window
        self.reset()

    def reset(self):
        self.count = 0
        self.total_s = 0.0
        self.budget_violations = 0
        self.consecutive_violations = 0
        self.invalid_observations = 0
        self.failures = 0
        self._latencies = collections.deque(maxlen=self.window)
        self._occ_sum = 0.0
        self._occ_n = 0

    # -- observations -----------------------------------------------------
    def observe_latency(self, dt_s: float) -> bool:
        """Record one request's latency; returns True when within budget.
        Non-finite observations (a poisoned timer, a NaN from upstream) are
        counted separately and never enter the window — one bad sample must
        not turn every percentile into NaN."""
        if not math.isfinite(dt_s):
            self.invalid_observations += 1
            return False
        self.count += 1
        self.total_s += dt_s
        self._latencies.append(dt_s)
        within = dt_s <= self.latency_budget_s
        if within:
            self.consecutive_violations = 0
        else:
            self.budget_violations += 1
            self.consecutive_violations += 1
        return within

    def observe_failure(self):
        """Record one FAILED request (engine exception, non-finite output,
        batcher fault).  Failures never enter the latency window — a dead
        request has no honest latency — they are their own counter, exported
        as the ``repro_resilience_failures_total`` Prometheus family."""
        self.failures += 1

    def observe_occupancy(self, active: int, capacity: int):
        """Record one scheduling tick's slot occupancy."""
        self._occ_sum += active / capacity if capacity else 0.0
        self._occ_n += 1

    # -- derived ----------------------------------------------------------
    @property
    def mean_s(self) -> float:
        return self.total_s / self.count if self.count else 0.0

    @property
    def p50_s(self) -> float:
        """Median over the window — robust to scheduler spikes, so it is
        what benchmarks compare against planned latency."""
        if not self._latencies:
            return 0.0
        xs = sorted(self._latencies)
        return xs[len(xs) // 2]

    @property
    def p95_s(self) -> float:
        return percentile(self._latencies, 0.95)

    @property
    def p99_s(self) -> float:
        """Tail of the window — what the SLO monitor's p99 contracts and
        the replay snapshots judge (nearest-rank, like every percentile in
        the repo)."""
        return percentile(self._latencies, 0.99)

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots busy across observed ticks."""
        return self._occ_sum / self._occ_n if self._occ_n else 0.0

    def snapshot(self) -> dict:
        # _finite on every float: a math.inf budget (the "no budget" default)
        # or a poisoned aggregate must not leak Infinity/NaN tokens into a
        # snapshot that gets json.dumps'd with allow_nan=False downstream.
        return {
            "net_id": self.net_id,
            "count": self.count,
            "mean_s": _finite(self.mean_s, 0.0),
            "p50_s": _finite(self.p50_s, 0.0),
            "p95_s": _finite(self.p95_s, 0.0),
            "p99_s": _finite(self.p99_s, 0.0),
            "latency_budget_s": _finite(self.latency_budget_s),
            "budget_violations": self.budget_violations,
            "invalid_observations": self.invalid_observations,
            "failures": self.failures,
            "occupancy": _finite(self.occupancy, 0.0),
        }


def _safe_net_name(net_id: str) -> str:
    """Filesystem-safe tenant name (duplicate nets carry a '#index').

    Every character outside ``[A-Za-z0-9._-]`` maps to ``_`` (this covers
    path separators on both platforms, so a hostile net id can never walk
    out of ``json_dir``).  A net id that sanitizes to nothing but filler —
    empty, all underscores, or all dots (``"."``/``".."`` would otherwise
    yield the directory entries) — falls back to a short content hash so
    the file still gets a unique, stable name."""
    safe = re.sub(r"[^A-Za-z0-9._-]", "_", net_id)
    if not safe or set(safe) <= {".", "_", "-"}:
        digest = hashlib.sha256(net_id.encode()).hexdigest()[:8]
        return f"net_{digest}"
    return safe


def write_serve_snapshots(report: dict, json_dir, *,
                          meta: dict | None = None) -> list:
    """Export a router ``report()`` as per-tenant ``BENCH_serve_<net>.json``.

    One file per tenant, ``{"meta": ..., "rows": [...]}`` with the same row
    shape ``benchmarks/common.emit`` records (``name``/``us_per_call``/
    ``derived``), so :mod:`benchmarks.trend` diffs serving latency across
    runs exactly like benchmark runs.  Returns the written paths.

    Request-grain percentile rows are skipped for tenants with no completed
    requests (a 0.0 "latency" row would read as a regression-to-zero in the
    trend diff).  When the snapshot carries per-span-kind aggregates (the
    router's ``report()`` attaches ``engine.span_stats()``), each kind gets
    its own ``serve/<net>/<kind>/p50|p95`` rows so trend gating covers
    decode-step service time and queue wait separately from end-to-end
    request latency.  LM tenants additionally emit a
    ``serve/<net>/decode_step/planned`` model row: an LM plan's graph models
    one decode step, so ``plan.est_latency_s`` is the planned analogue of
    the measured decode-step row, not of request latency.
    """
    out_dir = pathlib.Path(json_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    paths = []
    for nid, snap in report.items():
        derived = (f"src=measured;count={snap['count']};"
                   f"violations={snap['budget_violations']};"
                   f"failures={snap.get('failures', 0)};"
                   f"kind={snap.get('kind', '?')}")
        rows = []
        if snap["count"]:
            rows += [
                {"name": f"serve/{nid}/p50", "us_per_call":
                 round(snap["p50_s"] * 1e6, 3), "derived": derived},
                {"name": f"serve/{nid}/p95", "us_per_call":
                 round(snap["p95_s"] * 1e6, 3), "derived": derived},
                {"name": f"serve/{nid}/p99", "us_per_call":
                 round(snap.get("p99_s", snap["p95_s"]) * 1e6, 3),
                 "derived": derived},
                {"name": f"serve/{nid}/mean", "us_per_call":
                 round(snap["mean_s"] * 1e6, 3), "derived": derived},
            ]
        if snap.get("planned_latency_s"):
            rows.append({"name": f"serve/{nid}/planned", "us_per_call":
                         round(snap["planned_latency_s"] * 1e6, 3),
                         "derived": "src=model"})
        for kind, agg in sorted((snap.get("spans") or {}).items()):
            if not agg.get("count"):
                continue
            span_derived = (f"src=measured;count={agg['count']};"
                            f"span={kind}")
            for pct in ("p50", "p95"):
                v = agg.get(f"{pct}_s", 0.0)
                if not math.isfinite(v):
                    continue
                rows.append({"name": f"serve/{nid}/{kind}/{pct}",
                             "us_per_call": round(v * 1e6, 3),
                             "derived": span_derived})
        if snap.get("kind") == "lm" and snap.get("planned_latency_s"):
            rows.append({"name": f"serve/{nid}/decode_step/planned",
                         "us_per_call":
                         round(snap["planned_latency_s"] * 1e6, 3),
                         "derived": "src=model"})
        payload = {"meta": {"net_id": nid, **(meta or {})}, "rows": rows}
        p = out_dir / f"BENCH_serve_{_safe_net_name(nid)}.json"
        p.write_text(json.dumps(payload, indent=2, sort_keys=True,
                                allow_nan=False) + "\n")
        paths.append(p)
    return paths
