"""Serving engine: prefill/decode step builders + continuous batcher +
int8 weight quantization + the extreme-edge low-latency path.

Two serving surfaces:

* **LM serving** (the assigned decode/prefill shapes): jitted prefill and
  decode steps with TP-sharded weights and head/batch-sharded caches, driven
  by a continuous-batching scheduler (fixed slot count, admit-on-free).
* **Edge serving** (the paper's own regime): batch-8, weights-on-chip int8
  dense pipelines executed through a compiled :class:`DeploymentPlan`
  (``repro.plan``): LARE chooses each layer's regime, the two-level tiling
  search fixes the Pallas block shapes, and :class:`EdgeEngine` runs the
  result — no hard-coded tiles or regime flags in this module.
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import partition, runtime
from repro.faults import InjectedFault, NonFiniteOutput
from repro.models import api
from repro.models.config import ModelConfig
from repro.obs import NULL_TRACER, summarize

F32 = jnp.float32


# ---------------------------------------------------------------------------
# int8 weight quantization (pjit path; kernels/gemm_int8 covers the TPU path)
# ---------------------------------------------------------------------------

_QUANT_MIN_SIZE = 1 << 16      # only quantize big matmul weights


# Embeddings are gathered directly; norm scales/biases must stay exact.
_QUANT_EXCLUDE = ("emb", "unemb", "pos_emb", "scale", "bias",
                  "ln0", "ln1", "ln2", "ln_x", "post_ln1", "post_ln2",
                  "final_norm", "gn", "q_norm", "kv_norm", "norm_h", "norm_e",
                  "enc_final", "dec_final")


def quantize_params(params: Any, *, min_size: int = _QUANT_MIN_SIZE) -> Any:
    """Per-output-channel symmetric int8 for >=2-D weight leaves.

    Quantized leaves become {"q8","scale"} marker dicts that
    ``runtime.maybe_dequant`` expands per layer inside the scan body, so at
    rest HBM holds int8 (the mixtral-8x22b @ TP16 fit story).  Embedding
    tables are excluded — they are index-gathered outside the dequant hook
    (and int8 embeddings measurably hurt quality anyway)."""

    def one(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if any(k in _QUANT_EXCLUDE for k in keys):
            return leaf
        if (not isinstance(leaf, jnp.ndarray) and
                not hasattr(leaf, "shape")):
            return leaf
        if leaf.ndim < 2 or leaf.size < min_size or \
                not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        w = leaf.astype(F32)
        scale = jnp.max(jnp.abs(w), axis=-2, keepdims=True) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
        return {"q8": q, "scale": scale.astype(F32)}

    return jax.tree_util.tree_map_with_path(one, params)


def quantized_bytes(params: Any) -> tuple[int, int]:
    """(bytes_before_assuming_bf16, bytes_after) for reporting."""
    before = after = 0
    for leaf in jax.tree.leaves(params):
        n = int(np.prod(leaf.shape))
        before += 2 * n
        after += n if leaf.dtype == jnp.int8 else 2 * n
    return before, after


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def prepare_params(params: Any, *, plan=None, quantize: bool = False) -> Any:
    """Apply the plan's weight-format decision (int8 vs bf16) to params."""
    if plan is not None:
        quantize = bool(plan.serve.get("quantize_weights", quantize))
    return quantize_params(params) if quantize else params


def build_serve_steps(cfg: ModelConfig, *, max_len: int,
                      quantize: bool = False, plan=None):
    """Returns (prefill_fn, decode_fn) — pure functions ready for jit.

    prefill_fn(params, tokens, state)        -> (logits_last, state)
    decode_fn(params, tokens, state, pos)    -> (logits, state)

    Execution policy comes from the :class:`DeploymentPlan` when one is
    given (``repro.plan.get_or_plan(cfg, target="tpu")``): the plan's
    ``serve`` section selects prefill chunking, and its weight-format
    decision is applied by :func:`prepare_params`, instead of ad-hoc flags
    at every call site.
    """
    chunk = None
    if plan is not None:
        chunk = plan.serve.get("prefill_chunk")

    def prefill_fn(params, tokens, state, extras=None):
        s = tokens.shape[1]
        if chunk is None or s <= chunk:
            logits, state = api.decode_step(params, cfg, tokens, state, 0,
                                            extras=extras or {})
            return logits[:, -1:], state
        logits = None
        for off in range(0, s, chunk):       # unrolled at trace time
            logits, state = api.decode_step(
                params, cfg, tokens[:, off:off + chunk], state, off,
                extras=extras or {})
        return logits[:, -1:], state

    def decode_fn(params, tokens, state, pos, extras=None):
        return api.decode_step(params, cfg, tokens, state, pos,
                               extras=extras or {})

    return prefill_fn, decode_fn


# ---------------------------------------------------------------------------
# Continuous batcher
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    filled: int = 0                  # prompt tokens prefilled so far (chunked)
    # Trace bookkeeping (perf_counter clock).  ``rid`` doubles as the trace
    # id: every span this request produces — queue wait, prefill chunks,
    # decode steps — carries it, so the flat span stream decomposes back
    # into per-request timelines.  Stamps survive shedding retries and
    # max_new_cap eviction: the request object is the source of truth.
    t_submit: float | None = None    # stamped by ContinuousBatcher.submit
    t_admit: float | None = None     # stamped when a slot is assigned
    t_done: float | None = None      # stamped when the request completes
    # Fault disposition: set (e.g. "non_finite_output") when the request
    # FAILED rather than completed — done=True with error set means the
    # slot was freed and no further tokens are coming, but ``out`` must
    # not be trusted.  The router counts these as per-tenant failures.
    error: str | None = None


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Continuous-batching policy, read from a plan's ``serve`` section.

    The batcher used to hard-code all of this; now the deployment plan (and
    the fleet planner's per-tenant serve sections) decides.  ``None`` keeps
    the permissive default for that knob."""
    slots: int = 4
    prefill_chunk: int | None = None   # prompt tokens prefilled per tick
    admit_per_tick: int | None = None  # max admissions per tick
    max_new_cap: int | None = None     # evict: hard cap on generated tokens

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        for name in ("prefill_chunk", "admit_per_tick", "max_new_cap"):
            v = getattr(self, name)
            # A zero chunk would stall prefill forever (no progress, no
            # decode, and run_until_drained's tick bound never advances).
            if v is not None and v < 1:
                raise ValueError(f"{name} must be >= 1 or None, got {v}")

    @classmethod
    def from_plan(cls, plan, **overrides) -> "BatchPolicy":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(overrides) - fields
        if unknown:
            raise TypeError(
                f"unknown BatchPolicy override(s): {sorted(unknown)} "
                f"(valid: {sorted(fields)})")
        serve = dict(getattr(plan, "serve", None) or {})
        slots = serve.get("slots")
        kw = {
            # `is None`, not truthiness: an explicit 0 in a plan must reach
            # __post_init__'s validation, not silently become the default.
            "slots": cls.slots if slots is None else slots,
            "prefill_chunk": serve.get("prefill_chunk"),
            "admit_per_tick": serve.get("admit_per_tick"),
            "max_new_cap": serve.get("max_new_cap"),
        }
        kw.update(overrides)
        return cls(**kw)


class ContinuousBatcher:
    """Fixed-slot continuous batching over the jitted decode step.

    Slots hold independent sequences; finished slots admit queued requests
    (per-slot position tracking; greedy sampling).  Admission, eviction and
    chunked-prefill sizes come from a :class:`BatchPolicy` — pass ``plan=``
    (a :class:`~repro.plan.artifact.DeploymentPlan`) to read the policy from
    the plan's ``serve`` section instead of the defaults.  CPU-scale smoke
    models exercise the exact code path the TPU deployment jits.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int | None = None,
                 max_len: int = 256, plan=None,
                 policy: "BatchPolicy | None" = None, tracer=None):
        self.cfg, self.params = cfg, params
        if policy is None:
            policy = (BatchPolicy.from_plan(plan) if plan is not None
                      else BatchPolicy())
        if slots is not None:           # explicit arg outranks the plan
            policy = dataclasses.replace(policy, slots=slots)
        self.policy = policy
        self.plan = plan
        self.slots, self.max_len = policy.slots, max_len
        # Span-decomposed service time.  The per-kind windows are ALWAYS
        # maintained (a handful of perf_counter calls per tick, invisible
        # next to a jitted decode) so decode-step p50 exists for the drift
        # watcher even with tracing off; the tracer additionally receives
        # per-request spans when one is attached (router or Deployment).
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.trace_label = cfg.name
        self._windows: dict[str, collections.deque] = {}
        self._span_totals: dict[str, int] = {}
        self.state = api.init_decode_state(cfg, self.slots, max_len)
        self.pos = np.zeros((self.slots,), np.int32)
        self.active: list[Request | None] = [None] * self.slots
        self.queue: "queue.Queue[Request]" = queue.Queue()

        # Per-slot decode: vmap over the slot axis so every slot advances at
        # ITS OWN cache position (staggered admissions must not share a
        # cursor), with `live` masking state writes so idle slots stay
        # byte-identical (recurrent families have no overwritable cache).
        # The batch axis is not uniform across state leaves (layer-stacked
        # caches carry it at axis 1, unstacked tails at axis 0): recover it
        # per leaf by diffing specs at two batch sizes.
        s1 = api.decode_state_specs(cfg, 1, max_len)
        s2 = api.decode_state_specs(cfg, 2, max_len)

        def batch_axis(a, b):
            for ax, (x, y) in enumerate(zip(a.shape, b.shape)):
                if x != y:
                    return ax
            return 0

        axes = self._axes = jax.tree.map(batch_axis, s1, s2)

        def decode_one(p, tok, state, pos, live):
            state_b = jax.tree.map(lambda v, ax: jnp.expand_dims(v, ax),
                                   state, axes)
            logits, new_state = api.decode_step(p, cfg, tok.reshape(1, 1),
                                                state_b, pos)
            new_state = jax.tree.map(
                lambda old, new, ax: jnp.where(live, jnp.squeeze(new, ax),
                                               old),
                state, new_state, axes)
            return logits[0], new_state

        self._decode = jax.jit(
            jax.vmap(decode_one, in_axes=(None, 0, axes, 0, 0),
                     out_axes=(0, axes)))
        self._steps = 0
        self._hlo_text: str | None = None
        self._reset_fn = None            # jitted slot reset, built on demand
        # Fault hooks (repro.faults): ``injector`` is armed by
        # Router.arm_faults for chaos runs; unarmed it costs one ``is not
        # None`` per tick.  ``faults`` counts failed requests/ticks
        # (injected or organic, e.g. non-finite logits).
        self.injector = None
        self.faults = 0

    def hlo_text(self) -> str:
        """Post-optimization HLO of the ACTUAL jitted decode step — the
        executable every decode tick runs, at serving shapes (slots, live
        masking, cache axes).  Feeds the loop-aware analyzer
        (:func:`repro.launch.hlo_analysis.analyze_hlo`) so the profiler can
        report model-FLOPs vs compiled-FLOPs overhead on the real
        executable instead of a stand-in.  Compiled once and cached."""
        if self._hlo_text is None:
            tok = np.zeros((self.slots,), np.int32)
            live = np.zeros((self.slots,), bool)
            self._hlo_text = self._decode.lower(
                self.params, tok, self.state, self.pos.copy(),
                live).compile().as_text()
        return self._hlo_text

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.put(req)

    # -- span recording ----------------------------------------------------
    def _record(self, kind: str, t0: float, t1: float, *, trace=None,
                emit: bool = True, **attrs):
        """One observed interval: window (always) + tracer (when enabled).
        ``emit=False`` keeps the window observation but skips the tracer —
        used when the caller emits finer-grained (per-request) spans for the
        same interval, so aggregates never double-count it."""
        win = self._windows.get(kind)
        if win is None:
            win = self._windows[kind] = collections.deque(maxlen=512)
            self._span_totals[kind] = 0
        win.append(t1 - t0)
        self._span_totals[kind] += 1
        if emit and self.tracer.enabled:
            self.tracer.add(kind, t0, t1, trace=trace,
                            tenant=self.trace_label, **attrs)

    def span_stats(self) -> dict:
        """Windowed per-kind service-time aggregates (count/mean/p50/p95
        over the recent window, plus the lifetime observation count)."""
        out = {}
        for kind, win in self._windows.items():
            agg = summarize(win)
            agg["total_count"] = self._span_totals[kind]
            out[kind] = agg
        return out

    @property
    def measured_decode_p50_s(self) -> float:
        """Median decode-step service time over the recent window — queue
        wait and prefill excluded, so it is directly comparable to the LM
        plan's ``est_latency_s`` (an LM plan models ONE decode step).  This
        is the statistic that lets LM tenants join drift replanning."""
        win = self._windows.get("decode_step")
        return summarize(win)["p50_s"] if win else 0.0

    @property
    def decode_steps_observed(self) -> int:
        return self._span_totals.get("decode_step", 0)

    def _decode_masked(self, tok: np.ndarray, live: np.ndarray):
        # Snapshot the host buffers: CPU device_put can alias numpy memory
        # zero-copy while dispatch is async, so handing jax the live buffers
        # (mutated by the admit/step loops) races.  The copies are local to
        # this call and never mutated.
        logits, self.state = self._decode(
            self.params,
            # Deliberate sync: sampled tokens must reach the host to detect EOS.
            np.array(tok[:, 0]),  # repro: check-ok(lint.host-sync)
            self.state,
            self.pos.copy(), live.copy())
        return logits

    def _reset_slot(self, i: int):
        """Fresh cache + position for a re-used slot (no stale KV).  One
        jitted executable (slot index traced, so every slot shares it)
        instead of 2x-layers eager ``.at[].set`` dispatches — admission
        runs before any span opens, so its cost must stay in the noise."""
        if self._reset_fn is None:
            self._reset_fn = jax.jit(
                lambda state, j: jax.tree.map(
                    lambda v, ax: v.at[(slice(None),) * ax + (j,)].set(0),
                    state, self._axes))
        self.state = self._reset_fn(self.state, jnp.int32(i))
        self.pos[i] = 0

    @property
    def n_active(self) -> int:
        """Occupied slots (the router's occupancy numerator)."""
        return sum(1 for r in self.active if r is not None)

    def _max_new(self, req: Request) -> int:
        """Eviction policy: the plan's cap bounds every request's budget."""
        cap = self.policy.max_new_cap
        return req.max_new if cap is None else min(req.max_new, cap)

    def _prefill_tick(self, i: int, req: Request):
        """Advance slot ``i``'s prefill by at most ``prefill_chunk`` tokens
        (the whole prompt when the policy sets no chunk).  Emits the first
        generated token once the prompt is fully consumed."""
        chunk = self.policy.prefill_chunk
        limit = (len(req.prompt) if chunk is None
                 else min(len(req.prompt), req.filled + chunk))
        if req.filled >= limit:
            return
        t0 = time.perf_counter()
        first = req.filled
        tok = np.zeros((self.slots, 1), np.int32)
        live = np.zeros((self.slots,), bool)
        live[i] = True
        logits = None
        for t in req.prompt[req.filled:limit]:
            tok[i, 0] = t
            logits = self._decode_masked(tok, live)
            self.pos[i] += 1
        req.filled = limit
        if req.filled == len(req.prompt):
            # Deliberate sync: the finiteness guard reads one logits row.
            row = np.asarray(logits[i, -1])  # repro: check-ok(lint.host-sync)
            if not np.isfinite(row).all():
                self._fail_request(i, req, "non_finite_output")
            else:
                req.out.append(int(row.argmax()))
        self._record("prefill_chunk", t0, time.perf_counter(), trace=req.rid,
                     tokens=limit - first, slot=i)

    def _admit(self, wait_s: float = 0.0, admit_cap: int | None = None) -> int:
        """Fill free slots from the queue.  ``wait_s > 0`` blocks on the
        FIRST pop (``queue.get(timeout=...)``) so an idle serving loop parks
        in the kernel instead of spinning on ``queue.empty()``.

        ``admit_cap`` tightens the policy's per-tick admission bound for
        THIS tick only (the router's SLO-aware deferral passes 0 to hold a
        lower-priority tenant's queue while a higher-priority tenant burns
        its budget — live slots keep decoding either way)."""
        caps = [c for c in (self.policy.admit_per_tick, admit_cap)
                if c is not None]
        cap = min(caps) if caps else None
        if cap is not None and cap <= 0:
            return 0
        admitted = 0
        for i in range(self.slots):
            if self.active[i] is not None:
                continue
            if cap is not None and admitted >= cap:
                break
            try:
                req = (self.queue.get(timeout=wait_s) if wait_s > 0
                       else self.queue.get_nowait())
            except queue.Empty:
                break
            wait_s = 0.0                 # block at most once per tick
            now = time.perf_counter()
            req.t_admit = now
            if req.t_submit is not None:
                self._record("queue", req.t_submit, now, trace=req.rid)
            if len(req.prompt) == 0:     # nothing to prefill or decode
                req.done = True
                req.t_done = now
                self._finish(req)
                continue
            self._reset_slot(i)
            req.filled = 0
            self.active[i] = req
            admitted += 1
        return admitted

    def _finish(self, req: Request):
        """Close out a completed (or evicted) request's trace: the request
        span covers submit -> done, whatever path ended it."""
        if self.tracer.enabled and req.t_submit is not None:
            extra = {"error": req.error} if req.error else {}
            self.tracer.add("request", req.t_submit, req.t_done,
                            trace=req.rid, tenant=self.trace_label,
                            tokens_out=len(req.out), **extra)

    def _fail_request(self, i: int, req: Request, kind: str):
        """A poisoned output FAILS the request instead of emitting garbage:
        the slot is freed, the fault counted (``fault/non_finite`` span),
        and the request span still closes so traces reconcile.  The router
        reads ``req.error`` and books a per-tenant failure."""
        now = time.perf_counter()
        self.faults += 1
        req.error = kind
        req.done = True
        req.t_done = now
        self.active[i] = None
        if self.tracer.enabled:
            self.tracer.add("fault/non_finite", now, now, trace=req.rid,
                            tenant=self.trace_label, slot=i)
        self._finish(req)

    def step(self, wait_s: float = 0.0, *,
             admit_cap: int | None = None) -> int:
        """One tick: admit, advance chunked prefills, decode live slots.
        Returns #active.  ``wait_s`` bounds the blocking idle wait — applied
        only when EVERY slot is empty, so a busy batcher never stalls its
        live decodes waiting for new arrivals.  ``admit_cap`` tightens this
        tick's admissions (0 = defer the queue, keep decoding)."""
        if self.injector is not None:
            spec = self.injector.fire("batcher.tick", tenant=self.trace_label)
            if spec is not None:
                if spec.kind == "batcher_stall":
                    if spec.magnitude_s > 0:
                        time.sleep(spec.magnitude_s)
                    return self.n_active   # tick skipped: no admit, no decode
                if spec.kind == "engine_exception":
                    self.faults += 1
                    raise InjectedFault(
                        f"injected batcher fault on {self.trace_label}")
                if spec.kind == "latency_spike" and spec.magnitude_s > 0:
                    time.sleep(spec.magnitude_s)
        self._admit(wait_s=wait_s if not any(self.active) else 0.0,
                    admit_cap=admit_cap)
        # Slots mid-prefill (including just-admitted ones) advance by one
        # chunk instead of decoding; with no chunk configured the whole
        # prompt lands in this tick, which is the pre-policy behavior.
        for i, req in enumerate(self.active):
            if req is not None and req.filled < len(req.prompt):
                self._prefill_tick(i, req)
        if not any(self.active):
            return 0
        tok = np.zeros((self.slots, 1), np.int32)
        live = np.zeros((self.slots,), bool)
        for i, req in enumerate(self.active):
            if req is not None and req.out and req.filled >= len(req.prompt):
                tok[i, 0] = req.out[-1]
                live[i] = True
        if live.any():
            t0 = time.perf_counter()
            logits = self._decode_masked(tok, live)
            if self.injector is not None:
                spec = self.injector.fire("batcher.decode",
                                          tenant=self.trace_label)
                if spec is not None and spec.kind == "non_finite_output":
                    logits = jnp.full_like(logits, jnp.nan)
            self._steps += 1
            stepped = []                 # (slot, request) pairs that decoded
            done_reqs = []
            for i, req in enumerate(self.active):
                if req is None or not live[i]:
                    continue
                self.pos[i] += 1
                # Deliberate sync: per-slot finiteness guard (see above).
                row = np.asarray(logits[i, -1])  # repro: check-ok(lint.host-sync)
                if not np.isfinite(row).all():
                    self._fail_request(i, req, "non_finite_output")
                    continue
                stepped.append((i, req))
                req.out.append(int(row.argmax()))
                if len(req.out) >= self._max_new(req):
                    req.done = True      # completion OR max_new_cap eviction
                    done_reqs.append(req)
                    self.active[i] = None
            # The int(argmax) consumption above synchronized the async
            # dispatch, so [t0, t1] is the honest batched service interval.
            t1 = time.perf_counter()
            self._record("decode_step", t0, t1, batch=len(stepped),
                         emit=False)
            if self.tracer.enabled:
                for i, req in stepped:   # per-request view of the shared step
                    self.tracer.add("decode_step", t0, t1, trace=req.rid,
                                    tenant=self.trace_label, slot=i)
            for req in done_reqs:
                req.t_done = t1
                self._finish(req)
        return self.n_active

    def run_until_drained(self, max_ticks: int = 10_000):
        while (not self.queue.empty() or any(self.active)) \
                and self._steps < max_ticks:
            self.step()


# ---------------------------------------------------------------------------
# Edge plan executor (the paper's serving regime)
# ---------------------------------------------------------------------------

class EdgeEngine:
    """Executes a :class:`DeploymentPlan` for an extreme-edge net.

    The engine owns the quantized weights and the jitted planned forward —
    one Pallas launch per DR7' fusion group, per-layer Pallas block shapes
    for singleton groups, nothing here hard-codes a tile or a group — and
    tracks measured wall time against the plan's estimate so deployments can
    report planned-vs-measured drift.  The forward (groups, tiles, scales
    included) is baked into ONE cached jit at construction: the hot path
    never touches the plan.

    Activation scales are calibrated at construction by running the float
    reference on a representative batch (``calibrate=False`` restores the
    legacy fixed ``x_scale``).
    """

    def __init__(self, cfg, params=None, *, plan=None, x_scale: float = 0.05,
                 seed: int = 0, calibrate: bool = True, qparams=None,
                 calib_x=None, tracer=None):
        from repro.models import edge as edge_lib
        self.cfg = cfg
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.trace_label = cfg.name
        self.plan = plan if plan is not None else edge_lib.deployment_plan(cfg)
        if qparams is None:
            if params is None:
                params = edge_lib.init_edge(jax.random.PRNGKey(seed), cfg)
            if calibrate and calib_x is None:
                calib_x = jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(seed), 7),
                    (cfg.batch, cfg.dims[0]), F32)
            qparams = edge_lib.quantize_edge(
                params, calib_x=calib_x if calibrate else None, act=cfg.act)
        self.qparams = qparams
        self.x_scale = x_scale
        self._fwd = jax.jit(lambda x: edge_lib.edge_forward_q8(
            self.qparams, cfg, x, x_scale=x_scale, plan=self.plan))
        self._hlo_text: str | None = None
        # Degradation ladder state (repro.serve.resilience): level 0 runs
        # the planned fused megakernel; level 1 the per-layer gemm_int8
        # path (``fused=False`` — bit-exact vs fused, so degrading never
        # changes answers).  The fallback jit is built lazily on first
        # demotion; ``injector``/``faults`` mirror the batcher's hooks.
        self.degrade_level = 0
        self._fwd_fallback = None
        self.injector = None
        self.faults = 0
        self.reset_measurements()

    def _fallback(self):
        """The per-layer (``fused=False``) jit, compiled on first use."""
        if self._fwd_fallback is None:
            from repro.models import edge as edge_lib
            self._fwd_fallback = jax.jit(
                lambda x: edge_lib.edge_forward_q8(
                    self.qparams, self.cfg, x, x_scale=self.x_scale,
                    plan=self.plan, fused=False))
        return self._fwd_fallback

    def degrade(self) -> bool:
        """Step down the ladder (fused -> per-layer).  Returns True if a
        demotion happened; False when already at the bottom rung this
        engine owns (the breaker's open state IS the shed rung)."""
        if self.degrade_level == 0:
            self.degrade_level = 1
            return True
        return False

    def restore(self) -> bool:
        """Re-promote to the fused fast path.  Returns True on change."""
        if self.degrade_level > 0:
            self.degrade_level = 0
            return True
        return False

    def hlo_text(self) -> str:
        """Post-optimization HLO of the jitted planned forward — the one
        executable :meth:`infer` runs.  Cached after the first compile; the
        profiler's HLO-overhead report analyzes this text."""
        if self._hlo_text is None:
            x = jnp.zeros((self.cfg.batch, self.cfg.dims[0]), F32)
            self._hlo_text = self._fwd.lower(x).compile().as_text()
        return self._hlo_text

    def infer(self, x) -> jax.Array:
        t0 = time.perf_counter()
        spec = None
        if self.injector is not None:
            spec = self.injector.fire("engine.infer", tenant=self.trace_label)
        if spec is not None:
            if spec.kind == "engine_exception":
                self.faults += 1
                raise InjectedFault(
                    f"injected engine fault on {self.trace_label}")
            if spec.kind == "latency_spike" and spec.magnitude_s > 0:
                time.sleep(spec.magnitude_s)   # inside [t0, t1]: visible
        fwd = self._fwd if self.degrade_level == 0 else self._fallback()
        # Deliberate sync: infer() returns a ready result by contract.
        y = jax.block_until_ready(fwd(x))  # repro: check-ok(lint.host-sync)
        if spec is not None and spec.kind == "non_finite_output":
            y = jnp.full_like(y, jnp.nan)      # poison; caught just below
        # Host-side finiteness guard: np.asarray on a ready CPU array is
        # zero-copy, and the reduction is microseconds next to the forward.
        # A poisoned output FAILS the call rather than returning garbage.
        if not bool(np.isfinite(np.asarray(y)).all()):  # repro: check-ok(lint.host-sync)
            t1 = time.perf_counter()
            self.faults += 1
            if self.tracer.enabled:
                self.tracer.add("fault/non_finite", t0, t1,
                                tenant=self.trace_label)
            raise NonFiniteOutput(
                f"{self.trace_label}: non-finite model output")
        t1 = time.perf_counter()
        dt = t1 - t0
        self.total_s += dt
        self.calls += 1
        self._latencies.append(dt)
        if self.tracer.enabled:
            self.tracer.add("infer", t0, t1, trace=self.calls,
                            tenant=self.trace_label)
        return y

    def span_stats(self) -> dict:
        """The edge path is synchronous — one span kind, ``infer``, whose
        service time IS the request latency (no queue decomposition)."""
        if not self._latencies:
            return {}
        agg = summarize(self._latencies)
        agg["total_count"] = self.calls
        return {"infer": agg}

    @property
    def planned_latency_s(self) -> float:
        return self.plan.est_latency_s

    @property
    def measured_mean_s(self) -> float:
        return self.total_s / self.calls if self.calls else 0.0

    @property
    def measured_p50_s(self) -> float:
        """Median over the recent-call window — the robust statistic the
        planned-vs-measured comparisons and the recalibration loop use (one
        scheduler spike must not swing a calibration)."""
        if not self._latencies:
            return 0.0
        xs = sorted(self._latencies)
        return xs[len(xs) // 2]

    def reset_measurements(self):
        """Drop accumulated timings (e.g. after jit warmup)."""
        self.calls, self.total_s = 0, 0.0
        self._latencies = collections.deque(maxlen=256)

    def record_calibration(self, cache=None):
        """Autotune hook: write the measured mean latency back into the plan
        cache (:func:`repro.plan.calibrate.feedback`), so a re-plan with the
        same key returns calibrated costs.  Returns the calibrated plan and
        adopts it as this engine's plan (tiles are unchanged — only cost
        annotations move)."""
        from repro.plan import calibrate
        if not self.calls:
            raise RuntimeError("no measurements recorded yet")
        self.plan = calibrate.feedback(self.plan, self.measured_mean_s,
                                       cache=cache)
        return self.plan
