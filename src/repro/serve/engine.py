"""Serving engine: prefill/decode step builders + continuous batcher +
int8 weight quantization + the extreme-edge low-latency path.

Two serving surfaces:

* **LM serving** (the assigned decode/prefill shapes): jitted prefill and
  decode steps with TP-sharded weights and head/batch-sharded caches, driven
  by a continuous-batching scheduler (fixed slot count, admit-on-free).
* **Edge serving** (the paper's own regime): batch-8, weights-on-chip int8
  dense pipelines deployed through the two-level tiling plan + fused Pallas
  kernels (`models/edge.py`), with the LARE decision rule choosing the
  execution regime per layer.
"""

from __future__ import annotations

import dataclasses
import queue
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import partition, runtime
from repro.models import api
from repro.models.config import ModelConfig

F32 = jnp.float32


# ---------------------------------------------------------------------------
# int8 weight quantization (pjit path; kernels/gemm_int8 covers the TPU path)
# ---------------------------------------------------------------------------

_QUANT_MIN_SIZE = 1 << 16      # only quantize big matmul weights


# Embeddings are gathered directly; norm scales/biases must stay exact.
_QUANT_EXCLUDE = ("emb", "unemb", "pos_emb", "scale", "bias",
                  "ln0", "ln1", "ln2", "ln_x", "post_ln1", "post_ln2",
                  "final_norm", "gn", "q_norm", "kv_norm", "norm_h", "norm_e",
                  "enc_final", "dec_final")


def quantize_params(params: Any, *, min_size: int = _QUANT_MIN_SIZE) -> Any:
    """Per-output-channel symmetric int8 for >=2-D weight leaves.

    Quantized leaves become {"q8","scale"} marker dicts that
    ``runtime.maybe_dequant`` expands per layer inside the scan body, so at
    rest HBM holds int8 (the mixtral-8x22b @ TP16 fit story).  Embedding
    tables are excluded — they are index-gathered outside the dequant hook
    (and int8 embeddings measurably hurt quality anyway)."""

    def one(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if any(k in _QUANT_EXCLUDE for k in keys):
            return leaf
        if (not isinstance(leaf, jnp.ndarray) and
                not hasattr(leaf, "shape")):
            return leaf
        if leaf.ndim < 2 or leaf.size < min_size or \
                not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        w = leaf.astype(F32)
        scale = jnp.max(jnp.abs(w), axis=-2, keepdims=True) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
        return {"q8": q, "scale": scale.astype(F32)}

    return jax.tree_util.tree_map_with_path(one, params)


def quantized_bytes(params: Any) -> tuple[int, int]:
    """(bytes_before_assuming_bf16, bytes_after) for reporting."""
    before = after = 0
    for leaf in jax.tree.leaves(params):
        n = int(np.prod(leaf.shape))
        before += 2 * n
        after += n if leaf.dtype == jnp.int8 else 2 * n
    return before, after


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def build_serve_steps(cfg: ModelConfig, *, max_len: int,
                      quantize: bool = False):
    """Returns (prefill_fn, decode_fn) — pure functions ready for jit.

    prefill_fn(params, tokens, state)        -> (logits_last, state)
    decode_fn(params, tokens, state, pos)    -> (logits, state)
    """

    def prefill_fn(params, tokens, state, extras=None):
        logits, state = api.decode_step(params, cfg, tokens, state, 0,
                                        extras=extras or {})
        return logits[:, -1:], state

    def decode_fn(params, tokens, state, pos, extras=None):
        return api.decode_step(params, cfg, tokens, state, pos,
                               extras=extras or {})

    return prefill_fn, decode_fn


# ---------------------------------------------------------------------------
# Continuous batcher
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Fixed-slot continuous batching over the jitted decode step.

    Slots hold independent sequences; finished slots admit queued requests
    immediately (per-slot position tracking; greedy sampling).  CPU-scale
    smoke models exercise the exact code path the TPU deployment jits.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.state = api.init_decode_state(cfg, slots, max_len)
        self.pos = np.zeros((slots,), np.int32)
        self.active: list[Request | None] = [None] * slots
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._decode = jax.jit(
            lambda p, t, s, pos: api.decode_step(p, cfg, t, s, pos))
        self._steps = 0

    def submit(self, req: Request):
        self.queue.put(req)

    def _admit(self):
        for i in range(self.slots):
            if self.active[i] is None and not self.queue.empty():
                req = self.queue.get()
                # Prefill the slot by stepping its prompt token-by-token
                # (simple and exact; a chunked prefill is the TPU fast path).
                tok = np.zeros((self.slots, 1), np.int32)
                for t in req.prompt:
                    tok[i, 0] = t
                    logits, self.state = self._decode(
                        self.params, jnp.asarray(tok), self.state,
                        int(self.pos[i]))
                    self.pos[i] += 1
                req.out.append(int(jnp.argmax(logits[i, -1])))
                self.active[i] = req

    def step(self) -> int:
        """One decode tick across all active slots.  Returns #active."""
        self._admit()
        if not any(self.active):
            return 0
        tok = np.zeros((self.slots, 1), np.int32)
        for i, req in enumerate(self.active):
            if req is not None and req.out:
                tok[i, 0] = req.out[-1]
        pos = int(max(self.pos))     # single shared position cursor
        logits, self.state = self._decode(self.params, jnp.asarray(tok),
                                          self.state, pos)
        self._steps += 1
        n_active = 0
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[i] += 1
            req.out.append(int(jnp.argmax(logits[i, -1])))
            if len(req.out) >= req.max_new:
                req.done = True
                self.active[i] = None
            else:
                n_active += 1
        return n_active

    def run_until_drained(self, max_ticks: int = 10_000):
        while (not self.queue.empty() or any(self.active)) \
                and self._steps < max_ticks:
            self.step()
