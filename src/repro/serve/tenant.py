"""Tenant — one co-resident network inside the serving runtime.

A tenant binds a :class:`~repro.plan.multinet.TenantPlan` slice (the plan,
the column range, the latency budget) to a live engine: an
:class:`~repro.serve.engine.EdgeEngine` for extreme-edge nets, a
:class:`~repro.serve.engine.ContinuousBatcher` for LM nets.  The router owns
one tenant per net id and never reaches around it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

from repro.obs.slo import priority_rank
from repro.serve.metrics import TenantMetrics


def plan_priority(plan) -> str:
    """A plan's priority class: its serve section's ``priority`` when the
    fleet planner wrote one, else the kind default (edge traffic is the
    trigger path — ``critical``; LM tenants are ``standard``)."""
    serve = getattr(plan, "serve", None) or {}
    p = serve.get("priority")
    if p is not None:
        return str(p)
    return "critical" if getattr(plan, "kind", "edge") == "edge" \
        else "standard"


@dataclasses.dataclass
class Tenant:
    net_id: str
    plan: Any                    # DeploymentPlan (the tenant's slice)
    engine: Any                  # EdgeEngine | ContinuousBatcher
    # Seeds metrics.latency_budget_s; AFTER construction the metrics copy is
    # the live one — enforcement, reporting and runtime adjustments all read
    # and write ``tenant.metrics.latency_budget_s``.
    latency_budget_s: float = math.inf
    metrics: TenantMetrics = None
    # Priority class (see repro.obs.slo.PRIORITY_CLASSES); None resolves
    # from the plan's serve section / kind default at construction.
    priority: str | None = None

    def __post_init__(self):
        if self.metrics is None:
            self.metrics = TenantMetrics(
                self.net_id, latency_budget_s=self.latency_budget_s)
        if self.priority is None:
            self.priority = plan_priority(self.plan)
        priority_rank(self.priority)         # validate early

    @property
    def kind(self) -> str:
        """"edge" (synchronous infer) or "lm" (batched decode)."""
        return getattr(self.plan, "kind", "edge")

    @property
    def slots(self) -> int:
        """Batching capacity (1 for the synchronous edge path)."""
        return getattr(self.engine, "slots", 1)


def edge_tenant(tenant_plan, *, cfg=None, params=None, x_scale: float = 0.05,
                seed: int = 0) -> Tenant:
    """Build an edge tenant from a fleet's :class:`TenantPlan`: the engine
    executes exactly the tenant's planned Pallas blocks."""
    from repro.models import edge as edge_lib
    from repro.serve.engine import EdgeEngine
    plan = tenant_plan.plan
    if cfg is None:
        cfg = edge_lib.edge_config(plan.network)
    engine = EdgeEngine(cfg, params, plan=plan, x_scale=x_scale, seed=seed)
    return Tenant(net_id=tenant_plan.net_id, plan=plan, engine=engine,
                  latency_budget_s=tenant_plan.latency_budget_s)


def lm_tenant(tenant_plan, cfg, params, *, max_len: int = 256) -> Tenant:
    """Build an LM tenant: a plan-driven continuous batcher (slots, chunked
    prefill and admit policy all read from the tenant plan's serve section)."""
    from repro.serve.engine import ContinuousBatcher
    plan = tenant_plan.plan
    batcher = ContinuousBatcher(cfg, params, plan=plan, max_len=max_len)
    return Tenant(net_id=tenant_plan.net_id, plan=plan, engine=batcher,
                  latency_budget_s=tenant_plan.latency_budget_s)
