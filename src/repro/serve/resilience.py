"""Supervised serving: per-tenant circuit breakers, bounded retries,
deadlines, and the graceful-degradation ladder.

The serving loop treats a sick tenant the way a trigger-path system must:
isolate it, keep the co-resident tenants draining, and degrade along a
*correctness-preserving* ladder instead of returning garbage or dying.

Circuit breaker (per tenant)
    closed --[K consecutive failures]--> open
    open   --[``cooldown`` refused requests]--> half-open (one probe)
    half-open --[probe ok]--> closed     (records time-to-recovery)
    half-open --[probe fails]--> open    (cooldown restarts)

    The half-open trigger is *count-based* (refusals, not wall-clock),
    mirroring the router's shed probe: replays and tests are exactly
    reproducible with no sleeps.

Degradation ladder (audited via ``degrade/`` spans)
    0. fused megakernel            — the planned fast path
    1. per-layer ``gemm_int8``     — bit-exact vs fused (PR-4 invariant,
                                     re-asserted in tests), engaged when
                                     the breaker opens; restored after a
                                     clean success streak
    2. shed                        — the breaker stays open; only probes run
    (planning has its own rung: fitted ``MachineModel`` → stock constants
    when recalibration fails, handled in ``repro.deploy``.)

Per-request deadlines come from the plan's ``serve["slo"]["p95_s"]``
budget × ``deadline_factor``.  Overruns are counted and audited
(``fault/deadline`` spans) but do NOT feed the breaker: planned budgets
are modeled accelerator time, and host wall-clock overshooting them is an
SLO problem (PR-7's monitor owns it), not a tenant-health problem.
"""

from __future__ import annotations

import time

from repro.faults import RESILIENCE_DEFAULTS, NonFiniteOutput
from repro.obs import NULL_TRACER

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"
BREAKER_STATES = (CLOSED, OPEN, HALF_OPEN)


class CircuitBreaker:
    """Per-tenant failure isolation with a deterministic half-open probe.

    Single-threaded by design (the router's dispatch loop is); every
    state transition is audited as a ``breaker/<state>`` span.
    """

    def __init__(self, *, k: int = 3, cooldown: int = 8, tenant: str = "",
                 tracer=NULL_TRACER):
        self.k = max(1, int(k))
        self.cooldown = max(1, int(cooldown))
        self.tenant = tenant
        self.tracer = tracer
        self.state = CLOSED
        self.consecutive_failures = 0
        self.refused = 0                  # refusals since (re-)opening
        self.opens = 0                    # closed/half-open -> open count
        self.recloses = 0                 # -> closed recoveries
        self.opened_tick: float | None = None   # start of current outage
        self.time_to_recovery_s: float | None = None  # last outage length

    def _transition(self, state: str) -> None:
        if self.tracer.enabled:
            now = time.perf_counter()
            self.tracer.add(f"breaker/{state}", now, now, tenant=self.tenant,
                            failures=self.consecutive_failures)
        self.state = state

    def allow(self) -> bool:
        """Pre-request gate.  Closed admits; open refuses and counts the
        refusal — after ``cooldown`` refusals the NEXT request is admitted
        as the half-open probe."""
        if self.state == CLOSED:
            return True
        if self.state == OPEN:
            if self.refused >= self.cooldown:
                self._transition(HALF_OPEN)
                return True               # this call is the probe
            self.refused += 1
            return False
        return True                       # half-open: admit the probe

    def record_success(self) -> None:
        self.consecutive_failures = 0
        if self.state != CLOSED:          # probe succeeded: recover
            if self.opened_tick is not None:
                self.time_to_recovery_s = (time.perf_counter()
                                           - self.opened_tick)
                self.opened_tick = None
            self.recloses += 1
            self.refused = 0
            self._transition(CLOSED)

    def record_failure(self) -> None:
        self.consecutive_failures += 1
        if self.state == HALF_OPEN:       # probe failed: back to open
            self.opens += 1
            self.refused = 0
            self._transition(OPEN)
        elif (self.state == CLOSED
              and self.consecutive_failures >= self.k):
            self.opens += 1
            self.refused = 0
            self.opened_tick = time.perf_counter()
            self._transition(OPEN)

    def snapshot(self) -> dict:
        return {
            "state": self.state,
            "consecutive_failures": self.consecutive_failures,
            "breaker_opens": self.opens,
            "breaker_recloses": self.recloses,
            "time_to_recovery_s": self.time_to_recovery_s,
        }


class Supervisor:
    """Wraps each tenant engine with retries, deadlines, a breaker and the
    degradation ladder.  The :class:`~repro.serve.router.Router` consults
    it at dispatch; with no supervisor the router behaves exactly as
    before (isolation excepted), so existing paths pay nothing."""

    def __init__(self, *, tracer=NULL_TRACER, injector=None, defaults=None):
        self.tracer = tracer
        self.injector = injector          # armed FaultInjector (or None)
        self.defaults = dict(RESILIENCE_DEFAULTS)
        if defaults:
            self.defaults.update(defaults)
        self._cfg: dict = {}              # net_id -> resolved knobs
        self._breakers: dict = {}
        self._deadline_s: dict = {}       # net_id -> seconds | None
        self._streak: dict = {}           # net_id -> consecutive successes
        self.retries: dict = {}
        self.deadline_exceeded: dict = {}
        self.degrades: dict = {}
        self.restores: dict = {}

    @classmethod
    def from_fleet(cls, fleet, *, tracer=NULL_TRACER, injector=None,
                   defaults=None) -> "Supervisor":
        sup = cls(tracer=tracer, injector=injector, defaults=defaults)
        for tp in fleet.tenants:
            sup.register(tp.net_id, tp.plan)
        return sup

    def register(self, net_id: str, plan=None) -> dict:
        """Resolve a tenant's knobs from its plan's ``serve["resilience"]``
        section (defaults fill gaps for pre-plan-6 artifacts)."""
        serve = (getattr(plan, "serve", None) or {}) if plan is not None \
            else {}
        cfg = {**self.defaults, **(serve.get("resilience") or {})}
        self._cfg[net_id] = cfg
        self._breakers[net_id] = CircuitBreaker(
            k=cfg["breaker_k"], cooldown=cfg["breaker_cooldown"],
            tenant=net_id, tracer=self.tracer)
        p95 = (serve.get("slo") or {}).get("p95_s")
        self._deadline_s[net_id] = (cfg["deadline_factor"] * p95
                                    if p95 else None)
        self._streak[net_id] = 0
        for d in (self.retries, self.deadline_exceeded, self.degrades,
                  self.restores):
            d[net_id] = 0
        return cfg

    def breaker(self, net_id: str) -> CircuitBreaker:
        if net_id not in self._breakers:
            self.register(net_id)
        return self._breakers[net_id]

    def cfg(self, net_id: str) -> dict:
        if net_id not in self._cfg:
            self.register(net_id)
        return self._cfg[net_id]

    # -- dispatch hooks (called by the router) ----------------------------
    def admit(self, net_id: str) -> bool:
        """Breaker gate; ``False`` means refuse (map to TenantBreakerOpen)."""
        return self.breaker(net_id).allow()

    def call_edge(self, tenant, x):
        """Run a sync edge inference with bounded retry-with-backoff.
        Non-finite outputs are deterministic (same input, same NaN) and
        are not retried; anything else is treated as transient."""
        cfg = self.cfg(tenant.net_id)
        attempts = max(1, int(cfg.get("retries", 0)) + 1)
        backoff = float(cfg.get("backoff_s", 0.0))
        for attempt in range(attempts):
            try:
                return tenant.engine.infer(x)
            except NonFiniteOutput:
                raise
            except Exception:
                if attempt + 1 >= attempts:
                    raise
                self.retries[tenant.net_id] = \
                    self.retries.get(tenant.net_id, 0) + 1
                if backoff > 0.0:
                    time.sleep(backoff * (2 ** attempt))

    def record_success(self, tenant, dt_s: float | None = None) -> None:
        nid = tenant.net_id
        br = self.breaker(nid)
        was_recovering = br.state != CLOSED
        br.record_success()
        if dt_s is not None:
            deadline = self._deadline_s.get(nid)
            if deadline is not None and dt_s > deadline:
                self.deadline_exceeded[nid] = \
                    self.deadline_exceeded.get(nid, 0) + 1
                if self.tracer.enabled:
                    now = time.perf_counter()
                    self.tracer.add("fault/deadline", now - dt_s, now,
                                    tenant=nid, deadline_s=deadline)
        self._streak[nid] = self._streak.get(nid, 0) + 1
        # ladder restore: a clean streak at the degraded level (one
        # breaker-cooldown's worth, after the probe that reclosed) earns
        # the fused path back.
        eng = tenant.engine
        if (not was_recovering and br.state == CLOSED
                and getattr(eng, "degrade_level", 0) > 0
                and self._streak[nid] >= br.cooldown
                and hasattr(eng, "restore") and eng.restore()):
            self.restores[nid] = self.restores.get(nid, 0) + 1
            if self.tracer.enabled:
                now = time.perf_counter()
                self.tracer.add("degrade/restore", now, now, tenant=nid,
                                level=getattr(eng, "degrade_level", 0))

    def record_failure(self, tenant) -> None:
        nid = tenant.net_id
        self._streak[nid] = 0
        br = self.breaker(nid)
        was_open = br.state != CLOSED
        br.record_failure()
        if br.state != CLOSED and not was_open:
            # breaker just opened: step down the ladder (fused ->
            # per-layer).  If the tenant is ALREADY per-layer, there is no
            # correct path left — the open breaker IS level 2 (shed).
            eng = tenant.engine
            if hasattr(eng, "degrade") and eng.degrade():
                self.degrades[nid] = self.degrades.get(nid, 0) + 1
                if self.tracer.enabled:
                    now = time.perf_counter()
                    self.tracer.add("degrade/fallback", now, now, tenant=nid,
                                    level=getattr(eng, "degrade_level", 1))

    # -- reporting --------------------------------------------------------
    def snapshot(self, net_id: str) -> dict:
        out = self.breaker(net_id).snapshot()
        out.update(retries=self.retries.get(net_id, 0),
                   deadline_exceeded=self.deadline_exceeded.get(net_id, 0),
                   degrades=self.degrades.get(net_id, 0),
                   restores=self.restores.get(net_id, 0))
        return out
