"""Machine models.

Three hardware models live here:

* :class:`TpuV5e` -- the TARGET device for the TPU-native adaptation of the
  paper's design rules.  All roofline terms in ``launch/roofline.py`` and all
  tiling-planner latency estimates in ``core/tiling.py`` read from this model.

* :class:`AieMl` -- the paper's AI-Engine machine model (VEK280, AIE-ML array),
  parameterized exactly as the paper describes it (Section IV-B).  Used by the
  paper-faithful reproduction of Figs. 2-7 / Table I.

* :class:`PlFabric` -- the paper's programmable-logic (HLS4ML) machine model:
  a reuse-factor-driven spatial dataflow cost model.  Used by the LARE metric
  (Alg. 1) and the Fig. 2/3 reproductions.

Every constant is a dataclass field so experiments can re-parameterize (e.g.
a different Versal part or a TPU v5p) without touching the algorithms.
"""

from __future__ import annotations

import dataclasses
import math

GiB = 1024**3
MiB = 1024**2
KiB = 1024


@dataclasses.dataclass(frozen=True)
class TpuV5e:
    """TPU v5e single-chip model + pod interconnect (assignment constants)."""

    # Compute.
    peak_bf16_flops: float = 197e12      # FLOP/s per chip (MXU, bf16)
    peak_int8_ops: float = 394e12        # OP/s per chip (int8)
    mxu: int = 128                       # systolic array dimension
    # Memory hierarchy.
    hbm_bytes: int = 16 * GiB
    hbm_bw: float = 819e9                # B/s per chip
    vmem_bytes: int = 128 * MiB          # on-chip vector memory
    vreg_lane: int = 128                 # lane count (last-dim tiling)
    vreg_sublane: int = 8                # sublanes for 4-byte types
    # Interconnect.
    ici_bw: float = 50e9                 # B/s per link (assignment constant)
    ici_links: int = 4                   # torus links per chip (2D torus, v5e)
    dcn_bw: float = 12.5e9               # B/s per chip cross-pod (est., documented)
    # Dispatch overhead charged per un-fused kernel boundary (seconds). This is
    # the fixed part of the paper's DR7 boundary-crossing cost on TPU.
    kernel_overhead_s: float = 2.2e-6
    # Cost of keeping a layer boundary INSIDE a fused megakernel: the epilogue
    # requantize (round/clip/cast through VMEM scratch) paid per fused inner
    # boundary instead of the full crossing.  The fuse-vs-split decision is
    # epilogue-vs-crossing; the characterization harness fits this from the
    # fused-chain sweep (``repro.characterize`` term ``fused_chain``).
    fused_epilogue_s: float = 3e-7

    def sublanes_for(self, itemsize: int) -> int:
        """Second-to-last-dim tiling multiple for a dtype of `itemsize` bytes."""
        return self.vreg_sublane * max(1, 4 // itemsize)

    def matmul_time(self, m: int, k: int, n: int, *, itemsize: int = 2) -> float:
        """Roofline time of one dense matmul on one chip (compute vs HBM)."""
        flops = 2.0 * m * k * n
        peak = self.peak_int8_ops if itemsize == 1 else self.peak_bf16_flops
        # MXU efficiency: padding waste when dims are not multiples of the MXU.
        eff = (
            min(1.0, m / _ceil_to(m, self.vreg_sublane))
            * min(1.0, k / _ceil_to(k, self.mxu))
            * min(1.0, n / _ceil_to(n, self.mxu))
        )
        t_compute = flops / (peak * max(eff, 1e-9))
        bytes_moved = itemsize * (m * k + k * n) + 4 * (m * n)
        t_memory = bytes_moved / self.hbm_bw
        return max(t_compute, t_memory)


@dataclasses.dataclass(frozen=True)
class AieMl:
    """AMD Versal VEK280 AIE-ML array model (paper Section IV-B constants)."""

    clock_hz: float = 1e9                # hardened, up to 1 GHz
    macs_per_cycle_int8: int = 256       # per compute tile
    tiles_total: int = 304               # 38 cols x 8 rows
    cols: int = 38
    rows: int = 8
    usable_cols: int = 31                # AIE4ML restriction (cols 7..37)
    local_mem_bytes: int = 64 * KiB      # per-tile data memory
    load_bw: float = 64e9                # B/s local read (2x256-bit @1GHz)
    store_bw: float = 32e9               # B/s local write (1x256-bit @1GHz)
    cascade_bits: int = 512              # west->east partial-sum bus
    stream_bits: int = 32                # per-tile in/out streaming ports
    plio_bw: float = 5e9                 # B/s (128-bit @ 312.5 MHz)
    dsp58_equiv_per_tile: float = 58.0   # paper: one tile ~ 58 DSP58s
    # Fig.-6 band-spill contention: fractional latency added per layer placed
    # in a spilled band.  A machine-model field (not a tiling-module constant)
    # so the characterization harness (repro.characterize) can substitute the
    # fitted slope and the plan key picks up the change.
    band2_penalty_per_layer: float = 0.085

    # Legal aie::mmul API tile shapes for i8 x i8 (paper Fig. 4 y-axis).
    legal_api_tiles_i8: tuple = (
        (4, 8, 4), (4, 8, 8), (4, 16, 4), (4, 16, 8), (8, 8, 4), (8, 8, 8),
    )

    # Empirical per-API-shape efficiency (fraction of peak MACs/cycle reached in
    # steady state), calibrated to reproduce Fig. 4's ordering: (4,8,8) and
    # (4,16,8) best; small-N shapes starve the wide accumulators.
    def api_efficiency(self, s_m: int, s_k: int, s_n: int) -> float:
        base = {
            (4, 8, 4): 0.52, (4, 8, 8): 0.95, (4, 16, 4): 0.55,
            (4, 16, 8): 0.93, (8, 8, 4): 0.60, (8, 8, 8): 0.82,
        }.get((s_m, s_k, s_n), 0.40)
        return base


@dataclasses.dataclass(frozen=True)
class PlFabric:
    """HLS4ML-on-PL spatial-dataflow model (VEK280 PL side, paper Section III).

    A dense layer (n_in, n_out) with reuse factor rf:
      * uses  ceil(n_in*n_out / rf) multipliers (DSP58s),
      * has initiation interval II ~= rf cycles (plus fixed pipeline depth),
      * stores all weights on-chip (BRAM under the Resource strategy, LUT/FF
        under the Latency strategy).
    """

    clock_hz: float = 312.5e6            # PL clock used in the paper
    dsp_total: int = 1312                # approximate VEK280 PL DSP58 budget
    lut_total: int = 900_000             # approximate; configurable
    bram_bits_total: int = 967 * 36 * 1024  # approximate 36kb BRAM blocks
    pipeline_depth: int = 12             # fixed pipeline fill latency (cycles)
    # The Latency strategy burns ~alpha LUTs per weight bit instead of BRAM.
    latency_strategy_lut_per_weight_bit: float = 1.1

    def legal_reuse_factors(self, n_in: int, n_out: int) -> list[int]:
        """HLS4ML legal rf values: divisors of n_in*n_out (capped)."""
        total = n_in * n_out
        rfs = [d for d in range(1, min(total, 4096) + 1) if total % d == 0]
        return rfs

    def dsps(self, n_in: int, n_out: int, rf: int) -> int:
        return math.ceil(n_in * n_out / rf)

    def interval_cycles(self, rf: int) -> int:
        return max(1, rf)

    def latency_s(self, n_in: int, n_out: int, rf: int, batch: int = 8) -> float:
        # Streaming batch through a pipelined datapath: fill + (batch-1)*II.
        cycles = self.pipeline_depth + math.ceil(math.log2(max(2, n_in))) \
            + (batch - 1) * self.interval_cycles(rf) + self.interval_cycles(rf)
        return cycles / self.clock_hz

    def interval_s(self, rf: int) -> float:
        return self.interval_cycles(rf) / self.clock_hz

    def resources(self, n_in: int, n_out: int, rf: int, *,
                  strategy: str = "resource", weight_bits: int = 8) -> dict:
        """Resource vector for one dense layer at a given reuse factor."""
        dsp = self.dsps(n_in, n_out, rf)
        w_bits = n_in * n_out * weight_bits
        if strategy == "latency":
            lut = int(w_bits * self.latency_strategy_lut_per_weight_bit) + 40 * dsp
            bram_bits = 0
        else:
            lut = 28 * dsp
            bram_bits = w_bits if rf > 1 else 0  # rf=1 keeps weights in fabric
        return {"dsp": dsp, "lut": lut, "bram_bits": bram_bits}

    def fits(self, res: dict) -> bool:
        return (res["dsp"] <= self.dsp_total and res["lut"] <= self.lut_total
                and res["bram_bits"] <= self.bram_bits_total)

    def resource_scalar(self, res: dict) -> float:
        """Single-number resource consumption: DSP-equivalents (paper's x-axis).

        LUT and BRAM contributions are folded in as fractional DSP-equivalents
        by budget share, so one scalar spans the three PL resource types.
        """
        return (res["dsp"]
                + res["lut"] / self.lut_total * self.dsp_total * 0.25
                + res["bram_bits"] / self.bram_bits_total * self.dsp_total * 0.25)


def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


# Canonical singletons (experiments may construct their own).
TPU_V5E = TpuV5e()
AIE_ML = AieMl()
PL_FABRIC = PlFabric()
