"""The facade's stages: characterize → plan → verify → engines, as
explicit objects.

Each stage is individually invokable: it reads its typed inputs off a
:class:`StageContext`, writes exactly one output back (plus an optional
artifact under ``ctx.artifact_dir``), and returns a :class:`StageResult`
describing what happened (output, wall time, whether it was served from
cache, where the artifact landed).  :class:`repro.deploy.Deployment` runs
them in order; partial pipelines — plan-only, serve-from-a-committed-plan —
just run (or skip) stages individually instead of copy-pasting glue.

Stage contract:

=============== =============================== =======================
stage           inputs (ctx fields)             output (ctx field)
=============== =============================== =======================
characterize    machine_model spec, target      model + plan_kw hw knobs
plan            configs, target, plan_kw, cache fleet (FleetPlan)
verify          fleet, plan_kw, verify flag     findings (design rules)
engines         fleet, configs, lm_params       engines {net_id: engine}
=============== =============================== =======================
"""

from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Any

from repro.obs import NULL_TRACER
from repro.plan import PlanCache, default_cache
from repro.plan.multinet import FleetPlan, plan_fleet

# Sweep-keyed memo for full characterization runs: every Deployment in the
# process shares one fitted MachineModel per sweep density instead of
# re-timing the microbenchmarks.
_SWEEP_MEMO: dict[str, Any] = {}

_MODEL_ARTIFACT = "machine_model.json"


@dataclasses.dataclass(frozen=True)
class StageResult:
    """What one stage did: its output, provenance and cost."""
    stage: str
    output: Any
    cached: bool = False                 # served from a cache/memo/artifact
    skipped: bool = False                # inputs made the stage a no-op
    artifact: pathlib.Path | None = None
    wall_s: float = 0.0
    detail: str = ""

    def __str__(self) -> str:
        state = ("cached" if self.cached else
                 "skipped" if self.skipped else "ran")
        art = f" -> {self.artifact}" if self.artifact else ""
        det = f" ({self.detail})" if self.detail else ""
        return f"{self.stage:<12} {state:<7} {self.wall_s:7.2f}s{det}{art}"


@dataclasses.dataclass
class StageContext:
    """Everything the stages read and write — the pipeline's typed state.

    Inputs are set by :meth:`repro.deploy.Deployment.build`; each stage
    fills in its output field (``model``/``fleet``/``engines``) and records
    its :class:`StageResult` under ``results``.
    """
    configs: list = dataclasses.field(default_factory=list)
    target: str = "tpu"
    machine_model: Any = "auto"          # spec; resolved by CharacterizeStage
    cache: PlanCache | None = None
    artifact_dir: pathlib.Path | None = None
    plan_kw: dict = dataclasses.field(default_factory=dict)
    lm_params: dict = dataclasses.field(default_factory=dict)
    batch: int | None = None
    x_scale: float = 0.05
    seed: int = 0
    tracer: Any = NULL_TRACER            # repro.obs.Tracer when tracing
    verify: bool = True                  # run the design-rule gate
    injector: Any = None                 # repro.faults.FaultInjector | None
    # stage outputs
    model: Any = None                    # MachineModel | TpuV5e | None
    fleet: FleetPlan | None = None
    findings: list = dataclasses.field(default_factory=list)
    engines: dict = dataclasses.field(default_factory=dict)
    results: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.cache is None:
            self.cache = default_cache()
        if self.artifact_dir is not None:
            self.artifact_dir = pathlib.Path(self.artifact_dir)

    def record(self, res: StageResult) -> StageResult:
        self.results[res.stage] = res
        return res


def resolve_configs(specs) -> list:
    """Accept one or many config specs; return concrete config objects.

    A spec is an ``EdgeConfig``/``ModelConfig``/``DataflowGraph`` passed
    through as-is, or a string: an ``EDGE_NETS`` name, or an LM arch id
    (resolved to its CPU-serveable ``smoke`` config; pass the full
    ``configs.get(name).config`` object explicitly to plan at scale).
    """
    from repro.models import edge
    if specs is None:
        return []
    if not isinstance(specs, (list, tuple)):
        specs = [specs]
    out = []
    for s in specs:
        if not isinstance(s, str):
            out.append(s)
            continue
        name = s[3:] if s.startswith("lm:") else s
        if not s.startswith("lm:") and name in edge.EDGE_NETS:
            out.append(edge.edge_config(name))
            continue
        try:
            from repro import configs as configs_lib
            out.append(configs_lib.get(name).smoke)
        except ModuleNotFoundError as exc:
            # Only the registry's own lookup miss means "unknown name"; a
            # config module failing to import one of ITS dependencies must
            # surface as the real error, not a misleading name complaint.
            if exc.name is None or not exc.name.startswith("repro.configs"):
                raise
            raise ValueError(
                f"unknown network {s!r}: not an edge net "
                f"({sorted(edge.EDGE_NETS)}) and not an LM arch id") from None
    return out


class CharacterizeStage:
    """Resolve the ``machine_model`` spec into fitted planner knobs.

    Spec values:

    * ``None`` / ``"stock"`` — hand-tuned ``hw.py`` constants (skip);
    * ``"auto"`` — the fast host calibration
      (:func:`repro.plan.calibrated_cpu_model`, memoized per process): the
      gemm term fitted to THIS host so planned-vs-measured is meaningful;
    * ``"quick"`` / ``"full"`` — the full characterization sweep at that
      density (``repro.characterize.characterize``, memoized per sweep;
      loaded from ``<artifact_dir>/machine_model.json`` when one exists);
    * a path — ``MachineModel.load(path)``;
    * a ``MachineModel`` — used as-is (``machine_model=`` planner knob);
    * a ``TpuV5e`` — used as-is (``tpu=`` planner knob).
    """

    name = "characterize"
    inputs = ("machine_model", "target")
    output = "model"

    def run(self, ctx: StageContext) -> StageResult:
        from repro import hw as hwlib
        from repro.characterize import MachineModel
        spec = ctx.machine_model
        t0 = time.perf_counter()

        def done(model, *, cached=False, skipped=False, artifact=None,
                 detail=""):
            ctx.model = model
            if model is None:
                pass
            elif isinstance(model, hwlib.TpuV5e):
                ctx.plan_kw.setdefault("tpu", model)
            else:
                ctx.plan_kw.setdefault("machine_model", model)
            return ctx.record(StageResult(
                stage=self.name, output=model, cached=cached, skipped=skipped,
                artifact=artifact, wall_s=time.perf_counter() - t0,
                detail=detail))

        if spec is None or spec == "stock":
            return done(None, skipped=True, detail="stock hw constants")
        if isinstance(spec, hwlib.TpuV5e):
            return done(spec, cached=True, detail="caller-supplied tpu model")
        if isinstance(spec, MachineModel):
            return done(spec, cached=True,
                        detail=f"caller-supplied {spec.version[:12]}")
        if spec == "auto":
            from repro.plan import calibrate
            cached = calibrate.cpu_model_memoized(batch=ctx.batch or 8)
            model = calibrate.calibrated_cpu_model(batch=ctx.batch or 8)
            return done(model, cached=cached, detail="host gemm calibration")
        if spec in ("quick", "full"):
            artifact = None
            if ctx.artifact_dir is not None:
                artifact = ctx.artifact_dir / _MODEL_ARTIFACT
                if artifact.exists():
                    model = MachineModel.load(artifact)
                    if _artifact_matches(model, spec):
                        return done(model, cached=True, artifact=artifact,
                                    detail=f"{spec} (loaded)")
            if spec in _SWEEP_MEMO:
                return done(_SWEEP_MEMO[spec], cached=True,
                            detail=f"{spec} sweep (memo)")
            from repro.characterize import characterize
            model = characterize(sweep=spec, tracer=ctx.tracer)
            _SWEEP_MEMO[spec] = model
            if artifact is not None:
                model.save(artifact)
            return done(model, artifact=artifact, detail=f"{spec} sweep")
        if isinstance(spec, (str, pathlib.Path)):
            model = MachineModel.load(spec)
            return done(model, cached=True,
                        detail=f"loaded {pathlib.Path(spec).name}")
        if isinstance(spec, dict):           # CLI: explicit sweep options
            from repro.characterize import characterize
            model = characterize(tracer=ctx.tracer, **spec)
            artifact = None
            if ctx.artifact_dir is not None:
                artifact = ctx.artifact_dir / _MODEL_ARTIFACT
                model.save(artifact)
            return done(model, artifact=artifact,
                        detail=f"sweep={spec.get('sweep', 'quick')}")
        raise TypeError(f"cannot resolve machine_model spec {spec!r}")


def _artifact_matches(model, spec: str) -> bool:
    """Whether an on-disk MachineModel can stand in for a fresh ``spec``
    sweep: fitted at the requested density, on THIS host and jax build.
    Anything else is the staleness the drift machinery exists to catch —
    refit rather than silently adopt another machine's constants."""
    import platform

    import jax
    prov = model.provenance
    return (prov.get("sweep") == spec
            and prov.get("host") == platform.node()
            and prov.get("jax") == jax.__version__)


class PlanStage:
    """Plan the configs as one (possibly single-tenant) fleet.

    Always goes through :func:`repro.plan.plan_fleet`, so single nets and
    fleets share one code path, every LM tenant gets its serve-section
    batching policy, and the fleet cache answers repeat questions (the
    ``cached`` flag on the result tells you it did).
    """

    name = "plan"
    inputs = ("configs", "target", "plan_kw", "cache")
    output = "fleet"

    def run(self, ctx: StageContext) -> StageResult:
        t0 = time.perf_counter()
        if ctx.fleet is not None:            # serve-from-artifact pipelines
            return ctx.record(StageResult(
                stage=self.name, output=ctx.fleet, cached=True,
                wall_s=time.perf_counter() - t0,
                detail="pre-built plan supplied"))
        if not ctx.configs:
            raise ValueError("plan stage needs at least one config "
                             "(or a pre-built plan=)")
        key = fleet_key(ctx)
        cached = ctx.cache.get_fleet(key) is not None
        ctx.fleet = plan_fleet(ctx.configs, target=ctx.target,
                               batch=ctx.batch, cache=ctx.cache,
                               **ctx.plan_kw)
        artifact = None
        if ctx.artifact_dir is not None:
            if len(ctx.fleet.tenants) == 1:
                t = ctx.fleet.tenants[0]
                artifact = t.plan.save(
                    ctx.artifact_dir / f"{t.net_id}_{ctx.target}.json")
            else:
                artifact = ctx.fleet.save(
                    ctx.artifact_dir
                    / f"fleet_{ctx.fleet.name}_{ctx.target}.json")
        return ctx.record(StageResult(
            stage=self.name, output=ctx.fleet, cached=cached,
            artifact=artifact, wall_s=time.perf_counter() - t0,
            detail=f"{len(ctx.fleet.tenants)} tenant(s), "
                   f"key={ctx.fleet.key[:12]}"))


def fleet_key(ctx: StageContext) -> str:
    """The serve-scoped fleet cache key this context's plan stage will use
    (delegates to the plan layer's own key derivation)."""
    from repro.plan.multinet import fleet_store_key
    return fleet_store_key(ctx.configs, target=ctx.target, batch=ctx.batch,
                           **ctx.plan_kw)


class VerifyStage:
    """The fail-closed design-rule gate between planning and engines.

    Runs :func:`repro.check.check_fleet` — the full layer-1 plan rules plus
    the layer-2 kernel contracts — over the planned (or artifact-loaded)
    fleet BEFORE any engine is constructed.  Error-severity findings raise
    :class:`repro.check.PlanVerificationError`; warnings and info findings
    accumulate on ``ctx.findings`` and surface in ``Deployment.summary()``.

    ``Deployment.build(check=False)`` records the stage as skipped (the
    escape hatch for deliberately-out-of-spec experiments).  The stage is
    fault-injectable at the ``build`` hook site with ``tenant="verify"`` —
    chaos drills can make the gate itself fail without corrupting a plan.
    """

    name = "verify"
    inputs = ("fleet", "plan_kw", "verify")
    output = "findings"

    def run(self, ctx: StageContext) -> StageResult:
        from repro.check import PlanVerificationError, check_fleet
        t0 = time.perf_counter()
        if not ctx.verify:
            return ctx.record(StageResult(
                stage=self.name, output=[], skipped=True,
                wall_s=time.perf_counter() - t0, detail="check=False"))
        if ctx.fleet is None:
            raise ValueError("verify stage needs a planned fleet "
                             "(run the plan stage first)")
        if ctx.injector is not None:
            spec = ctx.injector.fire("build", tenant="verify")
            if spec is not None:
                from repro.faults import InjectedFault
                raise InjectedFault("verify stage: injected failure")
        ctx.findings = check_fleet(ctx.fleet, tpu=ctx.plan_kw.get("tpu"))
        errors = [f for f in ctx.findings if f.severity == "error"]
        counts = {}
        for f in ctx.findings:
            counts[f.severity] = counts.get(f.severity, 0) + 1
        detail = (", ".join(f"{n} {s}" for s, n in sorted(counts.items()))
                  or "clean")
        res = ctx.record(StageResult(
            stage=self.name, output=list(ctx.findings),
            wall_s=time.perf_counter() - t0, detail=detail))
        if errors:
            raise PlanVerificationError(ctx.findings)
        return res


class EngineStage:
    """Build one live engine per tenant: quantize + calibrate + jit.

    Edge tenants get an :class:`~repro.serve.engine.EdgeEngine` executing
    exactly the tenant's planned Pallas blocks (weights int8-quantized with
    activation scales calibrated against the float reference); LM tenants
    get a plan-driven :class:`~repro.serve.engine.ContinuousBatcher`.  LM
    weights come from ``ctx.lm_params[net_id]``; when absent they are
    seed-initialized (serving smoke — real deployments pass trained params).
    """

    name = "engines"
    inputs = ("fleet", "configs", "lm_params")
    output = "engines"

    def run(self, ctx: StageContext) -> StageResult:
        import jax

        from repro.models import api, edge as edge_lib
        from repro.serve.engine import ContinuousBatcher, EdgeEngine
        if ctx.fleet is None:
            raise ValueError("engine stage needs a planned fleet "
                             "(run the plan stage first)")
        t0 = time.perf_counter()
        by_name = {getattr(c, "name", None): c for c in ctx.configs}
        for tp in ctx.fleet.tenants:
            if tp.net_id in ctx.engines:
                continue
            plan = tp.plan
            cfg = by_name.get(plan.network)
            if plan.kind == "lm":
                if tp.net_id in ctx.lm_params:
                    cfg, params = ctx.lm_params[tp.net_id]
                else:
                    if cfg is None:
                        raise ValueError(
                            f"LM tenant {tp.net_id!r} needs its config: "
                            f"pass lm_params={{net_id: (cfg, params)}} or "
                            f"build from config objects")
                    params = api.init(cfg, jax.random.PRNGKey(ctx.seed))
                ctx.engines[tp.net_id] = ContinuousBatcher(cfg, params,
                                                           plan=plan)
            else:
                if cfg is None:
                    cfg = edge_lib.edge_config(plan.network)
                ctx.engines[tp.net_id] = EdgeEngine(
                    cfg, plan=plan, x_scale=ctx.x_scale, seed=ctx.seed)
        kinds = [tp.plan.kind for tp in ctx.fleet.tenants]
        return ctx.record(StageResult(
            stage=self.name, output=ctx.engines,
            wall_s=time.perf_counter() - t0,
            detail=f"{kinds.count('edge')} edge + {kinds.count('lm')} lm"))


PIPELINE = (CharacterizeStage(), PlanStage(), VerifyStage(), EngineStage())
STAGES = {s.name: s for s in PIPELINE}
