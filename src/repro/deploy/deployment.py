"""``Deployment`` — the one-call facade over characterize → plan →
calibrate → engines → serve.

The paper's deliverable is a *decision procedure*: characterize the target,
plan under the fitted model, deploy what fits, measure, recalibrate.  After
PRs 1–4 those pieces lived in four subsystems with four entry points; this
module is the staged pipeline that composes them:

    from repro.deploy import Deployment
    dep = Deployment.build(["jet_tagger", "tau_select"])   # chars + plans +
    router = dep.serve()                                   #   engines, wired
    router.drive(iters=20)                                 # measured traffic
    rows = dep.bench()                                     # planned-vs-meas
    dep.recalibrate()                                      # drift loop

Every step is resumable and partial pipelines are first-class:
``Deployment.build(cfgs, stop_after="plan")`` is plan-only,
``Deployment.build(plan="fleet.json")`` serves a committed artifact, and
the individual stages (:mod:`repro.deploy.stages`) can be invoked by hand
against a :class:`StageContext`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

from repro.deploy.stages import (PIPELINE, StageContext, StageResult,
                                 resolve_configs)
from repro.obs import NULL_TRACER, Tracer
from repro.plan.artifact import DeploymentPlan
from repro.plan.multinet import FleetPlan

_STAGE_ORDER = tuple(s.name for s in PIPELINE)


@dataclasses.dataclass(frozen=True)
class BenchRow:
    """One planned-vs-measured judgement, in the benchmark-row vocabulary."""
    net_id: str
    planned_s: float
    measured_s: float
    extra: str = ""                      # extra "k=v;" derived fields

    @property
    def ratio(self) -> float:
        return (self.planned_s / self.measured_s if self.measured_s > 0
                else float("inf"))

    @property
    def within_2x(self) -> bool:
        return 0.5 <= self.ratio <= 2.0

    @property
    def derived(self) -> str:
        return (f"planned_us={self.planned_s * 1e6:.1f};"
                f"ratio={self.ratio:.2f};within_2x={self.within_2x};"
                f"{self.extra}src=measured")

    def as_record(self, name: str | None = None) -> dict:
        """A ``benchmarks/common.emit``-shaped row for trend.py."""
        return {"name": name or f"deploy/{self.net_id}/planned-vs-measured",
                "us_per_call": round(self.measured_s * 1e6, 3),
                "derived": self.derived}


def _fault_injector(faults):
    """Coerce a ``faults=`` argument into a live ``FaultInjector``:
    an injector passes through, a ``FaultPlan`` arms fresh counters, a
    list/tuple of specs (or spec dicts) becomes an ad-hoc plan, and
    anything else is treated as a path to a saved plan artifact."""
    from repro.faults import FaultInjector, FaultPlan
    if faults is None:
        return None
    if isinstance(faults, FaultInjector):
        return faults
    if isinstance(faults, FaultPlan):
        return faults.injector()
    if isinstance(faults, (list, tuple)):
        return FaultPlan(faults=tuple(faults)).injector()
    return FaultPlan.load(faults).injector()


def _load_plan(plan) -> FleetPlan:
    """Accept a FleetPlan, a DeploymentPlan, or a path to either artifact."""
    if isinstance(plan, FleetPlan):
        return plan
    if isinstance(plan, DeploymentPlan):
        return FleetPlan.from_plan(plan)
    return FleetPlan.load(plan)          # handles v1/v2/v3 + fleet artifacts


class Deployment:
    """A built (or building) deployment: plans + engines + serving surface.

    Construct via :meth:`build`; the staged pipeline state lives on
    ``self.ctx`` and per-stage provenance (cache hits, wall time, artifact
    paths) on :attr:`stage_results`.
    """

    def __init__(self, ctx: StageContext):
        self.ctx = ctx
        self._router = None
        self._router_kw = None
        self._injector = None

    # -- construction -----------------------------------------------------
    @classmethod
    def build(cls, configs=None, *, target: str = "tpu",
              machine_model: Any = "auto", cache=None, plan=None,
              artifact_dir=None, lm_params: dict | None = None,
              stop_after: str | None = None, batch: int | None = None,
              x_scale: float = 0.05, seed: int = 0, trace=False,
              faults=None, check: bool = True, **plan_kw) -> "Deployment":
        """Run the pipeline end-to-end (or up to ``stop_after``).

        ``configs`` — one or many: edge net names, ``EdgeConfig``s,
        ``ModelConfig``s (LM arch ids resolve to their smoke config).
        ``machine_model`` — see :class:`~repro.deploy.stages.
        CharacterizeStage`: ``"auto"`` (default) calibrates the planner to
        this host, ``None`` keeps stock constants, ``"quick"``/``"full"``
        run the characterization sweep, or pass a ``MachineModel``/path.
        ``plan`` — a committed plan artifact (path, ``DeploymentPlan`` or
        ``FleetPlan``): skips characterize+plan and serves it as-is.
        ``stop_after`` — ``"characterize"`` or ``"plan"`` for partial
        pipelines (``"plan"`` is the CLI's ``--dry-run``).
        ``trace`` — ``True`` (a fresh :class:`repro.obs.Tracer`) or a
        caller-supplied ``Tracer``: every stage emits a ``stage/<name>``
        span and the serving surface decomposes requests into
        queue/prefill/decode spans; export via :meth:`export_trace` /
        :meth:`export_prometheus`, judge via :meth:`attribution`.
        ``faults`` — a :class:`repro.faults.FaultPlan` (or injector, spec
        list, or saved-plan path): arms the plan cache's ``cache.read``
        hook during the build and is re-armed on the router by
        :meth:`replay`.
        ``check`` — ``True`` (default) runs the static design-rule
        verifier (:mod:`repro.check`) between planning and engines: a
        plan with error-severity findings raises
        :class:`repro.check.PlanVerificationError` and no engine is
        constructed.  ``check=False`` skips the gate (deliberately
        out-of-spec experiments).
        Planner knobs (``pl_budget``, ``pipeline_core_budget``, ``tpu=``,
        fleet serve knobs…) pass through ``plan_kw``.
        """
        if stop_after is not None and stop_after not in _STAGE_ORDER:
            raise ValueError(f"stop_after must be one of {_STAGE_ORDER}, "
                             f"got {stop_after!r}")
        tracer = (trace if isinstance(trace, Tracer)
                  else Tracer() if trace else NULL_TRACER)
        ctx = StageContext(
            configs=resolve_configs(configs), target=target,
            machine_model=machine_model if plan is None else None,
            cache=cache, artifact_dir=artifact_dir, plan_kw=dict(plan_kw),
            lm_params=dict(lm_params or {}), batch=batch, x_scale=x_scale,
            seed=seed, tracer=tracer, verify=check)
        if plan is not None:
            ctx.fleet = _load_plan(plan)
        dep = cls(ctx)
        dep._injector = _fault_injector(faults)
        if dep._injector is not None:
            ctx.cache.injector = dep._injector
            ctx.injector = dep._injector
            spec = dep._injector.fire("build")
            if spec is not None:
                from repro.faults import InjectedFault
                raise InjectedFault("deployment build: injected failure")
        dep._run_until(stop_after or _STAGE_ORDER[-1])
        return dep

    def _run_until(self, last: str):
        """Run pipeline stages (idempotently) through ``last``; each run
        emits a ``stage/<name>`` span carrying the cached/skipped flags."""
        for stage in PIPELINE:
            if stage.name not in self.ctx.results:
                t0 = time.perf_counter()
                res = stage.run(self.ctx)
                if self.ctx.tracer.enabled:
                    self.ctx.tracer.add(
                        f"stage/{stage.name}", t0, time.perf_counter(),
                        tenant="deploy", cached=res.cached,
                        skipped=res.skipped)
            if stage.name == last:
                break

    # -- typed views over the pipeline state ------------------------------
    @property
    def stage_results(self) -> dict[str, StageResult]:
        return dict(self.ctx.results)

    @property
    def machine_model(self):
        """The resolved model (``MachineModel``/``TpuV5e``) or None."""
        return self.ctx.model

    @property
    def fleet(self) -> FleetPlan:
        if self.ctx.fleet is None:
            raise RuntimeError("not planned yet (run the plan stage)")
        return self.ctx.fleet

    @property
    def plan(self):
        """The single-net ``DeploymentPlan``, or the ``FleetPlan`` when
        several networks were deployed together."""
        fleet = self.fleet
        return fleet.tenants[0].plan if len(fleet.tenants) == 1 else fleet

    @property
    def plans(self) -> dict[str, DeploymentPlan]:
        return {t.net_id: t.plan for t in self.fleet.tenants}

    @property
    def findings(self) -> list:
        """The design-rule findings the verify stage recorded (warnings and
        info advisories; error findings abort the build)."""
        return list(self.ctx.findings)

    @property
    def engines(self) -> dict:
        """net_id -> live engine (EdgeEngine | ContinuousBatcher), building
        them on first access if the pipeline stopped before that stage."""
        self._run_until("engines")
        return self.ctx.engines

    @property
    def tracer(self) -> Tracer:
        """The deployment's span sink (:data:`repro.obs.NULL_TRACER` unless
        built with ``trace=``)."""
        return self.ctx.tracer

    # -- serving ----------------------------------------------------------
    def serve(self, *, shed_after: int | None = None,
              drift_threshold: float | None = None,
              drift_min_samples: int = 5, slo: Any = True,
              defer_limit: int = 4, resilience: Any = True,
              fresh: bool = False):
        """The fleet behind a :class:`repro.serve.Router`, wired from the
        plan's serve section and this deployment's engines.  Memoized —
        repeated calls with the same knobs return the same live router;
        different knobs (or ``fresh=True``) rebuild it (engines and their
        compiled tiles are reused; router metrics start over).

        ``slo`` — ``True`` (default) attaches a
        :class:`repro.obs.slo.SloMonitor` with per-tenant p95/p99 budgets
        from each plan's serve section, enabling the router's SLO-aware
        priority scheduling; pass a ready monitor to customize windows and
        budgets, or ``False``/``None`` for the pre-SLO behavior.
        ``resilience`` — ``True`` (default) attaches a
        :class:`repro.serve.Supervisor` wired from each plan's
        ``serve["resilience"]`` knobs (per-tenant circuit breakers,
        bounded retries, deadline audit, the degradation ladder); pass a
        ready supervisor to customize, or ``False``/``None`` for the
        pre-supervisor behavior (fault isolation in the router remains).
        """
        from repro.obs.slo import SloMonitor
        from repro.serve import Router
        kw = {"shed_after": shed_after, "drift_threshold": drift_threshold,
              "drift_min_samples": drift_min_samples, "slo": slo,
              "defer_limit": defer_limit, "resilience": resilience}
        if self._router is None or fresh or kw != self._router_kw:
            tracer = (self.ctx.tracer
                      if self.ctx.tracer is not NULL_TRACER else None)
            monitor = slo if isinstance(slo, SloMonitor) else (
                SloMonitor.from_fleet(self.fleet, tracer=tracer)
                if slo else None)
            self._router = Router.from_fleet(
                self.fleet, engines=self.engines, cache=self.ctx.cache,
                tracer=tracer, slo=monitor, defer_limit=defer_limit,
                shed_after=shed_after, drift_threshold=drift_threshold,
                drift_min_samples=drift_min_samples,
                resilience=resilience or None)
            self._router_kw = kw
        return self._router

    @property
    def slo(self):
        """The live router's SLO monitor (None before :meth:`serve` or when
        serving with ``slo=False``)."""
        return self._router.slo if self._router is not None else None

    def health(self) -> dict:
        """The served fleet's resilience health — ``Router.health()``:
        per-tenant failure counters, breaker state, degradation-ladder
        level, plus fleet replan-failure counts.  Empty before
        :meth:`serve`."""
        return self._router.health() if self._router is not None else {}

    def replay(self, scenario: str = "steady", *, duration_s: float = 0.25,
               seed: int = 0, speed: float = 1.0, requests=None,
               json_dir=None, faults=None, **scenario_kw):
        """Open-loop traffic replay through the served fleet (see
        :mod:`repro.obs.workload`): generate (or take) a trace, warm the
        router, fire arrivals on the wall clock, and return the
        :class:`~repro.obs.workload.ReplayReport` (per-request e2e latency
        + scheduling lag).  ``requests`` overrides the generator with an
        explicit trace (e.g. :func:`repro.obs.workload.load_trace`);
        ``json_dir`` additionally writes the per-tenant
        ``BENCH_serve_<net>__<scenario>.json`` tail snapshots.

        ``faults`` — a :class:`repro.faults.FaultPlan` (or injector, spec
        list, or saved-plan path) armed on the router AFTER warmup, so
        compile-time traffic never consumes scheduled fault indices: the
        chaos replay.  Defaults to the plan given to :meth:`build`."""
        from repro.obs import workload
        router = self.serve()
        inputs = router.warmup()
        injector = (_fault_injector(faults) if faults is not None
                    else self._injector)
        if injector is not None:
            router.arm_faults(injector)
        if requests is None:
            tenants = {t.net_id: t.plan.kind for t in self.fleet.tenants}
            requests = workload.make_scenario(
                scenario, tenants, duration_s=duration_s, seed=seed,
                **scenario_kw)
        report = workload.replay(router, requests, inputs=inputs,
                                 speed=speed)
        report.scenario = scenario
        if json_dir is not None:
            workload.write_replay_snapshots(
                report, json_dir, scenario=scenario, slo=router.slo,
                meta={"source": "Deployment.replay", "seed": seed,
                      "duration_s": duration_s})
        return report

    # -- measurement ------------------------------------------------------
    def bench(self, *, iters: int = 5, warmup: int = 1) -> list[BenchRow]:
        """Planned-vs-measured rows for every edge tenant (trend.py's row
        shape via :meth:`BenchRow.as_record`): each engine is warmed up,
        timed for ``iters`` calls, and judged against its plan's estimate
        (median measured, the repo-wide robust statistic)."""
        import jax.numpy as jnp

        from repro.serve.engine import EdgeEngine
        rows = []
        for tp in self.fleet.tenants:
            eng = self.engines[tp.net_id]
            if not isinstance(eng, EdgeEngine):
                continue                 # LM latency includes queue wait
            x = jnp.ones((eng.cfg.batch, eng.cfg.dims[0]), jnp.float32)
            for _ in range(warmup):
                eng.infer(x)
            eng.reset_measurements()
            for _ in range(iters):
                eng.infer(x)
            groups = tp.plan.groups()
            rows.append(BenchRow(
                net_id=tp.net_id, planned_s=tp.plan.est_latency_s,
                measured_s=eng.measured_p50_s,
                extra=f"fuse_groups={len(groups)};"))
        return rows

    # -- the drift loop, behind one method --------------------------------
    def recalibrate(self, *, budget_factor: float | None = None) -> FleetPlan:
        """Feed measured latencies back and replan the fleet in place (the
        PR-3 drift loop): router metrics when the deployment is serving,
        engine measurements otherwise.  Costs and budgets move; tiles,
        regimes and engines stay.  Returns (and adopts) the new fleet.

        Degradation rung for the planner: when recalibration fails while a
        FITTED machine model is in play, the deployment drops to stock
        constants (``degrade/machine_model`` audit span), keeps the current
        fleet, and returns it — a sick calibration must not take down
        serving.  With stock constants already in play the failure is
        re-raised (there is no rung left)."""
        import time as _time
        try:
            return self._recalibrate(budget_factor=budget_factor)
        except Exception as exc:
            # Usage guidance ("nothing measured yet") is not a rung; with
            # stock constants already in play there is no rung left either.
            if self.ctx.model is None or "nothing measured" in str(exc):
                raise
            t0 = _time.perf_counter()
            self.ctx.model = None
            if self.ctx.tracer.enabled:
                self.ctx.tracer.add(
                    "degrade/machine_model", t0, _time.perf_counter(),
                    tenant="deploy", error=str(exc)[:160])
            return self.ctx.fleet

    def _recalibrate(self, *, budget_factor: float | None) -> FleetPlan:
        from repro.plan import calibrate
        if self._router is not None and any(
                t.metrics.count for t in self._router._tenants.values()):
            new_fleet = self._router.replan_fleet(
                budget_factor=budget_factor)
        else:
            measurements = calibrate.measurements_from_engines(self.engines)
            if not measurements:
                raise RuntimeError(
                    "nothing measured yet: serve traffic or run .bench() "
                    "before recalibrating")
            new_fleet = calibrate.recalibrate_fleet(
                self.fleet, measurements, cache=self.ctx.cache,
                budget_factor=budget_factor)
            if self._router is not None:
                # A live router must not keep serving the pre-recalibration
                # plans/budgets just because its own metrics were empty.
                self._router.adopt_fleet(new_fleet)
            else:
                for tp in new_fleet.tenants:
                    eng = self.ctx.engines.get(tp.net_id)
                    if eng is not None and hasattr(eng, "plan"):
                        eng.plan = tp.plan
        # No put_fleet: feedback already parked the calibrated tenant plans
        # in the cache, and the next fleet-cache hit re-adopts them.
        self.ctx.fleet = new_fleet
        return new_fleet

    # -- observability ----------------------------------------------------
    def export_trace(self, path="trace.json"):
        """Write the span stream as a Chrome/Perfetto ``trace.json``
        (load at https://ui.perfetto.dev); returns the path."""
        from repro.obs import write_chrome
        return write_chrome(self.tracer.spans, path,
                            dropped=self.tracer.dropped)

    def export_prometheus(self, path="metrics.prom"):
        """Write per-(tenant, kind) span aggregates as a Prometheus
        text-exposition snapshot — including the tracer's dropped-span
        counter and, once serving, the per-tenant SLO families and the
        ``repro_resilience_*`` health families; returns the path."""
        from repro.obs import aggregate, write_prometheus
        slo = self.slo
        return write_prometheus(
            aggregate(self.tracer.spans), path,
            dropped=self.tracer.dropped if self.tracer.enabled else None,
            slo=slo.snapshot() if slo is not None else None,
            profile=self.profile() or None,
            resilience=self.health() or None)

    def attribution(self):
        """Plan-vs-measured rows per (tenant, span kind) — see
        :func:`repro.obs.attribution`."""
        from repro.obs import attribution as attr
        return attr(self.plans, self.tracer.spans)

    def format_attribution(self) -> str:
        from repro.obs import format_attribution
        return format_attribution(self.attribution(), slo=self.slo,
                                  profile=self.profile())

    # -- roofline profiling -----------------------------------------------
    def profile_hw(self):
        """The roofline ceilings this deployment was planned under: the
        fitted :class:`MachineModel`'s substituted TPU terms when one was
        characterized, else the stock :data:`repro.hw.TPU_V5E` — the same
        single source of truth the planner's cost model reads."""
        from repro import hw as hwlib
        model = self.ctx.model
        if model is None:
            return hwlib.TPU_V5E
        tpu = getattr(model, "tpu", None)
        return tpu() if callable(tpu) else model

    def _profile_stats(self) -> dict:
        """Measured ``(tenant, kind)`` windows: the tracer's span stream
        when tracing is on, else the engines' always-on service-time
        windows (``span_stats()``) — profiling must not require
        ``trace=True``."""
        from repro.obs import aggregate
        if self.tracer.enabled and self.tracer.spans:
            return aggregate(self.tracer.spans)
        stats = {}
        for nid, eng in self.ctx.engines.items():
            for kind, agg in eng.span_stats().items():
                stats[(nid, kind)] = agg
        return stats

    def profile(self, *, hw=None) -> list:
        """Roofline-attributed profile rows (:func:`repro.obs.profile.
        profile`): per measured (tenant, span-kind) window and per fusion
        group — achieved FLOP/s and bytes/s, the roofline ceiling, a
        compute/memory/launch bound classification, the roofline fraction
        in (0, 1], and the per-tenant measured LARE.  Empty until traffic
        has been served (or :meth:`bench` has run)."""
        from repro.obs import profile as prof
        return prof(self.plans, self._profile_stats(),
                    hw=hw if hw is not None else self.profile_hw())

    def format_profile(self) -> str:
        from repro.obs import format_profile
        return format_profile(self.profile())

    def hlo_overhead(self) -> dict:
        """Model-FLOPs vs compiled-HLO-FLOPs per tenant, on the ACTUAL
        serving executables (:func:`repro.launch.hlo_analysis.
        hlo_overhead`): the EdgeEngine's jitted planned forward and the
        batcher's jitted decode step.  The batcher decodes all its slots
        per step, so its model FLOPs scale by the slot count."""
        from repro.launch.hlo_analysis import hlo_overhead as _overhead
        out = {}
        for nid, eng in self.engines.items():
            plan = self.plans.get(nid)
            if plan is None or not getattr(plan, "layers", None):
                continue
            model_flops = plan.work()["flops"]
            slots = getattr(eng, "slots", None)
            if slots:                    # ContinuousBatcher: vmapped slots
                model_flops *= slots
            out[nid] = _overhead(model_flops, eng)
        return out

    # -- reporting --------------------------------------------------------
    def summary(self) -> str:
        """Human-readable stage + tenant table (the CLI's deploy report)."""
        lines = ["stages:"]
        for name in _STAGE_ORDER:
            if name in self.ctx.results:
                lines.append(f"  {self.ctx.results[name]}")
        if self.ctx.fleet is not None:
            lines.append("tenants:")
            for t in self.ctx.fleet.tenants:
                lines.append(
                    f"  {t.net_id:<14} kind={t.plan.kind:<5} "
                    f"planned={t.plan.est_latency_s * 1e6:9.1f}us "
                    f"budget={t.latency_budget_s * 1e6:9.1f}us "
                    f"groups={len(t.plan.groups())}")
        if "verify" in self.ctx.results:
            res = self.ctx.results["verify"]
            if res.skipped:
                lines.append("check: skipped (check=False)")
            elif not self.ctx.findings:
                lines.append("check: clean (all design rules hold)")
            else:
                lines.append(f"check: {res.detail}")
                for f in self.ctx.findings:
                    lines.append(f"  {f}")
        if self.tracer.enabled:
            kinds: dict[str, int] = {}
            for s in self.tracer.spans:
                kinds[s.name] = kinds.get(s.name, 0) + 1
            per_kind = " ".join(f"{k}={n}" for k, n in sorted(kinds.items()))
            lines.append(f"tracing: {len(self.tracer.spans)} spans "
                         f"({self.tracer.dropped} dropped) {per_kind}")
        slo = self.slo
        if slo is not None:
            counts = slo.violation_counts()
            total = sum(counts.values())
            if total:
                per = " ".join(f"{t}={n}" for t, n in sorted(counts.items())
                               if n)
                lines.append(f"slo: {total} violation event(s) {per}")
            else:
                lines.append("slo: ok (no violation events)")
        health = self.health()
        if health:
            tenants = health.get("tenants", {})
            sick = {t: st for t, st in tenants.items()
                    if st.get("failures") or st.get("degrade_level")
                    or st.get("state", "closed") != "closed"}
            if sick:
                lines.append("health:")
                for t, st in sorted(sick.items()):
                    bits = [f"failures={st.get('failures', 0)}",
                            f"level={st.get('degrade_level', 0)}"]
                    if "state" in st:
                        bits.append(f"breaker={st['state']} "
                                    f"opens={st.get('breaker_opens', 0)} "
                                    f"recloses={st.get('breaker_recloses', 0)}")
                    lines.append(f"  {t:<14} " + " ".join(bits))
            else:
                supervised = ("supervised" if health.get("supervised")
                              else "unsupervised")
                lines.append(f"health: ok ({supervised}; no failures, "
                             f"all breakers closed, ladder at level 0)")
            if health.get("replan_failures"):
                lines.append(f"health: {health['replan_failures']} replan "
                             f"failure(s) — serving on the current fleet")
        prows = ([r for r in self.profile() if r.group is None]
                 if self.ctx.fleet is not None else [])
        if prows:
            lines.append("profile:")
            for r in prows:
                frac = (f"{r.roofline_fraction:.3f}"
                        if r.roofline_fraction is not None else "-")
                mlare = (f" mLARE={r.measured_lare:.1f}"
                         if r.measured_lare is not None else "")
                lines.append(
                    f"  {r.tenant:<14} {r.kind:<14} frac={frac} "
                    f"bound={r.bound}{mlare}")
        return "\n".join(lines)
