"""``repro.deploy`` — one staged facade for the whole decision procedure:

    characterize -> plan -> quantize+calibrate -> engines -> serve

:class:`Deployment` is the entry point the README quickstart ships on::

    from repro.deploy import Deployment
    dep = Deployment.build(["jet_tagger", "tau_select"])
    router = dep.serve()
    router.drive(iters=20)
    print(dep.summary())

The stages themselves (:mod:`repro.deploy.stages`) are explicit,
individually-invokable objects with typed inputs/outputs and artifact
paths, so partial pipelines (plan-only, serve-from-a-committed-plan-JSON)
are first-class.  CLI: ``python -m repro deploy <net...>`` (plus
``characterize``/``plan``/``serve``/``bench`` subcommands that route
through the same stages).
"""

from repro.deploy.deployment import BenchRow, Deployment
from repro.deploy.stages import (PIPELINE, STAGES, CharacterizeStage,
                                 EngineStage, PlanStage, StageContext,
                                 StageResult, resolve_configs)

__all__ = [
    "BenchRow", "CharacterizeStage", "Deployment", "EngineStage", "PIPELINE",
    "PlanStage", "STAGES", "StageContext", "StageResult", "resolve_configs",
]
