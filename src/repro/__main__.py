"""``python -m repro`` — the unified CLI over the staged deployment facade.

  python -m repro deploy jet_tagger tau_select       # end-to-end
  python -m repro plan all --target both --out plans/
  python -m repro characterize --sweep quick --out model.json
  python -m repro serve jet_tagger --lm qwen2_5_3b
  python -m repro bench jet_tagger tau_select
  python -m repro trace jet_tagger --lm qwen2_5_3b   # spans + attribution

See :mod:`repro.cli` for the subcommand implementations (each routes
through :mod:`repro.deploy`'s pipeline stages).
"""

import sys

from repro.cli import main

if __name__ == "__main__":
    sys.exit(main())
