"""Public jit'd entry points for the Pallas kernels.

On CPU (this container) every kernel executes in Pallas ``interpret=True``
mode, which runs the kernel body in Python for correctness; on a real TPU the
same call sites compile to Mosaic.  ``use_interpret()`` picks automatically;
tests force it explicitly so intent is visible.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import fused_dense as _fd
from repro.kernels import fused_mlp as _fm
from repro.kernels import gemm_int8 as _g8
from repro.kernels import rglru as _rg
from repro.kernels import rwkv6 as _rw
from repro.kernels import tiled_gemm as _tg


def use_interpret() -> bool:
    return jax.default_backend() == "cpu"


def tiled_gemm(x, w, **kw):
    kw.setdefault("interpret", use_interpret())
    return _tg.tiled_gemm(x, w, **kw)


def fused_dense(x, w, b, residual=None, **kw):
    kw.setdefault("interpret", use_interpret())
    return _fd.fused_dense(x, w, b, residual, **kw)


def gemm_int8(x, w, w_scale, x_scale=1.0, **kw):
    kw.setdefault("interpret", use_interpret())
    return _g8.gemm_int8(x, w, w_scale, x_scale, **kw)


def fused_mlp_q8(x, weights, w_scales, biases, x_scales, **kw):
    """A whole DR7' fusion group (N int8 dense layers) in one launch."""
    kw.setdefault("interpret", use_interpret())
    return _fm.fused_mlp_q8(x, tuple(weights), tuple(w_scales),
                            tuple(biases), x_scales, **kw)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", use_interpret())
    return _fa.flash_attention(q, k, v, **kw)


def linear_scan(a, b, **kw):
    kw.setdefault("interpret", use_interpret())
    return _rg.linear_scan(a, b, **kw)


def rglru(x, gate_a, gate_x, log_lambda, *, c: float = 8.0, **kw):
    """Full RG-LRU layer: gates + the Pallas linear scan.

    a_t = exp(-c * softplus(log_lambda) * sigmoid(gate_a))
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(gate_x) * x_t)
    """
    log_a = -c * jax.nn.softplus(log_lambda)[None, None, :] * jax.nn.sigmoid(
        gate_a.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(gate_x.astype(jnp.float32)) * x.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    return linear_scan(a.astype(jnp.float32), b.astype(jnp.float32),
                       **kw).astype(x.dtype)


def rwkv6_scan(r, k, v, w, u, **kw):
    kw.setdefault("interpret", use_interpret())
    return _rw.rwkv6_scan(r, k, v, w, u, **kw)
