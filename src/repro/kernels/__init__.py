"""Pallas TPU kernels for the paper's compute hot-spots (validated via
interpret=True on CPU; see ops.py for the public entry points)."""
