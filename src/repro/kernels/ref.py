"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the per-kernel allclose sweeps in
``tests/test_kernels.py`` and for the hypothesis property tests.  They are
deliberately written in the most obvious way (no blocking, no online
statistics) so a mismatch always indicts the kernel.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

_NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


def tiled_gemm(x: jax.Array, w: jax.Array,
               out_dtype: jnp.dtype | None = None) -> jax.Array:
    out_dtype = out_dtype or (
        jnp.int32 if jnp.issubdtype(x.dtype, jnp.integer) else x.dtype)
    acc = jnp.int32 if jnp.issubdtype(x.dtype, jnp.integer) else jnp.float32
    return jnp.dot(x, w, preferred_element_type=acc).astype(out_dtype)


def fused_dense(x, w, b, residual=None, *, act: str = "relu",
                out_dtype=None) -> jax.Array:
    acts = {
        "none": lambda v: v,
        "relu": lambda v: jnp.maximum(v, 0.0),
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "tanh": jnp.tanh,
        "sigmoid": jax.nn.sigmoid,
    }
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    y = acts[act](y + b.astype(jnp.float32))
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    return y.astype(out_dtype or x.dtype)


def gemm_int8(x, w, w_scale, x_scale=1.0, *, out_dtype=jnp.bfloat16):
    acc = jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32))
    scale = jnp.asarray(x_scale, jnp.float32) * jnp.asarray(w_scale, jnp.float32)
    return (acc.astype(jnp.float32) * scale[None, :]).astype(out_dtype)


def attention(q, k, v, *, causal=True, window=None, softcap=None, scale=None):
    """Full (quadratic) masked softmax attention with GQA broadcast."""
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((s, sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, _NEG)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(mask[None, None], probs, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def linear_scan(a, b):
    """h_t = a_t h_{t-1} + b_t via lax.scan (time axis 1)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    _, hs = jax.lax.scan(step, jnp.zeros_like(a32[:, 0]),
                         (a32.swapaxes(0, 1), b32.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(a.dtype)


def rwkv6_scan(r, k, v, w, u):
    """RWKV-6 recurrence via lax.scan.  r/k/v/w: (BH, T, D), u: (D,)."""
    bh, t, d = r.shape

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw
        kv = kt[:, :, None] * vt[:, None, :]                    # (BH, D, D)
        out = jnp.einsum("bk,bkv->bv", rt,
                         s + u[None, :, None] * kv)
        s = wt[:, :, None] * s + kv
        return s, out

    r32 = r.astype(jnp.float32).swapaxes(0, 1)
    k32 = k.astype(jnp.float32).swapaxes(0, 1)
    v32 = v.astype(jnp.float32).swapaxes(0, 1)
    w32 = w.astype(jnp.float32).swapaxes(0, 1)
    s0 = jnp.zeros((bh, d, d), jnp.float32)
    _, outs = jax.lax.scan(step, s0, (r32, k32, v32, w32))
    return outs.swapaxes(0, 1).astype(r.dtype)
