"""Fused dense layer: GEMM + bias + activation (+ residual) in ONE kernel.

This is the DR7' "boundary-crossing eliminator" (DESIGN.md §2): on the AIE the
paper prices each PL<->AIE hand-off at ~3.9% latency; on TPU the analogous
boundary is an un-fused XLA op boundary, which forces the activation tensor
through HBM and pays a kernel dispatch.  Fusing the epilogue into the GEMM's
flush step removes both — `core.boundary.plan_fusion` decides when this is
worthwhile; this kernel is the mechanism.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

from repro.core.tiling import plan_api

_ACTS = {
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
}


def _fused_kernel(x_ref, w_ref, b_ref, r_ref, o_ref, acc_ref, *,
                  n_k: int, act: str, residual: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        y = _ACTS[act](y)
        if residual:
            y = y + r_ref[...].astype(jnp.float32)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("act", "block_m", "block_k", "block_n", "out_dtype",
                     "interpret"),
)
def fused_dense(
    x: jax.Array,                   # (M, K)
    w: jax.Array,                   # (K, N)
    b: jax.Array,                   # (N,)
    residual: jax.Array | None = None,   # (M, N) optional skip connection
    *,
    act: str = "relu",
    block_m: int | None = None,
    block_k: int | None = None,
    block_n: int | None = None,
    out_dtype: jnp.dtype | None = None,
    interpret: bool = False,
) -> jax.Array:
    """``act(x @ w + b) (+ residual)`` in a single Pallas launch."""
    m, k = x.shape
    _, n = w.shape
    assert b.shape == (n,), b.shape
    if block_m is None or block_k is None or block_n is None:
        plan = plan_api(m, k, n, itemsize=x.dtype.itemsize)
        block_m = block_m or plan.block_m
        block_k = block_k or plan.block_k
        block_n = block_n or plan.block_n
    out_dtype = out_dtype or x.dtype

    pad_m, pad_k, pad_n = (-m) % block_m, (-k) % block_k, (-n) % block_n
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        w = jnp.pad(w, ((0, pad_k), (0, pad_n)))
    b2 = jnp.pad(b, (0, pad_n)).reshape(1, -1)
    has_res = residual is not None
    if has_res:
        r2 = jnp.pad(residual, ((0, pad_m), (0, pad_n)))
    else:
        r2 = jnp.zeros((block_m, b2.shape[1]), x.dtype)  # dummy, never read
    mp, kp = x.shape
    np_ = w.shape[1]
    grid = (mp // block_m, np_ // block_n, kp // block_k)

    out = pl.pallas_call(
        functools.partial(_fused_kernel, n_k=grid[2], act=act,
                          residual=has_res),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
            pl.BlockSpec((block_m, block_n),
                         (lambda i, j, kk: (i, j)) if has_res
                         else (lambda i, j, kk: (0, j))),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="repro_fused_dense",
    )(x, w, b2, r2)
    if pad_m or pad_n:
        out = out[:m, :n]
    return out
