"""Fused-group MLP megakernel: a whole DR7' fusion group in ONE launch.

``gemm_int8`` executes one layer per ``pallas_call``; the planner's fusion DP
(:func:`repro.core.boundary.plan_fusion`) has always *charged* for those
un-fused boundaries, but until now nothing *executed* its decision — the
executor paid N dispatches plus N-1 HBM round trips per N-layer group it was
never billed for.  This kernel closes that gap: an entire fusion group — N
consecutive int8 dense layers with dequantize + bias + activation +
requantize fused into each layer's epilogue — runs in a single launch.
Intermediate activations never leave the chip: the requantized int8
activations live in a VMEM scratch buffer between layers, so the only HBM
traffic is the group's input, its weights, and its output.

Numerics match the per-layer path bit-for-bit on the int8 side: each
epilogue applies the same ``clip(round(h / x_scale))`` requantization the
host-side per-layer loop applies between ``gemm_int8`` launches, with the
per-layer calibrated ``x_scale`` read from an SMEM vector.

Shapes are padded to TPU tile legality ((32, 128) for int8 operands); edge
nets are tiny (<=512-wide layers), so a whole group's weights fit VMEM with
orders of magnitude to spare — ``plan_fusion``'s VMEM budget guards the
general case.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INT8_SUBLANE = 32                 # min second-to-last tile dim for int8
_LANE = 128                        # last-dim tile multiple


def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


def _act(name: str, y):
    if name == "relu":
        return jnp.maximum(y, 0.0)
    if name == "none":
        return y
    raise ValueError(f"unsupported fused activation {name!r}")


def _mega_kernel(xs_ref, x_ref, *refs, n_layers: int, act: str,
                 act_last: bool, widths: tuple, rows: int):
    """One fusion group.  ``refs`` is ``n_layers`` triples of
    (w_q, scale_row, bias_row) followed by the output ref and the int8
    activation scratch.  ``widths`` are the PADDED per-layer activation
    widths (input first), so every scratch slice is lane-aligned and static.

    ``rows`` is the LIVE batch extent: buffers are padded to the int8 tile
    (32 sublanes), but a single-invocation megakernel is not grid-blocked,
    so — unlike the per-layer kernel, whose BlockSpec tiles pin every GEMM
    to the full (32, lane) block — compute runs on just the rows that carry
    data.  At the paper's batch 8 that is 4x less GEMM work per layer, on
    top of the eliminated launches: the structural win of megakernelization.
    """
    o_ref, h_ref = refs[-2], refs[-1]
    # Entry quantization (the per-layer path's host-side clip/round/cast).
    h_ref[:rows, :widths[0]] = jnp.clip(
        jnp.round(x_ref[:rows, :] / xs_ref[0]), -127, 127).astype(jnp.int8)
    y = None
    for i in range(n_layers):
        w_ref, s_ref, b_ref = refs[3 * i], refs[3 * i + 1], refs[3 * i + 2]
        acc = jax.lax.dot_general(
            h_ref[:rows, :widths[i]], w_ref[...],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        # Epilogue: dequantize (x_scale_i * w_scale folded into s_ref), bias,
        # activation, and — for every non-final layer — requantize back into
        # the VMEM activation scratch at the NEXT layer's input scale.
        y = acc.astype(jnp.float32) * s_ref[...] + b_ref[...]
        last = i == n_layers - 1
        if not last or act_last:
            y = _act(act, y)
        if not last:
            h_ref[:rows, :widths[i + 1]] = jnp.clip(
                jnp.round(y / xs_ref[i + 1]), -127, 127).astype(jnp.int8)
    o_ref[:rows, :] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("act", "act_last", "out_dtype", "interpret"),
)
def fused_mlp_q8(
    x: jax.Array,                   # (M, K0) float input
    weights: tuple,                 # per layer: (K_i, N_i) int8
    w_scales: tuple,                # per layer: (N_i,) f32 per-out-channel
    biases: tuple,                  # per layer: (N_i,) f32
    x_scales: jax.Array,            # (L,) f32 per-layer input act scale
    *,
    act: str = "relu",
    act_last: bool = False,         # apply `act` to the group's last layer
    out_dtype: jnp.dtype = jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Run ``L`` int8 dense layers in a single Pallas launch.

    Per layer ``i``:  ``h = act(clip(round(h/xs_i)) @ w_i * (xs_i*ws_i) + b_i)``
    with the activation applied to every layer except the last (unless
    ``act_last``, for groups that end mid-network).  Returns the final f32
    activations, un-padded to ``(M, N_last)``.
    """
    n_layers = len(weights)
    assert n_layers >= 1
    assert len(w_scales) == len(biases) == n_layers
    m, k0 = x.shape
    dims = [k0] + [w.shape[1] for w in weights]
    for i, w in enumerate(weights):
        assert w.dtype == jnp.int8 and w.shape[0] == dims[i], (i, w.shape)

    pm = _ceil_to(m, _INT8_SUBLANE)        # buffer padding: int8 tile rows
    rows = _ceil_to(m, 8)                  # live compute rows (f32 sublane)
    pads = [_ceil_to(d, _LANE) for d in dims]
    xs = jnp.asarray(x_scales, jnp.float32).reshape(n_layers)

    xp = jnp.pad(x.astype(jnp.float32),
                 ((0, pm - m), (0, pads[0] - k0)))
    operands = [xp]
    for i, (w, ws, b) in enumerate(zip(weights, w_scales, biases)):
        pk, pn = pads[i] - dims[i], pads[i + 1] - dims[i + 1]
        operands.append(jnp.pad(w, ((0, pk), (0, pn))))
        # Dequant scale row: per-tensor activation scale x per-channel weight
        # scale, folded host-side so the epilogue is one multiply.
        s = jnp.asarray(ws, jnp.float32) * xs[i]
        operands.append(jnp.pad(s, (0, pn)).reshape(1, -1))
        operands.append(jnp.pad(jnp.asarray(b, jnp.float32),
                                (0, pn)).reshape(1, -1))

    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM)]           # x_scales
    in_specs += [pl.BlockSpec(memory_space=pltpu.VMEM)
                 for _ in operands]
    out = pl.pallas_call(
        functools.partial(_mega_kernel, n_layers=n_layers, act=act,
                          act_last=act_last, widths=tuple(pads), rows=rows),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((pm, pads[-1]), out_dtype),
        # Inter-layer activations stay on-chip: one int8 scratch wide enough
        # for the widest layer in the group.
        scratch_shapes=[pltpu.VMEM((pm, max(pads[:-1])), jnp.int8)],
        interpret=interpret,
        name=f"repro_fused_mlp_x{n_layers}",
    )(xs, *operands)
    if pm != m or pads[-1] != dims[-1]:
        out = out[:m, :dims[-1]]
    return out
