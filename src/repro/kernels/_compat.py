"""Version shims for ``jax.experimental.pallas.tpu`` API renames.

Newer jax exposes ``pltpu.CompilerParams``; 0.4.x calls the same class
``TPUCompilerParams``.  Kernels import the name from here so they compile
against either.
"""

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or _pltpu.TPUCompilerParams
