"""RWKV-6 ("Finch") recurrence kernel with data-dependent decay.

Per head of size D the recurrence over time is

    S_t = diag(w_t) . S_{t-1} + k_t v_t^T          (state S: D x D, f32)
    o_t = r_t . (S_{t-1} + diag(u) . k_t v_t^T)

with w_t the data-dependent per-channel decay and u the learned "bonus" for
the current token.  TPU adaptation mirrors :mod:`repro.kernels.rglru`: time is
blocked into VMEM chunks (grid: batch*heads x time-blocks, time innermost) and
the D x D state matrix lives in VMEM scratch across grid steps.  The per-step
outer product / matvec are (D, D) VPU/MXU ops with D = head_dim (64 for
rwkv6-7b), so the working set is tiny and stays on-chip — weights-stationary
in exactly the paper's sense.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                  block_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)          # (bt, d)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (d,)

    def step(t, s):
        kv = k[t][:, None] * v[t][None, :]                  # (d, d)
        out = (r[t][None, :] @ (s + u[:, None] * kv))[0]     # (d,)
        o_ref[0, t, :] = out.astype(o_ref.dtype)
        return w[t][:, None] * s + kv

    s_ref[...] = jax.lax.fori_loop(0, block_t, step, s_ref[...])


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan(
    r: jax.Array,           # (B*H, T, D) receptance
    k: jax.Array,           # (B*H, T, D) key
    v: jax.Array,           # (B*H, T, D) value
    w: jax.Array,           # (B*H, T, D) data-dependent decay in (0,1)
    u: jax.Array,           # (D,) bonus
    *,
    block_t: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, t, d = r.shape
    block_t = min(block_t, t)
    pad_t = (-t) % block_t
    if pad_t:
        # w=1, k=0 padding leaves the state untouched.
        r = jnp.pad(r, ((0, 0), (0, pad_t), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad_t), (0, 0)), constant_values=1.0)
    tp = r.shape[1]
    grid = (bh, tp // block_t)
    u2 = u.reshape(1, d)

    out = pl.pallas_call(
        functools.partial(_rwkv6_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, d), lambda bi, ti: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tp, d), r.dtype),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="repro_rwkv6_scan",
    )(r, k, v, w, u2)
    return out[:, :t, :]
