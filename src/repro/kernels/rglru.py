"""RG-LRU linear-recurrence kernel (recurrentgemma / Griffin).

The recurrence is the first-order diagonal linear scan

    h_t = a_t * h_{t-1} + b_t,        a_t in (0, 1), elementwise over D,

with RG-LRU's gating folded into the inputs by the caller
(``a_t = exp(-c * softplus(L) * r_t)``, ``b_t = sqrt(1 - a_t^2) * i_t * x_t``).

TPU adaptation: the time dimension cannot ride the MXU, so the kernel blocks
time into VMEM-resident chunks (grid: batch x time-blocks, time innermost /
``arbitrary``) and carries the hidden state in a VMEM scratch across grid
steps — the same on-chip-accumulator discipline as the paper's cascade chain.
Within a block the scan runs as a ``fori_loop`` of VPU vector ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _scan_kernel(a_ref, b_ref, o_ref, h_ref, *, block_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)          # (bt, d)
    bb = b_ref[0].astype(jnp.float32)         # (bt, d)

    def step(t, h):
        h = a[t] * h + bb[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_ref[0])
    h_ref[0] = h


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def linear_scan(
    a: jax.Array,           # (B, T, D) decay in (0,1)
    b: jax.Array,           # (B, T, D) input term
    *,
    block_t: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Computes h_t = a_t * h_{t-1} + b_t along T, h_0 = 0.  Returns h (B,T,D)."""
    bsz, t, d = a.shape
    block_t = min(block_t, t)
    pad_t = (-t) % block_t
    if pad_t:
        # Padding with a=1, b=0 leaves the carried state unchanged.
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, 0)))
    tp = a.shape[1]
    grid = (bsz, tp // block_t)

    out = pl.pallas_call(
        functools.partial(_scan_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, tp, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="repro_rglru_scan",
    )(a, b)
    return out[:, :t, :]
