"""Blocked online-softmax (flash) attention for TPU.

Supports the features the assigned LM architectures need:

* causal masking,
* sliding-window (local) attention — gemma2 / recurrentgemma local layers,
* logit soft-capping  ``cap * tanh(logits / cap)`` — gemma2,
* GQA: ``n_q_heads`` a multiple of ``n_kv_heads`` (KV blocks indexed by
  ``head // group`` in the BlockSpec index maps, so KV is fetched once per
  group, not per query head).

Tiling follows the paper's two-level discipline: the (block_q, block_kv)
choice is the API-level tile (VMEM-bounded, lane-aligned); the KV grid
dimension is innermost/sequential and the running (m, l, acc) statistics in
VMEM scratch play the role of the cascade accumulator.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

_NEG = -0.7 * float(jnp.finfo(jnp.float32).max)
_LANE = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_kv: int, block_q: int, block_kv: int, scale: float,
                  causal: bool, window: int | None, softcap: float | None):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Data-independent block-level skip (causal/window out-of-range blocks).
    q_lo = qi * block_q
    q_hi = q_lo + block_q - 1
    k_lo = ki * block_kv
    k_hi = k_lo + block_kv - 1
    in_range = True
    if causal:
        in_range = jnp.logical_and(in_range, k_lo <= q_hi)
    if window is not None:
        in_range = jnp.logical_and(in_range, k_hi >= q_lo - window + 1)

    @pl.when(in_range)
    def _body():
        q = q_ref[0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[...][:, :1]                        # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)                       # kill masked mass
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_new = l_ref[...][:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_kv - 1)
    def _flush():
        l = l_ref[...][:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q",
                     "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,            # (B, Hq, S, D)
    k: jax.Array,            # (B, Hkv, S, D)
    v: jax.Array,            # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, s)
    block_kv = min(block_kv, sk)

    pad_q = (-s) % block_q
    pad_kv = (-sk) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    sp, skp = q.shape[2], k.shape[2]
    qf = q.reshape(b * hq, sp, d)
    kf = k.reshape(b * hkv, skp, d)
    vf = v.reshape(b * hkv, skp, d)
    grid = (b * hq, sp // block_q, skp // block_kv)

    def kv_index(bh, qi, ki):
        # map query head -> kv head:  bh = batch*Hq + h ;  group = Hq//Hkv
        bb = bh // hq
        h = bh % hq
        return (bb * hkv + h // group, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, n_kv=grid[2], block_q=block_q, block_kv=block_kv,
            scale=scale, causal=causal, window=window, softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, d), kv_index),
            pl.BlockSpec((1, block_kv, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANE), jnp.float32),   # running max
            pltpu.VMEM((block_q, _LANE), jnp.float32),   # running denom
            pltpu.VMEM((block_q, d), jnp.float32),       # running numerator
        ],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="repro_flash_attention",
    )(qf, kf, vf)
    out = out.reshape(b, hq, sp, d)
    if pad_q:
        out = out[:, :, :s, :]
    return out
