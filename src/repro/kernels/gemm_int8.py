"""int8 x int8 -> int32 GEMM with fused dequantization epilogue.

The paper's extreme-edge convention is 8-bit quantization end-to-end (all
Table-I models, the `aie::mmul` i8 datatype, batch 8).  On TPU the analogue is
the int8 MXU path (2x the bf16 peak).  This kernel accumulates in int32 and
applies per-tensor activation scale x per-output-channel weight scale in the
flush step, emitting bf16/f32 — so quantized serving costs one launch, not
three (quant GEMM, dequant, bias would each be a DR7' boundary crossing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

from repro.core.tiling import plan_api


def _int8_kernel(x_ref, w_ref, sw_ref, sx_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _flush():
        scale = sx_ref[0] * sw_ref[...].astype(jnp.float32)     # (1, bn)
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * scale).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_n", "out_dtype", "interpret"),
)
def gemm_int8(
    x: jax.Array,            # (M, K) int8
    w: jax.Array,            # (K, N) int8
    w_scale: jax.Array,      # (N,) f32 per-output-channel
    x_scale: jax.Array | float = 1.0,   # scalar per-tensor
    *,
    block_m: int | None = None,
    block_k: int | None = None,
    block_n: int | None = None,
    out_dtype: jnp.dtype = jnp.bfloat16,
    interpret: bool = False,
) -> jax.Array:
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    m, k = x.shape
    _, n = w.shape
    if block_m is None or block_k is None or block_n is None:
        plan = plan_api(m, k, n, itemsize=1)
        block_m = block_m or plan.block_m
        block_k = block_k or plan.block_k
        block_n = block_n or plan.block_n

    pad_m, pad_k, pad_n = (-m) % block_m, (-k) % block_k, (-n) % block_n
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        w = jnp.pad(w, ((0, pad_k), (0, pad_n)))
    sw = jnp.pad(jnp.asarray(w_scale, jnp.float32), (0, pad_n)).reshape(1, -1)
    sx = jnp.asarray(x_scale, jnp.float32).reshape(1)
    mp, kp = x.shape
    np_ = w.shape[1]
    grid = (mp // block_m, np_ // block_n, kp // block_k)

    out = pl.pallas_call(
        functools.partial(_int8_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="repro_gemm_int8",
    )(x, w, sw, sx)
    if pad_m or pad_n:
        out = out[:m, :n]
    return out
