"""Two-level tiled GEMM — paper Algorithm 2 adapted to the TPU MXU/VMEM.

Mapping from the paper's AIE formulation (DESIGN.md §2):

* the *API-level* tile ``(S_M,S_K,S_N)`` becomes the Pallas ``BlockSpec``
  block shape ``(block_m, block_k, block_n)`` — legal when the last dim is a
  multiple of 128 lanes and the second-to-last a multiple of the dtype's
  sublane packing (8 for f32, 16 for bf16, 32 for int8);
* the ``(R_M,R_K,R_N)`` repeat loops become the Pallas grid — K innermost
  with ``arbitrary`` dimension semantics so the f32 VMEM scratch accumulator
  plays the role of the AIE cascade chain (partial sums stay on-chip);
* "weights stationary" holds per output block: the B block is re-fetched
  across the K grid but never leaves VMEM within a (m, n) program family.

The spatial level (``P_K x P_N`` across compute tiles) is NOT in this file:
it is a mesh sharding decided by ``core.tiling.plan_spatial`` and applied by
``shard_map`` in the distribution layer, with ``psum_scatter`` standing in
for the cascade bus across chips.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

from repro.core.tiling import ApiPlan, plan_api


def _acc_dtype(dtype: jnp.dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(dtype, jnp.integer) else jnp.float32


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """One (m, n) output block; K iterates innermost (grid dim 2)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...],
        preferred_element_type=acc_ref.dtype,
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_n", "out_dtype", "interpret"),
)
def tiled_gemm(
    x: jax.Array,                 # (M, K)
    w: jax.Array,                 # (K, N)
    *,
    block_m: int | None = None,
    block_k: int | None = None,
    block_n: int | None = None,
    out_dtype: jnp.dtype | None = None,
    interpret: bool = False,
) -> jax.Array:
    """``x @ w`` with explicit two-level tiling (API level of Alg. 2).

    Block shapes default to the planner's DR1' choice for the shape/dtype.
    Inputs whose dims are not multiples of the block are zero-padded (the
    TPU analogue of the paper's "legal shape" restriction).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    if block_m is None or block_k is None or block_n is None:
        plan = plan_api(m, k, n, itemsize=x.dtype.itemsize)
        block_m = block_m or plan.block_m
        block_k = block_k or plan.block_k
        block_n = block_n or plan.block_n
    out_dtype = out_dtype or (
        jnp.int32 if jnp.issubdtype(x.dtype, jnp.integer) else x.dtype)

    pad_m = (-m) % block_m
    pad_k = (-k) % block_k
    pad_n = (-n) % block_n
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        w = jnp.pad(w, ((0, pad_k), (0, pad_n)))
    mp, kp = x.shape
    _, np_ = w.shape
    grid = (mp // block_m, np_ // block_n, kp // block_k)

    acc = _acc_dtype(x.dtype)
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), acc)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="repro_tiled_gemm",
    )(x, w)
    if pad_m or pad_n:
        out = out[:m, :n]
    return out
