"""Deterministic shard-aware synthetic data pipeline.

Every batch is a pure function of (seed, step) so restarts and elastic
re-meshes replay identical data — the property the fault-tolerance tests
assert.  The pipeline emits the per-family extras (whisper frame embeddings,
qwen2-vl M-RoPE position ids) so one loader serves every assigned arch.
A host-local prefetch thread overlaps batch synthesis with device compute.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def synth_batch(cfg: ModelConfig, *, batch: int, seq: int, step: int,
                seed: int = 0) -> dict:
    """One global batch: {"tokens","labels"} + family extras (numpy)."""
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step))
    # Markov-ish token stream (not uniform noise, so losses move in examples).
    base = rng.integers(0, cfg.vocab_size, size=(batch, seq + 1), dtype=np.int64)
    drift = np.cumsum(rng.integers(0, 7, size=(batch, seq + 1)), axis=1)
    toks = (base + drift) % cfg.vocab_size
    out = {"tokens": toks[:, :-1].astype(np.int32),
           "labels": toks[:, 1:].astype(np.int32)}
    if cfg.family == "encdec":
        e = cfg.encdec
        out["encoder_frames"] = rng.standard_normal(
            (batch, e.encoder_len, cfg.d_model)).astype(np.float32)
    if cfg.mrope_sections is not None:
        pos = np.broadcast_to(np.arange(seq, dtype=np.int32), (batch, seq))
        out["mrope_positions"] = np.stack([pos, pos, pos]).astype(np.int32)
    return out


class Prefetcher:
    """Background-thread batch prefetch (depth-2 by default)."""

    def __init__(self, cfg: ModelConfig, *, batch: int, seq: int,
                 seed: int = 0, start_step: int = 0, depth: int = 2):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            b = synth_batch(self.cfg, batch=self.batch, seq=self.seq,
                            step=step, seed=self.seed)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
