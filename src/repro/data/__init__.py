"""Data pipeline."""
