"""Traffic traces, scenario generators, and the open-loop replay driver.

Every latency number the repo produced before this module came from
*closed-loop* probe traffic (``Router.drive``: issue, wait, repeat) — which
can never show queueing, because the next request politely waits for the
last one.  This module is the open-loop frontend:

* **Trace format** — a :class:`TraceRequest` is one arrival (relative
  arrival time, tenant, kind, prompt/new token counts).  Traces serialize
  as JSONL (:func:`save_trace` / :func:`load_trace`): one strict-JSON
  object per line, so traces diff, grep and stream.
* **Scenario generators** — deterministic arrival processes per tenant,
  seeded as ``random.Random(f"{seed}:{scenario}:{net_id}")`` so the same
  seed reproduces the same trace on any platform: ``steady`` (homogeneous
  Poisson), ``bursty`` (two-state MMPP: exponentially-dwelling low/high
  rate), ``diurnal`` (sinusoidally modulated rate, one "day" per trace),
  ``flash_crowd`` (a rate spike in the middle of the trace).  All
  non-homogeneous processes are sampled by thinning, so a scenario's
  offered-request count is a pure function of (seed, knobs) — the trend
  gate's deterministic ``offered`` row relies on that.
* **Open-loop replay** — :func:`replay` submits a trace against a
  wall-clock schedule through a live ``Router``: arrivals fire at their
  scheduled time whether or not earlier requests finished (that is what
  "open loop" means), LM batchers tick while the driver waits for the next
  arrival, and every request records BOTH its end-to-end latency and its
  **submission-scheduling lag** (how late the driver fired it) — the
  measurement error is itself observable.
* **Snapshots** — :func:`write_replay_snapshots` emits per-tenant
  ``BENCH_serve_<net>__<scenario>.json`` tail rows (p50/p95/p99/max +
  scheduling lag, with shed/violation counts in ``derived``) in the exact
  shape ``benchmarks/trend.py`` diffs; only the deterministic ``offered``
  and ``slo_p95_budget`` model rows gate.

No jax at module import time (the obs discipline): the replay driver only
touches engines through the router it is handed.
"""

from __future__ import annotations

import dataclasses
import json
import math
import pathlib
import random
import time
from typing import Callable, Iterable

from repro.obs.trace import percentile

_KINDS = ("edge", "lm")


# ---------------------------------------------------------------------------
# Trace format
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One arrival in a workload trace (times relative to trace start)."""
    arrival_s: float
    tenant: str
    kind: str = "edge"            # "edge" (sync infer) | "lm" (batched)
    prompt_tokens: int = 3        # LM prompt length (ignored for edge)
    new_tokens: int = 4           # LM generation budget (ignored for edge)
    rid: int = 0                  # request id; doubles as the trace id

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, "
                             f"got {self.kind!r}")
        if self.arrival_s < 0:
            raise ValueError(f"arrival_s must be >= 0, got {self.arrival_s}")

    def to_dict(self) -> dict:
        return {"arrival_s": self.arrival_s, "tenant": self.tenant,
                "kind": self.kind, "prompt_tokens": self.prompt_tokens,
                "new_tokens": self.new_tokens, "rid": self.rid}

    @classmethod
    def from_dict(cls, d: dict) -> "TraceRequest":
        return cls(arrival_s=float(d["arrival_s"]), tenant=str(d["tenant"]),
                   kind=d.get("kind", "edge"),
                   prompt_tokens=int(d.get("prompt_tokens", 3)),
                   new_tokens=int(d.get("new_tokens", 4)),
                   rid=int(d.get("rid", 0)))


def save_trace(requests: Iterable[TraceRequest], path) -> pathlib.Path:
    """Write a trace as JSONL (one strict-JSON object per line)."""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    lines = [json.dumps(r.to_dict(), sort_keys=True, allow_nan=False)
             for r in requests]
    p.write_text("\n".join(lines) + ("\n" if lines else ""))
    return p


def load_trace(path) -> list[TraceRequest]:
    """Read a JSONL trace back; blank lines are skipped."""
    out = []
    for lineno, line in enumerate(
            pathlib.Path(path).read_text().splitlines(), 1):
        if not line.strip():
            continue
        try:
            out.append(TraceRequest.from_dict(json.loads(line)))
        except (KeyError, ValueError) as e:
            raise ValueError(f"malformed trace line {lineno}: {e}") from e
    return out


# ---------------------------------------------------------------------------
# Scenario generators
# ---------------------------------------------------------------------------

def _thin(rng: random.Random, rate_fn: Callable[[float], float],
          rate_max: float, duration_s: float) -> list[float]:
    """Non-homogeneous Poisson arrivals by thinning: draw a homogeneous
    process at ``rate_max``, keep each point with prob rate(t)/rate_max."""
    out, t = [], 0.0
    while True:
        t += rng.expovariate(rate_max)
        if t >= duration_s:
            return out
        if rng.random() * rate_max <= rate_fn(t):
            out.append(t)


def _number(reqs: list[TraceRequest]) -> list[TraceRequest]:
    """Merge-sort by arrival and assign sequential rids — rid order IS
    arrival order, so replay logs read chronologically."""
    reqs = sorted(reqs, key=lambda r: (r.arrival_s, r.tenant))
    return [dataclasses.replace(r, rid=i) for i, r in enumerate(reqs)]


def _per_tenant(name: str, tenants, duration_s: float, rate_hz: float,
                lm_rate_hz: float, seed: int, prompt_tokens: int,
                new_tokens: int,
                shape: Callable[[random.Random, float],
                                tuple[Callable[[float], float], float]]
                ) -> list[TraceRequest]:
    """Shared generator scaffolding: per-tenant seeded rng + thinning.
    ``shape(rng, base_rate) -> (rate_fn, rate_max)`` is the scenario."""
    if duration_s <= 0:
        raise ValueError(f"duration_s must be > 0, got {duration_s}")
    reqs = []
    for nid, kind in sorted(dict(tenants).items()):
        if kind not in _KINDS:
            raise ValueError(f"tenant {nid!r}: kind must be one of "
                             f"{_KINDS}, got {kind!r}")
        base = lm_rate_hz if kind == "lm" else rate_hz
        if base <= 0:
            continue
        rng = random.Random(f"{seed}:{name}:{nid}")
        rate_fn, rate_max = shape(rng, base)
        for t in _thin(rng, rate_fn, rate_max, duration_s):
            reqs.append(TraceRequest(
                arrival_s=t, tenant=nid, kind=kind,
                prompt_tokens=prompt_tokens, new_tokens=new_tokens))
    return _number(reqs)


def steady(tenants, *, duration_s: float = 0.25, rate_hz: float = 200.0,
           lm_rate_hz: float = 16.0, seed: int = 0, prompt_tokens: int = 3,
           new_tokens: int = 4) -> list[TraceRequest]:
    """Homogeneous Poisson arrivals per tenant (the null scenario)."""
    def shape(rng, base):
        return (lambda t: base), base
    return _per_tenant("steady", tenants, duration_s, rate_hz, lm_rate_hz,
                       seed, prompt_tokens, new_tokens, shape)


def bursty(tenants, *, duration_s: float = 0.25, rate_hz: float = 200.0,
           lm_rate_hz: float = 16.0, seed: int = 0, prompt_tokens: int = 3,
           new_tokens: int = 4, burst_factor: float = 6.0,
           dwell_s: float = 0.03) -> list[TraceRequest]:
    """Two-state MMPP: the rate alternates between ``base`` and
    ``burst_factor * base`` with exponential dwell times (mean
    ``dwell_s``), the standard Markov-modulated burst model."""
    def shape(rng, base):
        segs, t, hi = [], 0.0, False
        while t < duration_s:
            d = rng.expovariate(1.0 / dwell_s)
            segs.append((t, t + d, base * burst_factor if hi else base))
            t += d
            hi = not hi

        def rate(tq: float) -> float:
            for a, b, r in segs:
                if a <= tq < b:
                    return r
            return base
        return rate, base * burst_factor
    return _per_tenant("bursty", tenants, duration_s, rate_hz, lm_rate_hz,
                       seed, prompt_tokens, new_tokens, shape)


def diurnal(tenants, *, duration_s: float = 0.25, rate_hz: float = 200.0,
            lm_rate_hz: float = 16.0, seed: int = 0, prompt_tokens: int = 3,
            new_tokens: int = 4, depth: float = 0.8) -> list[TraceRequest]:
    """Sinusoidally modulated rate — one "day" compressed into the trace:
    rate(t) = base * (1 + depth * sin(2*pi*t / duration))."""
    if not 0.0 <= depth <= 1.0:
        raise ValueError(f"depth must be in [0, 1], got {depth}")

    def shape(rng, base):
        def rate(t: float) -> float:
            return base * (1.0 + depth * math.sin(
                2.0 * math.pi * t / duration_s))
        return rate, base * (1.0 + depth)
    return _per_tenant("diurnal", tenants, duration_s, rate_hz, lm_rate_hz,
                       seed, prompt_tokens, new_tokens, shape)


def flash_crowd(tenants, *, duration_s: float = 0.25,
                rate_hz: float = 200.0, lm_rate_hz: float = 16.0,
                seed: int = 0, prompt_tokens: int = 3, new_tokens: int = 4,
                spike_factor: float = 8.0, spike_start: float = 0.4,
                spike_frac: float = 0.2) -> list[TraceRequest]:
    """Baseline Poisson with a ``spike_factor``x rate spike over
    ``[spike_start, spike_start + spike_frac] * duration`` — the triggered
    burst an extreme-edge deployment must absorb without blowing p99."""
    t_lo = spike_start * duration_s
    t_hi = (spike_start + spike_frac) * duration_s

    def shape(rng, base):
        def rate(t: float) -> float:
            return base * spike_factor if t_lo <= t < t_hi else base
        return rate, base * spike_factor
    return _per_tenant("flash_crowd", tenants, duration_s, rate_hz,
                       lm_rate_hz, seed, prompt_tokens, new_tokens, shape)


SCENARIOS: dict[str, Callable] = {
    "steady": steady,
    "bursty": bursty,
    "diurnal": diurnal,
    "flash_crowd": flash_crowd,
}


def make_scenario(name: str, tenants, **kw) -> list[TraceRequest]:
    """Generate a named scenario's trace for a tenant map
    (``{net_id: kind}``)."""
    try:
        gen = SCENARIOS[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; choose from "
                         f"{sorted(SCENARIOS)}") from None
    return gen(tenants, **kw)


def smoke_trace(tenants, *, edge_iters: int = 10, lm_requests: int = 3,
                edge_interval_s: float = 5e-4, lm_interval_s: float = 2e-3,
                prompt_tokens: int = 3,
                new_tokens: int = 4) -> list[TraceRequest]:
    """The CLI's fixed-interval smoke trace: ``edge_iters`` evenly-spaced
    inferences per edge tenant and ``lm_requests`` per LM tenant — the
    deterministic replacement for the old hand-rolled submit/drain loop."""
    reqs = []
    for nid, kind in sorted(dict(tenants).items()):
        n, dt = ((lm_requests, lm_interval_s) if kind == "lm"
                 else (edge_iters, edge_interval_s))
        for i in range(n):
            reqs.append(TraceRequest(
                arrival_s=i * dt, tenant=nid, kind=kind,
                prompt_tokens=prompt_tokens, new_tokens=new_tokens))
    return _number(reqs)


# ---------------------------------------------------------------------------
# Open-loop replay driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RequestRecord:
    """One replayed request's outcome."""
    rid: int
    tenant: str
    kind: str
    arrival_s: float              # scheduled (trace) arrival
    lag_s: float                  # how late the driver fired it
    e2e_s: float | None           # end-to-end latency; None if not completed
    status: str                   # "ok" | "shed" | "queue_full" | "fault"
                                  # | "breaker" | "stuck"


@dataclasses.dataclass
class ReplayReport:
    """All records from one replay, plus per-tenant tail summaries."""
    records: list[RequestRecord]
    wall_s: float
    speed: float = 1.0
    scenario: str = ""

    def tenants(self) -> list[str]:
        return sorted({r.tenant for r in self.records})

    def summary(self) -> dict[str, dict]:
        """Per-tenant: counts by status, e2e tail percentiles, scheduling
        lag percentiles.  Every value finite (empty windows read 0.0)."""
        out = {}
        for nid in self.tenants():
            recs = [r for r in self.records if r.tenant == nid]
            ok = [r.e2e_s for r in recs
                  if r.status == "ok" and r.e2e_s is not None]
            lags = [r.lag_s for r in recs]
            out[nid] = {
                "kind": recs[0].kind,
                "count": len(recs),
                "ok": len(ok),
                "shed": sum(1 for r in recs if r.status == "shed"),
                "queue_full": sum(1 for r in recs
                                  if r.status == "queue_full"),
                "fault": sum(1 for r in recs if r.status == "fault"),
                "breaker": sum(1 for r in recs if r.status == "breaker"),
                "stuck": sum(1 for r in recs if r.status == "stuck"),
                "p50_s": percentile(ok, 0.50),
                "p95_s": percentile(ok, 0.95),
                "p99_s": percentile(ok, 0.99),
                "max_s": max(ok) if ok else 0.0,
                "lag_p50_s": percentile(lags, 0.50),
                "lag_p95_s": percentile(lags, 0.95),
                "lag_max_s": max(lags) if lags else 0.0,
            }
        return out


def _lm_prompt(tr: TraceRequest, vocab: int):
    """Deterministic prompt tokens (ids in [2, 2+13) mod vocab): replay
    measures scheduling, not language modeling, so cheap and reproducible
    beats random."""
    import numpy as np
    n = max(1, tr.prompt_tokens)
    lo = 2 if vocab > 2 else 0
    span = max(1, min(13, vocab - lo))
    return np.array([lo + (tr.rid + i) % span for i in range(n)], np.int32)


def replay(router, requests: Iterable[TraceRequest], *,
           inputs: dict | None = None, speed: float = 1.0,
           max_drain_ticks: int = 10_000,
           idle_sleep_s: float = 2e-4) -> ReplayReport:
    """Replay a trace open-loop through a live router.

    Arrivals fire at ``arrival_s / speed`` on the wall clock regardless of
    whether earlier requests completed (``speed > 1`` time-compresses a
    trace).  While waiting for the next arrival the driver ticks the LM
    batchers if they hold work, else sleeps in short slices — an idle
    replay must not spin.  After the last arrival the LM tenants are
    drained (bounded by ``max_drain_ticks``); requests still incomplete
    after the drain are recorded as ``"stuck"``.

    Edge requests run synchronously (``router.infer``) against
    ``inputs[tenant]`` (``router.default_inputs()`` when not given —
    warm the router first or the first request measures jit compilation).
    LM requests become ``engine.Request``s via ``router.submit``; their
    e2e latency is submit-to-``t_done`` on the request object.  Refusals
    (shedding, queue-depth bound) are recorded, not raised: under open
    loop, back-pressure is data — and so are faults: a request the engine
    failed records ``"fault"``, one refused by an open circuit breaker
    records ``"breaker"`` (most-specific exception first, since the serve
    exceptions form a hierarchy under ``TenantOverBudget``).
    """
    from repro.serve.engine import Request
    from repro.serve.router import (TenantBreakerOpen, TenantFaulted,
                                    TenantOverBudget, TenantQueueFull)
    if speed <= 0:
        raise ValueError(f"speed must be > 0, got {speed}")
    requests = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
    needs_inputs = any(r.kind == "edge" for r in requests)
    if inputs is None and needs_inputs:
        inputs = router.default_inputs()
    records: list[RequestRecord] = []
    inflight: list[tuple[TraceRequest, float, float, Request]] = []
    lm_pending = getattr(router, "lm_pending", lambda: False)
    start = time.perf_counter()
    for tr in requests:
        target = tr.arrival_s / speed
        while True:
            now = time.perf_counter() - start
            if now >= target:
                break
            if lm_pending():
                router.step()
            else:
                time.sleep(min(target - now, idle_sleep_s))
        lag = (time.perf_counter() - start) - target
        if tr.kind == "edge":
            t0 = time.perf_counter()
            try:
                router.infer(tr.tenant, inputs[tr.tenant])
            except TenantBreakerOpen:
                records.append(RequestRecord(tr.rid, tr.tenant, tr.kind,
                                             tr.arrival_s, lag, None,
                                             "breaker"))
                continue
            except TenantQueueFull:
                records.append(RequestRecord(tr.rid, tr.tenant, tr.kind,
                                             tr.arrival_s, lag, None,
                                             "queue_full"))
                continue
            except TenantFaulted:
                records.append(RequestRecord(tr.rid, tr.tenant, tr.kind,
                                             tr.arrival_s, lag, None,
                                             "fault"))
                continue
            except TenantOverBudget:
                records.append(RequestRecord(tr.rid, tr.tenant, tr.kind,
                                             tr.arrival_s, lag, None,
                                             "shed"))
                continue
            records.append(RequestRecord(
                tr.rid, tr.tenant, tr.kind, tr.arrival_s, lag,
                time.perf_counter() - t0, "ok"))
        else:
            eng = router.tenant(tr.tenant).engine
            vocab = getattr(getattr(eng, "cfg", None), "vocab_size", 64)
            req = Request(rid=tr.rid, prompt=_lm_prompt(tr, vocab),
                          max_new=max(1, tr.new_tokens))
            t0 = time.perf_counter()
            try:
                router.submit(tr.tenant, req)
            except TenantBreakerOpen:
                records.append(RequestRecord(tr.rid, tr.tenant, tr.kind,
                                             tr.arrival_s, lag, None,
                                             "breaker"))
                continue
            except TenantQueueFull:
                records.append(RequestRecord(tr.rid, tr.tenant, tr.kind,
                                             tr.arrival_s, lag, None,
                                             "queue_full"))
                continue
            except TenantFaulted:
                records.append(RequestRecord(tr.rid, tr.tenant, tr.kind,
                                             tr.arrival_s, lag, None,
                                             "fault"))
                continue
            except TenantOverBudget:
                records.append(RequestRecord(tr.rid, tr.tenant, tr.kind,
                                             tr.arrival_s, lag, None,
                                             "shed"))
                continue
            inflight.append((tr, lag, t0, req))
    router.run_until_drained(max_ticks=max_drain_ticks)
    for tr, lag, t0, req in inflight:
        if req.done and getattr(req, "error", None):
            records.append(RequestRecord(tr.rid, tr.tenant, tr.kind,
                                         tr.arrival_s, lag, None, "fault"))
        elif req.done and req.t_done is not None:
            records.append(RequestRecord(tr.rid, tr.tenant, tr.kind,
                                         tr.arrival_s, lag,
                                         req.t_done - t0, "ok"))
        else:
            records.append(RequestRecord(tr.rid, tr.tenant, tr.kind,
                                         tr.arrival_s, lag, None, "stuck"))
    records.sort(key=lambda r: r.rid)
    return ReplayReport(records=records,
                        wall_s=time.perf_counter() - start, speed=speed)


# ---------------------------------------------------------------------------
# Snapshots + human-readable report
# ---------------------------------------------------------------------------

def write_replay_snapshots(report: ReplayReport, json_dir, *,
                           scenario: str | None = None, slo=None,
                           meta: dict | None = None) -> list[pathlib.Path]:
    """Per-tenant ``BENCH_serve_<net>__<scenario>.json`` tail snapshots.

    Measured rows (``src=measured`` — trend-reported, never gated):
    ``serve/<net>/<scenario>/{p50,p95,p99,max}`` end-to-end latency and
    ``.../lag/{p50,p95}`` scheduling lag; ``derived`` carries the
    shed/queue_full/stuck counters and the tenant's SLO violation count.
    Model rows (``src=model`` — deterministic, trend-GATED):
    ``.../offered`` (the seeded generator's arrival count — a pure function
    of seed + knobs) and ``.../slo_p95_budget`` (the plan-derived budget,
    exact under ``--machine-model stock``)."""
    from repro.serve.metrics import _safe_net_name
    scenario = scenario or report.scenario or "replay"
    out_dir = pathlib.Path(json_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    slo_snap = slo.snapshot() if slo is not None else {}
    paths = []
    for nid, s in report.summary().items():
        prefix = f"serve/{nid}/{scenario}"
        violations = slo_snap.get(nid, {}).get("violations", 0)
        derived = (f"src=measured;scenario={scenario};count={s['count']};"
                   f"ok={s['ok']};shed={s['shed']};"
                   f"queue_full={s['queue_full']};"
                   f"fault={s.get('fault', 0)};"
                   f"breaker={s.get('breaker', 0)};stuck={s['stuck']};"
                   f"violations={violations};kind={s['kind']}")
        rows = []
        if s["ok"]:
            rows += [{"name": f"{prefix}/{pct}",
                      "us_per_call": round(s[f"{pct}_s"] * 1e6, 3),
                      "derived": derived}
                     for pct in ("p50", "p95", "p99", "max")]
        if s["count"]:
            rows += [{"name": f"{prefix}/lag/{pct}",
                      "us_per_call": round(s[f"lag_{pct}_s"] * 1e6, 3),
                      "derived": derived}
                     for pct in ("p50", "p95")]
        rows.append({"name": f"{prefix}/offered",
                     "us_per_call": float(s["count"]),
                     "derived": f"src=model;scenario={scenario};"
                                f"unit=requests"})
        budget = slo_snap.get(nid, {}).get("p95_budget_s")
        if budget is not None:
            rows.append({"name": f"{prefix}/slo_p95_budget",
                         "us_per_call": round(budget * 1e6, 3),
                         "derived": f"src=model;scenario={scenario}"})
        payload = {"meta": {"net_id": nid, "scenario": scenario,
                            "speed": report.speed, **(meta or {})},
                   "rows": rows}
        p = out_dir / (f"BENCH_serve_{_safe_net_name(nid)}__"
                       f"{_safe_net_name(scenario)}.json")
        p.write_text(json.dumps(payload, indent=2, sort_keys=True,
                                allow_nan=False) + "\n")
        paths.append(p)
    return paths


def format_replay(report: ReplayReport, *, slo=None) -> str:
    """Human-readable per-tenant tail + scheduling-lag table, with SLO
    verdict lines when a monitor is given (the word ``VIOLATION`` marks
    flagged tenants — the CI smoke greps for it)."""
    lines = [f"replay: {len(report.records)} requests in "
             f"{report.wall_s * 1e3:.1f}ms wall"
             + (f" (speed={report.speed:g}x)" if report.speed != 1.0
                else "")]
    hdr = (f"  {'tenant':<14}{'kind':<5}{'n':>5}{'ok':>5}{'shed':>5}"
           f"{'full':>5}{'flt':>5}{'brk':>5}  "
           f"{'p50':>9}{'p95':>9}{'p99':>9}{'max':>9}")
    lines.append(hdr)
    summary = report.summary()
    for nid, s in summary.items():
        lines.append(
            f"  {nid:<14}{s['kind']:<5}{s['count']:>5}{s['ok']:>5}"
            f"{s['shed']:>5}{s['queue_full']:>5}"
            f"{s.get('fault', 0):>5}{s.get('breaker', 0):>5}  "
            f"{s['p50_s'] * 1e6:>7.1f}us{s['p95_s'] * 1e6:>7.1f}us"
            f"{s['p99_s'] * 1e6:>7.1f}us{s['max_s'] * 1e6:>7.1f}us")
    lines.append("scheduling lag (how late arrivals fired — open-loop "
                 "measurement error):")
    for nid, s in summary.items():
        lines.append(f"  {nid:<14} lag_p50={s['lag_p50_s'] * 1e6:8.1f}us "
                     f"lag_p95={s['lag_p95_s'] * 1e6:8.1f}us "
                     f"lag_max={s['lag_max_s'] * 1e6:8.1f}us")
    if slo is not None:
        lines.append("slo:")
        for nid, st in sorted(slo.snapshot().items()):
            budget = st["p95_budget_s"]
            budget_txt = (f"{budget * 1e6:.1f}us" if budget is not None
                          else "none")
            verdict = ""
            if st["violations"] or st["in_violation"]:
                verdict = (f"  VIOLATION x{st['violations']}"
                           f"{' (active)' if st['in_violation'] else ''}")
            lines.append(
                f"  {nid:<14} prio={st['priority']:<9} "
                f"p95={st['p95_s'] * 1e6:8.1f}us vs budget {budget_txt:<10} "
                f"burn fast={st['burn_fast']:.2f} "
                f"slow={st['burn_slow']:.2f}{verdict}")
    return "\n".join(lines)
