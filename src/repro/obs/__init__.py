"""``repro.obs`` — end-to-end tracing, span-decomposed service time, and
plan-vs-measured attribution.

The measurement substrate under the characterize → plan → engine → serve
pipeline: a lightweight host-side span/trace API (:mod:`repro.obs.trace`),
Chrome/Perfetto + Prometheus exporters (:mod:`repro.obs.export`), and a
plan-attribution layer joining measured spans against planned costs per
span kind (:mod:`repro.obs.attribution`).

Quick start::

    from repro.deploy import Deployment
    dep = Deployment.build(["jet_tagger", "lm:qwen2_5_3b"], trace=True)
    router = dep.serve()
    ...                                    # traffic
    dep.export_trace("trace.json")         # load in ui.perfetto.dev
    print(dep.format_attribution())        # planned-vs-measured per kind

or ``python -m repro trace`` for the CLI equivalent.
"""

from repro.obs.attribution import (AttributionRow, aggregate, attribution,
                                   format_attribution, reconcile)
from repro.obs.export import (parse_prometheus, prometheus_text, to_chrome,
                              write_chrome, write_prometheus)
from repro.obs.trace import (NULL_TRACER, Span, Tracer, percentile,
                             summarize)

__all__ = [
    "NULL_TRACER", "AttributionRow", "Span", "Tracer", "aggregate",
    "attribution", "format_attribution", "parse_prometheus", "percentile",
    "prometheus_text", "reconcile", "summarize", "to_chrome", "write_chrome",
    "write_prometheus",
]
