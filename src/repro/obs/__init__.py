"""``repro.obs`` — end-to-end tracing, span-decomposed service time, and
plan-vs-measured attribution.

The measurement substrate under the characterize → plan → engine → serve
pipeline: a lightweight host-side span/trace API (:mod:`repro.obs.trace`),
Chrome/Perfetto + Prometheus exporters (:mod:`repro.obs.export`), a
plan-attribution layer joining measured spans against planned costs per
span kind (:mod:`repro.obs.attribution`), workload traces + scenario
generators + the open-loop replay driver (:mod:`repro.obs.workload`),
the per-tenant SLO monitor with priority classes and burn-rate windows
(:mod:`repro.obs.slo`), and the roofline-attributed profiler joining
measured windows with plan-derived work and hardware ceilings —
achieved FLOP/s, bound classification, measured LARE
(:mod:`repro.obs.profile`).

Quick start::

    from repro.deploy import Deployment
    dep = Deployment.build(["jet_tagger", "lm:qwen2_5_3b"], trace=True)
    router = dep.serve()
    ...                                    # traffic
    dep.export_trace("trace.json")         # load in ui.perfetto.dev
    print(dep.format_attribution())        # planned-vs-measured per kind

or ``python -m repro trace`` for the CLI equivalent.
"""

from repro.obs.attribution import (AttributionRow, aggregate, attribution,
                                   format_attribution, reconcile)
from repro.obs.export import (parse_prometheus, prometheus_text, to_chrome,
                              write_chrome, write_prometheus)
from repro.obs.profile import (PROFILE_KINDS, ProfileRow, format_profile,
                               profile, roofline_terms,
                               write_profile_snapshots)
from repro.obs.slo import (PRIORITY_CLASSES, SloBudget, SloMonitor,
                           SloViolation, priority_rank)
from repro.obs.trace import (NULL_TRACER, Span, Tracer, percentile,
                             summarize)
from repro.obs.workload import (SCENARIOS, ReplayReport, RequestRecord,
                                TraceRequest, format_replay, load_trace,
                                make_scenario, replay, save_trace,
                                smoke_trace, write_replay_snapshots)

__all__ = [
    "NULL_TRACER", "PRIORITY_CLASSES", "PROFILE_KINDS", "AttributionRow",
    "ProfileRow", "ReplayReport", "RequestRecord", "SCENARIOS", "SloBudget",
    "SloMonitor", "SloViolation", "Span", "TraceRequest", "Tracer",
    "aggregate", "attribution", "format_attribution", "format_profile",
    "format_replay", "load_trace", "make_scenario", "parse_prometheus",
    "percentile", "priority_rank", "profile", "prometheus_text",
    "reconcile", "replay", "roofline_terms", "save_trace", "smoke_trace",
    "summarize", "to_chrome", "write_chrome", "write_profile_snapshots",
    "write_prometheus", "write_replay_snapshots",
]
