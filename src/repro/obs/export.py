"""Span exporters: Chrome/Perfetto ``trace.json`` and Prometheus text.

Two inspection surfaces over one span stream:

* :func:`to_chrome` / :func:`write_chrome` — the Trace Event Format that
  ``chrome://tracing`` and https://ui.perfetto.dev load directly: one
  complete (``"ph": "X"``) event per span, rows (``tid``) grouped by tenant
  so a request's queue/prefill/decode decomposition reads left-to-right on
  one timeline.
* :func:`prometheus_text` — a Prometheus text-exposition snapshot of span
  aggregates (summary-style quantiles + count + sum per ``{tenant, kind}``),
  for scrape-shaped consumers and the CI smoke that validates it with
  :func:`parse_prometheus`.

Both outputs are strict: JSON is written with ``allow_nan=False`` (a NaN in
a trace is a bug upstream, not something to smuggle into a viewer) and the
Prometheus serializer emits only finite samples.
"""

from __future__ import annotations

import json
import math
import pathlib
import re
from typing import Iterable

from repro.obs.trace import Span

_PROM_METRIC = "repro_span_seconds"


def _chrome_tid_map(spans: Iterable[Span]) -> dict[str, int]:
    """Stable tenant -> tid assignment (row order in the viewer)."""
    tids: dict[str, int] = {}
    for s in spans:
        tenant = str(s.attrs.get("tenant", "-"))
        if tenant not in tids:
            tids[tenant] = len(tids) + 1
    return tids


def to_chrome(spans: Iterable[Span], *, dropped: int = 0) -> dict:
    """Spans as a Trace Event Format payload (``{"traceEvents": [...]}``).

    Timestamps are microseconds on the process ``perf_counter`` clock; each
    tenant gets its own thread row, and thread-name metadata events label
    the rows so Perfetto shows tenant ids instead of bare tids."""
    spans = list(spans)
    tids = _chrome_tid_map(spans)
    events = [
        {"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
         "args": {"name": f"tenant:{tenant}"}}
        for tenant, tid in tids.items()
    ]
    for s in spans:
        args = {k: v for k, v in s.attrs.items() if k != "tenant"}
        if s.trace_id is not None:
            args["trace_id"] = s.trace_id
        events.append({
            "name": s.name,
            "cat": str(s.attrs.get("tenant", "repro")),
            "ph": "X",
            "ts": round(s.t0_s * 1e6, 3),
            "dur": round(s.dur_s * 1e6, 3),
            "pid": 1,
            "tid": tids[str(s.attrs.get("tenant", "-"))],
            "args": args,
        })
    meta = {"clock": "perf_counter", "spans": len(spans), "dropped": dropped}
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": meta}


def write_chrome(spans: Iterable[Span], path, *, dropped: int = 0):
    """Write the Perfetto-loadable ``trace.json``; returns the path."""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    payload = to_chrome(spans, dropped=dropped)
    p.write_text(json.dumps(payload, indent=1, sort_keys=True,
                            allow_nan=False) + "\n")
    return p


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _prom_escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _fmt(x: float) -> str:
    return repr(float(x))


def prometheus_text(stats: dict, *, metric: str = _PROM_METRIC,
                    dropped: int | None = None,
                    slo: dict | None = None,
                    profile: list | None = None,
                    resilience: dict | None = None) -> str:
    """Render span aggregates as a Prometheus text-format snapshot.

    ``stats`` maps ``(tenant, kind)`` to a :func:`repro.obs.trace.summarize`
    dict.  Output is summary-typed: ``{quantile="0.5"|"0.95"}`` samples plus
    ``_count``/``_sum`` series per label set.  Non-finite values are skipped
    rather than serialized (Prometheus would accept ``NaN`` but every
    downstream alert rule then mis-fires).

    ``dropped`` (a :attr:`repro.obs.Tracer.dropped` count) adds the
    ``repro_tracer_dropped_total`` counter — a scrape that silently
    truncates its own evidence is worse than none.  ``slo`` (a
    :meth:`repro.obs.slo.SloMonitor.snapshot` dict) adds the SLO families:
    per-tenant budget/latency quantile gauges, fast/slow burn rates, and
    the violation-event counter.  ``profile`` (a list of
    :class:`repro.obs.profile.ProfileRow`) adds the ``repro_profile_*``
    families: achieved FLOP/s / bytes/s, roofline fraction, the bound
    classification as an info-style gauge, and measured LARE.
    ``resilience`` (a ``Router.health()`` dict) adds the
    ``repro_resilience_*`` families: per-tenant failure counters, circuit
    breaker state/opens/recloses, degradation-ladder level, retry and
    deadline-overrun counters, and the fleet-level replan-failure count."""
    lines = [
        f"# HELP {metric} Span-decomposed service time by tenant and kind.",
        f"# TYPE {metric} summary",
    ]
    for (tenant, kind), agg in sorted(stats.items()):
        labels = (f'tenant="{_prom_escape(str(tenant))}",'
                  f'kind="{_prom_escape(str(kind))}"')
        for q, key in (("0.5", "p50_s"), ("0.95", "p95_s")):
            v = agg.get(key, 0.0)
            if not math.isfinite(v):
                continue
            lines.append(f'{metric}{{{labels},quantile="{q}"}} {_fmt(v)}')
        total = agg.get("total_s", 0.0)
        if math.isfinite(total):
            lines.append(f"{metric}_sum{{{labels}}} {_fmt(total)}")
        lines.append(f"{metric}_count{{{labels}}} {int(agg.get('count', 0))}")
    if dropped is not None:
        lines += [
            "# HELP repro_tracer_dropped_total Spans dropped after the "
            "tracer's maxlen filled (the snapshot under-counts by this).",
            "# TYPE repro_tracer_dropped_total counter",
            f"repro_tracer_dropped_total {int(dropped)}",
        ]
    if slo:
        lines += _slo_families(slo)
    if profile:
        lines += _profile_families(profile)
    if resilience:
        lines += _resilience_families(resilience)
    return "\n".join(lines) + "\n"


def _profile_families(rows: list) -> list[str]:
    """The ``repro_profile_*`` families from :func:`repro.obs.profile.
    profile` rows.  Non-finite/None values are skipped per sample (a
    zero-duration window simply has no achieved-rate or fraction sample);
    fusion-group rows carry an extra ``group`` label."""
    def labels(r) -> str:
        out = (f'tenant="{_prom_escape(str(r.tenant))}",'
               f'kind="{_prom_escape(str(r.kind))}"')
        if r.group is not None:
            out += f',group="{int(r.group)}"'
        return out

    flops, byts, frac, bound, lare = [], [], [], [], []
    for r in rows:
        lab = labels(r)
        for samples, v in ((flops, r.achieved_flops),
                           (byts, r.achieved_bytes_per_s),
                           (frac, r.roofline_fraction)):
            if v is not None and math.isfinite(v):
                samples.append((lab, v))
        bound.append((f'{lab},bound="{_prom_escape(r.bound)}"', 1.0))
        if r.group is None and r.measured_lare is not None \
                and math.isfinite(r.measured_lare):
            lare.append((f'tenant="{_prom_escape(str(r.tenant))}"',
                         r.measured_lare))
    lines = []
    for name, help_txt, samples in (
            ("repro_profile_achieved_flops",
             "Achieved FLOP/s over the measured window (plan-derived "
             "work / measured p50).", flops),
            ("repro_profile_achieved_bytes_per_second",
             "Achieved HBM bytes/s over the measured window.", byts),
            ("repro_profile_roofline_fraction",
             "Roofline ceiling time / measured p50, clamped to (0,1]; "
             "1.0 = running at the model ceiling.", frac),
            ("repro_profile_bound_info",
             "Bound classification (compute/memory/launch) as an "
             "info-style gauge.", bound),
            ("repro_profile_measured_lare",
             "Measured LARE (paper Alg. 1 with the measured interval "
             "injected), in PL DSP-equivalents.", lare)):
        if samples:
            lines += [f"# HELP {name} {help_txt}",
                      f"# TYPE {name} gauge",
                      *(f"{name}{{{lab}}} {_fmt(v)}" for lab, v in samples)]
    return lines


def _resilience_families(health: dict) -> list[str]:
    """The ``repro_resilience_*`` families from a ``Router.health()`` dict.

    Breaker state is exported info-style (one ``{tenant, state}`` sample at
    1.0 per tenant — alert rules match on the label, not a magic number);
    every counter defaults to 0 so unsupervised tenants still expose the
    family with a stable label set."""
    tenants = health.get("tenants", {})
    fail, state, opens, recloses, level, retries, deadline = (
        [], [], [], [], [], [], [])
    for tenant, st in sorted(tenants.items()):
        t = f'tenant="{_prom_escape(str(tenant))}"'
        fail.append(f"repro_resilience_failures_total{{{t}}} "
                    f"{int(st.get('failures', 0))}")
        br_state = st.get("state")
        if br_state:
            state.append(f'repro_resilience_breaker_state{{{t},'
                         f'state="{_prom_escape(str(br_state))}"}} 1.0')
            opens.append(f"repro_resilience_breaker_opens_total{{{t}}} "
                         f"{int(st.get('breaker_opens', 0))}")
            recloses.append(
                f"repro_resilience_breaker_recloses_total{{{t}}} "
                f"{int(st.get('breaker_recloses', 0))}")
            retries.append(f"repro_resilience_retries_total{{{t}}} "
                           f"{int(st.get('retries', 0))}")
            deadline.append(
                f"repro_resilience_deadline_exceeded_total{{{t}}} "
                f"{int(st.get('deadline_exceeded', 0))}")
        level.append(f"repro_resilience_degrade_level{{{t}}} "
                     f"{int(st.get('degrade_level', 0))}")
    lines = []
    for name, kind, help_txt, samples in (
            ("repro_resilience_failures_total", "counter",
             "Failed requests per tenant (engine exceptions, non-finite "
             "outputs, batcher faults); never counted as latency.", fail),
            ("repro_resilience_breaker_state", "gauge",
             "Circuit breaker state as an info-style gauge "
             "(closed/open/half_open).", state),
            ("repro_resilience_breaker_opens_total", "counter",
             "Circuit breaker open transitions per tenant.", opens),
            ("repro_resilience_breaker_recloses_total", "counter",
             "Circuit breaker re-close (recovery) transitions per tenant.",
             recloses),
            ("repro_resilience_degrade_level", "gauge",
             "Degradation-ladder rung: 0=fused, 1=per-layer fallback, "
             "2=shedding (breaker open).", level),
            ("repro_resilience_retries_total", "counter",
             "Supervisor retry attempts per tenant.", retries),
            ("repro_resilience_deadline_exceeded_total", "counter",
             "Requests whose wall-clock service time exceeded the "
             "plan-derived deadline (audited, not breaker-fed).", deadline)):
        if samples:
            lines += [f"# HELP {name} {help_txt}", f"# TYPE {name} {kind}",
                      *samples]
    if "replan_failures" in health:
        lines += [
            "# HELP repro_resilience_replan_failures_total Drift-triggered "
            "replans that failed and fell back to the current fleet.",
            "# TYPE repro_resilience_replan_failures_total counter",
            f"repro_resilience_replan_failures_total "
            f"{int(health.get('replan_failures', 0))}",
        ]
    return lines


def _slo_families(slo: dict) -> list[str]:
    """The SLO metric families from a ``SloMonitor.snapshot()`` dict."""
    budget, latency, burn, viol = [], [], [], []
    for tenant, st in sorted(slo.items()):
        t = f'tenant="{_prom_escape(str(tenant))}"'
        prio = f'priority="{_prom_escape(str(st.get("priority", "")))}"'
        for q, key in (("0.95", "p95_budget_s"), ("0.99", "p99_budget_s")):
            v = st.get(key)
            if v is not None and math.isfinite(v):
                budget.append(
                    f'repro_slo_budget_seconds{{{t},{prio},'
                    f'quantile="{q}"}} {_fmt(v)}')
        for q, key in (("0.95", "p95_s"), ("0.99", "p99_s")):
            v = st.get(key, 0.0)
            if math.isfinite(v):
                latency.append(
                    f'repro_slo_latency_seconds{{{t},'
                    f'quantile="{q}"}} {_fmt(v)}')
        for window in ("fast", "slow"):
            v = st.get(f"burn_{window}", 0.0)
            if math.isfinite(v):
                burn.append(f'repro_slo_burn_rate{{{t},'
                            f'window="{window}"}} {_fmt(v)}')
        viol.append(f"repro_slo_violations_total{{{t}}} "
                    f"{int(st.get('violations', 0))}")
    lines = []
    for name, kind, help_txt, samples in (
            ("repro_slo_budget_seconds", "gauge",
             "Per-tenant tail-latency SLO budget (plan-derived).", budget),
            ("repro_slo_latency_seconds", "gauge",
             "Per-tenant measured tail latency over the SLO window.",
             latency),
            ("repro_slo_burn_rate", "gauge",
             "Error-budget burn rate (1.0 = exactly at contract).", burn),
            ("repro_slo_violations_total", "counter",
             "Edge-triggered SLO violation events.", viol)):
        if samples:
            lines += [f"# HELP {name} {help_txt}", f"# TYPE {name} {kind}",
                      *samples]
    return lines


_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)\s*$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text: str) -> list[dict]:
    """Parse a text-exposition snapshot back into sample dicts.

    A deliberately strict reader (names, label syntax, float values) used by
    the tests and the CI smoke to prove the exporter emits well-formed
    output; raises ``ValueError`` on any malformed line."""
    samples = []
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"malformed Prometheus sample "
                             f"(line {lineno}): {line!r}")
        labels = dict(_LABEL_RE.findall(m["labels"] or ""))
        try:
            value = float(m["value"])
        except ValueError:
            raise ValueError(f"non-numeric sample value "
                             f"(line {lineno}): {line!r}") from None
        if not math.isfinite(value):
            raise ValueError(f"non-finite sample value "
                             f"(line {lineno}): {line!r}")
        samples.append({"name": m["name"], "labels": labels, "value": value})
    if not samples:
        raise ValueError("no samples found in Prometheus text")
    return samples


def write_prometheus(stats: dict, path, *, metric: str = _PROM_METRIC,
                     dropped: int | None = None, slo: dict | None = None,
                     profile: list | None = None,
                     resilience: dict | None = None):
    """Write the Prometheus snapshot; returns the path."""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(prometheus_text(stats, metric=metric, dropped=dropped,
                                 slo=slo, profile=profile,
                                 resilience=resilience))
    return p
