"""Plan-vs-measured attribution: join spans against a plan's cost story.

The fig8–fig11 benchmarks judge "planned within 2x of measured" once, at the
end-to-end request grain.  This module makes that judgement *continuous and
per component*: measured spans aggregate per ``(tenant, kind)`` and each
kind joins against the plan term that prices it —

========================= ==============================================
span kind                 planned analogue
========================= ==============================================
``infer`` (edge request)  ``plan.est_latency_s`` (the whole pipeline)
``decode_step`` (lm)      ``plan.est_latency_s`` (an LM plan's graph IS
                          one decode step — ``plan.graph.model_graph``)
``prefill_chunk`` (lm)    ``plan.est_latency_s`` x tokens in the chunk
                          (prefill runs the decode forward per token)
``queue`` / ``admit``     none — scheduling wait is exactly the part the
                          plan does NOT price, which is why it must be
                          separated before latencies feed recalibration
========================= ==============================================

The decomposition is what lets LM tenants join the drift/replan loop: the
router compares measured *decode-step* service time (queue wait excluded)
against the plan estimate, the same quantity-vs-quantity comparison the
edge path has had since PR 3.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.obs.trace import Span, summarize

# Span kinds whose planned cost is the plan's full latency estimate.
_FULL_LATENCY_KINDS = ("infer", "decode_step", "request")
# Span kinds that scale with the token count carried in span attrs.
_PER_TOKEN_KINDS = ("prefill_chunk",)


def aggregate(spans: Iterable[Span]) -> dict:
    """Per ``(tenant, kind)`` duration aggregates over a span stream.

    Returns ``{(tenant, kind): summary}`` where ``summary`` is
    :func:`repro.obs.trace.summarize` output plus ``tokens`` (summed from
    span attrs, 0 when absent) — the regressor the per-token attribution
    needs."""
    groups: dict[tuple, list[float]] = {}
    tokens: dict[tuple, int] = {}
    for s in spans:
        key = (str(s.attrs.get("tenant", "-")), s.name)
        groups.setdefault(key, []).append(s.dur_s)
        tokens[key] = tokens.get(key, 0) + int(s.attrs.get("tokens", 0))
    out = {}
    for key, durs in groups.items():
        agg = summarize(durs)
        agg["tokens"] = tokens[key]
        out[key] = agg
    return out


@dataclasses.dataclass(frozen=True)
class AttributionRow:
    """One ``(tenant, span-kind)`` planned-vs-measured judgement."""
    tenant: str
    kind: str
    count: int
    measured_p50_s: float
    measured_p95_s: float
    total_s: float
    planned_s: float | None          # None: no plan term prices this kind

    @property
    def ratio(self) -> float | None:
        """measured/planned (the drift convention); None when unplanned."""
        if self.planned_s is None or self.planned_s <= 0 \
                or self.measured_p50_s <= 0:
            return None
        return self.measured_p50_s / self.planned_s

    @property
    def within_2x(self) -> bool | None:
        r = self.ratio
        return None if r is None else 0.5 <= r <= 2.0


def _planned_for(kind: str, plan, agg: dict) -> float | None:
    est = getattr(plan, "est_latency_s", 0.0) or 0.0
    if est <= 0:
        return None
    if kind in _FULL_LATENCY_KINDS:
        return est
    if kind in _PER_TOKEN_KINDS:
        count = agg.get("count", 0)
        toks = agg.get("tokens", 0)
        if count and toks:
            return est * (toks / count)   # mean tokens per chunk
        return None
    return None


def attribution(plans: dict, stats_or_spans) -> list[AttributionRow]:
    """Join measured span aggregates against per-tenant plans.

    ``plans`` maps tenant/net id to its :class:`DeploymentPlan` (e.g.
    ``Deployment.plans`` or ``{tp.net_id: tp.plan for tp in fleet.tenants}``);
    the second argument is either a span iterable or a pre-built
    :func:`aggregate` dict.  Rows sort by tenant then total time spent, so
    the biggest consumer of a tenant's wall clock reads first."""
    stats = (stats_or_spans if isinstance(stats_or_spans, dict)
             else aggregate(stats_or_spans))
    rows = []
    for (tenant, kind), agg in stats.items():
        plan = plans.get(tenant)
        planned = _planned_for(kind, plan, agg) if plan is not None else None
        rows.append(AttributionRow(
            tenant=tenant, kind=kind, count=agg["count"],
            measured_p50_s=agg["p50_s"], measured_p95_s=agg["p95_s"],
            total_s=agg["total_s"], planned_s=planned))
    rows.sort(key=lambda r: (r.tenant, -r.total_s, r.kind))
    return rows


def format_attribution(rows: list[AttributionRow], *, slo=None,
                       profile=None) -> str:
    """Human-readable attribution table (the ``repro trace`` report).

    Pass ``slo=`` (a :class:`repro.obs.slo.SloMonitor`) to append the
    tail-contract verdict under the component table: per-tenant measured
    p95/p99 vs budget, burn rates, and the violation-event count — the
    span decomposition says *where* the time went, the SLO lines say
    whether the tenant's contract survived it.  Pass ``profile=`` (rows
    from :func:`repro.obs.profile.profile`) to append the roofline
    judgement under that: how far from the hardware ceiling each window
    ran, and what bounds it."""
    tenant_w = max([18] + [len(r.tenant) + 1 for r in rows])
    kind_w = max([20] + [len(r.kind) + 1 for r in rows])
    lines = [f"{'tenant':<{tenant_w}}{'span kind':<{kind_w}}{'n':>6}"
             f"{'p50':>14}{'p95':>14}{'total':>12}{'planned':>13}"
             f"{'ratio':>10}  2x"]
    for r in rows:
        planned = (f"{r.planned_s * 1e6:11.1f}us" if r.planned_s is not None
                   else f"{'-':>13}")
        ratio = f"{r.ratio:9.2f}" if r.ratio is not None else f"{'-':>9}"
        within = {True: "ok", False: "MISS", None: "-"}[r.within_2x]
        lines.append(
            f"{r.tenant:<{tenant_w}}{r.kind:<{kind_w}}{r.count:>6}"
            f"{r.measured_p50_s * 1e6:12.1f}us"
            f"{r.measured_p95_s * 1e6:12.1f}us"
            f"{r.total_s * 1e3:10.2f}ms{planned}{ratio}  {within}")
    if slo is not None:
        lines.append("slo:")
        for tenant, st in sorted(slo.snapshot().items()):
            budget = st["p95_budget_s"]
            budget_txt = (f"{budget * 1e6:.1f}us" if budget is not None
                          else "none")
            verdict = (f"  VIOLATION x{st['violations']}"
                       if st["violations"] or st["in_violation"] else "  ok")
            lines.append(
                f"  {tenant:<{tenant_w - 2}} prio={st['priority']:<9} "
                f"p95={st['p95_s'] * 1e6:9.1f}us / {budget_txt:<10} "
                f"p99={st['p99_s'] * 1e6:9.1f}us "
                f"burn={st['burn_fast']:.2f}/{st['burn_slow']:.2f}"
                f"{verdict}")
    if profile:
        from repro.obs.profile import format_profile
        lines.append("roofline:")
        lines.extend("  " + ln for ln in format_profile(profile).splitlines())
    return "\n".join(lines)


def reconcile(spans: Iterable[Span], trace_id, e2e_s: float) -> dict:
    """How much of one request's end-to-end latency its spans explain.

    Returns ``{"sum_s", "e2e_s", "coverage", "by_kind"}`` where coverage is
    ``sum(span durations) / e2e``.  Decode steps are batched, so a span can
    cover work shared with co-resident slots — coverage slightly above 1 is
    legitimate overlap, far below 1 means the request spent wall time no
    span accounts for (the observability gap the tests bound)."""
    mine = [s for s in spans if s.trace_id == trace_id]
    by_kind: dict[str, float] = {}
    for s in mine:
        if s.name == "request":      # the e2e envelope, not a component
            continue
        by_kind[s.name] = by_kind.get(s.name, 0.0) + s.dur_s
    total = sum(by_kind.values())
    cov = total / e2e_s if e2e_s > 0 else math.nan
    return {"sum_s": total, "e2e_s": e2e_s, "coverage": cov,
            "by_kind": by_kind}
