"""Roofline-attributed profiling: measured spans joined with planned work.

PR 6/7 observability says *where* time goes (span decomposition, tail
contracts); this module says *how far from the hardware ceiling* each of
those components runs.  For every measured ``(tenant, span-kind)`` window
and every DR7' fusion group it joins three ingredients —

* **measured time** — the span aggregates the engines keep always-on
  (:func:`repro.obs.attribution.aggregate` shape),
* **planned work** — MACs, weight/activation bytes and launch counts from
  :meth:`repro.plan.artifact.DeploymentPlan.work` (the same per-layer
  accounting as :mod:`repro.plan.graph`),
* **hardware ceilings** — peak FLOP/s, HBM bandwidth and per-launch
  overhead from :mod:`repro.hw` or a fitted
  :class:`repro.characterize.model.MachineModel` (one ceiling of truth,
  shared with ``launch/roofline.py``)

— into achieved FLOP/s, achieved bytes/s, the roofline ceiling time, a
bound classification (compute- / memory- / launch-boundary-bound), and a
roofline fraction ``ceiling / measured`` in ``(0, 1]``.

**Measured LARE.**  The paper's Algorithm 1 prices a layer's AIE mapping by
the PL resource budget that matches its *interval*; :func:`repro.core.lare.
lare` explicitly supports injecting a measured interval.  Here we inject
the measured share of the tenant's dominant layer (largest ``macs x
repeat``): ``interval = measured_p50 x (layer's share of the plan
estimate)``.  A measured LARE above the plan's static LARE means the
deployment runs *further* from the ceiling than planned — a smaller PL
budget would already match it, i.e. the mapping under-utilizes the array
(the paper's efficiency-indicator reading, now on live traffic).
"""

from __future__ import annotations

import dataclasses
import json
import math
import pathlib

from repro import hw as hwlib
from repro.core.lare import lare as _lare
from repro.obs.attribution import aggregate

# Span kinds whose window prices plan-derived work.  ``infer`` covers one
# planned edge inference; ``decode_step`` one LM decode step (an LM plan's
# graph IS a decode step); ``prefill_chunk`` scales by tokens per chunk.
PROFILE_KINDS = ("infer", "decode_step", "prefill_chunk")
# The kind that carries a tenant's per-request work (group rows + LARE
# attach here).
_PRIMARY_KINDS = ("infer", "decode_step")


def roofline_terms(flops: float, bytes_moved: float, launches: float, *,
                   itemsize: int = 2, hw=None,
                   collective_bytes: float = 0.0) -> dict:
    """Roofline time terms + bound classification for one work bundle.

    Returns ``{"t_compute_s", "t_memory_s", "t_launch_s",
    "t_collective_s", "bound", "ceiling_s", "peak_flops"}``.  The ceiling
    is the max of the terms (each term alone lower-bounds execution); the
    bound label names the term that dominates.  ``hw`` is any object with
    ``peak_bf16_flops``/``peak_int8_ops``/``hbm_bw``/``ici_bw``/
    ``kernel_overhead_s`` — :data:`repro.hw.TPU_V5E` or a fitted
    ``MachineModel.tpu()``."""
    hw = hw if hw is not None else hwlib.TPU_V5E
    peak = hw.peak_int8_ops if itemsize == 1 else hw.peak_bf16_flops
    terms = {
        "compute": flops / peak,
        "memory": bytes_moved / hw.hbm_bw,
        "launch": launches * hw.kernel_overhead_s,
    }
    t_coll = collective_bytes / hw.ici_bw
    if collective_bytes:
        terms["collective"] = t_coll
    # max() keeps dict insertion order on ties -> deterministic label.
    bound = max(terms, key=terms.get)
    return {
        "t_compute_s": terms["compute"],
        "t_memory_s": terms["memory"],
        "t_launch_s": terms["launch"],
        "t_collective_s": t_coll,
        "bound": bound,
        "ceiling_s": max(terms.values()),
        "peak_flops": peak,
    }


@dataclasses.dataclass(frozen=True)
class ProfileRow:
    """One roofline judgement: a ``(tenant, kind[, group])`` window."""
    tenant: str
    kind: str
    group: int | None            # fusion-group id; None = whole window
    count: int
    measured_p50_s: float
    flops: float                 # planned work per window occurrence
    bytes: float
    launches: float
    t_compute_s: float
    t_memory_s: float
    t_launch_s: float
    ceiling_s: float
    bound: str                   # "compute" | "memory" | "launch"
    measured_lare: float | None = None   # primary-kind rows only
    planned_lare: float | None = None    # plan's static LARE, same layer

    @property
    def achieved_flops(self) -> float | None:
        """FLOP/s this window actually sustained (None: no finite time)."""
        if self.measured_p50_s <= 0 or not math.isfinite(self.measured_p50_s):
            return None
        return self.flops / self.measured_p50_s

    @property
    def achieved_bytes_per_s(self) -> float | None:
        if self.measured_p50_s <= 0 or not math.isfinite(self.measured_p50_s):
            return None
        return self.bytes / self.measured_p50_s

    @property
    def roofline_fraction(self) -> float | None:
        """``ceiling / measured`` clamped into ``(0, 1]``.

        1.0 means the window runs AT its roofline; the clamp absorbs
        timer jitter on sub-microsecond windows (measured below the model
        ceiling is a measurement artifact, not >100% efficiency).  None on
        zero-duration windows — a judgement needs a denominator."""
        if self.measured_p50_s <= 0 or not math.isfinite(self.measured_p50_s):
            return None
        if self.ceiling_s <= 0:
            return None
        return max(min(self.ceiling_s / self.measured_p50_s, 1.0), 1e-12)


def _dominant_layer(plan):
    """The layer carrying the most work (macs x repeat) — LARE's subject."""
    layers = getattr(plan, "layers", None) or ()
    best = None
    for l in layers:
        score = l.n_in * l.n_out * max(l.repeat, 1)
        if best is None or score > best[0]:
            best = (score, l)
    return best[1] if best else None


def _layer_share(plan, layer) -> float:
    """``layer``'s fraction of the plan's total estimated time (falls back
    to its MAC share when estimates are zero, e.g. hand-built plans)."""
    layers = getattr(plan, "layers", None) or ()
    est_total = sum((l.est_latency_s or 0.0) * max(l.repeat, 1)
                    for l in layers)
    if est_total > 0:
        return ((layer.est_latency_s or 0.0) * max(layer.repeat, 1)
                / est_total)
    mac_total = sum(l.n_in * l.n_out * max(l.repeat, 1) for l in layers)
    if mac_total > 0:
        return layer.n_in * layer.n_out * max(layer.repeat, 1) / mac_total
    return 1.0


def _measured_lare(plan, measured_p50_s: float):
    """(measured_lare, planned_lare) for the tenant's dominant layer.

    Injects the measured per-layer time as the AIE interval into the
    paper's Algorithm 1 (:func:`repro.core.lare.lare` clamps to the PL
    curve ends, so the result is always finite).  Returns (None, None)
    when the plan has no layers or the window has no finite duration.
    The plan's static per-layer ``lare`` rides along for comparison
    (negative = the planner's not-computed sentinel -> None)."""
    layer = _dominant_layer(plan)
    planned = getattr(layer, "lare", None)
    if planned is not None and (planned < 0 or not math.isfinite(planned)):
        planned = None
    if layer is None or measured_p50_s <= 0 \
            or not math.isfinite(measured_p50_s):
        return None, planned
    interval = measured_p50_s * _layer_share(plan, layer)
    batch = max(int(getattr(plan, "batch", 8) or 8), 1)
    res = _lare(layer.n_in, layer.n_out, batch=batch,
                aie_interval_s=interval)
    return res.lare, planned


def _plan_work(plan):
    """``plan.work()`` when the plan carries layers; None for duck-typed
    stand-ins (tests pass bare objects with only ``est_latency_s``)."""
    work = getattr(plan, "work", None)
    if not callable(work) or not getattr(plan, "layers", None):
        return None
    return work()


def profile(plans: dict, stats_or_spans, *, hw=None) -> list:
    """Join measured span windows against plan-derived roofline work.

    ``plans`` maps tenant id to its :class:`DeploymentPlan`; the second
    argument is a span iterable or a pre-built
    :func:`repro.obs.attribution.aggregate` dict.  Returns
    :class:`ProfileRow` s: one per measured ``(tenant, kind)`` window with
    a profile-priced kind, plus one per fusion group under the tenant's
    primary kind (group measured time apportioned from the window p50 by
    the group's share of the plan estimate).  Tenants with no measured
    spans produce no rows; plans without layer detail are skipped."""
    stats = (stats_or_spans if isinstance(stats_or_spans, dict)
             else aggregate(stats_or_spans))
    rows: list[ProfileRow] = []
    for (tenant, kind), agg in sorted(stats.items()):
        if kind not in PROFILE_KINDS:
            continue
        plan = plans.get(tenant)
        if plan is None:
            continue
        work = _plan_work(plan)
        if work is None:
            continue
        itemsize = work["itemsize"]
        p50 = agg.get("p50_s", 0.0)
        count = agg.get("count", 0)
        scale = 1.0
        if kind == "prefill_chunk":
            toks = agg.get("tokens", 0)
            # prefill runs the decode forward once per token in the chunk
            scale = (toks / count) if (count and toks) else 1.0
        flops = work["flops"] * scale
        nbytes = work["bytes"] * scale
        launches = work["launches"] * scale
        terms = roofline_terms(flops, nbytes, launches,
                               itemsize=itemsize, hw=hw)
        mlare = plare = None
        if kind in _PRIMARY_KINDS:
            mlare, plare = _measured_lare(plan, p50)
        rows.append(ProfileRow(
            tenant=tenant, kind=kind, group=None, count=count,
            measured_p50_s=p50, flops=flops, bytes=nbytes,
            launches=launches, t_compute_s=terms["t_compute_s"],
            t_memory_s=terms["t_memory_s"],
            t_launch_s=terms["t_launch_s"],
            ceiling_s=terms["ceiling_s"], bound=terms["bound"],
            measured_lare=mlare, planned_lare=plare))
        if kind in _PRIMARY_KINDS and len(work["per_group"]) > 1:
            rows.extend(_group_rows(tenant, kind, agg, work,
                                    itemsize=itemsize, hw=hw))
    rows.sort(key=lambda r: (r.tenant, r.kind,
                             -1 if r.group is None else r.group))
    return rows


def _group_rows(tenant: str, kind: str, agg: dict, work: dict, *,
                itemsize: int, hw=None) -> list:
    """Per-fusion-group rows under one measured primary window.

    The engines time the whole fused step, not each ``pallas_call``, so
    group *measured* time is apportioned from the window p50 by the
    group's share of the plan estimate (falling back to FLOP share) —
    exact enough to rank groups and classify their bound, which is what
    the fused-decode-step before/after comparison needs."""
    p50 = agg.get("p50_s", 0.0)
    count = agg.get("count", 0)
    groups = work["per_group"]
    est_total = sum(g.get("est_latency_s") or 0.0 for g in groups)
    flop_total = sum(g["flops"] for g in groups) or 1.0
    rows = []
    for g in groups:
        if est_total > 0:
            share = (g.get("est_latency_s") or 0.0) / est_total
        else:
            share = g["flops"] / flop_total
        g_bytes = g["weight_bytes"] + g["act_bytes"]
        terms = roofline_terms(g["flops"], g_bytes, g["launches"],
                               itemsize=itemsize, hw=hw)
        rows.append(ProfileRow(
            tenant=tenant, kind=kind, group=g["id"], count=count,
            measured_p50_s=p50 * share, flops=g["flops"], bytes=g_bytes,
            launches=g["launches"], t_compute_s=terms["t_compute_s"],
            t_memory_s=terms["t_memory_s"],
            t_launch_s=terms["t_launch_s"],
            ceiling_s=terms["ceiling_s"], bound=terms["bound"]))
    return rows


# ---------------------------------------------------------------------------
# Report formatting
# ---------------------------------------------------------------------------

def _fmt_rate(v: float | None, unit: float, suffix: str) -> str:
    return f"{v / unit:8.1f}{suffix}" if v is not None else f"{'-':>10}"


def format_profile(rows: list) -> str:
    """Human-readable roofline table (the ``repro profile`` report)."""
    if not rows:
        return "profile: no measured windows (run traffic first)"
    tenant_w = max([18] + [len(r.tenant) + 1 for r in rows])
    lines = [f"{'tenant':<{tenant_w}}{'window':<18}{'n':>6}{'p50':>12}"
             f"{'ceiling':>12}{'GFLOP/s':>10}{'GB/s':>10}"
             f"{'frac':>7}  {'bound':<8}{'mLARE':>9}{'pLARE':>9}"]
    for r in rows:
        window = r.kind if r.group is None else f"{r.kind}/g{r.group}"
        frac = (f"{r.roofline_fraction:6.3f}"
                if r.roofline_fraction is not None else f"{'-':>6}")
        mlare = (f"{r.measured_lare:8.1f}" if r.measured_lare is not None
                 else f"{'-':>8}")
        plare = (f"{r.planned_lare:8.1f}" if r.planned_lare is not None
                 else f"{'-':>8}")
        lines.append(
            f"{r.tenant:<{tenant_w}}{window:<18}{r.count:>6}"
            f"{r.measured_p50_s * 1e6:10.1f}us"
            f"{r.ceiling_s * 1e6:10.1f}us"
            f"{_fmt_rate(r.achieved_flops, 1e9, '')}"
            f"{_fmt_rate(r.achieved_bytes_per_s, 1e9, '')}"
            f"{frac}  {r.bound:<8}{mlare}{plare}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Trend-gateable snapshots
# ---------------------------------------------------------------------------

def _derived_terms(r: ProfileRow) -> str:
    """Roofline-term breakdown embedded in the ``derived`` field so
    ``benchmarks/trend.py --explain`` can attribute a regression to the
    term that moved (values in us, fixed 4-decimal rounding)."""
    return (f"bound={r.bound};"
            f"t_compute_us={round(r.t_compute_s * 1e6, 4)};"
            f"t_memory_us={round(r.t_memory_s * 1e6, 4)};"
            f"t_launch_us={round(r.t_launch_s * 1e6, 4)}")


def write_profile_snapshots(rows: list, json_dir, *,
                            meta: dict | None = None) -> list:
    """Export profile rows as per-tenant ``BENCH_profile_<net>.json``.

    Same snapshot format as :func:`repro.serve.metrics.
    write_serve_snapshots` so :mod:`benchmarks.trend` diffs/gates them.
    Two row families per tenant window:

    * ``profile/<net>/<kind>/ceiling`` — ``src=model``: pure function of
      the plan and the machine-model constants, byte-identical across
      runs under ``--machine-model stock``, so it GATES.  The ``derived``
      string carries the term breakdown ``--explain`` diffs.
    * ``profile/<net>/<kind>/p50`` and ``.../lare_measured`` —
      ``src=measured``: reported for trend visibility, never gated.

    Zero/non-finite measured values are skipped (a 0.0 row reads as a
    regression-to-zero in the diff)."""
    from repro.serve.metrics import _safe_net_name
    out_dir = pathlib.Path(json_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    by_tenant: dict[str, list] = {}
    for r in rows:
        by_tenant.setdefault(r.tenant, []).append(r)
    paths = []
    for tenant, trs in sorted(by_tenant.items()):
        out_rows = []
        for r in trs:
            window = r.kind if r.group is None else f"{r.kind}/g{r.group}"
            out_rows.append({
                "name": f"profile/{tenant}/{window}/ceiling",
                "us_per_call": round(r.ceiling_s * 1e6, 4),
                "derived": f"src=model;{_derived_terms(r)}",
            })
            if r.measured_p50_s > 0 and math.isfinite(r.measured_p50_s):
                out_rows.append({
                    "name": f"profile/{tenant}/{window}/p50",
                    "us_per_call": round(r.measured_p50_s * 1e6, 3),
                    "derived": f"src=measured;count={r.count};"
                               f"bound={r.bound}",
                })
            if r.group is None and r.planned_lare is not None \
                    and math.isfinite(r.planned_lare):
                out_rows.append({
                    "name": f"profile/{tenant}/lare_planned",
                    "us_per_call": round(r.planned_lare, 4),
                    "derived": "src=model;unit=dsp_equiv",
                })
            if r.measured_lare is not None \
                    and math.isfinite(r.measured_lare):
                out_rows.append({
                    "name": f"profile/{tenant}/lare_measured",
                    "us_per_call": round(r.measured_lare, 4),
                    "derived": "src=measured;unit=dsp_equiv",
                })
        payload = {"meta": {"net_id": tenant, **(meta or {})},
                   "rows": out_rows}
        p = out_dir / f"BENCH_profile_{_safe_net_name(tenant)}.json"
        p.write_text(json.dumps(payload, indent=2, sort_keys=True,
                                allow_nan=False) + "\n")
        paths.append(p)
    return paths
