"""SLO monitor: per-tenant tail-latency budgets, burn rates, priorities.

The serving stack enforces a *mean-style* budget (``TenantMetrics``
violation streaks + shedding).  This module adds the tail-side contract: a
:class:`SloBudget` per tenant (p95/p99 ceilings derived from the plan's
serve section, plus a **priority class**), and a :class:`SloMonitor` that
watches every completed request and answers three questions the scheduler
and the reports ask:

* *is this tenant currently violating its p95/p99 SLO?* — edge-triggered
  :class:`SloViolation` events (surfaced in ``Deployment.summary()``, the
  Prometheus export and the attribution table; each event also lands as a
  zero-duration ``slo/violation`` audit span when a tracer is attached);
* *how fast is it burning error budget?* — dual rolling **burn-rate**
  windows (a short *fast* window that reacts within tens of requests, a
  long *slow* window that filters one-off spikes), the multiwindow
  alerting shape from SRE practice: burn rate 1.0 means "violating exactly
  the allowed fraction", ``burn_alert`` (default 2.0) on the fast window
  marks the tenant :meth:`at_risk`;
* *who should yield?* — :data:`PRIORITY_CLASSES` orders tenants
  (``critical`` < ``standard`` < ``batch``); :meth:`pressure_rank` is the
  best (lowest) rank among at-risk tenants, and the router defers
  admission for strictly lower-priority tenants while pressure holds
  (bounded by an aging limit, so deferral can never starve a drain).

No jax imports here: like :mod:`repro.obs.trace`, this module must stay
cheap to import and safe to use from any layer.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Iterable

from repro.obs.trace import NULL_TRACER, percentile

# Lower rank = more important.  The names are the values plans/tenants use
# in their serve sections — keep them boring and stable.
PRIORITY_CLASSES = ("critical", "standard", "batch")


def priority_rank(name: str) -> int:
    """Numeric rank for a priority class (0 = most important)."""
    try:
        return PRIORITY_CLASSES.index(name)
    except ValueError:
        raise ValueError(f"unknown priority class {name!r}; choose from "
                         f"{PRIORITY_CLASSES}") from None


def _finite_or_none(x):
    if x is None:
        return None
    x = float(x)
    return x if math.isfinite(x) else None


@dataclasses.dataclass
class SloBudget:
    """One tenant's tail-latency contract: p95/p99 ceilings + priority."""
    tenant: str
    p95_s: float = math.inf
    p99_s: float = math.inf
    priority: str = "standard"

    def __post_init__(self):
        priority_rank(self.priority)          # validate early
        if self.p95_s <= 0 or self.p99_s <= 0:
            raise ValueError(f"SLO budgets must be > 0 "
                             f"(tenant {self.tenant!r}: p95={self.p95_s}, "
                             f"p99={self.p99_s})")

    @property
    def rank(self) -> int:
        return priority_rank(self.priority)

    @classmethod
    def from_plan(cls, tenant: str, plan,
                  latency_budget_s: float | None = None) -> "SloBudget":
        """Derive the contract from a plan's serve section.

        ``serve["slo"]`` (written by the fleet planner) wins; absent that —
        older cached plans, hand-built fleets — the mean-style
        ``latency_budget_s`` seeds p95 with p99 at 1.5x, so every tenant
        always has *some* tail contract."""
        serve = getattr(plan, "serve", None) or {}
        slo = serve.get("slo") or {}
        p95 = slo.get("p95_s", latency_budget_s)
        if p95 is None:
            p95 = math.inf
        p99 = slo.get("p99_s", 1.5 * p95 if math.isfinite(p95) else math.inf)
        priority = serve.get("priority")
        if priority is None:
            kind = getattr(plan, "kind", "edge")
            priority = "critical" if kind == "edge" else "standard"
        return cls(tenant=tenant, p95_s=p95, p99_s=p99, priority=priority)


@dataclasses.dataclass(frozen=True)
class SloViolation:
    """One edge-triggered violation event (entering the violating state)."""
    tenant: str
    slo: str                  # "p95" | "p99"
    measured_s: float
    budget_s: float
    count: int                # window samples when the event fired
    at_s: float               # perf_counter stamp


class SloMonitor:
    """Rolling per-tenant SLO evaluation over completed-request latencies.

    Feed it with :meth:`observe` (the router does, for every edge inference
    and every drained LM request); read :meth:`at_risk` /
    :meth:`pressure_rank` from the scheduler and :meth:`snapshot` /
    :attr:`violations` from the reports.  ``burn rate`` follows the SRE
    convention: (fraction of window samples over the p95 budget) divided by
    the 5% the p95 contract allows — 1.0 is "exactly at contract", and the
    fast window crossing ``burn_alert`` marks the tenant at risk.
    """

    #: Error budget of a p95 contract: 5% of requests may exceed it.
    P95_ERROR_BUDGET = 0.05

    def __init__(self, budgets: Iterable[SloBudget], *, window: int = 256,
                 fast_window: int = 32, slow_window: int = 128,
                 min_samples: int = 20, burn_alert: float = 2.0,
                 tracer=None):
        self.budgets: dict[str, SloBudget] = {}
        for b in budgets:
            if b.tenant in self.budgets:
                raise ValueError(f"duplicate SLO budget for {b.tenant!r}")
            self.budgets[b.tenant] = b
        self.window = window
        self.fast_window = fast_window
        self.slow_window = slow_window
        self.min_samples = min_samples
        self.burn_alert = burn_alert
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.reset()

    @classmethod
    def from_fleet(cls, fleet, *, tracer=None, **kw) -> "SloMonitor":
        """One budget per fleet tenant, from each plan's serve section."""
        budgets = [SloBudget.from_plan(tp.net_id, tp.plan,
                                       latency_budget_s=tp.latency_budget_s)
                   for tp in fleet.tenants]
        return cls(budgets, tracer=tracer, **kw)

    def reset(self):
        """Drop observations and events (e.g. after jit warmup); the
        budgets themselves are configuration and survive."""
        self._lat = {t: collections.deque(maxlen=self.window)
                     for t in self.budgets}
        # Burn windows hold booleans: "was this sample over the p95 budget".
        self._fast = {t: collections.deque(maxlen=self.fast_window)
                      for t in self.budgets}
        self._slow = {t: collections.deque(maxlen=self.slow_window)
                      for t in self.budgets}
        self._in_violation = {t: set() for t in self.budgets}
        self.violations: list[SloViolation] = []

    def set_budget(self, tenant: str, *, p95_s: float | None = None,
                   p99_s: float | None = None,
                   priority: str | None = None):
        """Tighten/relax one tenant's contract at runtime — or add a tenant
        the monitor was not built with (the CLI's ``--underbudget`` fault
        injection uses this)."""
        b = self.budgets.get(tenant) or SloBudget(tenant)
        self.budgets[tenant] = dataclasses.replace(
            b,
            p95_s=b.p95_s if p95_s is None else p95_s,
            p99_s=b.p99_s if p99_s is None else p99_s,
            priority=b.priority if priority is None else priority)
        self._ensure(tenant)

    def _ensure(self, tenant: str):
        """Window state for a tenant added after construction (budgets are
        a dict on purpose: fault injection and tests extend them live)."""
        self._lat.setdefault(tenant, collections.deque(maxlen=self.window))
        self._fast.setdefault(tenant,
                              collections.deque(maxlen=self.fast_window))
        self._slow.setdefault(tenant,
                              collections.deque(maxlen=self.slow_window))
        self._in_violation.setdefault(tenant, set())

    # -- feeding ----------------------------------------------------------
    def observe(self, tenant: str, latency_s: float):
        """One completed request.  Unknown tenants and non-finite samples
        are ignored (the metrics layer already counts poisoned timers)."""
        b = self.budgets.get(tenant)
        if b is None or not math.isfinite(latency_s):
            return
        self._ensure(tenant)
        self._lat[tenant].append(latency_s)
        over = latency_s > b.p95_s
        self._fast[tenant].append(over)
        self._slow[tenant].append(over)
        self._check(tenant, b)

    def _check(self, tenant: str, b: SloBudget):
        lat = self._lat[tenant]
        if len(lat) < self.min_samples:
            return
        for slo, q, budget in (("p95", 0.95, b.p95_s),
                               ("p99", 0.99, b.p99_s)):
            if not math.isfinite(budget):
                continue
            measured = percentile(lat, q)
            state = self._in_violation[tenant]
            if measured > budget:
                if slo in state:        # still violating: no new event
                    continue
                state.add(slo)
                now = time.perf_counter()
                ev = SloViolation(tenant=tenant, slo=slo,
                                  measured_s=measured, budget_s=budget,
                                  count=len(lat), at_s=now)
                self.violations.append(ev)
                if self.tracer.enabled:
                    # Zero-duration audit span: the violation edge is an
                    # event, not an interval.
                    self.tracer.add("slo/violation", now, now,
                                    tenant=tenant, slo=slo,
                                    measured_us=round(measured * 1e6, 3),
                                    budget_us=round(budget * 1e6, 3))
            else:
                state.discard(slo)      # re-arm once back under budget

    # -- scheduler queries -------------------------------------------------
    def burn_rate(self, tenant: str, window: str = "fast") -> float:
        """Error-budget burn over the named window (0.0 with no signal)."""
        win = (self._fast if window == "fast" else self._slow).get(tenant)
        if not win:
            return 0.0
        return (sum(win) / len(win)) / self.P95_ERROR_BUDGET

    def at_risk(self, tenant: str) -> bool:
        """True while the tenant's fast burn window says the p95 contract
        is being actively burned (both windows must agree once the slow one
        has signal, the multiwindow rule that keeps one spike from flapping
        the scheduler)."""
        win = self._fast.get(tenant)
        if win is None or len(win) < min(self.fast_window, self.min_samples):
            return False
        if self.burn_rate(tenant, "fast") < self.burn_alert:
            return False
        slow = self._slow[tenant]
        if len(slow) >= self.slow_window:
            return self.burn_rate(tenant, "slow") >= 1.0
        return True

    def pressure_rank(self) -> int | None:
        """The best (lowest) priority rank among at-risk tenants — the bar
        the router's deferral policy compares lower priorities against.
        None when nobody is at risk."""
        ranks = [b.rank for t, b in self.budgets.items() if self.at_risk(t)]
        return min(ranks) if ranks else None

    # -- reporting ---------------------------------------------------------
    def violation_counts(self) -> dict[str, int]:
        out: dict[str, int] = {t: 0 for t in self.budgets}
        for ev in self.violations:
            out[ev.tenant] = out.get(ev.tenant, 0) + 1
        return out

    def snapshot(self) -> dict[str, dict]:
        """Per-tenant state for exporters: budgets, measured tails, burn
        rates, event counts.  Every value is finite or None (strict-JSON
        safe)."""
        counts = self.violation_counts()
        out = {}
        for tenant, b in self.budgets.items():
            self._ensure(tenant)
            lat = self._lat[tenant]
            out[tenant] = {
                "priority": b.priority,
                "p95_budget_s": _finite_or_none(b.p95_s),
                "p99_budget_s": _finite_or_none(b.p99_s),
                "p95_s": percentile(lat, 0.95) if lat else 0.0,
                "p99_s": percentile(lat, 0.99) if lat else 0.0,
                "count": len(lat),
                "burn_fast": self.burn_rate(tenant, "fast"),
                "burn_slow": self.burn_rate(tenant, "slow"),
                "violations": counts.get(tenant, 0),
                "in_violation": bool(self._in_violation[tenant]),
                "at_risk": self.at_risk(tenant),
            }
        return out
