"""Span/trace primitives: the measurement substrate under the serving stack.

A :class:`Span` is one named host-side interval (``perf_counter`` based)
with an optional **trace id** — the request id that lets a request's
``queue -> prefill_chunk -> decode_step`` decomposition be reassembled from
the flat span stream — plus free-form attributes (tenant, token counts,
cache-hit flags).  A :class:`Tracer` is an append-only, bounded span sink
that the serving runtime (:mod:`repro.serve`), the deployment stages
(:mod:`repro.deploy`) and the characterization harness all emit into.

Overhead discipline: every emit site in a hot path guards on
``tracer.enabled`` (one attribute read) before doing any work, and the
shared :data:`NULL_TRACER` used as the default is permanently disabled —
tracing-off dispatch costs one branch (guarded by a micro-test in
``tests/test_obs.py``).  No jax imports here: the module must stay cheap to
import and safe to use from any layer.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Iterable


@dataclasses.dataclass(frozen=True)
class Span:
    """One completed interval: ``[t0_s, t0_s + dur_s]`` on this host's
    ``perf_counter`` clock (monotonic; comparable only within a process)."""
    name: str                       # span kind: "decode_step", "queue", ...
    t0_s: float
    dur_s: float
    trace_id: int | str | None = None   # request id (None = engine-level)
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def t1_s(self) -> float:
        return self.t0_s + self.dur_s

    def to_dict(self) -> dict:
        return {"name": self.name, "t0_s": self.t0_s, "dur_s": self.dur_s,
                "trace_id": self.trace_id, "attrs": dict(self.attrs)}


class _SpanCtx:
    """Context manager recording one span on exit (exceptions included —
    a span that died is still time the caller spent)."""
    __slots__ = ("_tracer", "_name", "_trace", "_attrs", "_t0")

    def __init__(self, tracer, name, trace, attrs):
        self._tracer, self._name = tracer, name
        self._trace, self._attrs = trace, attrs

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tracer.add(self._name, self._t0, time.perf_counter(),
                         trace=self._trace, **self._attrs)
        return False


class _NoopCtx:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP_CTX = _NoopCtx()


class Tracer:
    """Bounded, thread-safe span sink.

    ``maxlen`` caps memory for long-lived serving loops: once full, new
    spans are counted in :attr:`dropped` instead of appended (the exporters
    surface the truncation rather than silently pretending full coverage).
    """

    def __init__(self, *, enabled: bool = True, maxlen: int = 100_000):
        self.enabled = enabled
        self.maxlen = maxlen
        self.dropped = 0
        self._spans: list[Span] = []
        self._lock = threading.Lock()
        self._trace_ids = itertools.count(1)

    # -- emission ---------------------------------------------------------
    def span(self, name: str, *, trace=None, **attrs):
        """Context manager timing the enclosed block.  With the tracer
        disabled this returns a shared no-op (no allocation, no clock)."""
        if not self.enabled:
            return _NOOP_CTX
        return _SpanCtx(self, name, trace, attrs)

    def add(self, name: str, t0_s: float, t1_s: float, *, trace=None,
            **attrs) -> None:
        """Record an explicit interval (e.g. queue wait measured between a
        submit and an admit that happen in different call frames)."""
        if not self.enabled:
            return
        s = Span(name=name, t0_s=t0_s, dur_s=max(t1_s - t0_s, 0.0),
                 trace_id=trace, attrs=attrs)
        with self._lock:
            if len(self._spans) >= self.maxlen:
                self.dropped += 1
                return
            self._spans.append(s)

    def next_trace_id(self) -> int:
        """A fresh per-tracer trace id (for callers without a request id)."""
        return next(self._trace_ids)

    # -- access -----------------------------------------------------------
    @property
    def spans(self) -> list[Span]:
        """A snapshot copy — safe to iterate while serving continues."""
        with self._lock:
            return list(self._spans)

    def by_trace(self, trace_id) -> list[Span]:
        return [s for s in self.spans if s.trace_id == trace_id]

    def by_name(self, name: str) -> list[Span]:
        return [s for s in self.spans if s.name == name]

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0

    def __len__(self) -> int:
        return len(self._spans)

    def __bool__(self) -> bool:            # "if tracer:" == "is tracing on"
        return self.enabled


class _NullTracer(Tracer):
    """The permanently-disabled default.  Shared process-wide, so it must be
    impossible to flip on by accident (``enabled`` writes are ignored)."""

    def __init__(self):
        super().__init__(enabled=False, maxlen=0)

    @property
    def enabled(self) -> bool:
        return False

    @enabled.setter
    def enabled(self, _value) -> None:     # silently refuse: stay disabled
        pass


NULL_TRACER = _NullTracer()


def percentile(xs: Iterable[float], q: float) -> float:
    """Nearest-rank percentile over a finite sample; 0.0 on empty input.
    The same convention ``TenantMetrics`` uses, shared so span aggregates
    and tenant metrics never disagree on what "p95" means."""
    xs = sorted(xs)
    if not xs:
        return 0.0
    if q <= 0:
        return xs[0]
    import math
    return xs[min(len(xs) - 1, int(math.ceil(q * len(xs))) - 1)]


def summarize(durs: Iterable[float]) -> dict[str, Any]:
    """count/mean/p50/p95/total over a duration sample (seconds)."""
    xs = sorted(durs)
    n = len(xs)
    total = sum(xs)
    return {
        "count": n,
        "total_s": total,
        "mean_s": total / n if n else 0.0,
        "p50_s": xs[n // 2] if n else 0.0,
        "p95_s": percentile(xs, 0.95),
    }
