"""Runtime knobs threaded through model code: remat policy + quantized params.

``maybe_remat`` wraps scan bodies with ``jax.checkpoint`` according to the
active policy ("none" | "block" | "dots"); ``maybe_dequant`` transparently
expands int8-quantized weight leaves ({"q8", "scale"} marker dicts) inside the
per-layer scan body, so at-rest HBM holds int8 while only one layer's weights
ever exist in bf16 — the pjit-path analogue of the fused ``gemm_int8`` kernel.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Callable

import jax
import jax.numpy as jnp

_REMAT: contextvars.ContextVar[str] = contextvars.ContextVar(
    "repro_remat", default="none")


@contextlib.contextmanager
def remat_policy(policy: str):
    assert policy in ("none", "block", "dots")
    tok = _REMAT.set(policy)
    try:
        yield
    finally:
        _REMAT.reset(tok)


def maybe_remat(f: Callable) -> Callable:
    pol = _REMAT.get()
    if pol == "none":
        return f
    if pol == "block":
        return jax.checkpoint(f)
    return jax.checkpoint(
        f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def is_q8(leaf) -> bool:
    return isinstance(leaf, dict) and set(leaf) == {"q8", "scale"}


def dequant(leaf, dtype=jnp.bfloat16):
    return (leaf["q8"].astype(jnp.float32)
            * leaf["scale"].astype(jnp.float32)).astype(dtype)


def maybe_dequant(tree, dtype=jnp.bfloat16):
    """Expand {"q8","scale"} marker dicts into dense weights (no-op otherwise)."""
    if not isinstance(tree, dict):
        return tree
    if is_q8(tree):
        return dequant(tree, dtype)
    return {k: maybe_dequant(v, dtype) if isinstance(v, dict) else v
            for k, v in tree.items()}
