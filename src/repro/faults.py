"""Deterministic fault taxonomy + injection for the serving stack.

Production fleets fail *partially*: one tenant's engine throws, a cache
write is cut short, a model emits NaNs after a bad weight push.  This
module makes those failures first-class and — critically — *injectable
on purpose*, so the resilience machinery in :mod:`repro.serve.resilience`
is testable instead of aspirational.

The design mirrors the PR-7 scenario generators: a :class:`FaultPlan` is
a small JSON-serializable schedule, optionally drawn from
``random.Random(seed)``, so the same seed always produces the same fault
sequence.  A :class:`FaultInjector` executes the schedule by counting
invocations of named *hook sites* threaded through the runtime
(``Router``, ``EdgeEngine``, ``ContinuousBatcher``, ``PlanCache``,
``Deployment.build``) and answering "does a fault fire on THIS call?".
Hook sites are pure probes — an unarmed runtime (``injector is None``)
pays one attribute check and nothing else.

Fault taxonomy
==============

=================== =================== =====================================
kind                default site        effect at the hook
=================== =================== =====================================
engine_exception    engine.infer        raise :class:`InjectedFault`
latency_spike       engine.infer        sleep ``magnitude_s`` inside the call
non_finite_output   engine.infer        poison the output tensor with NaN
batcher_stall       batcher.tick        the batcher skips this tick entirely
replan_failure      replan              drift-watcher replan raises
cache_corruption    cache.read          cached plan artifact reads corrupt
=================== =================== =====================================

Everything here is pure stdlib (no jax) so the plan layer can import the
resilience knob defaults without touching the runtime.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import random

FAULT_KINDS = ("engine_exception", "latency_spike", "non_finite_output",
               "batcher_stall", "replan_failure", "cache_corruption")

HOOK_SITES = ("engine.infer", "batcher.tick", "batcher.decode", "replan",
              "cache.read", "build")

#: The hook site each fault kind targets when the spec doesn't name one.
DEFAULT_SITE = {
    "engine_exception": "engine.infer",
    "latency_spike": "engine.infer",
    "non_finite_output": "engine.infer",
    "batcher_stall": "batcher.tick",
    "replan_failure": "replan",
    "cache_corruption": "cache.read",
}

#: Per-tenant resilience knobs the planner writes into ``serve["resilience"]``
#: (and the Supervisor falls back to for plans predating PLANNER_VERSION
#: plan-6).  ``breaker_k``: consecutive failures that open the circuit;
#: ``breaker_cooldown``: refusals while open before a half-open probe is
#: admitted (count-based, like the router's shed probe, so tests and replays
#: are deterministic); ``retries``/``backoff_s``: bounded retry for transient
#: engine faults; ``deadline_factor``: per-request deadline as a multiple of
#: the plan's ``serve["slo"]["p95_s"]`` budget (overruns are *audited*, not
#: breaker-fed — planned budgets are modeled accelerator time, host
#: wall-clock overshoots them without the tenant being sick).
RESILIENCE_DEFAULTS = {
    "breaker_k": 3,
    "breaker_cooldown": 8,
    "retries": 1,
    "backoff_s": 0.0,
    "deadline_factor": 4.0,
}


class InjectedFault(RuntimeError):
    """A fault fired by a :class:`FaultInjector` (deliberate, for tests)."""


class NonFiniteOutput(RuntimeError):
    """A model produced NaN/Inf outputs; the request fails instead of
    returning garbage (extends the PR-6 rule that metrics reject
    non-finite observations)."""


def fault_kind(exc: BaseException) -> str:
    """Short classification label for a caught fault, used in
    ``fault/<kind>`` span names and health counters."""
    if isinstance(exc, NonFiniteOutput):
        return "non_finite"
    if isinstance(exc, InjectedFault):
        return "injected"
    return "exception"


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: fire ``count`` times at a hook site, starting
    on the ``after``-th invocation of that (site, tenant) hook.

    ``tenant=None`` matches any tenant at the site.  ``magnitude_s`` is
    the spike duration for ``latency_spike`` and ignored otherwise.
    """

    kind: str
    site: str = ""
    tenant: str | None = None
    after: int = 0
    count: int = 1
    magnitude_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if not self.site:
            object.__setattr__(self, "site", DEFAULT_SITE[self.kind])
        if self.site not in HOOK_SITES:
            raise ValueError(f"unknown hook site {self.site!r}; "
                             f"expected one of {HOOK_SITES}")
        if self.after < 0 or self.count < 1:
            raise ValueError(f"need after >= 0 and count >= 1, got "
                             f"after={self.after} count={self.count}")

    def matches(self, site: str, tenant: str | None, n: int) -> bool:
        """Does this spec fire on invocation ``n`` of (site, tenant)?"""
        return (self.site == site
                and (self.tenant is None or self.tenant == tenant)
                and self.after <= n < self.after + self.count)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSpec":
        return cls(**{k: d[k] for k in
                      ("kind", "site", "tenant", "after", "count",
                       "magnitude_s") if k in d})


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A JSON-serializable fault schedule.

    Build one by hand from :class:`FaultSpec`, as a targeted
    :meth:`burst` (the chaos CLI's shape: N consecutive engine faults on
    one tenant), or draw a randomized-but-reproducible schedule with
    :meth:`generate` — same seed, same faults, always.
    """

    faults: tuple = ()
    seed: int | None = None

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(
            f if isinstance(f, FaultSpec) else FaultSpec.from_dict(f)
            for f in self.faults))

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)

    def scheduled(self, tenant: str | None = None,
                  kind: str | None = None) -> int:
        """Total faults this plan can fire — a pure function of the plan
        (deterministic: safe to trend-gate as a model row)."""
        return sum(f.count for f in self.faults
                   if (tenant is None or f.tenant in (None, tenant))
                   and (kind is None or f.kind == kind))

    # -- construction -----------------------------------------------------
    @classmethod
    def burst(cls, tenant: str, *, kind: str = "engine_exception",
              after: int = 8, count: int = 6,
              magnitude_s: float = 0.0) -> "FaultPlan":
        """N consecutive faults of one kind on one tenant — enough to
        open its breaker, then stop so the half-open probe re-closes it."""
        return cls(faults=(FaultSpec(kind=kind, tenant=tenant, after=after,
                                     count=count, magnitude_s=magnitude_s),))

    @classmethod
    def generate(cls, tenants, *, seed: int = 0, n_faults: int = 6,
                 kinds=("engine_exception", "latency_spike",
                        "non_finite_output", "batcher_stall"),
                 window: tuple = (4, 64),
                 magnitude_s: float = 0.002) -> "FaultPlan":
        """Draw a reproducible random schedule over ``tenants``.

        Seeded like the PR-7 scenario generators
        (``random.Random(f"{seed}:faults")``) so schedules are stable
        across hosts and runs.
        """
        rng = random.Random(f"{seed}:faults")
        tenants = list(tenants)
        faults = []
        for _ in range(n_faults):
            kind = rng.choice(list(kinds))
            faults.append(FaultSpec(
                kind=kind, tenant=rng.choice(tenants),
                after=rng.randrange(window[0], window[1]),
                magnitude_s=magnitude_s if kind == "latency_spike" else 0.0))
        return cls(faults=tuple(faults), seed=seed)

    # -- (de)serialization ------------------------------------------------
    def to_dict(self) -> dict:
        return {"version": 1, "seed": self.seed,
                "faults": [f.to_dict() for f in self.faults]}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        return cls(faults=tuple(FaultSpec.from_dict(f)
                                for f in d.get("faults", ())),
                   seed=d.get("seed"))

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls.from_dict(json.loads(text))

    def save(self, path) -> pathlib.Path:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_json() + "\n")
        return p

    @classmethod
    def load(cls, path) -> "FaultPlan":
        return cls.from_json(pathlib.Path(path).read_text())


class FaultInjector:
    """Executes a :class:`FaultPlan` against the runtime's hook sites.

    Each hook calls :meth:`fire(site, tenant)` once per event; the
    injector counts invocations per (site, tenant) and returns the
    matching :class:`FaultSpec` when the schedule says this call faults
    (else ``None``).  Every fired fault is appended to :attr:`log` —
    tests and the chaos report read it to know exactly what happened.
    """

    def __init__(self, plan: FaultPlan | None = None):
        self.plan = plan if plan is not None else FaultPlan()
        self.calls: dict = {}          # (site, tenant) -> invocation count
        self.log: list = []            # fired events, in order

    def fire(self, site: str, tenant: str | None = None):
        key = (site, tenant)
        n = self.calls.get(key, 0)
        self.calls[key] = n + 1
        for spec in self.plan.faults:
            if spec.matches(site, tenant, n):
                self.log.append({"kind": spec.kind, "site": site,
                                 "tenant": tenant, "call": n})
                return spec
        return None

    def fired(self, tenant: str | None = None,
              kind: str | None = None) -> int:
        """How many faults actually fired (optionally filtered)."""
        return sum(1 for e in self.log
                   if (tenant is None or e["tenant"] == tenant)
                   and (kind is None or e["kind"] == kind))
