"""Core: the paper's contribution — tiling planner, LARE metric, boundary cost."""
from repro.core import boundary, lare, tiling  # noqa: F401
