"""Two-level GEMM tiling (paper Algorithm 2) — AIE-faithful model + TPU planner.

The paper decomposes an ``A(M,K) @ B(K,N)`` workload twice:

* **spatial level** — across ``P_K x P_N`` compute tiles (K-parallel partial sums
  cascade west->east; N-parallel shards the output columns);
* **API level**   — within one tile into legal ``aie::mmul`` blocks
  ``(S_M,S_K,S_N)`` called ``(R_M,R_K,R_N)`` times.

This module provides both halves of the reproduction:

1. :func:`aie_tile_latency`, :func:`aie_spatial_latency` — the paper-faithful
   AIE-ML cost model (calibrated to Figs. 4-6) driving the micro-benchmark
   reproductions and the LARE metric.

2. :func:`plan_api`, :func:`plan_spatial`, :func:`plan_gemm` — the TPU-native
   planner.  API-level tiles become Pallas ``BlockSpec`` block shapes legal for
   the VREG/MXU tiling; spatial tiles become mesh shardings with an explicit
   collective-cost model.  The paper's design rules are re-derived for TPU and
   exposed as the planner's decision procedure (annotated on each plan).

TPU design-rule analogues (constants re-derived in EXPERIMENTS.md §4):

* **DR1'** default API tile: ``(bm, bk, bn)`` with ``bk=bn=512``-class blocks,
  ``bm`` = the padded batch (sublane multiple).  Chosen by VMEM-bounded search.
* **DR2'** favor N over K when trading block dims: larger ``bn`` keeps the
  output block (the accumulator) wide and amortizes A-tile re-reads.
* **DR3'** spatial expansion prefers K-sharding (reduction axis) while the
  per-device reduction payload stays small — mirrors cascade-first placement.
* **DR4'/DR5'** per-device workload knee and floor: below the floor the fixed
  dispatch + collective latency dominates and extra devices *hurt*.
* **DR6'** mesh-axis exhaustion: ``P_K`` beyond one mesh axis wraps onto the
  second ("band spill") and the reduction crosses the slow axis — penalized.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Sequence

from repro import hw as hwlib


# --------------------------------------------------------------------------
# Shared plan containers
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ApiPlan:
    """API-level (within-core) tiling: Pallas BlockSpec block shapes."""
    block_m: int
    block_k: int
    block_n: int
    r_m: int
    r_k: int
    r_n: int
    vmem_bytes: int
    est_s: float

    @property
    def blocks(self) -> tuple[int, int, int]:
        return (self.block_m, self.block_k, self.block_n)


@dataclasses.dataclass(frozen=True)
class SpatialPlan:
    """Spatial (across-core) tiling: mesh sharding factors for K and N."""
    p_k: int
    p_n: int
    q_k: int                      # per-device K extent
    q_n: int                      # per-device N extent
    bands: int                    # 1 == fits a single mesh axis (DR6')
    est_collective_s: float

    @property
    def tiles(self) -> int:
        return self.p_k * self.p_n


@dataclasses.dataclass(frozen=True)
class GemmPlan:
    m: int
    k: int
    n: int
    itemsize: int
    spatial: SpatialPlan
    api: ApiPlan
    est_s: float
    rules: tuple[str, ...]        # which design rules drove the decision


# --------------------------------------------------------------------------
# Paper-faithful AIE-ML cost model (calibrated to Figs. 4-6)
# --------------------------------------------------------------------------

_AIE_CALL_OVERHEAD_CYC = 6        # per aie::mmul macro-call loop overhead
_AIE_DMA_SETUP_CYC = 220          # per-tile DMA/lock setup per inference
_AIE_CASCADE_HOP_CYC = 14         # partial-sum hop west->east
# Band-spill contention lives on the machine model now
# (``AieMl.band2_penalty_per_layer``) so a fitted MachineModel can replace
# it; this alias keeps the historical name importable.
_AIE_BAND_PENALTY = hwlib.AIE_ML.band2_penalty_per_layer
_AIE_UNROLL = 2                   # manual 2x2x2 unrolling (paper IV-C)


def aie_api_legal(s: tuple[int, int, int], m: int, q_k: int, q_n: int,
                  aie: hwlib.AieMl = hwlib.AIE_ML) -> bool:
    s_m, s_k, s_n = s
    if (s_m, s_k, s_n) not in aie.legal_api_tiles_i8:
        return False
    # 2x unrolling makes the effective tile twice the base size per dim.
    return (m % (s_m * _AIE_UNROLL) == 0 and q_k % (s_k * _AIE_UNROLL) == 0
            and q_n % (s_n * _AIE_UNROLL) == 0)


def aie_tile_latency(m: int, q_k: int, q_n: int,
                     s: tuple[int, int, int] = (4, 8, 8),
                     aie: hwlib.AieMl = hwlib.AIE_ML) -> float:
    """Latency (s) of one (m, q_k, q_n) i8 GEMM on ONE AIE-ML compute tile.

    Model: compute cycles at the API shape's calibrated efficiency, local-
    memory load cycles for the A/B sub-tiles (2x256-bit loads/cycle), per-call
    loop overhead, and fixed DMA/lock setup.  Shape asymmetry (paper Fig. 4:
    up to 2x faster when q_n > q_k) enters through the output-accumulator
    utilization factor.
    """
    s_m, s_k, s_n = s
    r_m = math.ceil(m / (s_m * _AIE_UNROLL))
    r_k = math.ceil(q_k / (s_k * _AIE_UNROLL))
    r_n = math.ceil(q_n / (s_n * _AIE_UNROLL))
    calls = r_m * r_k * r_n
    macs_per_call = (s_m * s_k * s_n) * _AIE_UNROLL**3
    eff = aie.api_efficiency(s_m, s_k, s_n)
    # Output-stationarity: wide-N workloads keep the 2x-unrolled accumulators
    # busy; K-heavy workloads serialize on the reduction chain.
    shape_util = min(1.0, 0.55 + 0.45 * min(2.0, q_n / max(q_k, 1)) / 2.0 * 2)
    if q_k > q_n:
        shape_util = max(0.5, 1.0 - 0.25 * math.log2(q_k / q_n))
    compute_cyc = calls * macs_per_call / (aie.macs_per_cycle_int8 * eff * shape_util)
    # Local-memory traffic: A and B sub-tiles re-read per call (64 B/cycle).
    load_cyc = calls * (s_m * s_k + s_k * s_n) * _AIE_UNROLL**2 / 64.0
    cyc = max(compute_cyc, load_cyc) + calls * _AIE_CALL_OVERHEAD_CYC / _AIE_UNROLL \
        + _AIE_DMA_SETUP_CYC
    return cyc / aie.clock_hz


def aie_spatial_latency(m: int, k: int, n: int, p_k: int, p_n: int,
                        s: tuple[int, int, int] = (4, 8, 8),
                        layers_in_band_2: int = 0,
                        aie: hwlib.AieMl = hwlib.AIE_ML) -> float:
    """Latency (s) of spatially tiling an (m,k,n) GEMM over p_k x p_n tiles.

    Adds: input streaming over the 32-bit per-tile port, cascade hops for the
    K-direction partial sums, and the Fig.-6 band-spill contention penalty.
    """
    q_k, q_n = math.ceil(k / p_k), math.ceil(n / p_n)
    t_tile = aie_tile_latency(m, q_k, q_n, s, aie)
    stream_in_cyc = (m * q_k) / (aie.stream_bits / 8)      # bytes @ 4 B/cycle
    cascade_cyc = (p_k - 1) * _AIE_CASCADE_HOP_CYC
    stream_out_cyc = (m * q_n) / (aie.stream_bits / 8)
    t = t_tile + (stream_in_cyc + cascade_cyc + stream_out_cyc) / aie.clock_hz
    if layers_in_band_2 > 0:
        t *= 1.0 + aie.band2_penalty_per_layer * layers_in_band_2
    return t


def aie_tile_interval(m: int, q_k: int, q_n: int,
                      s: tuple[int, int, int] = (4, 8, 8),
                      aie: hwlib.AieMl = hwlib.AIE_ML) -> float:
    """STEADY-STATE initiation interval (s) of one tile — the paper's
    throughput measure (Fig. 2/Table I report MHz = batch/interval).

    Unlike :func:`aie_tile_latency`, per-inference setup (DMA locks, loop
    prologue) pipelines away; the interval is bound by the slowest of
    compute, the 32-bit input stream, and the 32-bit output stream.
    """
    s_m, s_k, s_n = s
    eff = aie.api_efficiency(s_m, s_k, s_n)
    shape_util = min(1.0, 0.55 + 0.45 * min(2.0, q_n / max(q_k, 1)))
    shape_util = max(0.5, min(shape_util, 1.0))
    compute_cyc = (m * q_k * q_n) / (aie.macs_per_cycle_int8 * eff * shape_util)
    stream_in_cyc = (m * q_k) / (aie.stream_bits / 8)
    stream_out_cyc = (m * q_n) / (aie.stream_bits / 8)
    return max(compute_cyc, stream_in_cyc, stream_out_cyc) / aie.clock_hz


def aie_spatial_interval(m: int, k: int, n: int, p_k: int, p_n: int,
                         s: tuple[int, int, int] = (4, 8, 8),
                         layers_in_band_2: int = 0,
                         aie: hwlib.AieMl = hwlib.AIE_ML) -> float:
    """Steady-state interval of a spatially tiled layer: per-tile interval on
    its (q_k, q_n) slice + cascade chain + band-spill contention (DR6)."""
    q_k, q_n = math.ceil(k / p_k), math.ceil(n / p_n)
    cyc = aie_tile_interval(m, q_k, q_n, s, aie) * aie.clock_hz
    cyc += (p_k - 1) * _AIE_CASCADE_HOP_CYC
    t = cyc / aie.clock_hz
    if layers_in_band_2 > 0:
        t *= 1.0 + aie.band2_penalty_per_layer * layers_in_band_2
    return t


def aie_optimized_interval(layer_shapes, batch: int = 8, *,
                           max_tiles_per_layer: int = 12,
                           aie: hwlib.AieMl = hwlib.AIE_ML) -> float:
    """Deploy a dense pipeline with the Section-IV design rules: per layer,
    spatially tile over up to `max_tiles_per_layer` tiles, K-expansion first
    (DR3), DR5 floor on split dims, one band (DR6).  Returns the steady-state
    pipeline interval (slowest layer)."""
    n_layers = len(layer_shapes)
    t_worst = 0.0
    for n_in, n_out in layer_shapes:
        best = aie_tile_interval(batch, n_in, n_out, aie=aie)
        for p_k in (1, 2, 3, 4, 6):
            for p_n in (1, 2, 3, 4, 6):
                if p_k * p_n > max_tiles_per_layer:
                    continue
                q_k, q_n = n_in / p_k, n_out / p_n
                # DR5 floor applies to the dims being SPLIT (stream-bound
                # narrow layers may still split K alone).
                if (p_k > 1 and q_k < 16) or (p_n > 1 and q_n < 32):
                    continue
                if n_layers * p_k > aie.usable_cols:
                    continue                     # DR6: one band
                best = min(best, aie_spatial_interval(batch, n_in, n_out,
                                                      p_k, p_n, aie=aie))
        t_worst = max(t_worst, best)
    return t_worst


def aie_best_single_tile(m: int, k: int, n: int,
                         aie: hwlib.AieMl = hwlib.AIE_ML,
                         ) -> tuple[tuple[int, int, int], float]:
    """DR1 search: best legal API tile for a single-tile workload."""
    best = None
    for s in aie.legal_api_tiles_i8:
        if not aie_api_legal(s, m, k, n, aie):
            continue
        t = aie_tile_latency(m, k, n, s, aie)
        if best is None or t < best[1]:
            best = (s, t)
    if best is None:  # fall back: pad to the default shape
        best = ((4, 8, 8), aie_tile_latency(m, k, n, (4, 8, 8), aie))
    return best


# --------------------------------------------------------------------------
# TPU-native planner
# --------------------------------------------------------------------------

def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


def _divisors_leq(x: int, cap: int) -> list[int]:
    return [d for d in range(1, min(x, cap) + 1) if x % d == 0]


def legal_block_dims(extent: int, multiple: int, cap: int) -> list[int]:
    """Legal Pallas block sizes for one dim: multiples of `multiple` that
    divide the (padded) extent, capped."""
    padded = _ceil_to(extent, multiple)
    out = []
    b = multiple
    while b <= min(padded, cap):
        if padded % b == 0:
            out.append(b)
        b += multiple
    return out or [min(padded, cap)]


def plan_api(m: int, q_k: int, q_n: int, *, itemsize: int = 2,
             tpu: hwlib.TpuV5e = hwlib.TPU_V5E,
             vmem_budget: int | None = None) -> ApiPlan:
    """Pick Pallas block shapes for a per-core (m, q_k, q_n) GEMM (DR1'/DR2').

    Search over legal (block_m, block_k, block_n); score with an HBM-traffic +
    MXU-utilization model; tie-break toward larger block_n (DR2').  The VMEM
    budget accounts double-buffered A/B blocks plus the f32 accumulator.
    """
    vmem = vmem_budget or int(tpu.vmem_bytes * 0.75)
    sub = tpu.sublanes_for(itemsize)
    lane = tpu.vreg_lane
    bm_cands = legal_block_dims(m, sub, 1024)
    bk_cands = legal_block_dims(q_k, lane, 2048)
    bn_cands = legal_block_dims(q_n, lane, 2048)
    best: tuple[float, float, ApiPlan] | None = None
    for bm, bk, bn in itertools.product(bm_cands, bk_cands, bn_cands):
        vmem_bytes = 2 * (bm * bk + bk * bn) * itemsize + bm * bn * 4
        if vmem_bytes > vmem:
            continue
        r_m = _ceil_to(m, sub) // bm if _ceil_to(m, sub) % bm == 0 else math.ceil(m / bm)
        r_k = math.ceil(_ceil_to(q_k, lane) / bk)
        r_n = math.ceil(_ceil_to(q_n, lane) / bn)
        # HBM traffic: A re-read per N-block, B re-read per M-block, C once.
        traffic = (m * q_k * r_n + q_k * q_n * r_m) * itemsize + m * q_n * 4
        t_mem = traffic / tpu.hbm_bw
        flops = 2.0 * m * q_k * q_n
        peak = tpu.peak_int8_ops if itemsize == 1 else tpu.peak_bf16_flops
        eff = (min(1.0, bm / sub / math.ceil(bm / sub))  # == 1; keep for clarity
               * min(1.0, m / (r_m * bm))               # M padding waste
               * min(1.0, q_k / (r_k * bk))
               * min(1.0, q_n / (r_n * bn)))
        t_compute = flops / (peak * max(eff, 1e-9))
        est = max(t_mem, t_compute) + tpu.kernel_overhead_s
        # DR2' tie-break: prefer wider N blocks at (near-)equal time.
        score = (est, -bn, -bk)
        if best is None or score < (best[0], -best[2].block_n, -best[2].block_k):
            best = (est, -bn, ApiPlan(bm, bk, bn, r_m, r_k, r_n, vmem_bytes, est))
    assert best is not None
    return best[2]


def collective_time(bytes_per_device: float, group: int, *, axis_bw: float,
                    kind: str = "reduce_scatter") -> float:
    """Ring-collective time model over a `group`-sized mesh axis."""
    if group <= 1 or bytes_per_device <= 0:
        return 0.0
    steps = group - 1
    if kind == "all_reduce":
        vol = 2.0 * bytes_per_device * steps / group
    elif kind in ("reduce_scatter", "all_gather"):
        vol = bytes_per_device * steps / group
    elif kind == "all_to_all":
        vol = bytes_per_device * steps / group
    else:
        raise ValueError(kind)
    return vol / axis_bw


def plan_spatial(m: int, k: int, n: int, *, itemsize: int = 2,
                 axis_sizes: Sequence[int] = (16,),
                 tpu: hwlib.TpuV5e = hwlib.TPU_V5E,
                 q_k_floor: int = 512, q_n_floor: int = 512,
                 max_tiles: int | None = None) -> SpatialPlan:
    """Pick (P_K, P_N) sharding over the mesh axes (DR3'-DR6').

    ``axis_sizes`` lists the usable mesh axes in *preference order* (fast axis
    first).  Factors beyond ``axis_sizes[0]`` spill onto later axes ("bands"),
    which multiplies the reduction cost by the hop penalty (DR6').
    """
    total_devices = math.prod(axis_sizes)
    cap = min(total_devices, max_tiles or total_devices)
    axis0 = axis_sizes[0]
    best: tuple[float, SpatialPlan] | None = None
    for p_k in _divisors_leq(max(k // 128, 1), cap):
        for p_n in _divisors_leq(max(n // 128, 1), cap // p_k):
            q_k, q_n = math.ceil(k / p_k), math.ceil(n / p_n)
            if p_k * p_n > 1 and (q_k < q_k_floor or q_n < q_n_floor):
                continue  # DR5' per-device floor
            bands = 1 if p_k <= axis0 else math.ceil(p_k / axis0)
            # Partial-sum reduction over the K group (the "cascade").
            red_bytes = m * q_n * 4
            bw = tpu.ici_bw * tpu.ici_links / 2
            t_red = collective_time(red_bytes, p_k, axis_bw=bw,
                                    kind="reduce_scatter")
            if bands > 1:
                t_red *= 1.0 + 0.5 * (bands - 1)  # DR6' slow-axis wrap penalty
            api = plan_api(m, q_k, q_n, itemsize=itemsize, tpu=tpu)
            est = api.est_s + t_red
            plan = SpatialPlan(p_k, p_n, q_k, q_n, bands, t_red)
            # DR3' tie-break: prefer K-expansion at (near-)equal time.
            if best is None or (est, -p_k) < (best[0], -best[1].p_k):
                best = (est, plan)
    assert best is not None
    return best[1]


def plan_gemm(m: int, k: int, n: int, *, itemsize: int = 2,
              axis_sizes: Sequence[int] = (16,),
              tpu: hwlib.TpuV5e = hwlib.TPU_V5E,
              max_tiles: int | None = None) -> GemmPlan:
    """Full two-level plan for one GEMM (paper Alg. 2, TPU-native)."""
    rules: list[str] = []
    spatial = plan_spatial(m, k, n, itemsize=itemsize, axis_sizes=axis_sizes,
                           tpu=tpu, max_tiles=max_tiles)
    if spatial.p_k > 1:
        rules.append("DR3'(K-expansion)")
    if spatial.tiles > 1:
        rules.append("DR5'(per-device floor held)")
    if spatial.bands > 1:
        rules.append("DR6'(band spill penalized)")
    api = plan_api(m, spatial.q_k, spatial.q_n, itemsize=itemsize, tpu=tpu)
    rules.append(f"DR1'(block={api.blocks})")
    if api.block_n >= api.block_k:
        rules.append("DR2'(N-favored)")
    est = api.est_s + spatial.est_collective_s
    return GemmPlan(m, k, n, itemsize, spatial, api, est, tuple(rules))
