"""Boundary-crossing cost model and fusion/split planners (paper DR7).

The paper measures a ~3.9% latency penalty per PL<->AIE boundary crossing
(Fig. 7, R^2=0.98 linear fit) and states DR7: split a pipeline across domains
only when the domain-preference gain exceeds the crossing cost.

TPU adaptation (DR7'): the two "domains" on one TPU chip are *inside a fused
Pallas kernel* vs *separate XLA ops through HBM*.  Every un-fused boundary
costs (a) a round trip of the activation bytes through HBM and (b) a fixed
dispatch overhead.  The same model prices host<->device and ICI<->DCN
boundaries for heterogeneous placements.

Two planners consume the model:

* :func:`plan_fusion` — given a chain of stages with per-stage compute times
  and inter-stage activation sizes, choose fusion groups minimizing total time
  subject to a VMEM working-set budget (this is what motivates the
  ``fused_dense`` kernel: GEMM+bias+activation in one launch).
* :func:`plan_hybrid_split` — the paper's Fig.-7 experiment generalized:
  stages have a preferred domain with a speedup factor; crossing adds the DR7
  cost; dynamic programming picks the optimal assignment.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro import hw as hwlib


@dataclasses.dataclass(frozen=True)
class Stage:
    name: str
    compute_s: float            # stage time in its default domain; for the
    # TPU fusion DP this is PURE compute (launch dispatch excluded — each
    # fusion group charges one dispatch of its own in fused_group_cost).
    out_bytes: int              # activation bytes handed to the next stage
    vmem_bytes: int = 0         # working set if fused (for plan_fusion)
    # Compute time when executed INSIDE a fused megakernel.  A megakernel is
    # not grid-blocked, so it escapes the per-layer kernel's block-shape
    # padding (e.g. the 32-row int8 tile at batch 8) — when that matters the
    # planner sets this lower than compute_s; None means "same".
    fused_compute_s: float | None = None
    # For plan_hybrid_split: time in each domain (e.g. {'aie':..., 'pl':...}).
    domain_s: dict | None = None

    @property
    def in_group_compute_s(self) -> float:
        """Compute charged when this stage runs inside a multi-stage group."""
        return (self.fused_compute_s if self.fused_compute_s is not None
                else self.compute_s)


def crossing_cost_tpu(act_bytes: int, tpu: hwlib.TpuV5e = hwlib.TPU_V5E) -> float:
    """DR7' per-boundary cost: HBM round trip + kernel dispatch."""
    return 2.0 * act_bytes / tpu.hbm_bw + tpu.kernel_overhead_s


def fused_group_cost(stages: Sequence[Stage],
                     tpu: hwlib.TpuV5e = hwlib.TPU_V5E) -> float:
    """Execution cost of one fusion group as the runtime runs it: ONE launch
    dispatch, the members' compute, and a fused-epilogue requantize at every
    boundary kept inside the kernel (``stages[i].compute_s`` must be the
    pure compute time, dispatch excluded — the group charges its own).  A
    singleton group is a plain per-layer launch; multi-stage groups run as a
    megakernel and use each stage's (possibly cheaper) fused compute."""
    if len(stages) == 1:
        return tpu.kernel_overhead_s + stages[0].compute_s
    return (tpu.kernel_overhead_s
            + sum(s.in_group_compute_s for s in stages)
            + tpu.fused_epilogue_s * max(len(stages) - 1, 0))


def crossing_cost_aie(act_bytes: int, base_latency_s: float,
                      aie: hwlib.AieMl = hwlib.AIE_ML) -> float:
    """Paper-faithful PL<->AIE crossing: PLIO transfer + sync, calibrated so a
    16-layer batch-8 model sees ~3.9% of baseline per crossing (Fig. 7)."""
    transfer = act_bytes / aie.plio_bw
    sync = 0.039 * base_latency_s - transfer
    return transfer + max(sync, 0.0)


def chain_latency(stages: Sequence[Stage], groups: Sequence[int],
                  tpu: hwlib.TpuV5e = hwlib.TPU_V5E) -> float:
    """Total time of a stage chain under a fusion grouping.

    ``groups[i]`` is the fusion-group id of stage i (non-decreasing).  Each
    group pays :func:`fused_group_cost` (one dispatch + compute + fused
    epilogues); each boundary BETWEEN groups pays the activation's HBM round
    trip — the following group's dispatch is already in its group cost, so
    an all-singleton grouping reduces exactly to the classic per-layer
    launch chain (N dispatches + N-1 crossings)."""
    total = 0.0
    i = 0
    n = len(stages)
    while i < n:
        j = i
        while j + 1 < n and groups[j + 1] == groups[i]:
            j += 1
        total += fused_group_cost(stages[i:j + 1], tpu)
        if j + 1 < n:
            total += 2.0 * stages[j].out_bytes / tpu.hbm_bw
        i = j + 1
    return total


def plan_fusion(stages: Sequence[Stage], *,
                tpu: hwlib.TpuV5e = hwlib.TPU_V5E,
                vmem_budget: int | None = None) -> list[int]:
    """Greedy-optimal fusion grouping (chain DP) under a VMEM budget.

    Returns a group id per stage.  DP over split points: cost(i..j fused) =
    :func:`fused_group_cost` (one dispatch + compute + a fused-epilogue
    requantize per inner boundary), feasible iff the union working set fits
    VMEM; the activation handed between groups pays its HBM round trip.  A
    boundary fuses exactly when ``fused_epilogue_s`` undercuts the crossing —
    the DR7' decision, now priced on both sides.
    """
    n = len(stages)
    vmem = vmem_budget or int(tpu.vmem_bytes * 0.75)
    INF = float("inf")

    def group_ok(i: int, j: int) -> bool:
        return sum(s.vmem_bytes for s in stages[i:j + 1]) <= vmem

    best = [INF] * (n + 1)   # best[i] = min cost of stages[0:i]
    choice = [0] * (n + 1)
    best[0] = 0.0
    for j in range(1, n + 1):
        for i in range(j):
            if not group_ok(i, j - 1):
                continue
            c = best[i] + fused_group_cost(stages[i:j], tpu)
            if i > 0:
                c += 2.0 * stages[i - 1].out_bytes / tpu.hbm_bw
            if c < best[j]:
                best[j], choice[j] = c, i
    # Reconstruct groups.
    groups = [0] * n
    j, g = n, 0
    bounds = []
    while j > 0:
        bounds.append((choice[j], j))
        j = choice[j]
    for gid, (i, j) in enumerate(reversed(bounds)):
        for t in range(i, j):
            groups[t] = gid
    return groups


def plan_hybrid_split(stages: Sequence[Stage], domains: Sequence[str], *,
                      crossing_s: float) -> tuple[list[str], float]:
    """Paper DR7 decision: assign each stage to a domain; each adjacent pair in
    different domains pays ``crossing_s``.  DP over (stage, domain)."""
    n = len(stages)
    INF = float("inf")
    cost = {d: [INF] * n for d in domains}
    prev: dict[str, list[str | None]] = {d: [None] * n for d in domains}
    for d in domains:
        cost[d][0] = (stages[0].domain_s or {}).get(d, stages[0].compute_s)
    for i in range(1, n):
        for d in domains:
            t = (stages[i].domain_s or {}).get(d, stages[i].compute_s)
            for p in domains:
                c = cost[p][i - 1] + t + (crossing_s if p != d else 0.0)
                if c < cost[d][i]:
                    cost[d][i], prev[d][i] = c, p
    end = min(domains, key=lambda d: cost[d][n - 1])
    assign = [end]
    for i in range(n - 1, 0, -1):
        assign.append(prev[assign[-1]][i])  # type: ignore[arg-type]
    assign.reverse()
    return assign, cost[end][n - 1]
