"""LARE — Latency-Adjusted Resource Equivalence (paper Algorithm 1).

For a dense layer ``(n_in, n_out)``:

1. sweep the PL (HLS4ML) reuse factor ``rf`` over its legal values, collecting
   the resource/performance trade-off curve ``(R_PL(rf), P_PL(rf))``;
2. take the fixed AIE performance point ``P_AIE`` for the same layer;
3. interpolate the PL curve to find ``rf_eq`` with
   ``P_PL(rf_eq) == P_AIE`` — the **latency-adjusted resource equivalent** is
   ``LARE = R_PL(rf_eq)``.

LARE is simultaneously:

* a **decision boundary** — deploy the layer on PL iff its PL resource budget
  exceeds LARE (then PL matches/beats the AIE latency);
* an **efficiency indicator** — a low LARE says a small PL budget already
  matches the AIE mapping, i.e. the AIE mapping under-utilizes its tile and
  needs the Section-IV tiling optimizations.

The TPU analogue (:func:`lare_tpu`) swaps the substrates: "PL spatial
dataflow" becomes a layer-pipelined spatial execution with dedicated cores per
layer (resource = core count, reuse factor = time-multiplexing fraction per
stage), and "AIE" becomes the tiled-kernel execution on a fixed core group.
The metric keeps its meaning: the minimum number of dedicated pipeline cores
needed to match the tiled kernel's latency.
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Callable

from repro import hw as hwlib
from repro.core import tiling


@dataclasses.dataclass(frozen=True)
class LarePoint:
    """One point of the PL trade-off curve."""
    rf: int
    interval_s: float           # 1/throughput (paper's performance measure)
    latency_s: float
    resource: float             # scalar resource (DSP-equivalents)
    fits: bool


@dataclasses.dataclass(frozen=True)
class LareResult:
    n_in: int
    n_out: int
    aie_interval_s: float
    rf_eq: float                # interpolated equivalent reuse factor
    lare: float                 # R_PL at rf_eq (the metric)
    pl_curve: tuple[LarePoint, ...]
    aie_favorable_below: float  # budget threshold: below -> deploy on AIE

    def decide(self, pl_budget: float) -> str:
        """Decision boundary: 'pl' if the budget can match AIE, else 'aie'."""
        return "pl" if pl_budget >= self.lare else "aie"

    @property
    def aie_efficiency(self) -> float:
        """Efficiency indicator in [0,1]: LARE normalized by the resource an
        ideally-utilized AIE tile would pin down (dsp-equivalents)."""
        return min(1.0, self.lare / hwlib.AIE_ML.dsp58_equiv_per_tile)


def pl_curve(n_in: int, n_out: int, *, batch: int = 8,
             strategy: str = "resource",
             pl: hwlib.PlFabric = hwlib.PL_FABRIC) -> list[LarePoint]:
    """HLS4ML resource/performance sweep over legal reuse factors."""
    pts = []
    for rf in pl.legal_reuse_factors(n_in, n_out):
        res = pl.resources(n_in, n_out, rf, strategy=strategy)
        pts.append(LarePoint(
            rf=rf,
            interval_s=pl.interval_s(rf),
            latency_s=pl.latency_s(n_in, n_out, rf, batch),
            resource=pl.resource_scalar(res),
            fits=pl.fits(res),
        ))
    return pts


def lare(n_in: int, n_out: int, *, batch: int = 8,
         strategy: str = "resource",
         pl: hwlib.PlFabric = hwlib.PL_FABRIC,
         aie: hwlib.AieMl = hwlib.AIE_ML,
         aie_interval_s: float | None = None) -> LareResult:
    """Paper Algorithm 1.  ``aie_interval_s`` may be injected from a measured
    run; by default it comes from the calibrated single-tile model (naive
    1-layer-per-tile mapping, as in Section III-B)."""
    curve = pl_curve(n_in, n_out, batch=batch, strategy=strategy, pl=pl)
    if aie_interval_s is None:
        s_best, _ = tiling.aie_best_single_tile(batch, n_in, n_out, aie)
        aie_interval_s = tiling.aie_tile_interval(batch, n_in, n_out, s_best,
                                                  aie)
    # PL curve is monotone: interval increases with rf, resource decreases.
    ivals = [p.interval_s for p in curve]
    idx = bisect.bisect_left(ivals, aie_interval_s)
    if idx == 0:
        rf_eq, r_eq = float(curve[0].rf), curve[0].resource
    elif idx >= len(curve):
        rf_eq, r_eq = float(curve[-1].rf), curve[-1].resource
    else:
        lo, hi = curve[idx - 1], curve[idx]
        f = (aie_interval_s - lo.interval_s) / max(hi.interval_s - lo.interval_s, 1e-30)
        rf_eq = lo.rf + f * (hi.rf - lo.rf)
        # log-space interpolation of resources (curve is ~1/rf).
        r_eq = math.exp(math.log(max(lo.resource, 1e-9))
                        + f * (math.log(max(hi.resource, 1e-9))
                               - math.log(max(lo.resource, 1e-9))))
    return LareResult(n_in, n_out, aie_interval_s, rf_eq, r_eq,
                      tuple(curve), aie_favorable_below=r_eq)


# --------------------------------------------------------------------------
# TPU analogue: core-equivalence between pipelined-spatial and tiled regimes
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LareTpuResult:
    n_in: int
    n_out: int
    tiled_latency_s: float       # tiled-kernel latency on `kernel_cores`
    kernel_cores: int
    core_eq: float               # pipeline cores needed to match (the metric)
    pipeline_curve: tuple[tuple[int, float], ...]   # (cores, latency_s)

    def decide(self, pipeline_core_budget: int) -> str:
        return "pipeline" if pipeline_core_budget >= self.core_eq else "tiled"


def lare_tpu(n_in: int, n_out: int, *, batch: int = 8, itemsize: int = 2,
             kernel_cores: int = 1, max_cores: int = 64,
             tpu: hwlib.TpuV5e = hwlib.TPU_V5E,
             tiled_latency_s: float | None = None,
             pipeline_latency_fn: Callable[[int], float] | None = None,
             ) -> LareTpuResult:
    """Core-equivalence metric on TPU (the LARE adaptation, DESIGN.md §2).

    *Tiled regime* (the "AIE side"): the layer runs as one planned Pallas GEMM
    on ``kernel_cores`` cores (latency from the API planner / measured).

    *Pipelined-spatial regime* (the "PL side"): the layer owns ``c`` dedicated
    cores of a layer-pipeline; its stage time is the K-sharded GEMM time on
    ``c`` cores plus the stage-boundary transfer — the analogue of the
    reuse-factor sweep, since stage time ~ 1/c the way PL interval ~ rf.
    """
    if tiled_latency_s is None:
        plan = tiling.plan_gemm(batch, n_in, n_out, itemsize=itemsize,
                                axis_sizes=(kernel_cores,), tpu=tpu,
                                max_tiles=kernel_cores)
        tiled_latency_s = plan.est_s
    curve: list[tuple[int, float]] = []
    c = 1
    while c <= max_cores:
        if pipeline_latency_fn is not None:
            t = pipeline_latency_fn(c)
        else:
            sp = tiling.plan_spatial(batch, n_in, n_out, itemsize=itemsize,
                                     axis_sizes=(c,), tpu=tpu, max_tiles=c,
                                     q_k_floor=1, q_n_floor=1)
            api = tiling.plan_api(batch, sp.q_k, sp.q_n, itemsize=itemsize, tpu=tpu)
            # stage-boundary activation hand-off (ppermute of the outputs)
            handoff = batch * n_out * itemsize / tpu.ici_bw
            t = api.est_s + sp.est_collective_s + handoff
        curve.append((c, t))
        c *= 2
    # Find the smallest core count whose pipelined latency <= tiled latency.
    core_eq = float("inf")
    for c, t in curve:
        if t <= tiled_latency_s:
            prev = next(((pc, pt) for pc, pt in reversed(curve) if pc < c), None)
            if prev is not None and prev[1] > tiled_latency_s:
                pc, pt = prev
                f = (pt - tiled_latency_s) / max(pt - t, 1e-30)
                core_eq = pc + f * (c - pc)
            else:
                core_eq = float(c)
            break
    return LareTpuResult(n_in, n_out, tiled_latency_s, kernel_cores,
                         core_eq, tuple(curve))
