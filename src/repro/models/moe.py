"""Mixture-of-Experts block with scatter-based (one-hot-free) dispatch.

Two execution paths share one math core (:func:`_moe_math`):

* **local** — no mesh context: all experts on one device (smoke tests, edge).
* **shard_map** — expert parallelism over the ``model`` mesh axis.  Two weight
  layouts, picked automatically:

  - ``ep``  (num_experts % model_axis == 0): experts sharded over ``model``;
    each device dispatches the tokens of its data shard to its local experts
    and the per-token contributions are ``psum``-combined over ``model`` —
    the TPU rendition of the paper's cascade-combine.  Expert weights are
    additionally FSDP-sharded over ``data`` (gathered per layer).
  - ``tp``  (few experts, e.g. mixtral's 8 on a 16-way axis): every expert's
    FFN is tensor-parallel over ``model`` (d_ff sharded); dispatch stays
    local; the down-projection partial sums ``psum`` over ``model``.

Dispatch avoids one-hot einsums entirely (they would inflate HLO FLOPs by
>1000x — see DESIGN.md): token->slot assignment is computed with a per-shard
sort, the expert input buffer is built with a ``take(mode=fill)`` gather, and
the combine is a scatter-add.  Capacity drops follow the standard
capacity-factor policy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers import dense_init, dtype_of
from repro import sharding as shlib

F32 = jnp.float32


def init_moe(key, cfg: ModelConfig) -> dict:
    mo = cfg.moe
    d, f, e = cfg.d_model, mo.d_ff_expert, mo.num_experts
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d, e), F32, scale=0.02),
        "w_gate": dense_init(ks[1], (e, d, f), dt),
        "w_up": dense_init(ks[2], (e, d, f), dt),
        "w_down": dense_init(ks[3], (e, f, d), dt, scale=1.0 / (f ** 0.5)),
    }
    if mo.router_type == "sigmoid":
        p["router_bias"] = jnp.zeros((e,), F32)
    if mo.num_shared_experts:
        fs = f * mo.num_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[4], (d, fs), dt),
            "w_up": dense_init(ks[5], (d, fs), dt),
            "w_down": dense_init(jax.random.fold_in(ks[4], 1), (fs, d), dt,
                                 scale=1.0 / (fs ** 0.5)),
        }
    return p


def _route(p: dict, x2d: jax.Array, mo: MoEConfig):
    """Router scores -> (weights (T,k), ids (T,k), aux load-balance loss)."""
    logits = jnp.einsum("td,de->te", x2d.astype(F32), p["router"],
                        preferred_element_type=F32)
    if mo.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["router_bias"][None, :]
        top_w, top_i = jax.lax.top_k(sel, mo.top_k)
        top_w = jnp.take_along_axis(scores, top_i, axis=1)
        top_w = top_w / (jnp.sum(top_w, axis=1, keepdims=True) + 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(probs, mo.top_k)
        top_w = top_w / (jnp.sum(top_w, axis=1, keepdims=True) + 1e-9)
        scores = probs
    # Switch-style load-balance aux: E * sum_e (frac_tokens_e * mean_prob_e).
    t = x2d.shape[0]
    counts = jnp.zeros((mo.num_experts,), F32).at[top_i.reshape(-1)].add(1.0)
    frac = counts / (t * mo.top_k)
    mean_prob = jnp.mean(scores, axis=0)
    aux = mo.num_experts * jnp.sum(frac * mean_prob)
    return top_w, top_i, aux


def _dispatch_indices(top_i: jax.Array, top_w: jax.Array, *,
                      num_experts: int, e_start: int, e_count: int,
                      capacity: int):
    """Token->(expert,slot) assignment via per-shard sort (no one-hots).

    Returns (token_for_slot (e_count, C), weight_for_slot (e_count, C)) where
    out-of-range entries point at token index T (dropped by mode='fill').
    """
    t, k = top_i.shape
    n = t * k
    flat_e = top_i.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = top_w.reshape(-1)
    # Slot within expert group = rank among same-expert assignments.
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    group_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_group = jnp.arange(n, dtype=jnp.int32) - group_start.astype(jnp.int32)
    slot = jnp.zeros((n,), jnp.int32).at[order].set(pos_in_group)
    local = (flat_e >= e_start) & (flat_e < e_start + e_count)
    valid = local & (slot < capacity)
    e_idx = jnp.where(valid, flat_e - e_start, e_count)      # OOB -> dropped
    s_idx = jnp.where(valid, slot, capacity)
    token_for_slot = jnp.full((e_count, capacity), t, jnp.int32)
    token_for_slot = token_for_slot.at[e_idx, s_idx].set(flat_t, mode="drop")
    weight_for_slot = jnp.zeros((e_count, capacity), F32)
    weight_for_slot = weight_for_slot.at[e_idx, s_idx].set(flat_w, mode="drop")
    return token_for_slot, weight_for_slot


def _expert_ffn(wg, wu, wd, buf, gather_axes: tuple = ()):
    """buf: (E_loc, C, D) -> (E_loc, C, D); silu-gated FFN, f32 accum.

    Runs ONE EXPERT AT A TIME (checkpointed lax.map, safe here: we are inside
    shard_map, so sharding is manual and the map cannot be "helpfully"
    replicated by GSPMD).  FSDP weight gathers happen per expert inside the
    map — peak gathered weights are one expert's (D,F), not the whole bank
    (measured ~10 GiB on the 671B train cell otherwise, mesh-independent).
    """

    def one(inputs):
        wge, wue, wde, bufe = inputs
        for a in reversed(gather_axes):
            wge = jax.lax.all_gather(wge, a, axis=0, tiled=True)
            wue = jax.lax.all_gather(wue, a, axis=0, tiled=True)
            wde = jax.lax.all_gather(wde, a, axis=1, tiled=True)
        g = jnp.einsum("cd,df->cf", bufe, wge, preferred_element_type=F32)
        u = jnp.einsum("cd,df->cf", bufe, wue, preferred_element_type=F32)
        h = (jax.nn.silu(g) * u).astype(bufe.dtype)
        return jnp.einsum("cf,fd->cd", h, wde, preferred_element_type=F32)

    return jax.lax.map(jax.checkpoint(one), (wg, wu, wd, buf))


def _moe_math(p: dict, x2d: jax.Array, mo: MoEConfig, *,
              e_start: int, e_count: int, capacity: int,
              gather_axes: tuple = ()):
    """Contribution of experts [e_start, e_start+e_count) for tokens x2d."""
    t, d = x2d.shape
    top_w, top_i, aux = _route(p, x2d, mo)
    tok4slot, w4slot = _dispatch_indices(
        top_i, top_w, num_experts=mo.num_experts, e_start=e_start,
        e_count=e_count, capacity=capacity)
    buf = jnp.take(x2d, tok4slot.reshape(-1), axis=0,
                   mode="fill", fill_value=0).reshape(e_count, capacity, d)
    y = _expert_ffn(p["w_gate"], p["w_up"], p["w_down"], buf,
                    gather_axes)                                 # (E_loc,C,D)
    y = y * w4slot[..., None]
    out = jnp.zeros((t, d), F32).at[tok4slot.reshape(-1)].add(
        y.reshape(-1, d), mode="drop")
    return out.astype(x2d.dtype), aux


def _mesh_size(mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= mesh.shape[a]
    return n


def _dp_size(mesh) -> int:
    n = 1
    for a in shlib.dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def _moe_a2a(p: dict, x: jax.Array, cfg: ModelConfig):
    """SP + all-to-all dispatch (beyond-paper, §Perf).

    One shard_map over the whole MoE block with x kept 3-D — the local
    reshape to tokens happens INSIDE (manual sharding), so no GSPMD boundary
    reshard of the mixed (batch@dp, seq@model) residual occurs (measured as
    "involuntary full rematerialization" warnings + >30 GiB of transients
    when the reshape sat outside).  Shared-expert weights stay FSDP-sharded
    and are gathered locally (88 MB/layer for deepseek-v3).
    """
    mo = cfg.moe
    b, s, d = x.shape
    ctx = shlib.current()
    mesh = ctx.mesh
    dp = shlib.dp_axes(mesh)
    dp_n, model_n = _dp_size(mesh), mesh.shape["model"]
    world = dp_n * model_n
    # Full-mesh 2D-EP when experts divide the whole mesh (deepseek: 256
    # experts over 256 chips -> ONE resident expert per device, ZERO weight
    # gathers).  Otherwise EP over model with FSDP gathers.
    ep2d = mo.num_experts % world == 0
    ep_axes = tuple(dp) + ("model",) if ep2d else ("model",)
    e_count = mo.num_experts // (world if ep2d else model_n)
    t_loc = (b // dp_n) * (s // model_n)
    cap_src = max(2, _capacity(t_loc, mo))
    fsdp = () if ep2d else (
        dp if cfg.d_model % max(dp_n, 1) == 0 and dp else ())

    x_spec = P(dp, "model", None)
    w_spec = {"router": P(None, None),
              "w_gate": P(ep_axes, fsdp or None, None),
              "w_up": P(ep_axes, fsdp or None, None),
              "w_down": P(ep_axes, None, fsdp or None)}
    if "router_bias" in p:
        w_spec["router_bias"] = P(None)
    has_shared = "shared" in p
    if has_shared:
        w_spec["shared"] = {"w_gate": P(None, fsdp or None),
                            "w_up": P(None, fsdp or None),
                            "w_down": P(fsdp or None, None)}

    def body(xl, pl):
        bl, sl, _ = xl.shape
        x2 = xl.reshape(bl * sl, d)
        top_w, top_i, aux = _route(pl, x2, mo)
        tok4slot, w4slot = _dispatch_indices(
            top_i, top_w, num_experts=mo.num_experts, e_start=0,
            e_count=mo.num_experts, capacity=cap_src)
        buf = jnp.take(x2, tok4slot.reshape(-1), axis=0,
                       mode="fill", fill_value=0
                       ).reshape(mo.num_experts, cap_src, d)
        buf = jax.lax.all_to_all(buf, ep_axes, split_axis=0,
                                 concat_axis=1, tiled=True)
        y = _expert_ffn(pl["w_gate"], pl["w_up"], pl["w_down"], buf,
                        tuple(fsdp))
        y = jax.lax.all_to_all(y.astype(xl.dtype), ep_axes, split_axis=1,
                               concat_axis=0, tiled=True)
        y = y.astype(F32) * w4slot[..., None]
        out = jnp.zeros((bl * sl, d), F32).at[tok4slot.reshape(-1)].add(
            y.reshape(-1, d), mode="drop")
        if has_shared:
            sw = pl["shared"]
            wg, wu, wd = sw["w_gate"], sw["w_up"], sw["w_down"]
            for a in reversed(fsdp):
                wg = jax.lax.all_gather(wg, a, axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, a, axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, a, axis=0, tiled=True)
            g = jnp.einsum("td,df->tf", x2, wg, preferred_element_type=F32)
            u = jnp.einsum("td,df->tf", x2, wu, preferred_element_type=F32)
            h = (jax.nn.silu(g) * u).astype(xl.dtype)
            out = out + jnp.einsum("tf,fd->td", h, wd,
                                   preferred_element_type=F32)
        return (out.astype(xl.dtype).reshape(bl, sl, d),
                jax.lax.pmean(aux, ep_axes))

    y, aux = compat.shard_map(
        body, mesh=mesh, in_specs=(x_spec, w_spec), out_specs=(x_spec, P()),
        check_vma=False,
    )(x, {k: p[k] for k in w_spec})
    return y, aux


def _capacity(tokens: int, mo: MoEConfig) -> int:
    cap = int(tokens * mo.top_k / mo.num_experts * mo.capacity_factor)
    return max(mo.top_k, min(cap, tokens))


def moe_block(p: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Apply the MoE FFN.  x: (B, S, D).  Returns (y, aux_loss)."""
    mo = cfg.moe
    b, s, d = x.shape
    ctx = shlib.current()
    x2d = x.reshape(b * s, d)

    a2a_tokens = (mo.impl == "a2a" and ctx is not None
                  and "model" in ctx.mesh.axis_names
                  and mo.num_experts % ctx.mesh.shape["model"] == 0
                  and b % _dp_size(ctx.mesh) == 0
                  and s % ctx.mesh.shape["model"] == 0)
    if a2a_tokens:
        return _moe_a2a(p, x, cfg)

    shared_y = None
    if "shared" in p:
        sp = p["shared"]
        g = jnp.einsum("td,df->tf", x2d, sp["w_gate"], preferred_element_type=F32)
        u = jnp.einsum("td,df->tf", x2d, sp["w_up"], preferred_element_type=F32)
        h = (jax.nn.silu(g) * u).astype(x.dtype)
        h = shlib.shard(h.reshape(b, s, -1),
                        "batch", None, "mlp").reshape(b * s, -1)
        shared_y = jnp.einsum("tf,fd->td", h, sp["w_down"],
                              preferred_element_type=F32).astype(x.dtype)

    if ctx is None or "model" not in ctx.mesh.axis_names:
        cap = _capacity(b * s, mo)
        y, aux = _moe_math(p, x2d, mo, e_start=0, e_count=mo.num_experts,
                           capacity=cap)
    else:
        mesh = ctx.mesh
        model_n = mesh.shape["model"]
        dp = shlib.dp_axes(mesh)
        dp_n = 1
        for a in dp:
            dp_n *= mesh.shape[a]
        layout = "ep" if mo.num_experts % model_n == 0 else "tp"
        t_loc = (b * s) // dp_n if (b * s) % dp_n == 0 else b * s
        cap = _capacity(t_loc, mo)
        batch_axes = dp if b % dp_n == 0 else None
        x_spec = P(batch_axes, None)
        route_p = {k: v for k, v in p.items() if k != "shared"}

        if layout == "ep":
            e_count = mo.num_experts // model_n
            # experts sharded over model on E; FSDP over data on D
            fsdp = dp if cfg.d_model % dp_n == 0 else None
            w_spec = {"router": P(None, None),
                      "w_gate": P("model", fsdp, None),
                      "w_up": P("model", fsdp, None),
                      "w_down": P("model", None, fsdp)}
            if "router_bias" in route_p:
                w_spec["router_bias"] = P(None)

            def _ep(xl, pl):
                e_start = jax.lax.axis_index("model") * e_count
                y, aux = _moe_math(pl, xl, mo, e_start=e_start,
                                   e_count=e_count, capacity=cap,
                                   gather_axes=tuple(fsdp or ()))
                return (jax.lax.psum(y, "model"),
                        jax.lax.psum(aux, "model") / model_n)

            y, aux = compat.shard_map(
                _ep, mesh=mesh,
                in_specs=(x_spec, w_spec),
                out_specs=(x_spec, P()),
                check_vma=False,
            )(x2d, {k: route_p[k] for k in w_spec})
        else:
            # tp layout: all experts local; d_ff sharded over model; D FSDP/data.
            fsdp = dp if cfg.d_model % dp_n == 0 else None
            w_spec = {"router": P(None, None),
                      "w_gate": P(None, fsdp, "model"),
                      "w_up": P(None, fsdp, "model"),
                      "w_down": P(None, "model", fsdp)}
            if "router_bias" in route_p:
                w_spec["router_bias"] = P(None)

            def _tp(xl, pl):
                y, aux = _moe_math(pl, xl, mo, e_start=0,
                                   e_count=mo.num_experts, capacity=cap,
                                   gather_axes=tuple(fsdp or ()))
                return jax.lax.psum(y, "model"), aux

            y, aux = compat.shard_map(
                _tp, mesh=mesh,
                in_specs=(x_spec, w_spec),
                out_specs=(x_spec, P()),
                check_vma=False,
            )(x2d, {k: route_p[k] for k in w_spec})

    if shared_y is not None:
        y = y + shared_y
    return y.reshape(b, s, d), aux


def moe_param_specs(cfg: ModelConfig, mesh) -> dict:
    """PartitionSpecs for MoE params matching moe_block's shard_map layout."""
    mo = cfg.moe
    model_n = mesh.shape["model"] if "model" in mesh.axis_names else 1
    dp = shlib.dp_axes(mesh)
    dp_n = 1
    for a in dp:
        dp_n *= mesh.shape[a]
    fsdp = dp if cfg.d_model % max(dp_n, 1) == 0 and dp else None
    if mo.num_experts % max(model_n, 1) == 0 and model_n > 1:
        specs = {"router": P(None, None),
                 "w_gate": P("model", fsdp, None),
                 "w_up": P("model", fsdp, None),
                 "w_down": P("model", None, fsdp)}
    else:
        specs = {"router": P(None, None),
                 "w_gate": P(None, fsdp, "model"),
                 "w_up": P(None, fsdp, "model"),
                 "w_down": P(None, "model", fsdp)}
    specs["router_bias"] = P(None)
    specs["shared"] = {"w_gate": P(None, "model"), "w_up": P(None, "model"),
                       "w_down": P("model", None)}
    return specs
