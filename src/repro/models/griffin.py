"""Griffin / recurrentgemma family: RG-LRU recurrent blocks + local attention.

Layer pattern (config): (rec, rec, attn) repeating.  The recurrent block is

    y = W_out( gelu(W_y x) * RG-LRU(conv1d(W_x x)) )

with the RG-LRU gated diagonal recurrence
    r_t = sigmoid(W_a u_t + b_a);  i_t = sigmoid(W_i u_t + b_i)
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * u_t)

The pure-JAX path evaluates the scan with ``lax.associative_scan`` (O(log T)
depth, O(T) memory, autodiff-safe); the TPU hot-spot kernel is
``kernels/rglru.py``.  Local attention uses a bounded window, which is what
makes the 500k-token decode cell sub-quadratic-feasible for this arch.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (F32, attention, dense_init, dtype_of, mask_padded_vocab,
                                 init_attention, init_mlp, init_rmsnorm, mlp,
                                 rmsnorm)
from repro.runtime import maybe_dequant, maybe_remat
from repro.sharding import shard

_C_RGLRU = 8.0


def init_recurrent_block(key, cfg: ModelConfig) -> dict:
    g = cfg.griffin
    d, w = cfg.d_model, g.lru_width
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_y": dense_init(ks[0], (d, w), dt),
        "w_x": dense_init(ks[1], (d, w), dt),
        "conv": dense_init(ks[2], (g.conv_width, w), dt, scale=0.3),
        "conv_b": jnp.zeros((w,), dt),
        "w_a": dense_init(ks[3], (w, w), dt),
        "b_a": jnp.zeros((w,), dt),
        "w_i": dense_init(ks[4], (w, w), dt),
        "b_i": jnp.zeros((w,), dt),
        "lam": jnp.asarray(
            jax.random.uniform(jax.random.fold_in(key, 7), (w,), F32,
                               0.4, 0.8)),
        "w_out": dense_init(ks[5], (w, d), dt, scale=1.0 / math.sqrt(w)),
    }


def _causal_conv1d(p: dict, x: jax.Array, *, state: jax.Array | None = None):
    """Depthwise causal conv, width W.  x (B,T,D); state (B,W-1,D) for decode."""
    w = p["conv"].shape[0]
    if state is None:
        hist = jnp.zeros_like(x[:, :w - 1])
    else:
        hist = state.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv"][i][None, None]
              for i in range(w))
    new_state = xp[:, -(w - 1):] if state is not None else None
    return out + p["conv_b"][None, None], new_state


def _rglru_assoc(a: jax.Array, b: jax.Array,
                 h0: jax.Array | None = None) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t via associative scan along axis 1 (f32)."""
    if h0 is not None:
        # Fold the initial state into the first input.
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru(p: dict, u: jax.Array, *, h0: jax.Array | None = None):
    """RG-LRU over u (B,T,W).  Returns (h (B,T,W), h_final (B,W))."""
    r = jax.nn.sigmoid(
        jnp.einsum("btw,wv->btv", u, p["w_a"], preferred_element_type=F32)
        + p["b_a"].astype(F32))
    i = jax.nn.sigmoid(
        jnp.einsum("btw,wv->btv", u, p["w_i"], preferred_element_type=F32)
        + p["b_i"].astype(F32))
    log_a = -_C_RGLRU * jax.nn.softplus(p["lam"])[None, None] * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * u.astype(F32))
    h = _rglru_assoc(a, gated, h0=h0)
    return h.astype(u.dtype), h[:, -1]


def recurrent_block(p: dict, x: jax.Array, cfg: ModelConfig, *,
                    state: dict | None = None):
    """x (B,T,D) -> (B,T,D).  state (decode): {"conv": (B,W-1,lru), "h": (B,lru)}."""
    y = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["w_y"],
                               preferred_element_type=F32))
    u = jnp.einsum("btd,dw->btw", x, p["w_x"],
                   preferred_element_type=F32).astype(x.dtype)
    u = shard(u, "batch", None, "lru")
    u, conv_state = _causal_conv1d(p, u, state=state["conv"] if state else None)
    h, h_fin = rglru(p, u.astype(x.dtype),
                     h0=state["h"] if state else None)
    out = (y.astype(x.dtype) * h)
    z = jnp.einsum("btw,wd->btd", out, p["w_out"], preferred_element_type=F32)
    new_state = None
    if state is not None:
        new_state = {"conv": conv_state.astype(state["conv"].dtype),
                     "h": h_fin}
    return z.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Full model: pattern-block scan like the transformer family
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 2)
    dt = dtype_of(cfg)
    p = {"ln1": init_rmsnorm(cfg.d_model, dt),
         "ln2": init_rmsnorm(cfg.d_model, dt)}
    if kind == "rec":
        p["rec"] = init_recurrent_block(ks[0], cfg)
    else:
        p["attn"] = init_attention(ks[0], cfg)
    p["mlp"] = init_mlp(ks[1], cfg, gated=True)
    return p


def init_griffin(key, cfg: ModelConfig) -> dict:
    g = cfg.griffin
    u = len(g.pattern)
    dt = dtype_of(cfg)
    ks = jax.random.split(key, cfg.num_layers + 2)
    n_blocks, tail = divmod(cfg.num_layers, u)
    params: dict = {
        "emb": dense_init(ks[-1], (cfg.padded_vocab, cfg.d_model), dt, scale=0.02),
        "final_norm": init_rmsnorm(cfg.d_model, dt),
    }
    if n_blocks:
        params["blocks"] = {
            f"slot{j}": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[_init_layer(ks[b * u + j], cfg, g.pattern[j])
                  for b in range(n_blocks)])
            for j in range(u)}
    if tail:
        params["tail"] = [
            _init_layer(ks[n_blocks * u + j], cfg, g.pattern[j])
            for j in range(tail)]
    return params


def _apply_griffin_layer(pl, x, cfg, kind, *, state=None, cache_pos=None):
    pl = maybe_dequant(pl)
    h = rmsnorm(pl["ln1"], x, cfg.norm_eps)
    if kind == "rec":
        a, new_state = recurrent_block(pl["rec"], h, cfg, state=state)
    else:
        ring = None
        if state is not None and state["k"].shape[2] == cfg.griffin.local_window:
            ring = cfg.griffin.local_window
        a, new_state = attention(pl["attn"], h, cfg, kind="local",
                                 cache=state, cache_pos=cache_pos,
                                 ring_window=ring)
    x = x + a
    f = mlp(pl["mlp"], rmsnorm(pl["ln2"], x, cfg.norm_eps), act="gelu")
    x = x + f
    return shard(x, "batch", "seq", None), new_state


def griffin_forward(params: dict, cfg: ModelConfig, tokens: jax.Array,
                    **_) -> dict:
    g = cfg.griffin
    u = len(g.pattern)
    x = jnp.take(params["emb"], tokens, axis=0)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    x = shard(x, "batch", "seq", None)

    if "blocks" in params:
        def body(xx, pb):
            for j in range(u):
                xx, _ = _apply_griffin_layer(pb[f"slot{j}"], xx, cfg,
                                             g.pattern[j])
            return xx, None
        x, _ = jax.lax.scan(maybe_remat(body), x, params["blocks"])
    for j, pl in enumerate(params.get("tail", [])):
        x, _ = _apply_griffin_layer(pl, x, cfg, g.pattern[j])

    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["emb"].T,
                        preferred_element_type=F32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    logits = mask_padded_vocab(cfg, logits)
    return {"logits": shard(logits, "batch", None, "vocab"),
            "aux_loss": jnp.zeros((), F32)}


def griffin_state_specs(cfg: ModelConfig, batch: int, attn_window: int) -> dict:
    """Decode state: recurrent layers carry (conv, h); attn layers a bounded
    ring KV cache of `attn_window` (the sub-quadratic long_500k story)."""
    g = cfg.griffin
    dt = dtype_of(cfg)
    u = len(g.pattern)
    n_blocks, tail = divmod(cfg.num_layers, u)
    rec = {"conv": jax.ShapeDtypeStruct((batch, g.conv_width - 1, g.lru_width), dt),
           "h": jax.ShapeDtypeStruct((batch, g.lru_width), F32)}
    att = {"k": jax.ShapeDtypeStruct(
               (batch, cfg.num_kv_heads, attn_window, cfg.head_dim), dt),
           "v": jax.ShapeDtypeStruct(
               (batch, cfg.num_kv_heads, attn_window, cfg.head_dim), dt)}

    def stacked(tree, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)

    specs: dict = {}
    if n_blocks:
        specs["blocks"] = {
            f"slot{j}": stacked(rec if g.pattern[j] == "rec" else att, n_blocks)
            for j in range(u)}
    if tail:
        specs["tail"] = [dict(rec if g.pattern[j] == "rec" else att)
                         for j in range(tail)]
    return specs


def griffin_init_state(cfg: ModelConfig, batch: int, attn_window: int) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        griffin_state_specs(cfg, batch, attn_window))


def griffin_decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                        state: dict, cache_pos, **_):
    g = cfg.griffin
    u = len(g.pattern)
    x = jnp.take(params["emb"], tokens, axis=0)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)

    new_state: dict = {}
    if "blocks" in params:
        def body(xx, inp):
            pb, st = inp
            ns = {}
            for j in range(u):
                xx, s_j = _apply_griffin_layer(
                    pb[f"slot{j}"], xx, cfg, g.pattern[j],
                    state=st[f"slot{j}"], cache_pos=cache_pos)
                ns[f"slot{j}"] = s_j
            return xx, ns
        x, ns = jax.lax.scan(body, x, (params["blocks"], state["blocks"]))
        new_state["blocks"] = ns
    if "tail" in params:
        new_state["tail"] = []
        for j, pl in enumerate(params["tail"]):
            x, s_j = _apply_griffin_layer(pl, x, cfg, g.pattern[j],
                                          state=state["tail"][j],
                                          cache_pos=cache_pos)
            new_state["tail"].append(s_j)

    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["emb"].T,
                        preferred_element_type=F32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return mask_padded_vocab(cfg, logits), new_state
