"""The paper's own extreme-edge scientific workloads (Section V / Table I).

Layer widths are reconstructed so the MAC counts match Table I exactly:

* VAE (collider trigger, Jia et al.)  — 34.8k MACs:
    [56, 128, 128, 64, 32] + 16-d mu/logvar heads  -> 34,816 MACs
* Qubit readout (Gautam et al.)       — 82.9k MACs:
    [250, 300, 26, 5]                              -> 82,930 MACs
* Deep Autoencoder (MLPerf Tiny)      — 116.7k MACs:
    [320, 128, 128, 8, 128, 128, 320]              -> 116,736 MACs
* Jet-tagger (FastML benchmark)       — the classic [16, 64, 32, 32, 5]
* tau event selection (Belle-II L1)   — [27, 32, 16, 2] (small, PL-feasible)

All are batch-8, int8-quantized dense pipelines in deployment (the paper's
extreme-edge convention).  ``edge_forward`` is the float reference path;
``edge_forward_q8`` is the int8 path used by the serving engine: one Pallas
launch per DR7' fusion group (``kernels/fused_mlp`` megakernel for
multi-layer groups, ``gemm_int8`` for singletons), with block shapes and
groups both read from the :class:`DeploymentPlan`.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

F32 = jnp.float32

# Layer splits are width-balanced reconstructions: the paper publishes MAC
# totals (Table I) and throughputs but not per-layer widths; a balanced split
# is the only shape consistent with the reported naive-AIE intervals (the
# slowest layer bounds the pipeline interval at ~1/5 of total MACs).
EDGE_NETS: dict[str, dict] = {
    "jet_tagger": {"dims": [16, 64, 32, 32, 5], "act": "relu"},
    "tau_select": {"dims": [27, 32, 16, 2], "act": "relu"},
    "vae": {"dims": [64, 104, 104, 104, 64, 16], "act": "relu"},       # 36.0k
    "qubit": {"dims": [250, 96, 128, 128, 128, 96, 5], "act": "relu"},  # 81.8k
    "autoencoder": {"dims": [136, 136, 136, 136, 8, 136, 136, 136, 136],
                    "act": "relu"},                                     # 113.2k
}


@dataclasses.dataclass(frozen=True)
class EdgeConfig:
    name: str
    dims: tuple[int, ...]
    act: str = "relu"
    batch: int = 8          # the paper's extreme-edge batch size

    @property
    def macs(self) -> int:
        return sum(a * b for a, b in zip(self.dims[:-1], self.dims[1:]))

    @property
    def layer_shapes(self) -> list[tuple[int, int]]:
        return list(zip(self.dims[:-1], self.dims[1:]))


def edge_config(name: str) -> EdgeConfig:
    spec = EDGE_NETS[name]
    return EdgeConfig(name=name, dims=tuple(spec["dims"]), act=spec["act"])


def init_edge(key, cfg: EdgeConfig) -> list[dict]:
    params = []
    for i, (n_in, n_out) in enumerate(cfg.layer_shapes):
        k1, _ = jax.random.split(jax.random.fold_in(key, i))
        w = jax.random.normal(k1, (n_in, n_out), F32) / jnp.sqrt(float(n_in))
        params.append({"w": w, "b": jnp.zeros((n_out,), F32)})
    return params


def edge_forward(params: list[dict], cfg: EdgeConfig,
                 x: jax.Array) -> jax.Array:
    """Float reference forward (B, dims[0]) -> (B, dims[-1])."""
    h = x.astype(F32)
    last = len(params) - 1
    for i, p in enumerate(params):
        h = h @ p["w"] + p["b"]
        if i != last and cfg.act == "relu":
            h = jnp.maximum(h, 0.0)
    return h


def quantize_edge(params: list[dict], *, calib_x: jax.Array | None = None,
                  act: str = "relu") -> list[dict]:
    """Per-output-channel symmetric int8 weight quantization.

    With ``calib_x`` (a representative float input batch), each layer also
    gets a calibrated per-layer ACTIVATION scale: the float reference is run
    once at quantize time and ``x_scale_i = max|h_i| / 127`` is stored on the
    layer, replacing the historical hard-coded per-tensor 0.05 guess.  The
    executors read it via ``p["x_scale"]`` and fall back to their ``x_scale``
    argument for uncalibrated params."""
    qparams = []
    h = None if calib_x is None else calib_x.astype(F32)
    last = len(params) - 1
    for i, p in enumerate(params):
        scale = jnp.max(jnp.abs(p["w"]), axis=0) / 127.0 + 1e-12
        qw = jnp.clip(jnp.round(p["w"] / scale[None, :]), -127, 127)
        q = {"w_q": qw.astype(jnp.int8), "w_scale": scale, "b": p["b"]}
        if h is not None:
            q["x_scale"] = max(float(jnp.max(jnp.abs(h))) / 127.0, 1e-8)
            h = h @ p["w"] + p["b"]
            if i != last and act == "relu":
                h = jnp.maximum(h, 0.0)
        qparams.append(q)
    return qparams


def deployment_plan(cfg: EdgeConfig, **kw):
    """The net's cached TPU-path :class:`DeploymentPlan` (lazy import keeps
    ``repro.plan`` -> ``repro.models.edge`` one-directional at import time)."""
    from repro import plan as plan_lib
    return plan_lib.get_or_plan(cfg, target="tpu", **kw)


def fleet_deployment(names, *, target: str = "tpu", **kw):
    """Joint :class:`~repro.plan.multinet.FleetPlan` for several edge nets
    co-resident on one array (paper Section V-C).  ``names`` are EDGE_NETS
    keys or ready EdgeConfigs; planner knobs pass through ``kw``."""
    from repro import plan as plan_lib
    cfgs = [edge_config(n) if isinstance(n, str) else n for n in names]
    return plan_lib.plan_fleet(cfgs, target=target, **kw)


def edge_forward_q8(qparams: list[dict], cfg: EdgeConfig, x: jax.Array, *,
                    x_scale: float = 0.05, plan=None,
                    block_m: int | None = None, block_k: int | None = None,
                    block_n: int | None = None,
                    fused: bool | None = None) -> jax.Array:
    """int8 deployment path, compiled from a :class:`DeploymentPlan`.

    The plan's DR7' fusion decision is EXECUTED, not just priced: each
    multi-layer fusion group runs as one ``fused_mlp_q8`` megakernel launch
    (requantize + bias + activation in the epilogue, activations in VMEM
    scratch); singleton groups run the per-layer ``gemm_int8`` kernel with
    the plan's Pallas block shapes.  Per-layer activation scales come from
    the calibrated ``x_scale`` stored on each quantized layer (``x_scale``
    argument = fallback for uncalibrated params).

    Explicit ``block_*`` arguments are a per-layer-kernel knob (the
    micro-benchmarks sweep them) and force the per-layer path, as does
    ``fused=False``; by default the plan is looked up in the cache, so
    repeated calls pay the planner search once.
    """
    n = len(qparams)
    last = n - 1
    explicit_blocks = not (block_m is None and block_k is None
                           and block_n is None)
    if plan is None and (block_m is None or block_k is None or block_n is None):
        plan = deployment_plan(cfg)
    scales = [p.get("x_scale", x_scale) for p in qparams]
    act = cfg.act if cfg.act in ("relu",) else "none"

    # Launch groups: the plan's fusion decision, unless the caller forces
    # the per-layer kernel (fused=False or explicit Pallas blocks).
    if plan is not None and fused is not False and not explicit_blocks:
        groups = plan.groups()
    else:
        groups = [[i] for i in range(n)]
    # Hoist the per-layer tile lookups out of the traced loop: one host-side
    # pass, no plan access in the hot path.
    if plan is not None:
        tiles = [plan.layer(i).api_tile for i in range(n)]
    else:
        tiles = [(block_m, block_k, block_n)] * n

    h = x.astype(F32)
    for grp in groups:
        if len(grp) > 1:
            h = kops.fused_mlp_q8(
                h,
                [qparams[i]["w_q"] for i in grp],
                [qparams[i]["w_scale"] for i in grp],
                [qparams[i]["b"] for i in grp],
                jnp.asarray([scales[i] for i in grp], jnp.float32),
                act=act, act_last=(grp[-1] != last), out_dtype=F32)
            continue
        for i in grp:
            tm, tk, tn = tiles[i]
            # `is not None`, not truthiness: an explicit block must override
            # the plan even in degenerate sweeps, and a plan tile must never
            # be shadowed by a falsy 0.
            bm = block_m if block_m is not None else tm
            bk = block_k if block_k is not None else tk
            bn = block_n if block_n is not None else tn
            p = qparams[i]
            hq = jnp.clip(jnp.round(h / scales[i]), -127, 127).astype(jnp.int8)
            y = kops.gemm_int8(hq, p["w_q"], p["w_scale"], scales[i],
                               block_m=bm, block_k=bk, block_n=bn,
                               out_dtype=F32)
            h = y + p["b"][None, :]
            if i != last and act == "relu":
                h = jnp.maximum(h, 0.0)
    return h
