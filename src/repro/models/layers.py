"""Shared neural-network building blocks (pure JAX, param pytrees).

Every block is a pair of functions: ``init_*(key, cfg, ...) -> params`` and
an apply function ``*(params, x, ...) -> y``.  Params are plain nested dicts
of jnp arrays so they stay pjit/scan/checkpoint friendly.  Sharding enters
only through ``repro.sharding.shard`` annotations (no-ops without a mesh).

Compute conventions: weights bf16 (cfg.dtype), norms and softmax statistics
in f32, matmul accumulation f32 via ``preferred_element_type``.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding import shard

F32 = jnp.float32


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, F32) * scale).astype(dtype)


def mask_padded_vocab(cfg, logits: jax.Array) -> jax.Array:
    """Set the padded vocab columns (cfg.vocab_size..padded_vocab) to -inf so
    sampling/argmax/CE never select them.  No-op when nothing is padded."""
    pv = cfg.padded_vocab
    if pv == cfg.vocab_size:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                   logits.ndim - 1)
    neg = jnp.asarray(-0.7 * jnp.finfo(jnp.float32).max, logits.dtype)
    return jnp.where(col < cfg.vocab_size, logits, neg)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    h = x.astype(F32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + eps)
    return (h * params["scale"].astype(F32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    h = x.astype(F32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return (h * params["scale"].astype(F32)
            + params["bias"].astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------

def rope_table(positions: jax.Array, dim: int, theta: float) -> tuple:
    """positions (..., S) -> cos/sin tables (..., S, dim/2) in f32."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=F32) / dim))
    ang = positions.astype(F32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, H, S, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    if cos.ndim == 2:
        cos_, sin_ = cos[None, None], sin[None, None]
    else:
        cos_, sin_ = cos[:, None], sin[:, None]
    return jnp.concatenate(
        [x1 * cos_ - x2 * sin_, x2 * cos_ + x1 * sin_], axis=-1
    ).astype(x.dtype)


def mrope_table(positions: jax.Array, dim: int, theta: float,
                sections: tuple[int, int, int]) -> tuple:
    """M-RoPE (qwen2-vl): positions (3, B, S) for (t, h, w); the frequency
    bands are split into three groups, each rotated by its own position id."""
    cos3, sin3 = rope_table(positions, dim, theta)     # (3, B, S, dim/2)
    parts_c, parts_s = [], []
    start = 0
    for i, sec in enumerate(sections):
        parts_c.append(cos3[i, ..., start:start + sec])
        parts_s.append(sin3[i, ..., start:start + sec])
        start += sec
    return jnp.concatenate(parts_c, -1), jnp.concatenate(parts_s, -1)


# ---------------------------------------------------------------------------
# Attention (GQA, softcap, sliding window, QKV bias) + chunked jnp fallback
# ---------------------------------------------------------------------------

_NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


def init_attention(key, cfg: ModelConfig, *, d_model: int | None = None) -> dict:
    d = d_model or cfg.d_model
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.q_dim), dt),
        "wk": dense_init(ks[1], (d, cfg.kv_dim), dt),
        "wv": dense_init(ks[2], (d, cfg.kv_dim), dt),
        "wo": dense_init(ks[3], (cfg.q_dim, d), dt, scale=1.0 / math.sqrt(cfg.q_dim)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
    return p


def chunked_attention(q, k, v, *, causal=True, window=None, softcap=None,
                      scale=None, chunk: int = 512, q_offset: int = 0):
    """Flash-style attention in pure XLA: lax.scan over KV chunks with online
    softmax statistics.  Memory O(S_q * chunk) instead of O(S_q * S_kv).

    q: (B, Hq, Sq, D);  k/v: (B, Hkv, Skv, D);  GQA via head grouping.
    ``q_offset`` positions the queries inside the KV timeline (prefill=0;
    decode: q_offset = cache length so far).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    dv = v.shape[-1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    chunk = min(chunk, skv)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_chunks = k.shape[2] // chunk
    qg = q.reshape(b, hkv, group, sq, d).astype(F32) * scale
    kc = k.reshape(b, hkv, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, n_chunks, chunk, dv).transpose(2, 0, 1, 3, 4)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m, l, acc = carry
        ci, kb, vb = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kb.astype(F32),
                       preferred_element_type=F32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = ci * chunk + jnp.arange(chunk)
        mask = k_pos[None, :] < skv                      # padding
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vb.astype(F32), preferred_element_type=F32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, group, sq, 1), _NEG, F32)
    l0 = jnp.zeros((b, hkv, group, sq, 1), F32)
    a0 = jnp.zeros((b, hkv, group, sq, dv), F32)
    # Checkpoint the chunk body: the backward pass otherwise saves the f32
    # (.., Sq, chunk) probability blocks for EVERY chunk (measured ~8 GiB on
    # a 2.6B train cell); recomputing them per chunk is the flash discipline.
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).reshape(b, hq, sq, dv)
    return out.astype(q.dtype)


def attention(params: dict, x: jax.Array, cfg: ModelConfig, *,
              kind: str = "global",
              positions: jax.Array | None = None,
              mrope_positions: jax.Array | None = None,
              cache: dict | None = None,
              cache_pos: jax.Array | None = None,
              cross_kv: tuple | None = None,
              use_rope: bool = True,
              ring_window: int | None = None) -> tuple[jax.Array, dict | None]:
    """GQA attention.  Returns (output, updated_cache).

    Train/prefill: ``cache`` None -> full-sequence chunked attention.
    Decode: ``cache`` = {"k","v"} ring buffers; x is (B, 1, D) and
    ``cache_pos`` the write index.
    Cross-attention (whisper decoder): ``cross_kv`` = (k, v) precomputed.
    """
    b, s, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dq->bsq", x, params["wq"], preferred_element_type=F32)
    if "bq" in params:
        q = q + params["bq"].astype(F32)
    q = shard(q.astype(x.dtype).reshape(b, s, h, dh).transpose(0, 2, 1, 3),
              "batch", "heads", None, None)

    if cross_kv is None:
        k = jnp.einsum("bsd,dq->bsq", x, params["wk"], preferred_element_type=F32)
        v = jnp.einsum("bsd,dq->bsq", x, params["wv"], preferred_element_type=F32)
        if "bk" in params:
            k = k + params["bk"].astype(F32)
            v = v + params["bv"].astype(F32)
        k = k.astype(x.dtype).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
        v = v.astype(x.dtype).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
        if use_rope:
            if positions is None:
                base = jnp.arange(s) if cache_pos is None else cache_pos + jnp.arange(s)
                positions = jnp.broadcast_to(base, (b, s))
            if cfg.mrope_sections is not None and mrope_positions is not None:
                cos, sin = mrope_table(mrope_positions, dh, cfg.rope_theta,
                                       cfg.mrope_sections)
            else:
                cos, sin = rope_table(positions, dh, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
    else:
        k, v = cross_kv

    window = cfg.window if kind == "local" else None
    new_cache = None
    if cache is not None and cross_kv is None and ring_window is not None and s > 1:
        # Ring-buffer prefill: windowed attention over the prompt itself,
        # then publish only the last `W` tokens into the ring (rolled so that
        # token j sits at slot j % W, matching the decode write pattern).
        w_buf = cache["k"].shape[2]
        out = chunked_attention(q, k, v, causal=True,
                                window=window or ring_window,
                                softcap=cfg.attn_softcap, q_offset=cache_pos)
        keep = min(s, w_buf)
        k_last, v_last = k[:, :, -keep:], v[:, :, -keep:]
        if keep < w_buf:
            k_buf = jax.lax.dynamic_update_slice(cache["k"], k_last,
                                                 (0, 0, cache_pos, 0))
            v_buf = jax.lax.dynamic_update_slice(cache["v"], v_last,
                                                 (0, 0, cache_pos, 0))
        else:
            shift = s % w_buf          # first kept token's slot
            k_buf = jnp.roll(k_last, shift, axis=2)
            v_buf = jnp.roll(v_last, shift, axis=2)
        new_cache = {"k": k_buf, "v": v_buf}
    elif cache is not None and cross_kv is None and ring_window is not None and s == 1:
        # Ring-buffer decode (bounded window, long-context): the buffer holds
        # exactly the last `ring_window` tokens; K was roped at its absolute
        # position, so no re-rotation is needed.
        slot = jnp.mod(cache_pos, ring_window)
        k_buf = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, slot, 0))
        v_buf = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, slot, 0))
        new_cache = {"k": k_buf, "v": v_buf}
        out = decode_attention(q, k_buf, v_buf,
                               jnp.minimum(cache_pos, ring_window - 1),
                               window=None, softcap=cfg.attn_softcap)
    elif cache is not None and cross_kv is None:
        # Decode/prefill: write the new K/V at cache_pos, attend over buffer.
        k_buf = jax.lax.dynamic_update_slice(
            cache["k"], k, (0, 0, cache_pos, 0))
        v_buf = jax.lax.dynamic_update_slice(
            cache["v"], v, (0, 0, cache_pos, 0))
        new_cache = {"k": k_buf, "v": v_buf}
        if s == 1:
            out = decode_attention(q, k_buf, v_buf, cache_pos + s - 1,
                                   window=window, softcap=cfg.attn_softcap)
        else:
            # Prefill: chunked (flash-style) attention over the buffer —
            # never materializes (S x S_buf) logits.
            out = chunked_attention(q, k_buf, v_buf, causal=True,
                                    window=window, softcap=cfg.attn_softcap,
                                    q_offset=cache_pos)
    elif cache is not None:
        out = decode_attention(q, k, v, None, window=None,
                               softcap=cfg.attn_softcap)
        new_cache = cache
    else:
        out = chunked_attention(q, k, v, causal=(cross_kv is None and
                                                 kind != "bidir"),
                                window=window, softcap=cfg.attn_softcap)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    y = jnp.einsum("bsq,qd->bsd", out, params["wo"], preferred_element_type=F32)
    return y.astype(x.dtype), new_cache


def decode_attention(q, k, v, last_pos, *, window=None, softcap=None,
                     scale=None):
    """Single/few-token attention against a (possibly padded) KV buffer.

    q: (B, H, s, D) with small s;  k/v: (B, Hkv, S_buf, D).
    Positions > last_pos are masked (unwritten cache slots).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, group, sq, d).astype(F32) * scale
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(F32),
                   preferred_element_type=F32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if last_pos is not None:
        q_pos = last_pos - (sq - 1) + jnp.arange(sq)
        mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None, None], p, 0.0)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(F32),
                     preferred_element_type=F32)
    return out.reshape(b, hq, sq, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP (gated / plain)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, *, d_model: int | None = None,
             d_ff: int | None = None, gated: bool = True) -> dict:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, f), dt),
         "w_down": dense_init(ks[1], (f, d), dt, scale=1.0 / math.sqrt(f))}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d, f), dt)
    return p


def mlp(params: dict, x: jax.Array, *, act: str = "silu") -> jax.Array:
    actf = {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True),
            "relu": lambda v: jnp.maximum(v, 0.0)}[act]
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"], preferred_element_type=F32)
    if "w_gate" in params:
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"],
                          preferred_element_type=F32)
        h = actf(gate) * up
    else:
        h = actf(up)
    h = shard(h.astype(x.dtype), "batch", None, "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, params["w_down"], preferred_element_type=F32)
    return y.astype(x.dtype)
