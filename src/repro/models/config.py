"""Model configuration schema covering all assigned architecture families.

One fat frozen dataclass + optional per-family sub-configs (MaxText-style).
Every assigned architecture in ``repro/configs/`` instantiates this; the smoke
tests instantiate ``reduced()`` variants of the same family.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    first_k_dense: int = 0            # leading layers use dense FFN (deepseek)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # deepseek-v3 sigmoid routing with bias correction; mixtral uses softmax
    router_type: str = "softmax"      # "softmax" | "sigmoid"
    # Dispatch implementation (§Perf):
    #  "gather_psum" — tokens replicated over the model axis per DP shard;
    #                  expert outputs psum-combined (baseline, works for any
    #                  batch), comm ~ 2 x tokens x d_model per layer.
    #  "a2a"         — tokens sharded over (dp x model); capacity buffers
    #                  all_to_all'd to expert owners and back, comm ~
    #                  2 x tokens x k x cf / E_owners x d_model — the
    #                  beyond-paper optimization that makes the 671B train
    #                  cell fit a single pod.  Falls back to gather_psum when
    #                  tokens don't divide the mesh.
    impl: str = "gather_psum"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (deepseek-v3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class GriffinConfig:
    """RG-LRU hybrid (recurrentgemma): pattern unit = (rec, rec, attn)."""
    lru_width: int = 2560
    conv_width: int = 4
    pattern: tuple[str, ...] = ("rec", "rec", "attn")
    local_window: int = 2048


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder backbone (whisper): frontend is a stub, the encoder
    consumes precomputed frame embeddings from input_specs()."""
    encoder_layers: int = 24
    decoder_layers: int = 24
    encoder_len: int = 1500           # whisper 30s @ 20ms after conv stride


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # transformer | encdec | rwkv | griffin | edge
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # Attention features.
    attn_pattern: tuple[str, ...] = ("global",)   # per-layer cycle: local|global
    window: Optional[int] = None                   # sliding window for "local"
    attn_softcap: Optional[float] = None           # gemma2 attn logit softcap
    logit_softcap: Optional[float] = None          # gemma2 final logit softcap
    qkv_bias: bool = False                         # qwen2.5
    rope_theta: float = 10000.0
    mrope_sections: Optional[tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    # Family sub-configs.
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    griffin: Optional[GriffinConfig] = None
    encdec: Optional[EncDecConfig] = None
    # RWKV.
    rwkv_head_dim: int = 64
    # Misc.
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    post_norms: bool = False          # gemma2: post-attn/post-ffn rmsnorms
    scale_embeddings: bool = False    # gemma family: x *= sqrt(d_model)
    use_rope: bool = True             # whisper: absolute positions instead
    norm_type: str = "rmsnorm"        # "rmsnorm" | "layernorm" (whisper)
    mlp_act: str = "silu"             # "gelu" for whisper
    mlp_gated: bool = True            # whisper: plain 2-matrix MLP
    # Whether a 500k-token decode is sub-quadratic-feasible (SSM/hybrid only).
    subquadratic: bool = False
    # Multi-token prediction extra head (deepseek-v3); adds one extra layer.
    mtp: bool = False

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the unembedding shards over any mesh
        axis (whisper's 51865 would otherwise force replicated logits).
        Padded columns are masked to -inf in the losses; checkpoints and
        logits semantics use the true ``vocab_size``."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_kind(self, i: int) -> str:
        return self.attn_pattern[i % len(self.attn_pattern)]

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d = self.d_model
        n = self.vocab_size * d                     # embeddings
        if not self.tie_embeddings:
            n += self.vocab_size * d
        if self.family == "rwkv":
            per = 4 * d * d + 3 * d * self.d_ff + 10 * d  # tmix + cmix approx
            return n + self.num_layers * per
        if self.family == "griffin":
            g = self.griffin
            rec = d * g.lru_width * 3 + g.lru_width * g.conv_width + 4 * g.lru_width
            att = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            mlp = 3 * d * self.d_ff
            per_pat = []
            for kind in g.pattern:
                per_pat.append((rec if kind == "rec" else att) + mlp)
            full, rem = divmod(self.num_layers, len(g.pattern))
            total = full * sum(per_pat) + sum(per_pat[:rem])
            return n + total
        # transformer / encdec
        if self.mla is not None:
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.num_heads
                    * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.num_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.num_heads * m.v_head_dim * d)
        else:
            attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        if self.moe is not None:
            mo = self.moe
            dense_ffn = 3 * d * self.d_ff
            exp_ffn = 3 * d * mo.d_ff_expert
            moe_layers = self.num_layers - mo.first_k_dense
            ffn_total = (mo.first_k_dense * dense_ffn
                         + moe_layers * (mo.num_experts + mo.num_shared_experts)
                         * exp_ffn + moe_layers * d * mo.num_experts)
        else:
            ffn_total = self.num_layers * 3 * d * self.d_ff
        layers = self.num_layers * attn + ffn_total
        if self.encdec is not None:
            # encoder layers add self-attn+mlp; decoder adds cross-attn
            layers += self.encdec.encoder_layers * (attn + 3 * d * self.d_ff)
            layers += self.encdec.decoder_layers * attn   # cross-attention
        return n + layers

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        d = self.d_model
        full = self.param_count()
        moe_layers = self.num_layers - mo.first_k_dense
        inactive = moe_layers * (mo.num_experts - mo.top_k) * 3 * d * mo.d_ff_expert
        return full - inactive
