"""Whisper-style encoder-decoder backbone (whisper-medium).

Per the assignment, the audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, enc_len, d_model) — i.e. the output of the
conv1d stem — and this module implements everything after it.  Whisper
conventions: LayerNorm (not RMSNorm), non-gated gelu MLP, no RoPE (sinusoidal
encoder positions, learned decoder positions), tied unembedding.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (F32, attention, chunked_attention, mask_padded_vocab,
                                 decode_attention, dense_init, dtype_of,
                                 init_attention, init_layernorm, init_mlp,
                                 layernorm, mlp)
from repro.runtime import maybe_dequant, maybe_remat
from repro.sharding import shard

DEC_MAX_POS = 32768     # covers the assigned prefill_32k / decode_32k shapes


def _sinusoid(length: int, dim: int) -> jax.Array:
    pos = jnp.arange(length, dtype=F32)[:, None]
    div = jnp.exp(-math.log(10000.0) * jnp.arange(0, dim, 2, dtype=F32) / dim)
    tab = jnp.zeros((length, dim), F32)
    tab = tab.at[:, 0::2].set(jnp.sin(pos * div))
    tab = tab.at[:, 1::2].set(jnp.cos(pos * div))
    return tab


def _init_enc_layer(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    dt = dtype_of(cfg)
    return {"ln1": init_layernorm(cfg.d_model, dt),
            "attn": init_attention(ks[0], cfg),
            "ln2": init_layernorm(cfg.d_model, dt),
            "mlp": init_mlp(ks[1], cfg, gated=False)}


def _init_dec_layer(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    dt = dtype_of(cfg)
    return {"ln1": init_layernorm(cfg.d_model, dt),
            "attn": init_attention(ks[0], cfg),
            "ln_x": init_layernorm(cfg.d_model, dt),
            "xattn": init_attention(ks[1], cfg),
            "ln2": init_layernorm(cfg.d_model, dt),
            "mlp": init_mlp(ks[2], cfg, gated=False)}


def init_whisper(key, cfg: ModelConfig) -> dict:
    e = cfg.encdec
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    ek = jax.random.split(ks[0], e.encoder_layers)
    dk = jax.random.split(ks[1], e.decoder_layers)
    stack = lambda ts: jax.tree.map(lambda *xs: jnp.stack(xs), *ts)
    return {
        "enc_blocks": stack([_init_enc_layer(k, cfg) for k in ek]),
        "enc_final": init_layernorm(cfg.d_model, dt),
        "dec_blocks": stack([_init_dec_layer(k, cfg) for k in dk]),
        "dec_final": init_layernorm(cfg.d_model, dt),
        "emb": dense_init(ks[2], (cfg.padded_vocab, cfg.d_model), dt, scale=0.02),
        "pos_emb": dense_init(ks[3], (DEC_MAX_POS, cfg.d_model), dt, scale=0.02),
    }


def whisper_encode(params: dict, cfg: ModelConfig,
                   frames: jax.Array) -> jax.Array:
    """frames (B, S_enc, D) -> encoder output (B, S_enc, D)."""
    x = frames.astype(dtype_of(cfg))
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    x = shard(x, "batch", None, None)

    def body(xx, pl):
        pl = maybe_dequant(pl)
        h = layernorm(pl["ln1"], xx)
        a, _ = attention(pl["attn"], h, cfg, kind="bidir", use_rope=False)
        xx = xx + a
        f = mlp(pl["mlp"], layernorm(pl["ln2"], xx), act="gelu")
        return xx + f, None

    x, _ = jax.lax.scan(maybe_remat(body), x, params["enc_blocks"])
    return layernorm(params["enc_final"], x)


def _cross_kv(pl: dict, enc: jax.Array, cfg: ModelConfig):
    b, se, _ = enc.shape
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,dq->bsq", enc, pl["xattn"]["wk"],
                   preferred_element_type=F32)
    v = jnp.einsum("bsd,dq->bsq", enc, pl["xattn"]["wv"],
                   preferred_element_type=F32)
    k = k.astype(enc.dtype).reshape(b, se, hkv, dh).transpose(0, 2, 1, 3)
    v = v.astype(enc.dtype).reshape(b, se, hkv, dh).transpose(0, 2, 1, 3)
    return k, v


def _dec_layer(pl, x, cfg, *, enc=None, cross=None, cache=None, cache_pos=None):
    pl = maybe_dequant(pl)
    h = layernorm(pl["ln1"], x)
    a, new_self = attention(pl["attn"], h, cfg, kind="global",
                            use_rope=False, cache=cache, cache_pos=cache_pos)
    x = x + a
    h = layernorm(pl["ln_x"], x)
    kv = cross if cross is not None else _cross_kv(pl, enc, cfg)
    a, _ = attention(pl["xattn"], h, cfg, kind="bidir", use_rope=False,
                     cross_kv=kv)
    x = x + a
    f = mlp(pl["mlp"], layernorm(pl["ln2"], x), act="gelu")
    x = x + f
    return shard(x, "batch", "seq", None), new_self


def whisper_forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
                    encoder_frames: jax.Array, **_) -> dict:
    """Training: teacher-forced decode over the full target sequence."""
    enc = whisper_encode(params, cfg, encoder_frames)
    b, s = tokens.shape
    x = jnp.take(params["emb"], tokens, axis=0)
    x = x + params["pos_emb"][None, :s]
    x = shard(x, "batch", "seq", None)

    def body(xx, pl):
        xx, _ = _dec_layer(pl, xx, cfg, enc=enc)
        return xx, None

    x, _ = jax.lax.scan(maybe_remat(body), x, params["dec_blocks"])
    h = layernorm(params["dec_final"], x)
    logits = jnp.einsum("bsd,dv->bsv", h, params["emb"].T,
                        preferred_element_type=F32)
    logits = mask_padded_vocab(cfg, logits)
    return {"logits": shard(logits, "batch", None, "vocab"),
            "aux_loss": jnp.zeros((), F32)}


def whisper_cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    e = cfg.encdec
    dt = dtype_of(cfg)
    self_kv = jax.ShapeDtypeStruct(
        (e.decoder_layers, batch, cfg.num_kv_heads, max_len, cfg.head_dim), dt)
    cross_kv = jax.ShapeDtypeStruct(
        (e.decoder_layers, batch, cfg.num_kv_heads, e.encoder_len,
         cfg.head_dim), dt)
    return {"k": self_kv, "v": self_kv, "xk": cross_kv, "xv": cross_kv}


def whisper_init_cache(params: dict, cfg: ModelConfig,
                       frames: jax.Array, max_len: int) -> dict:
    """Runs the encoder and precomputes per-layer cross K/V."""
    enc = whisper_encode(params, cfg, frames)

    def body(_, pl):
        return None, _cross_kv(pl, enc, cfg)

    _, (xk, xv) = jax.lax.scan(body, None, params["dec_blocks"])
    b = frames.shape[0]
    dt = dtype_of(cfg)
    e = cfg.encdec
    z = jnp.zeros((e.decoder_layers, b, cfg.num_kv_heads, max_len,
                   cfg.head_dim), dt)
    return {"k": z, "v": z, "xk": xk, "xv": xv}


def whisper_decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                        cache: dict, cache_pos, **_):
    b, s = tokens.shape
    x = jnp.take(params["emb"], tokens, axis=0)
    pos = jax.lax.dynamic_slice_in_dim(params["pos_emb"], cache_pos, s, 0) \
        if not isinstance(cache_pos, int) else params["pos_emb"][cache_pos:cache_pos + s]
    x = x + pos[None]

    def body(xx, inp):
        pl, k, v, xk, xv = inp
        xx, new_self = _dec_layer(pl, xx, cfg, cross=(xk, xv),
                                  cache={"k": k, "v": v}, cache_pos=cache_pos)
        return xx, new_self

    x, new_kv = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    h = layernorm(params["dec_final"], x)
    logits = jnp.einsum("bsd,dv->bsv", h, params["emb"].T,
                        preferred_element_type=F32)
    return mask_padded_vocab(cfg, logits), {"k": new_kv["k"], "v": new_kv["v"],
                    "xk": cache["xk"], "xv": cache["xv"]}
