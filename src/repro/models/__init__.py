"""Model zoo: composable pure-JAX definitions for all assigned families."""
