"""Decoder-only LM family (gemma2, qwen2.5, mixtral, deepseek-v3, qwen2-vl).

Layers are scanned in *pattern blocks*: the repeating unit of
``cfg.attn_pattern`` (e.g. gemma2's (local, global)) forms one scan step, so
per-layer heterogeneity is static inside the block while the HLO stays
O(pattern) instead of O(num_layers) — essential for the 40-cell dry-run's
compile times.  MoE configs with ``first_k_dense`` (deepseek) run the dense
prefix as a second scan group.

Public entry points:
  init_lm / lm_forward (train)          — full-sequence causal logits
  lm_prefill / lm_decode_step (serve)   — KV-cache paths (MLA: absorbed cache)
  lm_cache_specs                        — ShapeDtypeStructs for input_specs()
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models import mla as mla_lib
from repro.models import moe as moe_lib
from repro.models.config import ModelConfig
from repro.models.layers import (F32, attention, dense_init, dtype_of, mask_padded_vocab,
                                 init_attention, init_mlp, init_rmsnorm, mlp,
                                 rmsnorm)
from repro.runtime import maybe_dequant, maybe_remat
from repro.sharding import shard


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _is_moe_layer(cfg: ModelConfig, i: int) -> bool:
    return cfg.moe is not None and i >= cfg.moe.first_k_dense


def _init_layer(key, cfg: ModelConfig, i: int) -> dict:
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    p = {"ln1": init_rmsnorm(cfg.d_model, dt),
         "ln2": init_rmsnorm(cfg.d_model, dt)}
    if cfg.mla is not None:
        p["attn"] = mla_lib.init_mla(ks[0], cfg)
    else:
        p["attn"] = init_attention(ks[0], cfg)
    if _is_moe_layer(cfg, i):
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    if cfg.post_norms:
        p["post_ln1"] = init_rmsnorm(cfg.d_model, dt)
        p["post_ln2"] = init_rmsnorm(cfg.d_model, dt)
    return p


def _stack(trees: list) -> dict:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_lm(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    params: dict = {
        "emb": dense_init(ks[0], (cfg.padded_vocab, cfg.d_model), dt, scale=0.02),
        "final_norm": init_rmsnorm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["unemb"] = dense_init(ks[1], (cfg.d_model, cfg.padded_vocab), dt,
                                     scale=0.02)
    u = len(cfg.attn_pattern)
    first_dense = cfg.moe.first_k_dense if cfg.moe is not None else 0
    lkeys = jax.random.split(ks[2], cfg.num_layers)
    if first_dense:
        params["dense_blocks"] = _stack(
            [_init_layer(lkeys[i], cfg, i) for i in range(first_dense)])
    rest = list(range(first_dense, cfg.num_layers))
    n_blocks, tail = divmod(len(rest), u)
    if n_blocks:
        groups = []
        for slot in range(u):
            groups.append(_stack([
                _init_layer(lkeys[rest[b * u + slot]], cfg, rest[b * u + slot])
                for b in range(n_blocks)]))
        params["blocks"] = {f"slot{j}": g for j, g in enumerate(groups)}
    if tail:
        params["tail"] = [
            _init_layer(lkeys[i], cfg, i) for i in rest[n_blocks * u:]]
    if cfg.mtp:
        params["mtp"] = {
            "layer": _init_layer(ks[3], cfg, cfg.num_layers),
            "norm_h": init_rmsnorm(cfg.d_model, dt),
            "norm_e": init_rmsnorm(cfg.d_model, dt),
            "proj": dense_init(jax.random.fold_in(ks[3], 1),
                               (2 * cfg.d_model, cfg.d_model), dt),
        }
    return params


# ---------------------------------------------------------------------------
# Layer apply
# ---------------------------------------------------------------------------

def _apply_layer(p: dict, x: jax.Array, cfg: ModelConfig, *, kind: str,
                 is_moe: bool, positions, mrope_positions, cache, cache_pos):
    p = maybe_dequant(p, dtype_of(cfg))
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a, new_cache = mla_lib.mla_attention(
            p["attn"], h, cfg, positions=positions, cache=cache,
            cache_pos=cache_pos)
    else:
        ring = None
        if (cache is not None and kind == "local" and cfg.window is not None
                and cache["k"].shape[2] == cfg.window):
            ring = cfg.window
        a, new_cache = attention(
            p["attn"], h, cfg, kind=kind, positions=positions,
            mrope_positions=mrope_positions, cache=cache, cache_pos=cache_pos,
            use_rope=cfg.use_rope, ring_window=ring)
    if cfg.post_norms:
        a = rmsnorm(p["post_ln1"], a, cfg.norm_eps)
    x = x + a
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if is_moe:
        f, aux = moe_lib.moe_block(p["moe"], h, cfg)
    else:
        f, aux = mlp(p["mlp"], h, act=cfg.mlp_act), jnp.zeros((), F32)
    if cfg.post_norms:
        f = rmsnorm(p["post_ln2"], f, cfg.norm_eps)
    x = x + f
    x = shard(x, "batch", "seq", None)
    return x, aux, new_cache


def _scan_blocks(params: dict, x: jax.Array, cfg: ModelConfig, *,
                 positions, mrope_positions, caches=None, cache_pos=None):
    """Runs dense prefix + pattern-block scan + tail.  Returns
    (x, total_aux, new_caches_or_None)."""
    u = len(cfg.attn_pattern)
    first_dense = cfg.moe.first_k_dense if cfg.moe is not None else 0
    aux_total = jnp.zeros((), F32)
    new_caches: dict = {}

    if "dense_blocks" in params:
        db = params["dense_blocks"]
        cs = caches.get("dense") if caches else None

        if cs is not None:
            def dense_body(carry, inp):
                xx, aux = carry
                pl, cache_l = inp
                xx, a, nc = _apply_layer(pl, xx, cfg, kind=cfg.layer_kind(0),
                                         is_moe=False, positions=positions,
                                         mrope_positions=mrope_positions,
                                         cache=cache_l, cache_pos=cache_pos)
                return (xx, aux + a), nc
            (x, aux_total), nc = jax.lax.scan(maybe_remat(dense_body), (x, aux_total), (db, cs))
            new_caches["dense"] = nc
        else:
            def dense_body_nc(carry, pl):
                xx, aux = carry
                xx, a, _ = _apply_layer(pl, xx, cfg, kind=cfg.layer_kind(0),
                                        is_moe=False, positions=positions,
                                        mrope_positions=mrope_positions,
                                        cache=None, cache_pos=None)
                return (xx, aux + a), None
            (x, aux_total), _ = jax.lax.scan(maybe_remat(dense_body_nc), (x, aux_total), db)

    if "blocks" in params:
        blocks = params["blocks"]
        n_blocks = jax.tree.leaves(blocks["slot0"])[0].shape[0]
        first_dense_i = first_dense

        def block_body(carry, inp):
            xx, aux = carry
            pb = inp[0] if caches else inp
            cb = inp[1] if caches else None
            ncs = {}
            for j in range(u):
                i = first_dense_i + j            # layer index within pattern
                xx, a, nc = _apply_layer(
                    pb[f"slot{j}"], xx, cfg, kind=cfg.attn_pattern[j % u],
                    is_moe=_is_moe_layer(cfg, first_dense_i + j),
                    positions=positions, mrope_positions=mrope_positions,
                    cache=cb[f"slot{j}"] if cb is not None else None,
                    cache_pos=cache_pos)
                aux = aux + a
                if nc is not None:
                    ncs[f"slot{j}"] = nc
            return (xx, aux), (ncs if ncs else None)

        if caches:
            (x, aux_total), ncs = jax.lax.scan(
                maybe_remat(block_body), (x, aux_total),
                (blocks, caches["blocks"]))
            new_caches["blocks"] = ncs
        else:
            (x, aux_total), _ = jax.lax.scan(maybe_remat(block_body), (x, aux_total), blocks)

    if "tail" in params:
        for t_i, pl in enumerate(params["tail"]):
            i = cfg.num_layers - len(params["tail"]) + t_i
            cache_l = caches["tail"][t_i] if caches else None
            x, a, nc = _apply_layer(pl, x, cfg, kind=cfg.layer_kind(i),
                                    is_moe=_is_moe_layer(cfg, i),
                                    positions=positions,
                                    mrope_positions=mrope_positions,
                                    cache=cache_l, cache_pos=cache_pos)
            aux_total = aux_total + a
            if nc is not None:
                new_caches.setdefault("tail", []).append(nc)

    return x, aux_total, (new_caches if caches else None)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens=None, embeddings=None):
    if embeddings is None:
        x = jnp.take(params["emb"], tokens, axis=0)
    else:
        x = embeddings.astype(dtype_of(cfg))
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return shard(x, "batch", "seq", None)


def _unembed(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    w = params.get("unemb")
    if w is None:
        w = params["emb"].T
    logits = jnp.einsum("bsd,dv->bsv", h, w, preferred_element_type=F32)
    if cfg.logit_softcap is not None:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    logits = mask_padded_vocab(cfg, logits)
    return shard(logits, "batch", None, "vocab")


def lm_forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
               positions=None, mrope_positions=None,
               embeddings=None, want_hidden: bool = False) -> dict:
    """Training forward: tokens (B, S) -> f32 logits (B, S, V) + aux loss."""
    x = _embed(params, cfg, tokens, embeddings)
    x, aux, _ = _scan_blocks(params, x, cfg, positions=positions,
                             mrope_positions=mrope_positions)
    out = {"aux_loss": aux / max(cfg.num_layers, 1)}
    if cfg.mtp and "mtp" in params:
        out["mtp_hidden"] = x            # combined with shifted emb in loss
    if want_hidden:
        # Chunked-loss path: the caller computes CE from hidden states
        # without ever materializing the (B, S, V) logits.
        out["hidden"] = x
        return out
    out["logits"] = _unembed(params, cfg, x)
    return out


def mtp_logits(params: dict, cfg: ModelConfig, hidden: jax.Array,
               next_tokens: jax.Array) -> jax.Array:
    """deepseek-v3 multi-token prediction head: predict t+2 from
    (hidden_t, emb(token_{t+1}))."""
    m = params["mtp"]
    e = _embed(params, cfg, next_tokens)
    h = jnp.concatenate([rmsnorm(m["norm_h"], hidden, cfg.norm_eps),
                         rmsnorm(m["norm_e"], e, cfg.norm_eps)], axis=-1)
    h = jnp.einsum("bsd,dk->bsk", h, m["proj"],
                   preferred_element_type=F32).astype(hidden.dtype)

    def _mtp_block(pl, hh):
        out, _, _ = _apply_layer(pl, hh, cfg, kind="global",
                                 is_moe=_is_moe_layer(cfg, cfg.num_layers),
                                 positions=None, mrope_positions=None,
                                 cache=None, cache_pos=None)
        return out

    h = maybe_remat(_mtp_block)(m["layer"], h)
    return _unembed(params, cfg, h)


# ----------------------------- serving ------------------------------------

def _cache_shape_layer(cfg: ModelConfig, batch: int, max_len: int, *,
                       kind: str = "global", ring_local: bool = False):
    dt = dtype_of(cfg)
    if cfg.mla is not None:
        return mla_lib.mla_cache_shape(cfg, batch, max_len)
    size = max_len
    if ring_local and kind == "local" and cfg.window is not None:
        # Sliding-window layers only ever attend the last `window` tokens —
        # a ring buffer of exactly that size is lossless (the §Perf decode
        # memory-term lever: gemma2's 23 local layers shrink 8x at 32k).
        size = min(cfg.window, max_len)
    return {
        "k": jax.ShapeDtypeStruct((batch, cfg.num_kv_heads, size,
                                   cfg.head_dim), dt),
        "v": jax.ShapeDtypeStruct((batch, cfg.num_kv_heads, size,
                                   cfg.head_dim), dt),
    }


def lm_cache_specs(cfg: ModelConfig, batch: int, max_len: int, *,
                   ring_local: bool = False) -> dict:
    """ShapeDtypeStruct pytree matching _scan_blocks' cache layout."""
    u = len(cfg.attn_pattern)
    first_dense = cfg.moe.first_k_dense if cfg.moe is not None else 0
    rest = cfg.num_layers - first_dense
    n_blocks, tail = divmod(rest, u)

    def one(kind):
        return _cache_shape_layer(cfg, batch, max_len, kind=kind,
                                  ring_local=ring_local)

    def stacked(kind, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype),
            one(kind))

    specs: dict = {}
    if first_dense:
        specs["dense"] = stacked(cfg.layer_kind(0), first_dense)
    if n_blocks:
        specs["blocks"] = {f"slot{j}": stacked(cfg.attn_pattern[j], n_blocks)
                           for j in range(u)}
    if tail:
        specs["tail"] = [one(cfg.layer_kind(cfg.num_layers - tail + j))
                         for j in range(tail)]
    return specs


def lm_init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                  ring_local: bool = False) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        lm_cache_specs(cfg, batch, max_len,
                                       ring_local=ring_local))


def lm_decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                   cache: dict, cache_pos, *, mrope_positions=None,
                   embeddings=None) -> tuple[jax.Array, dict]:
    """One decode step.  tokens (B, s_small); cache as lm_init_cache.
    Returns (logits (B, s, V), new_cache)."""
    x = _embed(params, cfg, tokens, embeddings)
    x, _, new_caches = _scan_blocks(
        params, x, cfg, positions=None, mrope_positions=mrope_positions,
        caches=cache, cache_pos=cache_pos)
    return _unembed(params, cfg, x), new_caches


def lm_prefill(params: dict, cfg: ModelConfig, tokens: jax.Array,
               max_len: int, *, mrope_positions=None, embeddings=None):
    """Prefill: runs the full prompt through the decode path chunk-free by
    treating the whole prompt as one 'step' written at position 0."""
    b, s = tokens.shape[:2]
    cache = lm_init_cache(cfg, b, max_len)
    return lm_decode_step(params, cfg, tokens, cache, 0,
                          mrope_positions=mrope_positions,
                          embeddings=embeddings)
