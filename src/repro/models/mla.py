"""Multi-head Latent Attention (deepseek-v3).

Train/prefill use the *naive* expansion (latents decompressed to full per-head
K/V, then ordinary attention).  Decode uses the *absorbed* form: the KV cache
stores only the compressed latent ``c_kv`` (kv_lora_rank) plus the shared
rope key (qk_rope_head_dim) per token — 576 values/token instead of
``2 * H * 192`` — and the up-projections are absorbed into the query/output
paths.  This asymmetric pairing is exactly why the deepseek decode cells fit
where GQA-sized caches would not (EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (F32, apply_rope, chunked_attention,
                                 dense_init, dtype_of, init_rmsnorm, rmsnorm,
                                 rope_table)
from repro.sharding import shard


def init_mla(key, cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    return {
        "wdq": dense_init(ks[0], (d, m.q_lora_rank), dt),
        "q_norm": init_rmsnorm(m.q_lora_rank, dt),
        "wuq": dense_init(ks[1], (m.q_lora_rank, h * qk_dim), dt),
        "wdkv": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dt),
        "wukv": dense_init(ks[3], (m.kv_lora_rank,
                                   h * (m.qk_nope_head_dim + m.v_head_dim)), dt),
        "wo": dense_init(ks[4], (h * m.v_head_dim, d), dt,
                         scale=1.0 / math.sqrt(h * m.v_head_dim)),
    }


def _latents(p: dict, x: jax.Array, cfg: ModelConfig):
    """Shared down-projection: returns (c_kv (B,S,r), k_rope (B,1,S,dr))."""
    m = cfg.mla
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"], preferred_element_type=F32)
    ckv = ckv.astype(x.dtype)
    c_kv, k_rope = ckv[..., :m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
    c_kv = rmsnorm(p["kv_norm"], c_kv, cfg.norm_eps)
    return c_kv, k_rope[:, None]          # k_rope as a single shared "head"


def _queries(p: dict, x: jax.Array, cfg: ModelConfig, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = jnp.einsum("bsd,dr->bsr", x, p["wdq"], preferred_element_type=F32)
    cq = rmsnorm(p["q_norm"], cq.astype(x.dtype), cfg.norm_eps)
    q = jnp.einsum("bsr,rq->bsq", cq, p["wuq"], preferred_element_type=F32)
    q = q.astype(x.dtype).reshape(b, s, h, qk).transpose(0, 2, 1, 3)
    q = shard(q, "batch", "heads", None, None)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    cos, sin = rope_table(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope, (cos, sin)


def mla_attention(p: dict, x: jax.Array, cfg: ModelConfig, *,
                  positions: jax.Array | None = None,
                  cache: dict | None = None,
                  cache_pos=None) -> tuple[jax.Array, dict | None]:
    """MLA forward.  Cache (decode): {"c_kv": (B,S,r), "k_rope": (B,1,S,dr)}."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    if positions is None:
        base = jnp.arange(s) if cache_pos is None else cache_pos + jnp.arange(s)
        positions = jnp.broadcast_to(base, (b, s))
    q_nope, q_rope, (cos, sin) = _queries(p, x, cfg, positions)
    c_kv, k_rope = _latents(p, x, cfg)
    k_rope = apply_rope(k_rope, cos, sin)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)

    wukv = p["wukv"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wukv[..., :m.qk_nope_head_dim]          # (r, H, dn)
    w_uv = wukv[..., m.qk_nope_head_dim:]          # (r, H, dv)

    if cache is None or s > 1:
        # Naive expansion for train/prefill (flash-chunked, no (S,S) logits).
        k_nope = jnp.einsum("bsr,rhd->bhsd", c_kv, w_uk,
                            preferred_element_type=F32).astype(x.dtype)
        v = jnp.einsum("bsr,rhd->bhsd", c_kv, w_uv,
                       preferred_element_type=F32).astype(x.dtype)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, h) + k_rope.shape[2:])], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        out = chunked_attention(q, k, v, causal=True, scale=scale)
        new_cache = None
        if cache is not None:     # prefill: also publish the compressed cache
            new_cache = {
                "c_kv": jax.lax.dynamic_update_slice(
                    cache["c_kv"], c_kv, (0, cache_pos, 0)),
                "k_rope": jax.lax.dynamic_update_slice(
                    cache["k_rope"], k_rope, (0, 0, cache_pos, 0)),
            }
    else:
        # Absorbed decode: scores in latent space, cache stays compressed.
        c_buf = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv,
                                             (0, cache_pos, 0))
        r_buf = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope,
                                             (0, 0, cache_pos, 0))
        new_cache = {"c_kv": c_buf, "k_rope": r_buf}
        q_lat = jnp.einsum("bhsd,rhd->bhsr", q_nope.astype(F32), w_uk.astype(F32),
                           preferred_element_type=F32)       # absorb W_UK
        s_lat = jnp.einsum("bhsr,btr->bhst", q_lat, c_buf.astype(F32),
                           preferred_element_type=F32)
        s_rope = jnp.einsum("bhsd,bxtd->bhst", q_rope.astype(F32),
                            r_buf.astype(F32), preferred_element_type=F32)
        logits = (s_lat + s_rope) * scale
        last = cache_pos + s - 1
        t_pos = jnp.arange(c_buf.shape[1])
        q_pos = last - (s - 1) + jnp.arange(s)
        mask = t_pos[None, :] <= q_pos[:, None]
        logits = jnp.where(mask[None, None],
                           logits, -0.7 * jnp.finfo(F32).max)
        probs = jax.nn.softmax(logits, axis=-1)
        probs = jnp.where(mask[None, None], probs, 0.0)
        o_lat = jnp.einsum("bhst,btr->bhsr", probs, c_buf.astype(F32),
                           preferred_element_type=F32)
        out = jnp.einsum("bhsr,rhd->bhsd", o_lat, w_uv.astype(F32),
                         preferred_element_type=F32).astype(x.dtype)

    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim)
    y = jnp.einsum("bsq,qd->bsd", out, p["wo"], preferred_element_type=F32)
    return y.astype(x.dtype), new_cache


def mla_cache_shape(cfg: ModelConfig, batch: int, max_len: int):
    m = cfg.mla
    dt = dtype_of(cfg)
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dt),
        "k_rope": jax.ShapeDtypeStruct((batch, 1, max_len, m.qk_rope_head_dim), dt),
    }
