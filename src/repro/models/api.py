"""Family-dispatching model API: one surface for train/serve/dry-run code.

  init(cfg, key)                        -> params
  forward(params, cfg, batch)           -> {"logits", "aux_loss", ...}
  decode_state_specs(cfg, batch, ...)   -> ShapeDtypeStruct pytree
  init_decode_state(...)                -> zeroed state
  decode_step(params, cfg, tokens, state, pos) -> (logits, new_state)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import encdec, griffin, rwkv, transformer
from repro.models.config import ModelConfig


def init(cfg: ModelConfig, key) -> dict:
    if cfg.family == "transformer":
        return transformer.init_lm(key, cfg)
    if cfg.family == "encdec":
        return encdec.init_whisper(key, cfg)
    if cfg.family == "rwkv":
        return rwkv.init_rwkv(key, cfg)
    if cfg.family == "griffin":
        return griffin.init_griffin(key, cfg)
    raise ValueError(cfg.family)


def abstract_params(cfg: ModelConfig) -> dict:
    """Shape-only params (no allocation) — dry-run uses this."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: init(cfg, k), key)


def forward(params: dict, cfg: ModelConfig, batch: dict) -> dict:
    """batch: {"tokens": (B,S)} + family extras (encoder_frames,
    mrope_positions, embeddings)."""
    kw = {}
    for k in ("mrope_positions", "embeddings", "encoder_frames"):
        if k in batch:
            kw[k] = batch[k]
    if cfg.family == "transformer":
        return transformer.lm_forward(params, cfg, batch["tokens"], **kw)
    if cfg.family == "encdec":
        return encdec.whisper_forward(params, cfg, batch["tokens"], **kw)
    if cfg.family == "rwkv":
        return rwkv.rwkv_forward(params, cfg, batch["tokens"], **kw)
    if cfg.family == "griffin":
        return griffin.griffin_forward(params, cfg, batch["tokens"], **kw)
    raise ValueError(cfg.family)


def decode_state_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    if cfg.family == "transformer":
        return transformer.lm_cache_specs(cfg, batch, max_len)
    if cfg.family == "encdec":
        return encdec.whisper_cache_specs(cfg, batch, max_len)
    if cfg.family == "rwkv":
        return rwkv.rwkv_state_specs(cfg, batch)
    if cfg.family == "griffin":
        window = cfg.griffin.local_window
        return griffin.griffin_state_specs(cfg, batch,
                                           min(window, max_len))
    raise ValueError(cfg.family)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        decode_state_specs(cfg, batch, max_len))


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                state: dict, cache_pos, *, extras: dict | None = None):
    kw = dict(extras or {})
    if cfg.family == "transformer":
        return transformer.lm_decode_step(params, cfg, tokens, state,
                                          cache_pos, **kw)
    if cfg.family == "encdec":
        return encdec.whisper_decode_step(params, cfg, tokens, state,
                                          cache_pos, **kw)
    if cfg.family == "rwkv":
        return rwkv.rwkv_decode_step(params, cfg, tokens, state, cache_pos,
                                     **kw)
    if cfg.family == "griffin":
        return griffin.griffin_decode_step(params, cfg, tokens, state,
                                           cache_pos, **kw)
    raise ValueError(cfg.family)
