"""RWKV-6 "Finch" family (rwkv6-7b): attention-free, data-dependent decay.

Structure per block: time-mixing (the RWKV6 recurrence with 5-way
data-dependent token-shift interpolation) + channel-mixing (squared-relu FFN
with token shift).  The paper's attention-sharding aspects are inapplicable
here (DESIGN.md §Arch-applicability); the tiling planner still governs every
projection GEMM, and the recurrence itself is the Pallas scan kernel
(``kernels/rwkv6.py``) on TPU.

The pure-JAX training path uses the **chunk-recurrent form**: time is split
into chunks of 32; within a chunk the recurrence collapses into three
matmuls (inter-chunk via the carried state, intra-chunk via a decay-weighted
lower-triangular product, plus the current-token bonus), and only the
chunk-boundary states are carried through ``lax.scan`` — O(T/C) backward
memory instead of O(T), and MXU-shaped compute.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (F32, dense_init, dtype_of, init_layernorm, mask_padded_vocab,
                                 init_rmsnorm, layernorm, rmsnorm)
from repro.runtime import maybe_dequant, maybe_remat
from repro.sharding import shard

_LORA_MIX = 32
_LORA_DECAY = 64
_CHUNK = 32


def _shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """Token shift: x[t-1] (zeros / carried state at t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def init_time_mix(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 10)
    h = d // cfg.rwkv_head_dim
    return {
        "mu_x": jnp.zeros((d,), dt),
        "mu_rkvwg": jnp.zeros((5, d), dt),
        "w1_mix": dense_init(ks[0], (d, 5 * _LORA_MIX), dt, scale=0.01),
        "w2_mix": dense_init(ks[1], (5, _LORA_MIX, d), dt, scale=0.01),
        "w0_decay": jnp.full((d,), -1.0, dt),      # base log-log decay
        "w1_decay": dense_init(ks[2], (d, _LORA_DECAY), dt, scale=0.01),
        "w2_decay": dense_init(ks[3], (_LORA_DECAY, d), dt, scale=0.01),
        "u_bonus": dense_init(ks[4], (d,), dt, scale=0.3),
        "wr": dense_init(ks[5], (d, d), dt),
        "wk": dense_init(ks[6], (d, d), dt),
        "wv": dense_init(ks[7], (d, d), dt),
        "wg": dense_init(ks[8], (d, d), dt),
        "wo": dense_init(ks[9], (d, d), dt),
        "gn": init_layernorm(cfg.rwkv_head_dim, dt),   # per-head group norm
    }


def init_channel_mix(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), dt),
        "mu_r": jnp.zeros((d,), dt),
        "wk": dense_init(ks[0], (d, f), dt),
        "wv": dense_init(ks[1], (f, d), dt, scale=1.0 / math.sqrt(f)),
        "wr": dense_init(ks[2], (d, d), dt),
    }


def rwkv6_chunked(r, k, v, w, u, *, chunk: int = _CHUNK,
                  state0: jax.Array | None = None):
    """Chunk-recurrent RWKV6.  r/k/v/w: (B, H, T, D); u: (H, D).
    Returns (out (B,H,T,D), final_state (B,H,D,D))."""
    b, h, t, d = r.shape
    pad = (-t) % chunk
    if pad:
        z = lambda a, c=0.0: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)),
                                     constant_values=c)
        r, k, v = z(r), z(k), z(v)
        w = z(w, 1.0)
    n = r.shape[2] // chunk
    rc = r.reshape(b, h, n, chunk, d).transpose(2, 0, 1, 3, 4).astype(F32)
    kc = k.reshape(b, h, n, chunk, d).transpose(2, 0, 1, 3, 4).astype(F32)
    vc = v.reshape(b, h, n, chunk, d).transpose(2, 0, 1, 3, 4).astype(F32)
    wc = w.reshape(b, h, n, chunk, d).transpose(2, 0, 1, 3, 4).astype(F32)
    s0 = state0 if state0 is not None else jnp.zeros((b, h, d, d), F32)
    mask = jnp.tril(jnp.ones((chunk, chunk), F32), k=-1)   # strict lower

    def step(s, inp):
        rr, kk, vv, ww = inp
        logw = jnp.log(jnp.maximum(ww, 1e-12))
        lp_incl = jnp.cumsum(logw, axis=2)                 # (B,H,C,D)
        lp_prev = lp_incl - logw                           # exclusive
        p_c = jnp.exp(lp_incl[:, :, -1:])                  # (B,H,1,D)
        r_t = rr * jnp.exp(lp_prev)
        k_t = kk * jnp.exp(-lp_incl)
        k_up = kk * jnp.exp(lp_incl[:, :, -1:] - lp_incl)
        inter = jnp.einsum("bhcd,bhde->bhce", r_t, s, preferred_element_type=F32)
        a = jnp.einsum("bhcd,bhsd->bhcs", r_t, k_t, preferred_element_type=F32)
        a = a * mask[None, None]
        intra = jnp.einsum("bhcs,bhse->bhce", a, vv, preferred_element_type=F32)
        diag = jnp.einsum("bhcd,bhcd->bhc", rr, u[None, :, None, :] * kk)
        out = inter + intra + diag[..., None] * vv
        s_new = p_c[:, :, 0][:, :, :, None] * s + jnp.einsum(
            "bhcd,bhce->bhde", k_up, vv, preferred_element_type=F32)
        return s_new, out

    s_fin, outs = jax.lax.scan(jax.checkpoint(step), s0, (rc, kc, vc, wc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, n * chunk, d)
    return out[:, :, :t].astype(r.dtype), s_fin


def time_mix(p: dict, x: jax.Array, cfg: ModelConfig, *,
             state: dict | None = None):
    """RWKV6 attention-analogue.  x: (B,T,D).  state (decode): {"prev","s"}."""
    b, t, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    prev = state["prev"] if state is not None else None
    xx = _shift(x, prev) - x
    xxx = x + xx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("btd,dr->btr", xxx, p["w1_mix"],
                               preferred_element_type=F32))
    lora = lora.reshape(b, t, 5, _LORA_MIX)
    mixes = jnp.einsum("btfr,frd->btfd", lora, p["w2_mix"].astype(F32),
                       preferred_element_type=F32)
    mixes = mixes + p["mu_rkvwg"].astype(F32)[None, None]
    xr, xk, xv, xw, xg = [x + xx * mixes[:, :, i].astype(x.dtype)
                          for i in range(5)]
    r = jnp.einsum("btd,de->bte", xr, p["wr"], preferred_element_type=F32)
    k = jnp.einsum("btd,de->bte", xk, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("btd,de->bte", xv, p["wv"], preferred_element_type=F32)
    g = jnp.einsum("btd,de->bte", xg, p["wg"], preferred_element_type=F32)
    dec = jnp.einsum("btr,rd->btd",
                     jnp.tanh(jnp.einsum("btd,dr->btr", xw, p["w1_decay"],
                                         preferred_element_type=F32)),
                     p["w2_decay"].astype(F32), preferred_element_type=F32)
    logw = -jnp.exp(jnp.clip(p["w0_decay"].astype(F32)[None, None] + dec,
                             -8.0, 4.0))
    w = jnp.exp(logw)                                   # decay in (0,1)

    to_heads = lambda a: a.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    rh, kh, vh, wh = map(to_heads, (r, k, v, w))
    rh = shard(rh.astype(x.dtype), "batch", "heads", None, None)
    u = p["u_bonus"].astype(F32).reshape(h, hd)

    if state is None or t > 1:
        out, s_fin = rwkv6_chunked(rh, kh.astype(x.dtype), vh.astype(x.dtype),
                                   wh.astype(F32), u,
                                   state0=state["s"] if state else None)
    else:
        # Single-token decode: one recurrence step.
        s = state["s"]
        kv = kh[:, :, 0, :, None].astype(F32) * vh[:, :, 0, None, :].astype(F32)
        out = jnp.einsum("bhd,bhde->bhe", rh[:, :, 0].astype(F32),
                         s + u[None, :, :, None] * kv)[:, :, None, :]
        s_fin = wh[:, :, 0, :, None].astype(F32) * s + kv
        out = out.astype(x.dtype)

    out = out.transpose(0, 2, 1, 3)                     # (B,T,H,hd)
    out = layernorm(p["gn"], out, 64e-5).reshape(b, t, d)
    out = out * jax.nn.silu(g.astype(F32)).astype(x.dtype)
    y = jnp.einsum("btd,de->bte", out, p["wo"], preferred_element_type=F32)
    new_state = None
    if state is not None:
        new_state = {"prev": x[:, -1:], "s": s_fin}
    return y.astype(x.dtype), new_state


def channel_mix(p: dict, x: jax.Array, cfg: ModelConfig, *,
                state: dict | None = None):
    prev = state["prev"] if state is not None else None
    xx = _shift(x, prev) - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.einsum("btd,df->btf", xk, p["wk"], preferred_element_type=F32)
    k = jnp.square(jnp.maximum(k, 0.0)).astype(x.dtype)
    k = shard(k, "batch", None, "mlp")
    v = jnp.einsum("btf,fd->btd", k, p["wv"], preferred_element_type=F32)
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"],
                                  preferred_element_type=F32))
    y = (r * v).astype(x.dtype)
    new_state = {"prev": x[:, -1:]} if state is not None else None
    return y, new_state


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_rwkv(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, cfg.num_layers + 3)

    def block(i):
        k1, k2 = jax.random.split(ks[i])
        return {"ln1": init_rmsnorm(cfg.d_model, dt),
                "tmix": init_time_mix(k1, cfg),
                "ln2": init_rmsnorm(cfg.d_model, dt),
                "cmix": init_channel_mix(k2, cfg)}

    blocks = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[block(i) for i in range(cfg.num_layers)])
    return {
        "emb": dense_init(ks[-1], (cfg.padded_vocab, cfg.d_model), dt, scale=0.02),
        "ln0": init_rmsnorm(cfg.d_model, dt),
        "blocks": blocks,
        "final_norm": init_rmsnorm(cfg.d_model, dt),
        "unemb": dense_init(ks[-2], (cfg.d_model, cfg.padded_vocab), dt,
                            scale=0.02),
    }


def _rwkv_block(pl, x, cfg, state):
    pl = maybe_dequant(pl)
    a, st_t = time_mix(pl["tmix"], rmsnorm(pl["ln1"], x, cfg.norm_eps), cfg,
                       state=state["tmix"] if state else None)
    x = x + a
    f, st_c = channel_mix(pl["cmix"], rmsnorm(pl["ln2"], x, cfg.norm_eps), cfg,
                          state=state["cmix"] if state else None)
    x = x + f
    x = shard(x, "batch", "seq", None)
    new_state = {"tmix": st_t, "cmix": st_c} if state else None
    return x, new_state


def rwkv_forward(params: dict, cfg: ModelConfig, tokens: jax.Array, **_) -> dict:
    x = jnp.take(params["emb"], tokens, axis=0)
    x = rmsnorm(params["ln0"], x, cfg.norm_eps)
    x = shard(x, "batch", "seq", None)

    def body(xx, pl):
        xx, _ = _rwkv_block(pl, xx, cfg, None)
        return xx, None

    x, _ = jax.lax.scan(maybe_remat(body), x, params["blocks"])
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["unemb"],
                        preferred_element_type=F32)
    logits = mask_padded_vocab(cfg, logits)
    return {"logits": shard(logits, "batch", None, "vocab"),
            "aux_loss": jnp.zeros((), F32)}


def rwkv_state_specs(cfg: ModelConfig, batch: int) -> dict:
    dt = dtype_of(cfg)
    h = cfg.d_model // cfg.rwkv_head_dim
    one = {"tmix": {"prev": jax.ShapeDtypeStruct((batch, 1, cfg.d_model), dt),
                    "s": jax.ShapeDtypeStruct(
                        (batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim), F32)},
           "cmix": {"prev": jax.ShapeDtypeStruct((batch, 1, cfg.d_model), dt)}}
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.num_layers,) + s.shape, s.dtype),
        one)


def rwkv_init_state(cfg: ModelConfig, batch: int) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        rwkv_state_specs(cfg, batch))


def rwkv_decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                     state: dict, cache_pos=None, **_):
    x = jnp.take(params["emb"], tokens, axis=0)
    x = rmsnorm(params["ln0"], x, cfg.norm_eps)

    def body(xx, inp):
        pl, st = inp
        xx, new_st = _rwkv_block(pl, xx, cfg, st)
        return xx, new_st

    x, new_state = jax.lax.scan(body, x, (params["blocks"], state))
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["unemb"],
                        preferred_element_type=F32)
    return mask_padded_vocab(cfg, logits), new_state
