"""Characterization CLI.

  PYTHONPATH=src python -m repro.characterize                      # quick sweep
  PYTHONPATH=src python -m repro.characterize --sweep full --out model.json
  PYTHONPATH=src python -m repro.characterize --terms gemm_int8 boundary

Runs the microbenchmark sweeps on THIS host, fits every cost term, prints a
per-term table (fitted constants + relative-RMS residual + source), and
writes the sha256-versioned ``MachineModel`` JSON artifact.  Feed it back to
the planner with ``python -m repro.plan <net> --machine-model model.json``.
"""

from __future__ import annotations

import argparse
import sys

from repro.characterize import model as modellib
from repro.characterize import sweeps as sweeplib


def _fmt_constant(name: str, value: float) -> str:
    if name.endswith("_s"):
        return f"{name}={value * 1e6:.3g}us"
    if "penalty" in name:
        return f"{name}={value:.4f}"
    return f"{name}={value:.3g}"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.characterize",
                                 description=__doc__)
    ap.add_argument("--sweep", choices=sweeplib.SWEEPS, default="quick",
                    help="grid density (quick ~10s wall, full is denser)")
    ap.add_argument("--out", default="model.json",
                    help="path for the MachineModel JSON artifact")
    ap.add_argument("--terms", nargs="+", choices=sweeplib.TERMS,
                    default=list(sweeplib.TERMS),
                    help="cost terms to characterize (default: all)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--iters", type=int, default=5,
                    help="timed iterations per sweep point (median taken)")
    args = ap.parse_args(argv)

    print(f"# characterizing {len(args.terms)} cost term(s), "
          f"sweep={args.sweep}")
    mm = modellib.characterize(sweep=args.sweep, batch=args.batch,
                               iters=args.iters, terms=tuple(args.terms))

    print(f"\n{'term':<12}{'source':<10}{'residual':>10}  constants")
    for term, f in mm.fits.items():
        consts = "  ".join(_fmt_constant(k, v)
                           for k, v in f.constants.items())
        print(f"{term:<12}{f.source:<10}{f.residual_rel_rms:>9.1%}  {consts}")

    path = mm.save(args.out)
    print(f"\nversion {mm.version[:16]}…  wrote {path}")
    print(f"use it:  python -m repro.plan <net> --machine-model {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
