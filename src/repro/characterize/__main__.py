"""Characterization CLI — DEPRECATED shim over
``python -m repro characterize``.

  PYTHONPATH=src python -m repro.characterize                      # quick sweep
  PYTHONPATH=src python -m repro.characterize --sweep full --out model.json
  PYTHONPATH=src python -m repro.characterize --terms gemm_int8 boundary

Same flags, same artifact — the implementation moved to the unified CLI
(:mod:`repro.cli`), which routes through the staged deployment facade's
characterize stage.  Prefer ``python -m repro characterize ...``.
"""

from __future__ import annotations

import sys


def main(argv: list[str] | None = None) -> int:
    from repro.cli import deprecated_main
    return deprecated_main("repro.characterize", "characterize", argv)


if __name__ == "__main__":
    sys.exit(main())
