"""Least-squares model fitting over characterization samples.

Each cost term is a linear model in its sweep's regressors, so one
``lstsq`` per term recovers the machine constants the planner charges —
the generalization of ``calibrate.calibrated_cpu_model``'s 2-constant fit
(launch overhead + inverse peak) to the full term set:

* ``gemm_int8``:  t = overhead * launches + inv_peak * padded_ops
* ``gemm_f32``:   t = overhead * launches + inv_peak * ops
* ``fused_chain``: t = const + inv_peak * padded_ops + epilogue * inner_layers
* ``boundary``:   t = const + dispatch * launches + per_byte * launch_bytes
* ``contention``: t = base * (1 + slope * n_band2)

Every :class:`TermFit` carries its relative-RMS residual so an artifact is
auditable: a term whose residual blew up says "this host does not behave
linearly in this regressor", not "trust these constants".
"""

from __future__ import annotations

import dataclasses
import math

from repro.characterize.harness import Sample

# regressor design per term (column order matters: constants map 1:1).
_DESIGNS = {
    "gemm_int8": ("launches", "padded_ops"),
    "gemm_f32": ("launches", "ops"),
    "fused_chain": ("one", "padded_ops", "inner_layers"),
    "boundary": ("one", "launches", "launch_bytes"),
    "contention": ("one", "n_band2"),
}
# Wall-clock terms vs analytical-curve terms (artifact provenance labels).
_SOURCES = {"gemm_int8": "measured", "gemm_f32": "measured",
            "fused_chain": "measured", "boundary": "measured",
            "contention": "model"}


@dataclasses.dataclass(frozen=True)
class TermFit:
    """One fitted cost term: named constants + fit-quality evidence."""
    term: str
    constants: dict                # name -> fitted value (clamped, derived)
    coefficients: tuple            # raw lstsq solution, design order
    residual_rel_rms: float        # rms(pred - t) / mean(t)
    n_samples: int
    source: str                    # "measured" (wall clock) | "model"

    def to_dict(self) -> dict:
        return {"term": self.term, "constants": dict(self.constants),
                "coefficients": list(self.coefficients),
                "residual_rel_rms": self.residual_rel_rms,
                "n_samples": self.n_samples, "source": self.source}

    @classmethod
    def from_dict(cls, d: dict) -> "TermFit":
        return cls(term=d["term"], constants=dict(d["constants"]),
                   coefficients=tuple(d["coefficients"]),
                   residual_rel_rms=d["residual_rel_rms"],
                   n_samples=d["n_samples"], source=d["source"])


def _lstsq(samples: list[Sample], columns: tuple) -> tuple[tuple, float]:
    import numpy as np
    a = np.array([[s.regressors.get(c, 1.0 if c == "one" else 0.0)
                   for c in columns] for s in samples])
    t = np.array([s.seconds for s in samples])
    coef, *_ = np.linalg.lstsq(a, t, rcond=None)
    pred = a @ coef
    mean = float(np.mean(t)) or 1.0
    rel = float(np.sqrt(np.mean((pred - t) ** 2))) / mean
    return tuple(float(c) for c in coef), rel


def _constants_for(term: str, coef: tuple) -> dict:
    """Map raw coefficients to the named machine constants, with the
    physical clamps the planner needs (positive peaks, non-negative costs)."""
    if term == "gemm_int8":
        overhead, inv_peak = coef
        peak = 1.0 / inv_peak if inv_peak > 1e-15 else 1e12
        return {"kernel_overhead_s": max(overhead, 1e-6),
                "peak_int8_ops": max(peak, 1e6)}
    if term == "gemm_f32":
        _, inv_peak = coef
        peak = 1.0 / inv_peak if inv_peak > 1e-15 else 1e12
        return {"peak_flops": max(peak, 5e5)}
    if term == "fused_chain":
        _, _, epilogue = coef
        # The fused launch's own dispatch and throughput are characterized
        # by the gemm_int8 term; this sweep isolates what keeping a layer
        # boundary INSIDE the kernel costs (the epilogue requantize).
        return {"fused_epilogue_s": max(epilogue, 0.0)}
    if term == "boundary":
        _, dispatch, per_byte = coef
        # crossing_cost_tpu charges 2*bytes/hbm_bw per boundary; invert the
        # fitted per-byte slope into that effective bandwidth.  A slope at or
        # below noise means the round trip is unmeasurably cheap here ->
        # effectively infinite bandwidth (overhead-bound host).
        hbm_bw = 2.0 / per_byte if per_byte > 1e-18 else 1e15
        return {"dispatch_s": max(dispatch, 0.0), "hbm_bw": hbm_bw}
    if term == "contention":
        base, slope_abs = coef
        slope = slope_abs / base if base > 0 else 0.0
        return {"band2_penalty_per_layer": max(slope, 0.0)}
    raise ValueError(f"unknown term {term!r}")


def fit_term(term: str, samples: list[Sample]) -> TermFit:
    """Fit one cost term from its sweep samples."""
    rows = [s for s in samples if s.term == term]
    if len(rows) < len(_DESIGNS[term]):
        raise ValueError(f"term {term!r} needs >= {len(_DESIGNS[term])} "
                         f"samples, got {len(rows)}")
    coef, rel = _lstsq(rows, _DESIGNS[term])
    if not math.isfinite(rel):
        raise ValueError(f"term {term!r} fit diverged (residual={rel})")
    return TermFit(term=term, constants=_constants_for(term, coef),
                   coefficients=coef, residual_rel_rms=rel,
                   n_samples=len(rows), source=_SOURCES[term])


def fit_all(samples: list[Sample]) -> dict[str, TermFit]:
    """Fit every term present in the sample set."""
    terms = []
    for s in samples:                      # preserve first-seen term order
        if s.term not in terms:
            terms.append(s.term)
    return {t: fit_term(t, samples) for t in terms}
