"""Characterization harness: measured machine-model artifacts.

The paper's method is *systematic architectural characterization and
micro-benchmarking* feeding the LARE decision rule; this package is that
layer.  ``harness`` times the primitives the planner charges (multi-launch
int8 GEMM pipelines, float matmul chains, un-fused launch boundaries,
band-2 contention), ``sweeps`` parameterizes them into quick/full grids,
``fit`` least-squares-fits each cost term, and ``model`` packages the result
as a sha256-versioned :class:`MachineModel` JSON artifact with provenance.

The planner consumes the artifact directly::

    mm = characterize(sweep="quick")          # or MachineModel.load(path)
    plan = plan_deployment(cfg, machine_model=mm)

and mixes ``mm.version`` into the plan cache key, so plans made under a
stale model self-invalidate.  CLI::

    PYTHONPATH=src python -m repro.characterize --sweep quick --out model.json
    PYTHONPATH=src python -m repro.plan jet_tagger --machine-model model.json
"""

from repro.characterize.fit import TermFit, fit_all, fit_term
from repro.characterize.harness import Sample
from repro.characterize.model import (MODEL_SCHEMA_VERSION, MachineModel,
                                      characterize)
from repro.characterize.sweeps import SWEEPS, TERMS, run_sweep, run_term

__all__ = [
    "MODEL_SCHEMA_VERSION", "MachineModel", "SWEEPS", "Sample", "TERMS",
    "TermFit", "characterize", "fit_all", "fit_term", "run_sweep", "run_term",
]
