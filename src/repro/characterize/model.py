"""MachineModel — the versioned, serializable characterization artifact.

A ``MachineModel`` is what replaces hand-tuned ``hw.py`` constants: the
fitted cost terms (:class:`repro.characterize.fit.TermFit`) plus provenance
(host, jax version, sweep grids, residuals).  Its ``version`` is a sha256
over the SEMANTIC content — schema + fitted constants — so two runs that fit
the same constants agree on version, any constant change produces a new one,
and the plan cache (which mixes the version into the plan key) invalidates
stale plans automatically.

Consumers never read the fits directly; they ask for re-parameterized
hardware models::

    mm = characterize(sweep="quick")
    plan = plan_deployment(cfg, target="tpu", machine_model=mm)
    # planner internally uses mm.tpu(base=hw.TPU_V5E) / mm.aie(base=hw.AIE_ML)

JSON schema (``MODEL_SCHEMA_VERSION``)::

    {"schema": 1, "version": "<sha256>",
     "fits": {"gemm_int8": {"constants": {...}, "residual_rel_rms": ...},
              ...},
     "provenance": {"host": ..., "jax": ..., "sweep": ..., "grids": {...}}}
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib

from repro import hw as hwlib
from repro.characterize import fit as fitlib
from repro.characterize import sweeps as sweeplib
from repro.characterize.fit import TermFit

MODEL_SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Fitted machine-model artifact: cost-term fits + provenance."""
    fits: dict                     # term -> TermFit
    provenance: dict
    schema: int = MODEL_SCHEMA_VERSION

    # -- identity ---------------------------------------------------------
    @property
    def version(self) -> str:
        """sha256 over schema + the fitted CONSTANTS — the only part of a
        fit the planner reads.  Not provenance, not residuals, not raw
        coefficients: two characterization runs that land on the same
        clamped constants agree on version (so cached plans survive a
        re-characterization that changed nothing), and any constant change
        produces a new one."""
        payload = {"schema": self.schema,
                   "fits": {t: dict(f.constants) for t, f in
                            sorted(self.fits.items())}}
        blob = json.dumps(payload, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()

    def constant(self, term: str, name: str, default=None):
        f = self.fits.get(term)
        if f is None:
            return default
        return f.constants.get(name, default)

    def residuals(self) -> dict:
        return {t: f.residual_rel_rms for t, f in self.fits.items()}

    # -- hardware-model substitution --------------------------------------
    def tpu(self, base: hwlib.TpuV5e = hwlib.TPU_V5E) -> hwlib.TpuV5e:
        """``base`` with every TPU-side fitted constant substituted."""
        kw = {}
        overhead = self.constant("gemm_int8", "kernel_overhead_s")
        if overhead is not None:
            kw["kernel_overhead_s"] = overhead
        peak_i8 = self.constant("gemm_int8", "peak_int8_ops")
        if peak_i8 is not None:
            kw["peak_int8_ops"] = peak_i8
            # fall back to the int8-derived float peak unless gemm_f32 ran
            kw["peak_bf16_flops"] = max(peak_i8 / 2, 5e5)
        peak_f = self.constant("gemm_f32", "peak_flops")
        if peak_f is not None:
            kw["peak_bf16_flops"] = peak_f
        bw = self.constant("boundary", "hbm_bw")
        if bw is not None:
            kw["hbm_bw"] = bw
        epilogue = self.constant("fused_chain", "fused_epilogue_s")
        if epilogue is not None:
            kw["fused_epilogue_s"] = epilogue
        return dataclasses.replace(base, **kw) if kw else base

    def aie(self, base: hwlib.AieMl = hwlib.AIE_ML) -> hwlib.AieMl:
        """``base`` with every AIE-side fitted constant substituted."""
        slope = self.constant("contention", "band2_penalty_per_layer")
        if slope is None:
            return base
        return dataclasses.replace(base, band2_penalty_per_layer=slope)

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> dict:
        return {"schema": self.schema, "version": self.version,
                "fits": {t: f.to_dict() for t, f in self.fits.items()},
                "provenance": dict(self.provenance)}

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "MachineModel":
        if d.get("schema") != MODEL_SCHEMA_VERSION:
            raise ValueError(f"unsupported machine-model schema: "
                             f"{d.get('schema')!r}")
        mm = cls(fits={t: TermFit.from_dict(f) for t, f in d["fits"].items()},
                 provenance=dict(d.get("provenance", {})))
        want = d.get("version")
        if want is not None and want != mm.version:
            raise ValueError(
                f"machine-model version mismatch: artifact says "
                f"{want[:12]}…, content hashes to {mm.version[:12]}… "
                f"(artifact edited by hand?)")
        return mm

    @classmethod
    def from_json(cls, s: str) -> "MachineModel":
        return cls.from_dict(json.loads(s))

    def save(self, path: str | os.PathLike) -> pathlib.Path:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_json() + "\n")
        return p

    @classmethod
    def load(cls, path: str | os.PathLike) -> "MachineModel":
        return cls.from_json(pathlib.Path(path).read_text())


def _provenance(sweep: str, batch: int, iters: int, terms) -> dict:
    import platform
    try:
        import jax
        jax_version = jax.__version__
        backend = jax.default_backend()
    except Exception:                       # characterization without jax
        jax_version, backend = "unavailable", "none"
    return {
        "host": platform.node(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "jax": jax_version,
        "backend": backend,
        "sweep": sweep,
        "batch": batch,
        "iters": iters,
        "grids": {t: [list(g) if isinstance(g, tuple) else g
                      for g in sweeplib.grid(t, sweep)] for t in terms},
    }


def characterize(*, sweep: str = "quick", batch: int = 8, iters: int = 5,
                 terms=sweeplib.TERMS, timer=None, aie=None,
                 tracer=None) -> MachineModel:
    """Run the characterization sweeps and fit the machine model.

    ``timer`` replaces wall-clock measurement with a synthetic cost function
    (tests, dry runs); ``terms`` restricts the sweep (e.g. only
    ``("gemm_int8",)`` for the legacy calibration path); ``tracer`` (a
    :class:`repro.obs.Tracer`) records one span per term sweep.
    """
    samples = sweeplib.run_sweep(sweep=sweep, batch=batch, iters=iters,
                                 terms=terms, timer=timer, aie=aie,
                                 tracer=tracer)
    fits = fitlib.fit_all(samples)
    prov = _provenance(sweep, batch, iters, terms)
    if timer is not None:
        prov["timer"] = "synthetic"
    return MachineModel(fits=fits, provenance=prov)
