"""Microbenchmark primitives for architectural characterization.

Each ``time_*`` helper runs ONE microbenchmark point — the same shape of
computation the planner charges a cost term for — and returns a
:class:`Sample`: the measured wall time plus the regressor values the fitter
needs (launch count, padded op count, boundary bytes).  The helpers measure
the exact code paths the plan executors run (``kernels.ops.gemm_int8`` in
interpret mode on CPU, jitted XLA matmul chains, un-fused jit dispatch), so
the fitted constants describe THIS host, not a datasheet.

Every helper takes a ``timer`` hook so tests (and dry-run fits) can replace
wall-clock timing with a synthetic analytical cost: the whole sweep->fit->
artifact machinery then runs deterministically in milliseconds.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

_BM = 32                           # pipeline batch block (matches calibrate)


@dataclasses.dataclass(frozen=True)
class Sample:
    """One microbenchmark observation: measured seconds + fit regressors."""
    term: str                      # cost term this point characterizes
    inputs: dict                   # sweep coordinates (depth, width, dtype...)
    regressors: dict               # named regressor values for the LSQ fit
    seconds: float                 # measured (or synthetic) wall time

    def to_dict(self) -> dict:
        return {"term": self.term, "inputs": dict(self.inputs),
                "regressors": dict(self.regressors),
                "seconds": self.seconds}

    @classmethod
    def from_dict(cls, d: dict) -> "Sample":
        return cls(term=d["term"], inputs=dict(d["inputs"]),
                   regressors=dict(d["regressors"]), seconds=d["seconds"])


# Timer type: (build() -> (fn, args)) -> median seconds per call.
Timer = Callable[..., float]


def wall_timer(fn, *args, iters: int = 5, warmup: int = 1) -> float:
    """Median wall seconds per call (block_until_ready)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


def int8_pipeline_regressors(width: int, depth: int, batch: int) -> dict:
    """Fit regressors for a depth-layer width x width int8 GEMM pipeline.

    ``padded_ops`` (not logical FLOPs) is the throughput regressor because
    ``plan_api``'s efficiency term is exactly the padding-waste product —
    fitting logical ops would double-count the waste.  Inter-launch
    activation traffic is NOT a regressor here: it is characterized by the
    dedicated ``boundary`` sweep, whose per-byte slope the artifact folds
    into ``hbm_bw``.
    """
    bk = bn = min(_ceil_to(width, 128), 512)
    ops = depth * 2.0 * _ceil_to(batch, _BM) * _ceil_to(width, bk) \
        * _ceil_to(width, bn)
    return {"launches": float(depth), "padded_ops": ops}


def time_int8_pipeline(width: int, depth: int, *, batch: int = 8,
                       iters: int = 5, timer: Timer | None = None) -> Sample:
    """One (depth, width) point of the int8 GEMM-pipeline sweep — the same
    multi-launch shape :func:`repro.plan.calibrate.calibrated_cpu_model`
    originally timed, now a reusable characterization primitive."""
    regs = int8_pipeline_regressors(width, depth, batch)
    if timer is not None:
        return Sample("gemm_int8", {"depth": depth, "width": width,
                                    "dtype": "int8", "batch": batch},
                      regs, timer("gemm_int8", regs))
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops

    ws = jnp.ones((depth, width, width), jnp.int8)
    sc = jnp.ones((width,), jnp.float32)
    bk = bn = min(_ceil_to(width, 128), 512)

    @jax.jit
    def f(x):
        h = x
        for i in range(depth):
            y = kops.gemm_int8(h, ws[i], sc, 1.0, block_m=_BM, block_k=bk,
                               block_n=bn, out_dtype=jnp.float32)
            h = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
        return h

    x = jnp.ones((batch, width), jnp.int8)
    t = wall_timer(f, x, iters=iters)
    return Sample("gemm_int8", {"depth": depth, "width": width,
                                "dtype": "int8", "batch": batch}, regs, t)


def fused_chain_regressors(width: int, depth: int, batch: int) -> dict:
    """Fit regressors for a depth-layer fused megakernel chain.

    One launch regardless of depth; ``padded_ops`` uses the megakernel's OWN
    compute extent (live rows x lane-padded widths — it is not grid-blocked,
    so no 32-row int8 block padding), and ``inner_layers`` counts the fused
    epilogue requantizes, the per-boundary cost the planner charges as
    ``TpuV5e.fused_epilogue_s``."""
    rows = _ceil_to(batch, 8)
    pw = _ceil_to(width, 128)
    return {"one": 1.0,
            "padded_ops": depth * 2.0 * rows * pw * pw,
            "inner_layers": float(depth - 1)}


def time_fused_chain(width: int, depth: int, *, batch: int = 8,
                     iters: int = 5, timer: Timer | None = None) -> Sample:
    """One (depth, width) point of the fused-chain sweep: the SAME layer
    stack as :func:`time_int8_pipeline`, executed as ONE ``fused_mlp_q8``
    megakernel launch.  Fitting this against the multi-launch pipeline is
    what turns the fuse-vs-split decision into a measured trade-off instead
    of a hand-tuned constant."""
    regs = fused_chain_regressors(width, depth, batch)
    inputs = {"depth": depth, "width": width, "dtype": "int8", "batch": batch}
    if timer is not None:
        return Sample("fused_chain", inputs, regs, timer("fused_chain", regs))
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops

    ws = tuple(jnp.ones((width, width), jnp.int8) for _ in range(depth))
    scs = tuple(jnp.ones((width,), jnp.float32) for _ in range(depth))
    bs = tuple(jnp.zeros((width,), jnp.float32) for _ in range(depth))
    xs = jnp.ones((depth,), jnp.float32)

    @jax.jit
    def f(x):
        return kops.fused_mlp_q8(x, ws, scs, bs, xs, act="relu")

    x = jnp.ones((batch, width), jnp.float32)
    t = wall_timer(f, x, iters=iters)
    return Sample("fused_chain", inputs, regs, t)


def time_f32_chain(width: int, depth: int, *, batch: int = 8,
                   iters: int = 5, timer: Timer | None = None) -> Sample:
    """One point of the float matmul-chain sweep (the XLA path LM layers
    take): a jitted chain of ``depth`` dense matmuls at ``width``."""
    regs = {"launches": float(depth),
            "ops": depth * 2.0 * batch * width * width}
    if timer is not None:
        return Sample("gemm_f32", {"depth": depth, "width": width,
                                   "dtype": "float32", "batch": batch},
                      regs, timer("gemm_f32", regs))
    import jax
    import jax.numpy as jnp

    ws = jnp.ones((depth, width, width), jnp.float32) * 0.01

    @jax.jit
    def f(x):
        h = x
        for i in range(depth):
            h = h @ ws[i]                  # pure dot: ops regressor is exact
        return h

    x = jnp.ones((batch, width), jnp.float32)
    t = wall_timer(f, x, iters=iters)
    return Sample("gemm_f32", {"depth": depth, "width": width,
                               "dtype": "float32", "batch": batch}, regs, t)


def time_unfused_chain(n_launches: int, act_bytes: int, *, iters: int = 5,
                       timer: Timer | None = None) -> Sample:
    """One point of the DR7' boundary sweep: ``n_launches`` SEPARATE jitted
    element-wise launches over an ``act_bytes`` activation.  Each un-fused
    boundary pays a dispatch plus the activation round trip — exactly what
    :func:`repro.core.boundary.crossing_cost_tpu` charges."""
    regs = {"launches": float(n_launches),
            "launch_bytes": float(n_launches) * act_bytes}
    if timer is not None:
        return Sample("boundary", {"n_launches": n_launches,
                                   "act_bytes": act_bytes},
                      regs, timer("boundary", regs))
    import jax
    import jax.numpy as jnp

    n = max(act_bytes // 4, 1)                      # float32 elements
    step = jax.jit(lambda v: v * 1.0000001 + 0.5)

    def chain(v):
        for _ in range(n_launches):
            v = step(v)
        return v

    x = jnp.ones((n,), jnp.float32)
    t = wall_timer(chain, x, iters=iters)
    return Sample("boundary", {"n_launches": n_launches,
                               "act_bytes": act_bytes}, regs, t)


def model_band2_point(n_band2: int, *, shape=(8, 128, 128), aie=None,
                      timer: Timer | None = None) -> Sample:
    """One point of the band-2 contention sweep.

    The AIE array is not physically present on this host, so the sweep reads
    the paper-calibrated analytical curves (:mod:`repro.core.tiling`) instead
    of wall clock — labeled ``src=model`` in the artifact provenance.  On a
    real VEK280 the same fit consumes measured intervals.
    """
    m, k, n = shape
    regs = {"n_band2": float(n_band2), "one": 1.0}
    if timer is not None:
        return Sample("contention", {"n_band2": n_band2, "shape": list(shape)},
                      regs, timer("contention", regs))
    from repro import hw as hwlib
    from repro.core import tiling
    aie = aie or hwlib.AIE_ML
    t = tiling.aie_spatial_interval(m, k, n, 2, 2, layers_in_band_2=n_band2,
                                    aie=aie)
    return Sample("contention", {"n_band2": n_band2, "shape": list(shape)},
                  regs, t)
