"""Parameterized sweep grids over the cost terms the planner charges.

Five terms, matching the constants the deployment planner actually reads:

* ``gemm_int8``   — multi-launch int8 Pallas GEMM pipelines over a
  (depth, width) grid -> per-launch dispatch overhead
  (``TpuV5e.kernel_overhead_s``) + int8 throughput (``peak_int8_ops``).
* ``gemm_f32``    — jitted XLA matmul chains -> float throughput
  (``peak_bf16_flops``).
* ``fused_chain`` — the SAME int8 layer stacks executed as ONE
  ``fused_mlp_q8`` megakernel launch -> the per-fused-boundary epilogue cost
  (``TpuV5e.fused_epilogue_s``), so the planner's fuse-vs-split decision
  (DR7') is fitted against this host instead of hand-tuned.
* ``boundary``    — un-fused element-wise launch chains over an
  (n_launches, act_bytes) grid -> the DR7' crossing cost's fixed dispatch
  and per-byte parts.
* ``contention``  — band-2 spill population sweep -> the Fig.-6 contention
  slope (``AieMl.band2_penalty_per_layer``).  Sourced from the analytical
  AIE curves on hosts without the array (labeled ``model``).

Three grids: ``quick`` (CI-sized, ~10 s wall on the CPU interpreter),
``full`` (denser, for committed artifacts), and ``calibrate`` (the legacy
3-point grid :func:`repro.plan.calibrate.calibrated_cpu_model` fits).
"""

from __future__ import annotations

from repro.characterize import harness
from repro.characterize.harness import Sample, Timer

# (depth, width) grids for the GEMM pipeline sweeps.
_GEMM_GRIDS = {
    "calibrate": ((2, 128), (6, 128), (2, 512)),
    "quick": ((2, 64), (6, 64), (2, 128), (6, 128), (2, 512)),
    "full": ((2, 64), (4, 64), (6, 64), (2, 128), (4, 128), (6, 128),
             (2, 256), (4, 256), (2, 512), (4, 512)),
}
_F32_GRIDS = {
    # Wider layers than the int8 grid: the XLA f32 path's dispatch is cheap,
    # so compute must dominate for the throughput coefficient to condition.
    "calibrate": ((2, 256), (6, 256), (2, 768)),
    "quick": ((2, 256), (6, 256), (2, 768), (4, 768)),
    "full": ((2, 256), (4, 256), (6, 256), (2, 512), (6, 512), (2, 768),
             (4, 768)),
}
# (depth, width) grids for the fused megakernel chain sweep.  Two widths
# minimum: with a single width, `inner_layers` (= depth-1) is collinear with
# the {one, padded_ops} columns and the epilogue coefficient is unfittable.
_FUSED_GRIDS = {
    "calibrate": ((2, 64), (6, 64), (2, 256)),
    "quick": ((2, 64), (6, 64), (2, 256), (4, 256)),
    "full": ((2, 64), (4, 64), (6, 64), (8, 64), (2, 256), (4, 256),
             (6, 256)),
}
# (n_launches, act_bytes) grids for the boundary sweep.
_BOUNDARY_GRIDS = {
    "calibrate": ((2, 1 << 12), (8, 1 << 12), (2, 1 << 20)),
    "quick": ((2, 1 << 12), (8, 1 << 12), (2, 1 << 20), (8, 1 << 20)),
    "full": ((2, 1 << 12), (4, 1 << 12), (8, 1 << 12), (2, 1 << 16),
             (8, 1 << 16), (2, 1 << 20), (4, 1 << 20), (8, 1 << 20)),
}
_CONTENTION_GRIDS = {
    "calibrate": (0, 1, 2),
    "quick": (0, 1, 2, 3),
    "full": (0, 1, 2, 3, 4, 6),
}

TERMS = ("gemm_int8", "gemm_f32", "fused_chain", "boundary", "contention")
SWEEPS = ("calibrate", "quick", "full")


def grid(term: str, sweep: str):
    """The (term, sweep) coordinate grid — recorded in artifact provenance."""
    tables = {"gemm_int8": _GEMM_GRIDS, "gemm_f32": _F32_GRIDS,
              "fused_chain": _FUSED_GRIDS, "boundary": _BOUNDARY_GRIDS,
              "contention": _CONTENTION_GRIDS}
    if term not in tables:
        raise ValueError(f"unknown term {term!r}; choose from {TERMS}")
    if sweep not in tables[term]:
        raise ValueError(f"unknown sweep {sweep!r}; choose from {SWEEPS}")
    return tables[term][sweep]


def run_term(term: str, *, sweep: str = "quick", batch: int = 8,
             iters: int = 5, timer: Timer | None = None,
             aie=None, tracer=None) -> list[Sample]:
    """Run one cost term's sweep; returns its samples.  With ``tracer``
    (a :class:`repro.obs.Tracer`) the whole term sweep is timed as one
    ``characterize/<term>`` span, so a traced build shows where the
    characterization wall time went."""
    if tracer is not None and tracer.enabled:
        with tracer.span(f"characterize/{term}", tenant="characterize",
                         sweep=sweep):
            return run_term(term, sweep=sweep, batch=batch, iters=iters,
                            timer=timer, aie=aie)
    g = grid(term, sweep)
    if term == "gemm_int8":
        return [harness.time_int8_pipeline(w, d, batch=batch, iters=iters,
                                           timer=timer) for d, w in g]
    if term == "gemm_f32":
        return [harness.time_f32_chain(w, d, batch=batch, iters=iters,
                                       timer=timer) for d, w in g]
    if term == "fused_chain":
        return [harness.time_fused_chain(w, d, batch=batch, iters=iters,
                                         timer=timer) for d, w in g]
    if term == "boundary":
        return [harness.time_unfused_chain(l, b, iters=iters, timer=timer)
                for l, b in g]
    if term == "contention":
        return [harness.model_band2_point(n, aie=aie, timer=timer)
                for n in g]
    raise ValueError(f"unknown term {term!r}; choose from {TERMS}")


def run_sweep(*, sweep: str = "quick", batch: int = 8, iters: int = 5,
              terms=TERMS, timer: Timer | None = None,
              aie=None, tracer=None) -> list[Sample]:
    """Run every requested term's sweep (the CLI entry's workhorse)."""
    out: list[Sample] = []
    for term in terms:
        out.extend(run_term(term, sweep=sweep, batch=batch, iters=iters,
                            timer=timer, aie=aie, tracer=tracer))
    return out
