"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048(expert) vocab=129280, MoE 1 shared + 256 routed top-8, MLA, MTP. Dense first-3 layers d_ff=18432. [arXiv:2412.19437; hf]"""

from repro.configs import lm_shapes
from repro.models.config import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="transformer",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128, head_dim=128,
    d_ff=18432, vocab_size=129280,
    attn_pattern=("global",), rope_theta=10000.0, tie_embeddings=False,
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, first_k_dense=3,
                  router_type="sigmoid"),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    mtp=True,
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b-smoke", family="transformer",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=160, vocab_size=512,
    attn_pattern=("global",), tie_embeddings=False,
    moe=MoEConfig(capacity_factor=8.0, num_experts=8, top_k=2, d_ff_expert=64,
                  num_shared_experts=1, first_k_dense=1,
                  router_type="sigmoid"),
    mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    mtp=True,
)

SHAPES = lm_shapes(subquadratic=False)
