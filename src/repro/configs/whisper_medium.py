"""whisper-medium [audio]: 24L d_model=1024 16H d_ff=4096 vocab=51865 -- enc-dec, conv frontend (STUB: input_specs provides precomputed frame embeddings). [arXiv:2212.04356]"""

from repro.configs import lm_shapes
from repro.models.config import ModelConfig, EncDecConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865,
    attn_pattern=("global",), use_rope=False, norm_type="layernorm",
    mlp_act="gelu", mlp_gated=False, tie_embeddings=True,
    encdec=EncDecConfig(encoder_layers=24, decoder_layers=24, encoder_len=1500),
)

SMOKE = ModelConfig(
    name="whisper-medium-smoke", family="encdec",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    attn_pattern=("global",), use_rope=False, norm_type="layernorm",
    mlp_act="gelu", mlp_gated=False, tie_embeddings=True,
    encdec=EncDecConfig(encoder_layers=2, decoder_layers=2, encoder_len=32),
)

SHAPES = lm_shapes(subquadratic=False)
