"""Architecture registry: ``--arch <id>`` lookup for every assigned config.

Each ``<arch>.py`` exposes ``CONFIG`` (the exact published shape), ``SMOKE``
(a reduced same-family config for CPU tests) and ``SHAPES`` (the assigned
input-shape cells with skip annotations).  ``get(name)`` returns the bundle.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    phase: str                 # "train" | "prefill" | "decode"
    skip: str | None = None    # reason, if this (arch, shape) cell is skipped


# The four assigned LM shape cells.
def lm_shapes(*, subquadratic: bool, encoder_only: bool = False,
              long_ok: bool | None = None) -> dict[str, ShapeSpec]:
    long_ok = subquadratic if long_ok is None else long_ok
    shapes = {
        "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
        "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
        "decode_32k": ShapeSpec(
            "decode_32k", 32768, 128, "decode",
            skip="encoder-only arch has no decode step" if encoder_only else None),
        "long_500k": ShapeSpec(
            "long_500k", 524288, 1, "decode",
            skip=None if long_ok else
            "full-attention arch: 500k decode is not sub-quadratic-feasible"),
    }
    return shapes


ARCH_NAMES = [
    "gemma2_27b", "gemma2_9b", "gemma2_2b", "qwen2_5_3b", "whisper_medium",
    "mixtral_8x22b", "deepseek_v3_671b", "rwkv6_7b", "recurrentgemma_2b",
    "qwen2_vl_72b",
]

# Public --arch ids (hyphenated) -> module names.
ALIASES = {n.replace("_", "-"): n for n in ARCH_NAMES}
ALIASES.update({n: n for n in ARCH_NAMES})


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    config: ModelConfig
    smoke: ModelConfig
    shapes: dict[str, ShapeSpec]


def get(name: str) -> Arch:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return Arch(name=mod_name, config=mod.CONFIG, smoke=mod.SMOKE,
                shapes=mod.SHAPES)


def all_archs() -> list[str]:
    return list(ARCH_NAMES)
