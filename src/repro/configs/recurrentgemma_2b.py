"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680 -- RG-LRU + local attention, pattern (rec,rec,attn). [arXiv:2402.19427; hf]"""

from repro.configs import lm_shapes
from repro.models.config import ModelConfig, GriffinConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="griffin",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    window=2048, logit_softcap=30.0, rope_theta=10000.0,
    tie_embeddings=True, scale_embeddings=True, subquadratic=True,
    griffin=GriffinConfig(lru_width=2560, conv_width=4,
                          pattern=("rec", "rec", "attn"), local_window=2048),
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke", family="griffin",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=512,
    window=16, logit_softcap=30.0,
    tie_embeddings=True, scale_embeddings=True, subquadratic=True,
    griffin=GriffinConfig(lru_width=64, conv_width=4,
                          pattern=("rec", "rec", "attn"), local_window=16),
)

SHAPES = lm_shapes(subquadratic=True)
