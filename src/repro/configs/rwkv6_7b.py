"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336 vocab=65536 -- Finch, data-dependent decay. [arXiv:2404.05892; hf]"""

from repro.configs import lm_shapes
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64, head_dim=64,
    d_ff=14336, vocab_size=65536,
    rwkv_head_dim=64, tie_embeddings=False, subquadratic=True,
)

SMOKE = ModelConfig(
    name="rwkv6-7b-smoke", family="rwkv",
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=512,
    rwkv_head_dim=32, tie_embeddings=False, subquadratic=True,
)

SHAPES = lm_shapes(subquadratic=True)
