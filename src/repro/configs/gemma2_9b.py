"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000 -- local+global alternating, logit softcap. [arXiv:2408.00118; hf]"""

from repro.configs import lm_shapes
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="transformer",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    attn_pattern=("local", "global"), window=4096,
    attn_softcap=50.0, logit_softcap=30.0, rope_theta=10000.0,
    tie_embeddings=True, post_norms=True, scale_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma2-9b-smoke", family="transformer",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    attn_pattern=("local", "global"), window=16,
    attn_softcap=50.0, logit_softcap=30.0,
    tie_embeddings=True, post_norms=True, scale_embeddings=True,
)

SHAPES = lm_shapes(subquadratic=False)
