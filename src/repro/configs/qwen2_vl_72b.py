"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 -- M-RoPE, dynamic resolution (vision frontend STUB: input_specs provides patch embeddings + M-RoPE position ids). [arXiv:2409.12191; hf]"""

from repro.configs import lm_shapes
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="transformer",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    attn_pattern=("global",), qkv_bias=True, rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24), tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen2-vl-72b-smoke", family="transformer",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    attn_pattern=("global",), qkv_bias=True, mrope_sections=(2, 3, 3),
    tie_embeddings=False,
)

SHAPES = lm_shapes(subquadratic=False)
