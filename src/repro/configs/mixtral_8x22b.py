"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8 experts top-2, SWA. [arXiv:2401.04088; hf]"""

from repro.configs import lm_shapes
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="transformer",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    attn_pattern=("local",), window=4096, rope_theta=1_000_000.0,
    tie_embeddings=False,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384),
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke", family="transformer",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    attn_pattern=("local",), window=16, tie_embeddings=False,
    moe=MoEConfig(capacity_factor=8.0, num_experts=4, top_k=2, d_ff_expert=96),
)

SHAPES = lm_shapes(subquadratic=False)
