"""Roofline analysis from the dry-run artifacts (assignment §Roofline).

Per (arch x shape) cell on the single-pod mesh, derive the three terms:

    compute    = HLO_FLOPs / peak_FLOP/s          (per chip, loop-aware)
    memory     = HLO_bytes / HBM_bw               (per chip, loop-aware est.)
    collective = collective_operand_bytes / link_bw

Hardware constants (assignment): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  FLOPs and bytes come from the loop-aware HLO analyzer
(``hlo_analysis.py`` — ``cost_analysis()`` counts while bodies once, so raw
numbers undercount scanned stacks; both are stored in the cell JSON).

Also reported per cell:
  * dominant term (the bottleneck),
  * MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE), D = tokens processed,
  * MODEL_FLOPS / HLO_FLOPs (useful-compute fraction: remat/redundancy),
  * roofline fraction = compute_term / max(all terms)  (how close the cell
    is to being compute-bound — the figure of merit §Perf drives up),
  * one-line "what would move the dominant term down".

Usage:
  python -m repro.launch.roofline --inp results/dryrun --out results/roofline.md
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro import configs
from repro import hw as hwlib

TPU = hwlib.TPU_V5E
CHIPS_SINGLE = 256


def model_flops_for(arch_name: str, shape_name: str, *, phase: str) -> float:
    arch = configs.get(arch_name)
    cfg = arch.config
    sh = arch.shapes[shape_name]
    n_active = cfg.active_param_count()
    if phase == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n_active * tokens
    if phase == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * sh.global_batch


def advice(dom: str, cell: dict) -> str:
    arch, shape = cell["arch"], cell["shape"]
    if dom == "compute":
        return ("compute-bound: reduce remat recompute / fuse epilogues; "
                "already the desirable regime")
    if dom == "memory":
        if cell["phase"] == "decode":
            return ("memory-bound on weight+KV streaming: int8 weights, "
                    "MLA/ring caches, larger per-step batch amortization")
        return ("memory-bound: chunked vocab loss, wider fused blocks "
                "(DR1'), avoid re-materialized activations")
    return ("collective-bound: reshard to cut per-layer gathers (DR3'), "
            "overlap collectives with compute, compress cross-pod payloads")


def analyze_cell(cell: dict) -> dict | None:
    if "skipped" in cell or "error" in cell:
        return None
    flops = cell["flops"]
    byts = cell["hlo_bytes"]
    coll = cell["collective_operand_bytes"]
    t_compute = flops / TPU.peak_bf16_flops
    t_memory = byts / TPU.hbm_bw
    t_coll = coll / TPU.ici_bw
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops_for(cell["arch"], cell["shape"], phase=cell["phase"])
    mf_dev = mf / CHIPS_SINGLE
    t_bound = max(terms.values())
    return {
        **{k: cell[k] for k in ("arch", "shape", "phase", "mesh_kind")},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops_per_dev": mf_dev,
        "useful_fraction": mf_dev / flops if flops else 0.0,
        "roofline_fraction": t_compute / t_bound if t_bound else 0.0,
        "step_time_lower_bound_s": t_bound,
        "hbm_temp_gib": cell["temp_size_in_bytes"] / 2**30,
        "hbm_args_gib": cell["argument_size_in_bytes"] / 2**30,
        # donated buffers alias their outputs — count them once
        "fits_hbm": (cell["temp_size_in_bytes"]
                     + cell["argument_size_in_bytes"]
                     - cell.get("alias_size_in_bytes", 0)) <= TPU.hbm_bytes,
        "advice": advice(dom, cell),
    }


def fmt_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | phase | compute s | memory s | collective s | "
           "dominant | MF/HLO | roofline frac | HBM GiB (temp+args) | fits |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['phase']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_fraction']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['hbm_temp_gib']:.1f}+{r['hbm_args_gib']:.1f} "
            f"| {'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--inp", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.md")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()

    rows, skips, errors = [], [], []
    for path in sorted(glob.glob(os.path.join(args.inp, "*.json"))):
        with open(path) as f:
            cell = json.load(f)
        if cell.get("mesh_kind", cell.get("mesh")) != args.mesh and \
                args.mesh not in str(cell.get("mesh", "")):
            continue
        if "skipped" in cell:
            skips.append(cell)
            continue
        if "error" in cell:
            errors.append(cell)
            continue
        r = analyze_cell(cell)
        if r:
            rows.append(r)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["# Roofline table (single-pod 16x16, 256 chips, v5e constants)",
           "", fmt_table(rows), "", "## Skipped cells", ""]
    for s in skips:
        out.append(f"- {s['arch']} x {s['shape']}: {s['skipped']}")
    if errors:
        out.append("\n## Errored cells\n")
        for e in errors:
            out.append(f"- {e['arch']} x {e['shape']} ({e.get('mesh')}): "
                       f"{e['error'][:200]}")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write("\n".join(out) + "\n")
    print(f"wrote {args.out}: {len(rows)} cells, {len(skips)} skips, "
          f"{len(errors)} errors")
    # Per-cell advice lines for the EXPERIMENTS.md narrative.
    for r in rows:
        print(f"{r['arch']:20s} {r['shape']:12s} dom={r['dominant']:10s} "
              f"rf={r['roofline_fraction']:.2f} -> {r['advice']}")


if __name__ == "__main__":
    main()
