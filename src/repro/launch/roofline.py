"""Roofline analysis from the dry-run artifacts (assignment §Roofline).

Per (arch x shape) cell on the single-pod mesh, derive the three terms:

    compute    = HLO_FLOPs / peak_FLOP/s          (per chip, loop-aware)
    memory     = HLO_bytes / HBM_bw               (per chip, loop-aware est.)
    collective = collective_operand_bytes / link_bw

The term math is shared with the serving profiler
(:func:`repro.obs.profile.roofline_terms`) and the ceilings come from
:data:`repro.hw.TPU_V5E` or a fitted ``MachineModel`` (``--machine-model``)
— one ceiling of truth; this module no longer carries its own copies of
the peak constants.  FLOPs and bytes come from the loop-aware HLO analyzer
(``hlo_analysis.py`` — ``cost_analysis()`` counts while bodies once, so raw
numbers undercount scanned stacks; both are stored in the cell JSON).

Also reported per cell:
  * dominant term (the bottleneck),
  * MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE), D = tokens processed,
  * MODEL_FLOPS / HLO_FLOPs (useful-compute fraction: remat/redundancy),
  * roofline fraction = compute_term / max(all terms)  (how close the cell
    is to being compute-bound — the figure of merit §Perf drives up),
  * one-line "what would move the dominant term down".

Usage:
  python -m repro.launch.roofline --inp results/dryrun --out results/roofline.md
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro import configs
from repro import hw as hwlib
from repro.obs.profile import roofline_terms

TPU = hwlib.TPU_V5E
CHIPS_SINGLE = 256


def resolve_hw(spec: str | None):
    """Map a ``--machine-model`` flag onto roofline ceilings: ``None`` /
    ``"stock"`` -> the stock :data:`repro.hw.TPU_V5E`; a path -> the fitted
    :class:`repro.characterize.model.MachineModel`'s substituted TPU."""
    if spec is None or spec in ("stock", "none"):
        return TPU
    from repro.characterize import MachineModel
    return MachineModel.load(spec).tpu()


def model_flops_for(arch_name: str, shape_name: str, *, phase: str) -> float:
    arch = configs.get(arch_name)
    cfg = arch.config
    sh = arch.shapes[shape_name]
    n_active = cfg.active_param_count()
    if phase == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n_active * tokens
    if phase == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * sh.global_batch


def advice(dom: str, cell: dict) -> str:
    arch, shape = cell["arch"], cell["shape"]
    if dom == "compute":
        return ("compute-bound: reduce remat recompute / fuse epilogues; "
                "already the desirable regime")
    if dom == "memory":
        if cell["phase"] == "decode":
            return ("memory-bound on weight+KV streaming: int8 weights, "
                    "MLA/ring caches, larger per-step batch amortization")
        return ("memory-bound: chunked vocab loss, wider fused blocks "
                "(DR1'), avoid re-materialized activations")
    return ("collective-bound: reshard to cut per-layer gathers (DR3'), "
            "overlap collectives with compute, compress cross-pod payloads")


def analyze_cell(cell: dict, *, hw=None) -> dict | None:
    if "skipped" in cell or "error" in cell:
        return None
    hw = hw if hw is not None else TPU
    # Shared term math (one ceiling of truth with the serving profiler);
    # dry-run cells have no launch count, so the launch term stays zero and
    # the dominant label is compute/memory/collective as before.
    terms = roofline_terms(cell["flops"], cell["hlo_bytes"], 0, hw=hw,
                           collective_bytes=cell["collective_operand_bytes"])
    dom = terms["bound"]
    mf = model_flops_for(cell["arch"], cell["shape"], phase=cell["phase"])
    mf_dev = mf / CHIPS_SINGLE
    t_bound = terms["ceiling_s"]
    flops = cell["flops"]
    return {
        **{k: cell[k] for k in ("arch", "shape", "phase", "mesh_kind")},
        "t_compute_s": terms["t_compute_s"],
        "t_memory_s": terms["t_memory_s"],
        "t_collective_s": terms["t_collective_s"],
        "dominant": dom,
        "model_flops_per_dev": mf_dev,
        "useful_fraction": mf_dev / flops if flops else 0.0,
        "roofline_fraction": (terms["t_compute_s"] / t_bound if t_bound
                              else 0.0),
        "step_time_lower_bound_s": t_bound,
        "hbm_temp_gib": cell["temp_size_in_bytes"] / 2**30,
        "hbm_args_gib": cell["argument_size_in_bytes"] / 2**30,
        # donated buffers alias their outputs — count them once
        "fits_hbm": (cell["temp_size_in_bytes"]
                     + cell["argument_size_in_bytes"]
                     - cell.get("alias_size_in_bytes", 0)) <= hw.hbm_bytes,
        "advice": advice(dom, cell),
    }


def fmt_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | phase | compute s | memory s | collective s | "
           "dominant | MF/HLO | roofline frac | HBM GiB (temp+args) | fits |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['phase']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_fraction']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['hbm_temp_gib']:.1f}+{r['hbm_args_gib']:.1f} "
            f"| {'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--inp", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.md")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--machine-model", default=None, metavar="MODEL_JSON",
                    help="fitted MachineModel artifact for the ceilings "
                         "(default: stock TPU v5e constants)")
    args = ap.parse_args()
    hw = resolve_hw(args.machine_model)

    rows, skips, errors = [], [], []
    for path in sorted(glob.glob(os.path.join(args.inp, "*.json"))):
        with open(path) as f:
            cell = json.load(f)
        if cell.get("mesh_kind", cell.get("mesh")) != args.mesh and \
                args.mesh not in str(cell.get("mesh", "")):
            continue
        if "skipped" in cell:
            skips.append(cell)
            continue
        if "error" in cell:
            errors.append(cell)
            continue
        r = analyze_cell(cell, hw=hw)
        if r:
            rows.append(r)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["# Roofline table (single-pod 16x16, 256 chips, v5e constants)",
           "", fmt_table(rows), "", "## Skipped cells", ""]
    for s in skips:
        out.append(f"- {s['arch']} x {s['shape']}: {s['skipped']}")
    if errors:
        out.append("\n## Errored cells\n")
        for e in errors:
            out.append(f"- {e['arch']} x {e['shape']} ({e.get('mesh')}): "
                       f"{e['error'][:200]}")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write("\n".join(out) + "\n")
    print(f"wrote {args.out}: {len(rows)} cells, {len(skips)} skips, "
          f"{len(errors)} errors")
    # Per-cell advice lines for the EXPERIMENTS.md narrative.
    for r in rows:
        print(f"{r['arch']:20s} {r['shape']:12s} dom={r['dominant']:10s} "
              f"rf={r['roofline_fraction']:.2f} -> {r['advice']}")


if __name__ == "__main__":
    main()
