"""Serving launcher: continuous batching with optional int8 weights.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \\
      --requests 8 --max-new 8 --quant8

CPU-scale with ``--smoke``; on a pod the same engine jits against the
production mesh with the serve-regime shardings (TP weights, batch/seq-
sharded caches, optional sequence-parallel prefill).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import api
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--quant8", action="store_true")
    args = ap.parse_args()

    arch = configs.get(args.arch)
    cfg = arch.smoke if args.smoke else arch.config
    params = api.init(cfg, jax.random.PRNGKey(0))
    if args.quant8:
        params = engine.quantize_params(params, min_size=1024)
        before, after = engine.quantized_bytes(params)
        print(f"[serve] int8 weights: {before/1e6:.1f} -> {after/1e6:.1f} MB")

    batcher = engine.ContinuousBatcher(cfg, params, slots=args.slots,
                                       max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [engine.Request(
        rid=i, prompt=rng.integers(1, cfg.vocab_size,
                                   rng.integers(2, 9)).astype(np.int32),
        max_new=args.max_new) for i in range(args.requests)]
    t0 = time.perf_counter()
    for r in reqs:
        batcher.submit(r)
    batcher.run_until_drained()
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on this host)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.out}")


if __name__ == "__main__":
    main()
