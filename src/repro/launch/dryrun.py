import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import (jax locks the device count
on first init): the dry-run — and only the dry-run — sees 512 placeholder
host devices so ``make_production_mesh`` can build the 16x16 single-pod and
2x16x16 multi-pod meshes.  No arrays are ever allocated: parameters, batches
and caches enter ``lower()`` as sharded ShapeDtypeStructs.

Per cell this records:
  * ``compiled.memory_analysis()``   -> per-device bytes (proves it fits);
  * ``compiled.cost_analysis()``     -> HLO FLOPs / bytes for the roofline;
  * a pass over ``compiled.as_text()`` summing operand bytes of every
    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute (collective term of the roofline).

CLI:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs, partition, sharding as shlib
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def input_specs(arch: configs.Arch, shape_name: str, mesh) -> dict:
    """Sharded ShapeDtypeStructs for one (arch, shape) cell."""
    cfg = arch.config
    sh = arch.shapes[shape_name]
    b, s = sh.global_batch, sh.seq_len
    dp = shlib.dp_axes(mesh)
    dp_ok = dp if (b % max(1, _prod(mesh, dp))) == 0 else None
    tok_sh = NamedSharding(mesh, P(dp_ok, None))
    out: dict = {}
    if sh.phase == "train":
        out["tokens"] = _sds((b, s), jnp.int32, tok_sh)
        out["labels"] = _sds((b, s), jnp.int32, tok_sh)
    elif sh.phase == "prefill":
        out["tokens"] = _sds((b, s), jnp.int32, tok_sh)
    else:  # decode: one new token against a seq_len-deep state
        out["tokens"] = _sds((b, 1), jnp.int32, tok_sh)
    if cfg.family == "encdec":
        e = cfg.encdec
        fr_sh = NamedSharding(mesh, P(dp_ok, None, None))
        if sh.phase != "decode":
            out["encoder_frames"] = _sds((b, e.encoder_len, cfg.d_model),
                                         jnp.float32, fr_sh)
    if cfg.mrope_sections is not None:
        s_eff = s if sh.phase != "decode" else 1
        out["mrope_positions"] = _sds(
            (3, b, s_eff), jnp.int32,
            NamedSharding(mesh, P(None, dp_ok, None)))
    return out


def _prod(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def decode_state_specs(cfg: ModelConfig, batch: int, max_len: int, mesh,
                       ring_local: bool = False):
    if ring_local and cfg.family == "transformer":
        from repro.models import transformer as _tr
        abstract = _tr.lm_cache_specs(cfg, batch, max_len, ring_local=True)
    else:
        abstract = api.decode_state_specs(cfg, batch, max_len)
    shards = partition.cache_shardings(abstract, mesh)
    return jax.tree.map(
        lambda sds, sh: _sds(sds.shape, sds.dtype, sh), abstract, shards)


# Per-arch training optimizer defaults: f32 AdamW everywhere it fits; the
# 671B MoE needs Adafactor (8 TB of f32 moments do not fit a 256-chip pod —
# quantified in EXPERIMENTS.md §Dry-run).
_OPT_FOR_ARCH = {
    "deepseek_v3_671b": ("adafactor", {}),
    "mixtral_8x22b": ("adamw", {"state_dtype": "bfloat16"}),
    "qwen2_vl_72b": ("adamw", {"state_dtype": "bfloat16"}),
}

# Per-arch train-step defaults (production config, EXPERIMENTS.md §Dry-run):
# chunked vocab loss everywhere (100k+ vocabs), microbatch accumulation
# sized so activations fit 16 GiB HBM next to params+optimizer state.
_TRAIN_FOR_ARCH = {
    "gemma2_2b": {"microbatches": 2},
    "gemma2_9b": {"microbatches": 4},
    "gemma2_27b": {"microbatches": 4},
    "qwen2_5_3b": {"microbatches": 2},
    "whisper_medium": {"microbatches": 2},
    "mixtral_8x22b": {"microbatches": 8, "acc_dtype": "bfloat16"},
    "deepseek_v3_671b": {"microbatches": 8, "acc_dtype": "bfloat16"},
    "rwkv6_7b": {"microbatches": 2},
    "recurrentgemma_2b": {"microbatches": 4},
    "qwen2_vl_72b": {"microbatches": 8, "acc_dtype": "bfloat16"},
}


def train_options_for(arch_name: str, overrides: dict | None = None):
    opts = dict(remat="block", chunked_loss=True, microbatches=1)
    opts.update(_TRAIN_FOR_ARCH.get(arch_name, {}))
    opts.update(overrides or {})
    return step_lib.TrainOptions(**opts)


# ---------------------------------------------------------------------------
# Lower + compile one cell
# ---------------------------------------------------------------------------

def lower_cell(arch: configs.Arch, shape_name: str, mesh, *,
               opt_overrides: dict | None = None,
               train_overrides: dict | None = None,
               moe_impl: str | None = None,
               ring_local: bool = False,
               quant8: bool = False,
               serve_sp: bool = False):
    """Returns (lowered, compiled, meta) for one (arch, shape, mesh) cell."""
    import dataclasses as _dc
    cfg = arch.config
    if moe_impl and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, impl=moe_impl))
    sh = arch.shapes[shape_name]
    rules = (shlib.train_rules(mesh) if sh.phase == "train"
             else shlib.serve_rules(mesh, seq_shard=serve_sp))
    specs = input_specs(arch, shape_name, mesh)

    with mesh, shlib.use_rules(mesh, rules):
        if sh.phase == "train":
            name, okw = _OPT_FOR_ARCH.get(arch.name, ("adamw", {}))
            if opt_overrides:
                name, okw = opt_overrides.get("name", name), \
                    opt_overrides.get("kw", okw)
            opt = opt_lib.make(name, lr=3e-4, **okw)
            init_fn, step_fn = step_lib.build_train_step(
                cfg, opt, train_options_for(arch.name, train_overrides))
            state_abs = jax.eval_shape(init_fn,
                                       jax.ShapeDtypeStruct((2,), jnp.uint32))
            state_sh = step_lib.state_shardings(state_abs, cfg, mesh)
            state_in = jax.tree.map(
                lambda sds, shd: _sds(sds.shape, sds.dtype, shd),
                state_abs, state_sh)
            jitted = jax.jit(step_fn, donate_argnums=0)
            lowered = jitted.lower(state_in, specs)
        else:
            params_abs = api.abstract_params(cfg)
            if quant8:
                from repro.serve import engine as _eng
                params_abs = jax.eval_shape(
                    lambda p: _eng.quantize_params(p), params_abs)
            p_sh = partition.param_shardings(params_abs, cfg, mesh,
                                             regime="serve")
            params_in = jax.tree.map(
                lambda sds, shd: _sds(sds.shape, sds.dtype, shd),
                params_abs, p_sh)
            if sh.phase == "prefill":
                max_len = sh.seq_len
                state_in = decode_state_specs(cfg, sh.global_batch, max_len,
                                              mesh, ring_local=ring_local)
                extras = {k: v for k, v in specs.items() if k != "tokens"}

                def serve_step(params, tokens, state, extras):
                    logits, new_state = api.decode_step(
                        params, cfg, tokens, state, 0, extras=extras)
                    return logits[:, -1:], new_state

                jitted = jax.jit(serve_step, donate_argnums=2)
                lowered = jitted.lower(params_in, specs["tokens"], state_in,
                                       extras)
            else:
                max_len = sh.seq_len
                state_in = decode_state_specs(cfg, sh.global_batch, max_len,
                                              mesh, ring_local=ring_local)
                extras = {k: v for k, v in specs.items() if k != "tokens"}

                def serve_step(params, tokens, state, pos, extras):
                    return api.decode_step(params, cfg, tokens, state, pos,
                                           extras=extras)

                jitted = jax.jit(serve_step, donate_argnums=2)
                lowered = jitted.lower(
                    params_in, specs["tokens"], state_in,
                    _sds((), jnp.int32), extras)
        compiled = lowered.compile()
    meta = {"arch": arch.name, "shape": shape_name, "phase": sh.phase,
            "mesh": dict(zip(mesh.axis_names, mesh.devices.shape))}
    return lowered, compiled, meta


# ---------------------------------------------------------------------------
# Collective extraction from compiled HLO
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*[a-z0-9]+\[[0-9,]*\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-type operand bytes + wire bytes from optimized HLO."""
    stats: dict = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        shapes = _SHAPE_RE.findall(line.split("=", 1)[1].split("(")[0])
        if not shapes:
            continue
        # Result may be a tuple (shape list); sum them.
        result_bytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = 1
        mg = _GROUPS_RE.search(line)
        if mg:
            g = max(1, len([x for x in mg.group(1).split(",") if x.strip()]))
        if kind == "all-gather":
            operand = result_bytes // max(g, 1)
            wire = result_bytes * (g - 1) // max(g, 1)
        elif kind == "reduce-scatter":
            operand = result_bytes * g
            wire = result_bytes * (g - 1)
        elif kind == "all-reduce":
            operand = result_bytes
            wire = 2 * result_bytes * (g - 1) // max(g, 1)
        else:  # all-to-all / collective-permute
            operand = result_bytes
            wire = result_bytes * (g - 1) // max(g, 1) if kind == "all-to-all" \
                else result_bytes
        s = stats.setdefault(kind, {"count": 0, "operand_bytes": 0,
                                    "wire_bytes": 0})
        s["count"] += 1
        s["operand_bytes"] += operand
        s["wire_bytes"] += wire
    return stats


def analyze(lowered, compiled, meta: dict) -> dict:
    from repro.launch import hlo_analysis

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    out = dict(meta)
    # Raw cost_analysis numbers (while bodies counted ONCE — see
    # hlo_analysis docstring); kept for reference.
    out["flops_raw_cost_analysis"] = float(cost.get("flops", 0.0))
    out["bytes_raw_cost_analysis"] = float(cost.get("bytes accessed", 0.0))
    # Loop-aware numbers (scan bodies x trip counts) — the roofline inputs.
    la = hlo_analysis.analyze_hlo(text)
    out["flops"] = la["flops"]
    out["hlo_bytes"] = la["bytes_est"]
    out["collectives"] = la["collectives"]
    out["collective_operand_bytes"] = la["collective_operand_bytes"]
    out["collective_wire_bytes"] = la["collective_wire_bytes"]
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        out[attr] = int(getattr(mem, attr, 0) or 0)
    return out


def run_cell(arch_name: str, shape_name: str, mesh_kind: str, *,
             train_overrides: dict | None = None,
             moe_impl: str | None = None, ring_local: bool = False,
             quant8: bool = False, serve_sp: bool = False) -> dict:
    arch = configs.get(arch_name)
    sh = arch.shapes[shape_name]
    if sh.skip:
        return {"arch": arch.name, "shape": shape_name, "mesh": mesh_kind,
                "skipped": sh.skip}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    lowered, compiled, meta = lower_cell(arch, shape_name, mesh,
                                         train_overrides=train_overrides,
                                         moe_impl=moe_impl,
                                         ring_local=ring_local,
                                         quant8=quant8, serve_sp=serve_sp)
    result = analyze(lowered, compiled, meta)
    result["mesh_kind"] = mesh_kind
    result["compile_s"] = round(time.time() - t0, 1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", type=str, default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="results/dryrun")
    ap.add_argument("--chunked-loss", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = configs.all_archs() if args.all or not args.arch else [args.arch]
    shapes = (["train_4k", "prefill_32k", "decode_32k", "long_500k"]
              if args.all or not args.shape else [args.shape])
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    overrides = {}
    if args.chunked_loss:
        overrides["chunked_loss"] = True
    if args.microbatches:
        overrides["microbatches"] = args.microbatches

    failures = 0
    for an in archs:
        for sn in shapes:
            for mk in meshes:
                tag = f"{an.replace('-', '_')}.{sn}.{mk}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    res = run_cell(an, sn, mk,
                                   train_overrides=overrides or None)
                    status = ("SKIP " + res["skipped"]) if "skipped" in res \
                        else (f"ok flops={res['flops']:.3e} "
                              f"temp={res['temp_size_in_bytes']/2**30:.2f}GiB "
                              f"coll={res['collective_operand_bytes']/2**20:.0f}MiB "
                              f"({res['compile_s']}s)")
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures += 1
                    res = {"arch": an, "shape": sn, "mesh": mk,
                           "error": str(e),
                           "traceback": traceback.format_exc()}
                    status = f"FAIL {type(e).__name__}: {e}"
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                print(f"[dryrun] {tag:45s} {status}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
