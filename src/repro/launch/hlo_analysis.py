"""Loop-aware analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every while-loop body exactly ONCE
(verified in tests/test_roofline.py), which silently undercounts scanned
layer stacks by ~num_layers x — and misses that GSPMD-inserted collectives
inside the layer scan repeat per layer.  This module re-derives the roofline
inputs from the HLO text with loop multipliers:

1. parse computations and their instructions (result shapes resolvable
   per-computation; operands resolve through the local symbol table);
2. find ``while`` ops, extract static trip counts from the condition
   computation's comparison constant;
3. DFS from ENTRY accumulating a multiplier per computation
   (x trip for while bodies, x1 for fusions/calls);
4. sum, per computation and scaled by its multiplier:
   * dot FLOPs (2 x prod(result dims) x prod(contracted dims)),
   * HBM-traffic estimate (instruction results + dot/fusion/collective
     operands; parameters/GTEs/bitcasts excluded),
   * collective payloads by kind (operand bytes and ring wire bytes).

The traffic estimate is an op-level approximation of "bytes accessed" (it
cannot see register/cache reuse inside a fused loop); EXPERIMENTS.md states
the methodology wherever these numbers appear.

Since PR 8 this module also analyzes the ACTUAL serving executables:
:func:`jitted_hlo` / :func:`analyze_jitted` lower-and-compile any jitted
callable at its serving arguments, and :func:`analyze_engine` does so for
a serving engine (:class:`~repro.serve.engine.EdgeEngine` jitted forward,
:class:`~repro.serve.engine.ContinuousBatcher` jitted decode step) via its
``hlo_text()`` hook — the compiled-HLO FLOPs these return, divided into
the plan's model FLOPs, is the useful-compute fraction the profiler
reports (:func:`hlo_overhead`).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?[a-z0-9]+\[)")
_SHAPES = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPCODE = re.compile(r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
                     r"([a-z][\w\-]*)\(")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)")
_WHILE = re.compile(r"while\(")
_COND_BODY = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_GROUPS = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES = {"parameter", "get-tuple-element", "tuple", "bitcast",
               "constant", "while", "iota", "after-all", "partition-id",
               "replica-id"}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPES.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _result_text(line: str) -> str:
    rhs = line.split("=", 1)[1] if "=" in line else ""
    return rhs.split("(", 1)[0]


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    line: str
    result_bytes: int


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    shapes: dict                      # %name -> result-shape text


def parse_computations(text: str) -> dict[str, "Computation"]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for line in text.splitlines():
        if not line.startswith(" ") and ("->" in line) and ("{" in line):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name = m.group(1)
        op_m = _OPCODE.search(line)
        opcode = op_m.group(1) if op_m else "unknown"
        res_text = _result_text(line)
        cur.shapes[name] = res_text
        cur.instrs.append(Instr(name, opcode, line, _shape_bytes(res_text)))
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _trip_count(comps: dict, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for ins in cond.instrs:
        for c in _CONST_INT.findall(ins.line):
            best = max(best, int(c))
    return best


def _multipliers(comps: dict) -> dict[str, float]:
    mult: dict[str, float] = defaultdict(float)
    entry = comps.get("__entry__")
    if entry is None:
        return mult

    import sys
    sys.setrecursionlimit(10000)
    seen_stack = set()

    def visit(comp: Computation, m: float):
        mult[comp.name] += m
        if comp.name in seen_stack:      # defensive (HLO is acyclic)
            return
        seen_stack.add(comp.name)
        for ins in comp.instrs:
            if _WHILE.search(ins.line):
                cb = _COND_BODY.search(ins.line)
                if cb:
                    trip = _trip_count(comps, cb.group(1))
                    body = comps.get(cb.group(2))
                    if body is not None:
                        visit(body, m * trip)
                    cond = comps.get(cb.group(1))
                    if cond is not None:
                        mult[cond.name] += m * (trip + 1)
            else:
                for callee in _CALLS.findall(ins.line):
                    if callee in comps and "condition=" not in ins.line:
                        visit(comps[callee], m)
        seen_stack.discard(comp.name)

    visit(entry, 1.0)
    return dict(mult)


def _dot_flops(ins: Instr, comp: Computation) -> float:
    # result dims
    res = _SHAPES.findall(_result_text(ins.line))
    if not res:
        return 0.0
    n_res = 1
    for d in res[0][1].split(","):
        if d:
            n_res *= int(d)
    # contracted dims from lhs shape + contracting dims
    ops = _OPERANDS.findall(ins.line.split("(", 1)[1])
    contract = 1
    m = _CONTRACT.search(ins.line)
    if m and ops:
        lhs_shape = comp.shapes.get(ops[0], "")
        lhs = _SHAPES.findall(lhs_shape)
        if lhs:
            dims = [int(d) for d in lhs[0][1].split(",") if d]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
    return 2.0 * n_res * contract


def _operand_bytes(ins: Instr, comp: Computation) -> int:
    total = 0
    args = ins.line.split("(", 1)[1] if "(" in ins.line else ""
    args = args.split("), ")[0]
    for op in _OPERANDS.findall(args):
        total += _shape_bytes(comp.shapes.get(op, ""))
    return total


def analyze_hlo(text: str) -> dict:
    comps = parse_computations(text)
    mult = _multipliers(comps)
    flops = 0.0
    bytes_est = 0.0
    coll: dict[str, dict] = {}
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for ins in comp.instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, comp)
                bytes_est += m * (ins.result_bytes + _operand_bytes(ins, comp))
            elif any(ins.opcode.startswith(c) for c in _COLLECTIVES):
                kind = next(c for c in _COLLECTIVES if ins.opcode.startswith(c))
                rb = ins.result_bytes
                g = 1
                mg = _GROUPS_IOTA.search(ins.line)
                if mg:
                    g = max(1, int(mg.group(2)))   # [n_groups, group_size]
                else:
                    mg = _GROUPS.search(ins.line)
                    if mg:
                        g = max(1, len([x for x in mg.group(1).split(",")
                                        if x.strip()]))
                if kind == "all-gather":
                    operand, wire = rb // g, rb * (g - 1) // g
                elif kind == "reduce-scatter":
                    operand, wire = rb * g, rb * (g - 1)
                elif kind == "all-reduce":
                    operand, wire = rb, 2 * rb * (g - 1) // g
                else:
                    operand = rb
                    wire = rb * (g - 1) // g if kind == "all-to-all" else rb
                s = coll.setdefault(kind, {"count": 0.0, "operand_bytes": 0.0,
                                           "wire_bytes": 0.0})
                s["count"] += m
                s["operand_bytes"] += m * operand
                s["wire_bytes"] += m * wire
                bytes_est += m * rb
            elif ins.opcode in ("fusion", "custom-call", "convolution",
                                "scatter", "gather", "dynamic-slice",
                                "dynamic-update-slice", "sort",
                                "select-and-scatter", "concatenate"):
                # ("copy" excluded: CPU layout-assignment artifacts that the
                # TPU pipeline fuses or elides.)
                # Materializing ops: result only — their operands are other
                # ops' results (already counted where produced) or params
                # (counted at their consuming dot).  Counting both sides of
                # every edge double-counts; counting top-level elementwise /
                # convert / broadcast at all charges traffic a TPU fusion
                # pipeline never pays (CPU fuses far less than Mosaic/XLA-TPU
                # — validated against an analytic traffic model in
                # EXPERIMENTS.md §Roofline methodology).
                bytes_est += m * ins.result_bytes
    return {
        "flops": flops,
        "bytes_est": bytes_est,
        "collectives": coll,
        "collective_operand_bytes": sum(s["operand_bytes"]
                                        for s in coll.values()),
        "collective_wire_bytes": sum(s["wire_bytes"] for s in coll.values()),
        "n_computations": len(comps) - 1,
    }


# ---------------------------------------------------------------------------
# Serving-executable analysis (PR 8): the compiled step the engine runs
# ---------------------------------------------------------------------------

def jitted_hlo(fn, *args, **kwargs) -> str:
    """Post-optimization HLO text of a jitted callable at the given args
    (``fn.lower(...).compile().as_text()``) — what the runtime executes,
    after fusion/SPMD, not the traced stableHLO."""
    return fn.lower(*args, **kwargs).compile().as_text()


def analyze_jitted(fn, *args, **kwargs) -> dict:
    """:func:`analyze_hlo` over a jitted callable's compiled executable."""
    return analyze_hlo(jitted_hlo(fn, *args, **kwargs))


def analyze_engine(engine) -> dict:
    """Loop-aware analysis of a serving engine's hot executable.

    Any object with an ``hlo_text()`` hook works (both serving engines
    grew one): :class:`~repro.serve.engine.EdgeEngine` hands over its
    jitted planned forward, :class:`~repro.serve.engine.ContinuousBatcher`
    its jitted vmapped decode step."""
    return analyze_hlo(engine.hlo_text())


def hlo_overhead(model_flops: float, engine) -> dict:
    """Model-FLOPs vs compiled-HLO-FLOPs for one serving executable.

    ``model_flops`` is the plan-derived arithmetic the model *needs* per
    step (``DeploymentPlan.work()["flops"]``); the compiled executable
    spends more (epilogues, masking, layout ops) or occasionally less
    (algebraic simplification).  ``useful_fraction`` = model/HLO is the
    roofline report's remat/redundancy figure — a fused-decode-step PR
    should move it toward 1."""
    hlo = analyze_engine(engine)
    hlo_flops = hlo["flops"]
    return {
        "model_flops": model_flops,
        "hlo_flops": hlo_flops,
        "hlo_bytes_est": hlo["bytes_est"],
        "useful_fraction": (model_flops / hlo_flops) if hlo_flops else None,
        "collectives": hlo["collectives"],
    }
