"""Production meshes (assignment-specified shapes).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else must see the real (1-device) topology.
"""

from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(*, data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = data if data is not None else max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=_auto(2))
