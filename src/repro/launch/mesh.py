"""Production meshes (assignment-specified shapes).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else must see the real (1-device) topology.
"""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """`jax.make_mesh` with explicit-Auto axis types where the jax version
    has them (0.5+); older jax has neither `AxisType` nor the kwarg, and its
    meshes are Auto-only anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(*, data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = data if data is not None else max(1, n // model)
    return make_mesh((data, model), ("data", "model"))
