"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \\
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpts

On this CPU host the ``--smoke`` reduced configs run end-to-end; on a pod the
same launcher builds the production mesh, applies the partitioner's
shardings, and wraps the jitted step in the fault-tolerant TrainDriver
(checkpoint/restart, straggler detection).  The per-arch production step
options (microbatches, chunked loss, optimizer) come from the same table the
dry-run proves (`launch/dryrun.py`).
"""

from __future__ import annotations

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import synth_batch
from repro.train import fault, optimizer as opt_lib, schedule, step as step_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--opt", default="adamw",
                    choices=["adamw", "adafactor", "sgd"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="block",
                    choices=["none", "block", "dots"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    arch = configs.get(args.arch)
    cfg = arch.smoke if args.smoke else arch.config
    print(f"[train] arch={arch.name} smoke={args.smoke} "
          f"params~{cfg.param_count()/1e6:.1f}M steps={args.steps}")

    opt = opt_lib.make(args.opt, lr=schedule.warmup_cosine(
        args.lr, warmup_steps=max(args.steps // 20, 2),
        total_steps=args.steps))
    init_fn, step_fn = step_lib.build_train_step(
        cfg, opt, step_lib.TrainOptions(
            remat=args.remat, microbatches=args.microbatches,
            chunked_loss=cfg.family == "transformer"))
    state = jax.jit(init_fn)(jax.random.PRNGKey(0))
    jstep = jax.jit(step_fn, donate_argnums=0)

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in
                synth_batch(cfg, batch=args.batch, seq=args.seq,
                            step=step).items()}

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix=f"repro_{arch.name}_")
    driver = fault.TrainDriver(
        cfg=fault.DriverConfig(ckpt_dir=ckpt, ckpt_every=args.ckpt_every),
        step_fn=jstep, batch_fn=batch_fn, state=state)
    driver.run(args.steps)
    print(f"[train] done at step {driver.step}; events="
          f"{[e[0] for e in driver.events]}; checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
