"""The unified CLI: ``python -m repro <subcommand>``.

One entry point over the staged facade (:mod:`repro.deploy`) — every
subcommand routes through the same pipeline stages instead of re-wiring the
subsystems by hand:

  python -m repro characterize --sweep quick --out model.json
  python -m repro plan jet_tagger tau_select --target aie
  python -m repro deploy jet_tagger tau_select          # end-to-end
  python -m repro deploy vae --dry-run                  # stop after planning
  python -m repro serve jet_tagger --lm qwen2_5_3b
  python -m repro bench jet_tagger tau_select --iters 10
  python -m repro trace jet_tagger --lm qwen2_5_3b      # spans + attribution
  python -m repro replay --scenario flash_crowd         # open-loop traffic
  python -m repro profile jet_tagger --lm qwen2_5_3b    # roofline + LARE
  python -m repro chaos --scenario flash_crowd --seed 0 # replay under faults
  python -m repro check                                 # static design rules

``python -m repro.plan`` and ``python -m repro.characterize`` remain as
deprecation shims over the matching subcommands.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys


# ---------------------------------------------------------------------------
# plan printing (shared by `plan` and `deploy --dry-run`)
# ---------------------------------------------------------------------------

def _print_plan(plan) -> None:
    print(f"\n# {plan.network} [{plan.target}]  batch={plan.batch}  "
          f"key={plan.key[:12]}…")
    hdr = (f"{'layer':<10}{'shape':>12}  {'regime':<9}{'LARE':>8}"
           f"{'P_KxP_N':>9}{'band':>5}  {'tile':<16}{'interval':>11}")
    print(hdr)
    for l in plan.layers:
        rep = f" x{l.repeat}" if l.repeat > 1 else ""
        print(f"{l.name:<10}{f'{l.n_in}->{l.n_out}{rep}':>12}  "
              f"{l.regime:<9}{l.lare:>8.1f}{f'{l.p_k}x{l.p_n}':>9}"
              f"{l.band:>5}  {str(l.api_tile):<16}"
              f"{l.est_interval_s * 1e6:>9.2f}us")
    for b in plan.boundaries:
        print(f"  boundary after layer {b.after_layer}: "
              f"{b.from_regime}->{b.to_regime} "
              f"(+{b.crossing_s * 1e6:.2f}us)")
    print(f"totals: latency={plan.est_latency_s * 1e6:.2f}us  "
          f"interval={plan.est_interval_s * 1e6:.2f}us  "
          f"rate={plan.inferences_per_s / 1e6:.2f} MHz")


def _print_fleet(fleet) -> None:
    print(f"\n# fleet {fleet.name} [{fleet.target}]  "
          f"key={fleet.key[:12]}…  band1_cols={fleet.band1_cols_used}")
    print(f"{'tenant':<14}{'cols':>10}  {'planned':>11}{'+cross':>10}"
          f"{'budget':>11}")
    for t in fleet.tenants:
        cols = (f"{t.col_offset}..{t.col_offset + t.cols - 1}"
                if t.cols else "-")
        print(f"{t.net_id:<14}{cols:>10}  "
              f"{t.plan.est_latency_s * 1e6:>9.2f}us"
              f"{t.crossing_s * 1e6:>8.2f}us"
              f"{t.latency_budget_s * 1e6:>9.2f}us")
    for t in fleet.tenants:
        _print_plan(t.plan)


def _machine_model_spec(flag: str | None, default=None):
    """Map the --machine-model flag onto a CharacterizeStage spec."""
    if flag is None:
        return default
    if flag in ("stock", "none"):
        return None
    return flag          # "auto" | "quick" | "full" | an artifact path


# ---------------------------------------------------------------------------
# characterize
# ---------------------------------------------------------------------------

def cmd_characterize(argv: list[str] | None = None) -> int:
    from repro.characterize import sweeps as sweeplib
    ap = argparse.ArgumentParser(
        prog="python -m repro characterize",
        description="Run the microbenchmark sweeps on THIS host, fit every "
                    "cost term, and write the versioned MachineModel "
                    "artifact the planner consumes.")
    ap.add_argument("--sweep", choices=sweeplib.SWEEPS, default="quick",
                    help="grid density (quick ~10s wall, full is denser)")
    ap.add_argument("--out", default="model.json",
                    help="path for the MachineModel JSON artifact")
    ap.add_argument("--terms", nargs="+", choices=sweeplib.TERMS,
                    default=list(sweeplib.TERMS),
                    help="cost terms to characterize (default: all)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--iters", type=int, default=5,
                    help="timed iterations per sweep point (median taken)")
    args = ap.parse_args(argv)

    from repro.deploy import CharacterizeStage, StageContext
    print(f"# characterizing {len(args.terms)} cost term(s), "
          f"sweep={args.sweep}")
    ctx = StageContext(machine_model={
        "sweep": args.sweep, "batch": args.batch, "iters": args.iters,
        "terms": tuple(args.terms)})
    CharacterizeStage().run(ctx)
    mm = ctx.model

    print(f"\n{'term':<12}{'source':<10}{'residual':>10}  constants")
    for term, f in mm.fits.items():
        consts = "  ".join(_fmt_constant(k, v)
                           for k, v in f.constants.items())
        print(f"{term:<12}{f.source:<10}{f.residual_rel_rms:>9.1%}  {consts}")

    path = mm.save(args.out)
    print(f"\nversion {mm.version[:16]}…  wrote {path}")
    print(f"use it:  python -m repro plan <net> --machine-model {path}")
    return 0


def _fmt_constant(name: str, value: float) -> str:
    if name.endswith("_s"):
        return f"{name}={value * 1e6:.3g}us"
    if "penalty" in name:
        return f"{name}={value:.4f}"
    return f"{name}={value:.3g}"


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------

def cmd_plan(argv: list[str] | None = None) -> int:
    from repro.models import edge

    ap = argparse.ArgumentParser(
        prog="python -m repro plan",
        description="Plan deployments (LARE + tiling + column/band + DR7) "
                    "and write the DeploymentPlan/FleetPlan JSON artifacts. "
                    "Naming several nets plans them as a co-resident fleet.")
    ap.add_argument("net", nargs="+",
                    help="edge net name (see EDGE_NETS), an LM arch id with "
                         "--kind lm, or 'all'; several names plan a "
                         "co-resident fleet")
    ap.add_argument("--target", choices=("aie", "tpu", "both"),
                    default="both")
    ap.add_argument("--kind", choices=("edge", "lm"), default="edge")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--pl-budget", type=float, default=400.0,
                    help="PL DSP-equivalents per layer for the LARE decision")
    ap.add_argument("--machine-model", default=None, metavar="MODEL_JSON",
                    help="fitted MachineModel artifact (python -m repro "
                         "characterize), 'auto' for the host calibration, "
                         "or 'quick'/'full' to characterize inline")
    ap.add_argument("--out", default="plans",
                    help="directory for the JSON artifacts")
    args = ap.parse_args(argv)

    from repro.deploy import Deployment
    mm_spec = _machine_model_spec(args.machine_model)
    if mm_spec is not None and pathlib.Path(str(mm_spec)).exists():
        from repro.characterize import MachineModel
        mm_spec = MachineModel.load(mm_spec)
        print(f"# machine model {mm_spec.version[:12]}… "
              f"(sweep={mm_spec.provenance.get('sweep')}, "
              f"host={mm_spec.provenance.get('host')})")

    if args.kind == "lm":
        from repro import configs
        cfgs = [configs.get(n).config for n in args.net]
    elif args.net == ["all"]:
        cfgs = [edge.edge_config(n) for n in edge.EDGE_NETS]
    else:
        for n in args.net:
            if n not in edge.EDGE_NETS:
                print(f"unknown net {n!r}; choose from "
                      f"{sorted(edge.EDGE_NETS)} or 'all'", file=sys.stderr)
                return 2
        cfgs = [edge.edge_config(n) for n in args.net]

    targets = ("aie", "tpu") if args.target == "both" else (args.target,)
    if args.kind == "lm":
        targets = tuple(t for t in targets if t == "tpu") or ("tpu",)

    def build(cfg_or_cfgs, target):
        return Deployment.build(
            cfg_or_cfgs, target=target, machine_model=mm_spec,
            artifact_dir=args.out, stop_after="plan", batch=args.batch,
            pl_budget=args.pl_budget)

    # Several nets named explicitly: plan them as one co-resident fleet.
    if len(args.net) > 1 and args.net != ["all"]:
        for target in targets:
            dep = build(cfgs, target)
            _print_fleet(dep.fleet)
            print(f"wrote {dep.stage_results['plan'].artifact}")
        return 0

    for cfg in cfgs:
        for target in targets:
            dep = build(cfg, target)
            _print_plan(dep.plan)
            print(f"wrote {dep.stage_results['plan'].artifact}")
    return 0


# ---------------------------------------------------------------------------
# deploy / serve / bench
# ---------------------------------------------------------------------------

_DEFAULT_NETS = ("jet_tagger", "tau_select")


def _deploy_parser(prog: str, description: str) -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog=prog, description=description)
    ap.add_argument("net", nargs="*", default=list(_DEFAULT_NETS),
                    help="edge net names (default: jet_tagger tau_select)")
    ap.add_argument("--lm", default=None, metavar="ARCH",
                    help="add an LM tenant (smoke config, seed weights), "
                         "e.g. qwen2_5_3b")
    ap.add_argument("--machine-model", default="auto",
                    help="'auto' (host calibration, default), 'stock', "
                         "'quick'/'full' (characterize inline), or a "
                         "MachineModel artifact path")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--iters", type=int, default=10,
                    help="measured inferences per edge tenant")
    ap.add_argument("--out", default="deployments",
                    help="directory for plan/model artifacts")
    return ap


def _build_deployment(args, *, stop_after=None, trace=False):
    from repro.deploy import Deployment
    specs = list(args.net)
    if args.lm:
        specs.append(f"lm:{args.lm}")
    return Deployment.build(
        specs, target="tpu",
        machine_model=_machine_model_spec(args.machine_model),
        artifact_dir=args.out, stop_after=stop_after, batch=args.batch,
        trace=trace)


def _serve_smoke(dep, *, iters: int, requests: int = 3) -> dict:
    """Drive the deployment end-to-end through the open-loop replay
    driver: interleaved edge traffic plus a small LM request set (the
    same deterministic smoke trace everywhere); returns the router
    report."""
    from repro.obs import workload
    router = dep.serve()
    inputs = router.warmup()
    tenants = {t.net_id: t.plan.kind for t in dep.fleet.tenants}
    trace = workload.smoke_trace(tenants, edge_iters=iters,
                                 lm_requests=requests)
    report = workload.replay(router, trace, inputs=inputs)
    bad = [r for r in report.records if r.status != "ok"]
    assert not bad, f"smoke replay left non-ok requests: {bad[:3]}"
    return router.report()


def _print_report(report: dict) -> None:
    print("\nper-tenant report:")
    for nid, m in report.items():
        print(f"  {nid:<14} kind={m['kind']:<5} n={m['count']:<4} "
              f"p50={m['p50_s'] * 1e6:9.1f}us p95={m['p95_s'] * 1e6:9.1f}us "
              f"violations={m['budget_violations']} "
              f"drift={m['drift']:.2f}")


def cmd_deploy(argv: list[str] | None = None) -> int:
    ap = _deploy_parser(
        "python -m repro deploy",
        "End-to-end: characterize -> plan -> engines -> serve -> "
        "planned-vs-measured, through the staged facade.")
    ap.add_argument("--dry-run", action="store_true",
                    help="stop after the plan stage (no jit, no serving)")
    args = ap.parse_args(argv)
    dep = _build_deployment(
        args, stop_after="plan" if args.dry_run else None)
    print(dep.summary())
    if args.dry_run:
        print("\n(dry run: stopped after the plan stage)")
        return 0
    report = _serve_smoke(dep, iters=args.iters)
    _print_report(report)
    print("\nplanned-vs-measured (name,us_per_call,derived):")
    ok = True
    for row in dep.bench():
        rec = row.as_record()
        print(f"{rec['name']},{rec['us_per_call']:.3f},{rec['derived']}")
        ok &= row.within_2x
    verdict = ("all tenants within 2x of plan" if ok else
               "WARNING: a tenant missed the 2x planned-vs-measured band")
    print(f"\n{verdict}")
    return 0


def cmd_serve(argv: list[str] | None = None) -> int:
    ap = _deploy_parser(
        "python -m repro serve",
        "Plan (or reuse cached plans) and serve a fleet behind the "
        "multi-tenant router; drives smoke traffic and prints the report.")
    ap.add_argument("--requests", type=int, default=3,
                    help="LM smoke requests per LM tenant")
    args = ap.parse_args(argv)
    dep = _build_deployment(args)
    report = _serve_smoke(dep, iters=args.iters, requests=args.requests)
    _print_report(report)
    return 0


def cmd_bench(argv: list[str] | None = None) -> int:
    ap = _deploy_parser(
        "python -m repro bench",
        "Planned-vs-measured rows (trend.py's snapshot shape) for a "
        "deployment on this host.")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a BENCH-style snapshot")
    args = ap.parse_args(argv)
    dep = _build_deployment(args)
    rows = [r.as_record() for r in dep.bench(iters=args.iters)]
    print("name,us_per_call,derived")
    for rec in rows:
        print(f"{rec['name']},{rec['us_per_call']:.3f},{rec['derived']}")
    if args.json:
        p = pathlib.Path(args.json)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps({"meta": {"source": "python -m repro bench"},
                                 "rows": rows}, indent=2, sort_keys=True)
                     + "\n")
        print(f"[wrote {p}]")
    return 0


def cmd_trace(argv: list[str] | None = None) -> int:
    ap = _deploy_parser(
        "python -m repro trace",
        "Traced end-to-end run: build + serve with spans on, then export "
        "the Chrome/Perfetto trace.json, a Prometheus metrics snapshot, "
        "per-tenant BENCH_serve_<net>.json rows (with per-span-kind "
        "percentiles), and print the plan-vs-measured attribution table.")
    ap.add_argument("--requests", type=int, default=3,
                    help="LM smoke requests per LM tenant")
    ap.add_argument("--trace-out", default=None, metavar="DIR",
                    help="directory for trace.json / metrics.prom / "
                         "BENCH_serve_*.json (default: <--out>/obs)")
    args = ap.parse_args(argv)
    dep = _build_deployment(args, trace=True)
    print(dep.summary())
    report = _serve_smoke(dep, iters=args.iters, requests=args.requests)
    _print_report(report)

    from repro.serve.metrics import write_serve_snapshots
    out = pathlib.Path(args.trace_out or pathlib.Path(args.out) / "obs")
    trace_path = dep.export_trace(out / "trace.json")
    prom_path = dep.export_prometheus(out / "metrics.prom")
    bench_paths = write_serve_snapshots(
        report, out, meta={"source": "python -m repro trace"})

    print("\nplan-vs-measured attribution:")
    print(dep.format_attribution())
    print(f"\nwrote {trace_path}   (load at https://ui.perfetto.dev)")
    print(f"wrote {prom_path}")
    for p in bench_paths:
        print(f"wrote {p}")
    return 0


def cmd_profile(argv: list[str] | None = None) -> int:
    ap = _deploy_parser(
        "python -m repro profile",
        "Roofline-attributed profiling: serve smoke traffic, then join the "
        "measured span windows with plan-derived work (MACs, bytes, launch "
        "counts) and the machine-model ceilings — achieved FLOP/s, a "
        "compute/memory/launch bound classification, the roofline fraction "
        "and the measured LARE per tenant, plus model-FLOPs vs "
        "compiled-HLO-FLOPs overhead on the actual serving executables.")
    ap.add_argument("--requests", type=int, default=3,
                    help="LM smoke requests per LM tenant")
    ap.add_argument("--json-dir", default=None, metavar="DIR",
                    help="write trend-gateable BENCH_profile_<net>.json "
                         "snapshots here")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip the compiled-executable HLO analysis "
                         "(saves the extra lower+compile per engine)")
    args = ap.parse_args(argv)
    dep = _build_deployment(args, trace=True)
    _serve_smoke(dep, iters=args.iters, requests=args.requests)
    rows = dep.profile()
    print(dep.format_profile())
    if not rows:
        print("no profiled windows — did the smoke traffic run?",
              file=sys.stderr)
        return 1
    if not args.no_hlo:
        print("\ncompiled-HLO overhead (plan model FLOPs vs executable):")
        for nid, ov in sorted(dep.hlo_overhead().items()):
            uf = ov["useful_fraction"]
            useful = f"{uf:.2f}" if uf is not None else "-"
            print(f"  {nid:<14} model={ov['model_flops']:.4g} "
                  f"hlo={ov['hlo_flops']:.4g} useful={useful}")
    if args.json_dir:
        from repro.obs import write_profile_snapshots
        paths = write_profile_snapshots(
            rows, args.json_dir,
            meta={"source": "python -m repro profile"})
        for p in paths:
            print(f"wrote {p}")
    return 0


def cmd_replay(argv: list[str] | None = None) -> int:
    from repro.obs import workload as wl
    ap = _deploy_parser(
        "python -m repro replay",
        "Open-loop traffic replay against a served fleet: generate a "
        "deterministic scenario trace (or load one), fire arrivals on the "
        "wall clock regardless of completions, and report per-tenant tail "
        "latency, scheduling lag, and the SLO verdict.")
    ap.add_argument("--scenario", choices=sorted(wl.SCENARIOS),
                    default="flash_crowd")
    ap.add_argument("--duration", type=float, default=0.25, metavar="S",
                    help="trace duration in seconds (default 0.25)")
    ap.add_argument("--rate", type=float, default=None, metavar="HZ",
                    help="edge-tenant mean arrival rate")
    ap.add_argument("--lm-rate", type=float, default=None, metavar="HZ",
                    help="LM-tenant mean arrival rate")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--speed", type=float, default=1.0,
                    help="replay speedup: 2.0 compresses arrivals 2x")
    ap.add_argument("--trace-file", default=None, metavar="JSONL",
                    help="replay this saved trace instead of generating")
    ap.add_argument("--save-trace", default=None, metavar="JSONL",
                    help="also save the generated trace for re-replay")
    ap.add_argument("--json-dir", default=None, metavar="DIR",
                    help="write BENCH_serve_<net>__<scenario>.json tail "
                         "snapshots here")
    ap.add_argument("--underbudget", default=None, metavar="NET",
                    help="shrink NET's SLO budgets to ~0 before replay "
                         "(CI fault injection: the monitor must flag it)")
    args = ap.parse_args(argv)

    dep = _build_deployment(args)
    router = dep.serve()
    if args.underbudget:
        if router.slo is None:
            print("--underbudget needs the SLO monitor (serve(slo=True))",
                  file=sys.stderr)
            return 2
        router.slo.set_budget(args.underbudget, p95_s=1e-9, p99_s=1e-9)
        print(f"# injected near-zero SLO budget for {args.underbudget}")

    requests = None
    if args.trace_file:
        requests = wl.load_trace(args.trace_file)
        print(f"# loaded {len(requests)} request(s) from {args.trace_file}")
    scenario_kw = {}
    if args.rate is not None:
        scenario_kw["rate_hz"] = args.rate
    if args.lm_rate is not None:
        scenario_kw["lm_rate_hz"] = args.lm_rate
    if requests is None and args.save_trace:
        tenants = {t.net_id: t.plan.kind for t in dep.fleet.tenants}
        requests = wl.make_scenario(args.scenario, tenants,
                                    duration_s=args.duration,
                                    seed=args.seed, **scenario_kw)
        print(f"[wrote {wl.save_trace(requests, args.save_trace)}]")

    report = dep.replay(args.scenario, duration_s=args.duration,
                        seed=args.seed, speed=args.speed,
                        requests=requests, json_dir=args.json_dir,
                        **scenario_kw)
    print(wl.format_replay(report, slo=router.slo))
    if args.json_dir:
        out = pathlib.Path(args.json_dir)
        for p in sorted(out.glob("BENCH_serve_*__*.json")):
            print(f"wrote {p}")
    return 0


def _recovery_window(records, victim: str, budget, *, window: int = 8):
    """First post-fault rolling window of ok latencies with p95 back under
    the recovery target; returns ``(requests_until_recovered, window_p95_s,
    target_s)`` (the first two None when never recovered / not judgeable).

    The target is the SLO budget when it is attainable, else 2x the
    victim's PRE-fault window p95: plan budgets are modeled accelerator
    time, and a CPU-emulation replay that never met them even before the
    fault should be judged on returning to its own baseline, not on a
    bar it never cleared."""
    from repro.obs.trace import percentile
    recs = sorted((r for r in records if r.tenant == victim),
                  key=lambda r: r.rid)
    last_bad = max((i for i, r in enumerate(recs) if r.status != "ok"),
                   default=-1)
    pre = [r.e2e_s for r in recs[:last_bad + 1]
           if r.status == "ok" and r.e2e_s is not None]
    tail = [r.e2e_s for r in recs[last_bad + 1:]
            if r.status == "ok" and r.e2e_s is not None]
    baseline = 2.0 * percentile(pre, 0.95) if pre else None
    target = budget
    if baseline is not None:
        target = max(budget, baseline) if budget is not None else baseline
    if target is None or len(tail) < window:
        return None, (percentile(tail, 0.95) if tail else None), target
    for i in range(window, len(tail) + 1):
        p95 = percentile(tail[i - window:i], 0.95)
        if p95 <= target:
            return i, p95, target
    return None, percentile(tail[-window:], 0.95), target


def cmd_chaos(argv: list[str] | None = None) -> int:
    from repro import faults as flib
    from repro.obs import workload as wl
    ap = _deploy_parser(
        "python -m repro chaos",
        "Chaos replay: serve the fleet, arm a deterministic fault burst "
        "against one tenant AFTER warmup, replay a scenario under "
        "injection, and judge isolation + time-to-recovery (the breaker "
        "re-close and the first post-fault window with p95 back under "
        "the SLO budget).  Exits non-zero when the fleet did not recover.")
    ap.add_argument("--scenario", choices=sorted(wl.SCENARIOS),
                    default="flash_crowd")
    ap.add_argument("--duration", type=float, default=0.25, metavar="S")
    ap.add_argument("--rate", type=float, default=None, metavar="HZ")
    ap.add_argument("--lm-rate", type=float, default=None, metavar="HZ")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--speed", type=float, default=1.0)
    ap.add_argument("--faults", default=None, metavar="JSON",
                    help="saved FaultPlan artifact (default: a burst of "
                         "--fault-kind faults against --victim)")
    ap.add_argument("--victim", default=None, metavar="NET",
                    help="tenant the default burst targets "
                         "(default: first edge tenant)")
    ap.add_argument("--fault-kind", choices=sorted(flib.FAULT_KINDS),
                    default="engine_exception")
    ap.add_argument("--fault-at", type=int, default=8, metavar="N",
                    help="post-warmup call index the burst starts at")
    ap.add_argument("--fault-count", type=int, default=6)
    ap.add_argument("--json-dir", default=None, metavar="DIR",
                    help="write BENCH_serve_* tail snapshots plus the "
                         "BENCH_chaos recovery snapshot here")
    args = ap.parse_args(argv)

    dep = _build_deployment(args)
    router = dep.serve()
    victim = args.victim or next(
        (t.net_id for t in dep.fleet.tenants if t.plan.kind == "edge"),
        dep.fleet.tenants[0].net_id)
    if args.faults:
        plan = flib.FaultPlan.load(args.faults)
        print(f"# loaded fault plan ({len(plan.faults)} spec(s)) "
              f"from {args.faults}")
    else:
        plan = flib.FaultPlan.burst(
            victim, kind=args.fault_kind, after=args.fault_at,
            count=args.fault_count,
            magnitude_s=0.002 if args.fault_kind == "latency_spike" else 0.0)
        print(f"# fault burst: {args.fault_count}x {args.fault_kind} "
              f"against {victim!r} from call {args.fault_at}")
    injector = plan.injector()

    scenario_kw = {}
    if args.rate is not None:
        scenario_kw["rate_hz"] = args.rate
    if args.lm_rate is not None:
        scenario_kw["lm_rate_hz"] = args.lm_rate
    report = dep.replay(args.scenario, duration_s=args.duration,
                        seed=args.seed, speed=args.speed,
                        json_dir=args.json_dir, faults=injector,
                        **scenario_kw)
    print(wl.format_replay(report, slo=router.slo))

    health = router.health()
    vh = health["tenants"].get(victim, {})
    cfg = (router.supervisor.cfg(victim) if router.supervisor is not None
           else dict(flib.RESILIENCE_DEFAULTS))
    slo_snap = router.slo.snapshot() if router.slo is not None else {}
    budget = slo_snap.get(victim, {}).get("p95_budget_s")
    fired = injector.fired(tenant=victim)
    opens = vh.get("breaker_opens", 0)
    recloses = vh.get("breaker_recloses", 0)
    ttr = vh.get("time_to_recovery_s")
    n_rec, rec_p95, target = _recovery_window(report.records, victim,
                                              budget)

    print(f"\nchaos verdict for {victim!r}:")
    print(f"  faults: scheduled={plan.scheduled(victim)} injected={fired} "
          f"failures={vh.get('failures', 0)}")
    print(f"  breaker: opens={opens} recloses={recloses} "
          f"state={vh.get('state', '-')}"
          + (f" ttr={ttr * 1e3:.1f}ms" if ttr is not None else ""))
    if n_rec is not None:
        print(f"  p95 recovery: back under target "
              f"({target * 1e6:.1f}us) after {n_rec} post-fault "
              f"request(s), window p95={rec_p95 * 1e6:.1f}us")
    elif target is not None:
        print(f"  p95 recovery: window p95 never returned under the "
              f"target ({target * 1e6:.1f}us)"
              + (f"; last window p95={rec_p95 * 1e6:.1f}us"
                 if rec_p95 is not None else ""))
    healthy = [t for t in health["tenants"] if t != victim]
    isolated = all(
        report.summary().get(t, {}).get("ok", 0) > 0 for t in healthy)
    print(f"  isolation: co-residents {healthy} "
          f"{'kept serving' if isolated else 'STARVED'}")

    recovered = (fired > 0 and opens > 0 and recloses >= opens
                 and vh.get("state") == "closed" and isolated)
    print(f"\nchaos: {'RECOVERED' if recovered else 'NOT RECOVERED'} "
          f"(injected={fired}, breaker {opens}->{recloses}, "
          f"model={cfg['breaker_cooldown'] + 1} requests open->reclose)")

    if args.json_dir:
        from repro.serve.metrics import _safe_net_name
        prefix = f"chaos/{victim}/{args.scenario}"
        model_derived = (f"src=model;scenario={args.scenario};"
                         f"kind={args.fault_kind}")
        meas_derived = (f"src=measured;scenario={args.scenario};"
                        f"opens={opens};recloses={recloses};"
                        f"state={vh.get('state', '-')}")
        rows = [
            {"name": f"{prefix}/faults_scheduled",
             "us_per_call": float(plan.scheduled(victim)),
             "derived": f"{model_derived};unit=faults"},
            {"name": f"{prefix}/breaker_k",
             "us_per_call": float(cfg["breaker_k"]),
             "derived": f"{model_derived};unit=failures"},
            {"name": f"{prefix}/recovery_model",
             "us_per_call": float(cfg["breaker_cooldown"] + 1),
             "derived": f"{model_derived};unit=requests"},
            {"name": f"{prefix}/faults_injected",
             "us_per_call": float(fired),
             "derived": f"{meas_derived};unit=faults"},
        ]
        if ttr is not None:
            rows.append({"name": f"{prefix}/time_to_recovery",
                         "us_per_call": round(ttr * 1e6, 3),
                         "derived": meas_derived})
        if n_rec is not None:
            rows.append({"name": f"{prefix}/recovery_requests",
                         "us_per_call": float(n_rec),
                         "derived": f"{meas_derived};unit=requests"})
        out = pathlib.Path(args.json_dir)
        out.mkdir(parents=True, exist_ok=True)
        p = out / (f"BENCH_chaos_{_safe_net_name(victim)}__"
                   f"{_safe_net_name(args.scenario)}.json")
        p.write_text(json.dumps(
            {"meta": {"source": "python -m repro chaos",
                      "victim": victim, "scenario": args.scenario,
                      "fault_kind": args.fault_kind, "seed": args.seed},
             "rows": rows}, indent=2, sort_keys=True, allow_nan=False)
            + "\n")
        print(f"wrote {p}")
    return 0 if recovered else 1


# ---------------------------------------------------------------------------
# check
# ---------------------------------------------------------------------------

def cmd_check(argv: list[str] | None = None) -> int:
    from repro import check as checklib
    ap = argparse.ArgumentParser(
        prog="python -m repro check",
        description="Static design-rule verification with zero execution: "
                    "lint src/repro for jax hazards, verify every plan "
                    "artifact under deployments/ against the paper's "
                    "design rules (tiles, columns, VMEM, DR7 boundaries, "
                    "serve knobs) plus the Pallas kernel contracts, and "
                    "validate every bench/ BENCH_*.json snapshot. "
                    "Exit 0 clean, 1 on error findings, 2 on an "
                    "undecodable artifact (one-line stderr).")
    ap.add_argument("artifacts", nargs="*", metavar="PLAN_JSON",
                    help="verify just these plan artifacts instead of the "
                         "whole tree")
    ap.add_argument("--root", default=".",
                    help="repo root for the tree check (default: .)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the src/repro jax-hazard lint")
    ap.add_argument("--no-kernels", action="store_true",
                    help="skip the jax.eval_shape kernel contracts")
    args = ap.parse_args(argv)
    try:
        if args.artifacts:
            report = checklib.CheckReport()
            for p in args.artifacts:
                report.extend(checklib.check_artifact(
                    p, kernels=not args.no_kernels))
                report.checked.append(f"plan:{pathlib.Path(p).name}")
        else:
            report = checklib.check_tree(args.root,
                                         kernels=not args.no_kernels,
                                         lint=not args.no_lint)
            if not args.no_kernels:
                from repro.check import kernel_contracts
                report.extend(kernel_contracts.verify_kernel_library())
                report.checked.append("kernels:library self-check")
    except checklib.ArtifactError as e:
        print(f"check: {e}", file=sys.stderr)
        return checklib.EXIT_UNDECODABLE
    print(report.to_json() if args.json else str(report))
    return report.exit_code


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

_SUBCOMMANDS = {
    "characterize": cmd_characterize,
    "plan": cmd_plan,
    "deploy": cmd_deploy,
    "serve": cmd_serve,
    "bench": cmd_bench,
    "trace": cmd_trace,
    "replay": cmd_replay,
    "profile": cmd_profile,
    "chaos": cmd_chaos,
    "check": cmd_check,
}


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    # Dispatch by hand (no parse_known_args): the root parser must not
    # swallow `--help` meant for a subcommand — `python -m repro plan
    # --help` has to reach cmd_plan's parser.
    ap = argparse.ArgumentParser(
        prog="python -m repro", description=__doc__, add_help=False,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("subcommand", choices=sorted(_SUBCOMMANDS),
                    help="what to run (each routes through repro.deploy's "
                         "pipeline stages)")
    if not argv or argv[0] in ("-h", "--help"):
        ap.print_help()
        return 0 if argv else 2
    if argv[0] not in _SUBCOMMANDS:
        ap.print_usage(sys.stderr)
        print(f"python -m repro: unknown subcommand {argv[0]!r} "
              f"(choose from {', '.join(sorted(_SUBCOMMANDS))})",
              file=sys.stderr)
        return 2
    return _SUBCOMMANDS[argv[0]](argv[1:])


def deprecated_main(old: str, subcommand: str, argv=None) -> int:
    """Shim for the legacy per-subsystem CLIs (``python -m repro.plan`` /
    ``python -m repro.characterize``): warn, then run the unified
    subcommand with unchanged flags."""
    print(f"[deprecated] `python -m {old}` is now "
          f"`python -m repro {subcommand}` (same flags); the shim will "
          f"keep working but new options land on the unified CLI only.",
          file=sys.stderr)
    return _SUBCOMMANDS[subcommand](argv)
