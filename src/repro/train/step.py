"""Train-step builder: remat, microbatching, clipping, ZeRO/FSDP shardings.

``build_train_step`` returns (init_fn, step_fn) ready for ``jax.jit`` with
the partitioner's shardings.  The same builder serves the CPU-scale examples
(no mesh) and the 512-device dry-run (mesh + shardings), so the compiled
artifact the roofline reads is exactly the code the examples run.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro import partition, runtime
from repro.models import api, transformer
from repro.models.config import ModelConfig
from repro.train import loss as loss_lib
from repro.train.optimizer import Optimizer

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    remat: str = "block"            # "none" | "block" | "dots"
    microbatches: int = 1
    clip_norm: float = 1.0
    chunked_loss: bool = False      # vocab-chunked CE (transformer family)
    acc_dtype: str = "float32"      # microbatch grad accumulator (bf16 for
                                    # 100B+ models: halves a params-sized buffer)
    mtp_weight: float = 0.3
    aux_weight: float = 1.0         # MoE load-balance loss weight multiplier
    z_loss: float = 0.0


def global_norm(tree) -> jax.Array:
    # All-dims dot_general with f32 accumulation: no f32 materialization of
    # the (multi-GiB) bf16 gradient leaves, and NO reshape — flattening a
    # sharded leaf forces a full all-gather under GSPMD (measured TB-scale
    # regression on the 671B cell).
    def sq(l):
        dims = tuple(range(l.ndim))
        return jax.lax.dot_general(l, l, ((dims, dims), ((), ())),
                                   preferred_element_type=F32)
    leaves = [sq(l) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda l: (l * scale.astype(l.dtype)).astype(l.dtype),
                        tree), norm


def make_loss_fn(cfg: ModelConfig, opts: TrainOptions) -> Callable:
    def loss_fn(params, batch):
        with runtime.remat_policy(opts.remat):
            if opts.chunked_loss and cfg.family == "transformer":
                out = transformer.lm_forward(
                    params, cfg, batch["tokens"],
                    mrope_positions=batch.get("mrope_positions"),
                    embeddings=batch.get("embeddings"),
                    want_hidden=True)
                ce = loss_lib.chunked_xent(params, cfg, out["hidden"],
                                           batch["labels"], z_loss=opts.z_loss)
            else:
                out = api.forward(params, cfg, batch)
                ce = loss_lib.softmax_xent(out["logits"], batch["labels"],
                                           z_loss=opts.z_loss)
            total = ce + opts.aux_weight * out.get("aux_loss", 0.0)
            if cfg.mtp and "mtp_hidden" in out and opts.mtp_weight:
                # Predict token t+2 from (h_t, emb(label_t == token t+1)).
                # Keep the FULL sequence through the MTP layer (a sliced
                # 4095-long seq stops dividing the model axis and forces the
                # MoE into a conflicting layout — measured as a full expert-
                # bank replication); slice at the loss instead.
                mtp_lg = transformer.mtp_logits(params, cfg,
                                                out["mtp_hidden"],
                                                batch["labels"])
                mtp_ce = loss_lib.softmax_xent(mtp_lg[:, :-1],
                                               batch["labels"][:, 1:])
                total = total + opts.mtp_weight * mtp_ce
        return total, {"ce": ce, "aux": out.get("aux_loss", jnp.zeros((), F32))}
    return loss_fn


def build_train_step(cfg: ModelConfig, opt: Optimizer,
                     opts: TrainOptions = TrainOptions()):
    """Returns (init_fn(key) -> state, step_fn(state, batch) -> (state, metrics))."""
    loss_fn = make_loss_fn(cfg, opts)

    def init_fn(key):
        params = api.init(cfg, key)
        return {"params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    def step_fn(state, batch):
        params = state["params"]
        if opts.microbatches > 1:
            mb = opts.microbatches

            def reshape(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

            # mrope positions carry a leading (3,) axis — split on axis 1.
            def reshape_batch(b):
                out = {}
                for k, v in b.items():
                    if k == "mrope_positions":
                        out[k] = v.reshape(
                            (v.shape[0], mb, v.shape[1] // mb) + v.shape[2:]
                        ).swapaxes(0, 1)
                    else:
                        out[k] = reshape(v)
                return out

            mbatch = reshape_batch(batch)
            acc_dt = jnp.dtype(opts.acc_dtype)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)

            def accum(carry, mb_batch):
                g_acc, l_acc, m_acc = carry
                (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb_batch)
                g_acc = jax.tree.map(lambda a, b: a + (b / mb).astype(acc_dt),
                                     g_acc, g)
                return (g_acc, l_acc + l / mb,
                        jax.tree.map(lambda a, b: a + b / mb, m_acc, metrics)), None

            init_m = {"ce": jnp.zeros((), F32), "aux": jnp.zeros((), F32)}
            (grads, l, metrics), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros((), F32), init_m), mbatch)
        else:
            (l, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)

        # Clip scale is folded INTO the optimizer update (per-leaf transient)
        # instead of rewriting the whole gradient tree (a full params-sized
        # copy on 100B+ models).
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, opts.clip_norm / (gnorm + 1e-9))
        new_params, new_opt = opt.update(grads, state["opt"], params,
                                         state["step"], scale=scale)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics, loss=l, grad_norm=gnorm,
                       step=state["step"].astype(F32))
        return new_state, metrics

    return init_fn, step_fn


def state_shardings(state_abstract, cfg, mesh, *, regime="train"):
    """NamedShardings for the whole train state (ZeRO: moments follow params)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    param_sh = partition.param_shardings(state_abstract["params"], cfg, mesh,
                                         regime=regime)

    def opt_leaf(path, leaf):
        # int8-block moment dicts and factored slots: replicate scales,
        # shard q-blocks over DP when divisible.
        return NamedSharding(mesh, P())

    opt_sh = jax.tree.map(
        lambda leaf: NamedSharding(mesh, P()), state_abstract["opt"])
    # Moments with the same shape as a param reuse the param's sharding.
    flat_p = {tuple(str(getattr(k, 'key', getattr(k, 'idx', k)))
                    for k in path): sh
              for path, sh in jax.tree_util.tree_flatten_with_path(param_sh)[0]}

    def match_moment(path, leaf):
        keys = tuple(str(getattr(k, 'key', getattr(k, 'idx', k)))
                     for k in path)
        for skip in (1, 2):      # drop leading "m"/"v"/"mu"/"v" keys
            cand = keys[skip:]
            if cand in flat_p:
                return flat_p[cand]
            # adafactor factored slots: vr = param minus last dim,
            # vc = param minus second-to-last dim.
            if cand and cand[-1] in ("vr", "vc") and cand[:-1] in flat_p:
                spec = tuple(flat_p[cand[:-1]].spec)
                spec = spec + (None,) * (len(spec) - len(spec))
                drop = -1 if cand[-1] == "vr" else -2
                new = list(spec)
                if len(new) >= abs(drop):
                    del new[drop]
                return NamedSharding(mesh, P(*new))
        return NamedSharding(mesh, P())

    opt_sh = jax.tree_util.tree_map_with_path(match_moment,
                                              state_abstract["opt"])
    return {"params": param_sh, "opt": opt_sh,
            "step": NamedSharding(mesh, P())}
