"""Sharded, atomic, async checkpoints with elastic restore.

Layout:  <dir>/step_<N>/
           manifest.json        tree structure, dtypes, shapes, step, mesh
           <leaf-id>.npy        one file per leaf (host-gathered)

Properties the fault-tolerance tests assert:

* **atomic publish** — writes go to ``step_<N>.tmp`` and are renamed only
  after fsync, so a crash mid-write never corrupts the latest checkpoint;
* **async** — ``save_async`` snapshots to host RAM synchronously (cheap) and
  writes to disk on a background thread, overlapping the next train steps;
* **elastic restore** — ``restore`` takes the *target* mesh/shardings, so a
  checkpoint written on a 16x16 mesh can resume on 8x16 (or 1 CPU device):
  resharding happens at ``device_put`` time from the host-gathered arrays.

On a real multi-host pod each host would write only its addressable shards;
the manifest format already records per-leaf shape/dtype so that extension
is a write-strategy swap, not a format change.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for k in path:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        out.append(("/".join(parts), leaf))
    return out


def save(ckpt_dir: str, state: Any, step: int) -> str:
    """Synchronous atomic checkpoint.  Returns the published path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    entries = []
    for i, (path, leaf) in enumerate(_tree_paths(state)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        logical_dtype = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:       # numpy can't serialize bf16
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, fname), arr)
        entries.append({"path": path, "file": fname,
                        "shape": list(arr.shape), "dtype": logical_dtype})
    manifest = {"step": step, "leaves": entries}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk on a worker thread."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save_async(self, state: Any, step: int):
        self.wait()
        # Host snapshot now (so the donated buffers can be reused).
        host_state = jax.tree.map(lambda l: np.asarray(jax.device_get(l)),
                                  state)

        def work():
            save(self.ckpt_dir, host_state, step)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(latest_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def restore(ckpt_dir: str, state_like: Any, *, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of `state_like` (abstract ok).

    ``shardings`` (optional pytree of NamedSharding) enables elastic restore
    onto any mesh: arrays are device_put with the target sharding.
    """
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step = step if step is not None else steps[-1]
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    leaves_like, treedef = _flatten(state_like)
    named = _tree_paths(state_like)
    assert len(named) == len(leaves_like)
    sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(named))
    out = []
    for (pathname, like), sh in zip(named, sh_flat):
        e = by_path[pathname]
        arr = np.load(os.path.join(path, e["file"]))
        if e["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return treedef.unflatten(out), manifest["step"]
