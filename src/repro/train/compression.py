"""Gradient compression for cross-node reduction (int8 + error feedback).

``compressed_psum(x, axis)`` quantizes to int8 with a per-tensor psum'd
absmax scale, all-reduces the int8 payload as int32 partial sums, and
dequantizes — an 4x wire-bytes reduction vs f32 (2x vs bf16) for the
gradient all-reduce, which is exactly the cross-pod (DCN) bottleneck at
multi-pod scale.  ``ErrorFeedback`` carries the quantization residual into
the next step (Seide et al.), which keeps SGD/Adam convergence intact.

These compose with the explicit shard_map data-parallel trainer
(:func:`build_manual_dp_step`): the pjit/GSPMD path keeps its implicit
reductions, while deployments that need compression (cross-pod DCN) switch
the DP reduction to this explicit path.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

F32 = jnp.float32


def compressed_psum(x: jax.Array, axis: str, *, bits: int = 8) -> jax.Array:
    """int8-quantized psum over a mesh axis (inside shard_map).

    The scale is the psum-max of per-shard absmax, so the int32 accumulation
    of n shards cannot overflow (n * 127 << 2^31)."""
    assert bits == 8, "int8 is the supported wire format"
    absmax = jnp.max(jnp.abs(x)).astype(F32)
    scale = jax.lax.pmax(absmax, axis) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return total.astype(F32) * scale


def compress_tree_psum(tree: Any, axis: str) -> Any:
    return jax.tree.map(lambda l: compressed_psum(l, axis), tree)


class ErrorFeedback:
    """Residual carry for compressed reductions: g_hat = C(g + e); e += g - g_hat."""

    @staticmethod
    def init(grads_like: Any, *, world: int = 1) -> Any:
        """Residuals are per-DP-rank: leading `world` dim, sharded over dp."""
        return jax.tree.map(
            lambda g: jnp.zeros((world,) + tuple(g.shape), F32), grads_like)

    @staticmethod
    def apply(grads: Any, residual: Any, axis: str, *, world: int):
        def one(g, e):
            c = g.astype(F32) + e
            absmax = jnp.max(jnp.abs(c)).astype(F32)
            scale = jax.lax.pmax(absmax, axis) / 127.0 + 1e-12
            q = jnp.clip(jnp.round(c / scale), -127, 127).astype(jnp.int8)
            reduced = jax.lax.psum(q.astype(jnp.int32), axis).astype(F32) \
                * scale / world
            new_e = c - q.astype(F32) * scale   # local quantization error
            return reduced, new_e

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(residual)
        pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (tdef.unflatten([p[0] for p in pairs]),
                tdef.unflatten([p[1] for p in pairs]))


def build_manual_dp_step(loss_fn: Callable, opt, mesh: Mesh, *,
                         dp_axis: str = "data",
                         compress: bool = True) -> Callable:
    """Explicit shard_map data-parallel train step with (optionally
    compressed) gradient reduction.

    state: {"params" (replicated), "opt" (replicated), "step",
            "residual" (per-shard error feedback, sharded over dp)}.
    batch: leaves with leading dim sharded over `dp_axis`.
    """
    world = mesh.shape[dp_axis]

    def step(state, batch):
        def shard_fn(params, opt_state, step_c, residual, local_batch):
            residual = jax.tree.map(lambda r: r[0], residual)   # drop dp dim
            grads = jax.grad(lambda p: loss_fn(p, local_batch)[0])(params)
            if compress:
                grads, new_res = ErrorFeedback.apply(grads, residual, dp_axis,
                                                     world=world)
            else:
                grads = jax.tree.map(
                    lambda g: jax.lax.pmean(g.astype(F32), dp_axis), grads)
                new_res = residual
            new_params, new_opt = opt.update(grads, opt_state, params, step_c)
            new_res = jax.tree.map(lambda r: r[None], new_res)
            return new_params, new_opt, new_res

        n_batch_dims = jax.tree.map(lambda _: P(dp_axis), batch)
        rep = jax.tree.map(lambda _: P(), state["params"])
        rep_opt = jax.tree.map(lambda _: P(), state["opt"])
        res_spec = jax.tree.map(lambda _: P(dp_axis), state["residual"])
        new_params, new_opt, new_res = compat.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(rep, rep_opt, P(), res_spec, n_batch_dims),
            out_specs=(rep, rep_opt, res_spec),
            check_vma=False,
        )(state["params"], state["opt"], state["step"], state["residual"],
          batch)
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1, "residual": new_res}

    return step
