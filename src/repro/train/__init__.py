"""Training substrate: optimizers, schedules, losses, step builder,
checkpointing, fault tolerance, gradient compression, pipeline parallelism."""
