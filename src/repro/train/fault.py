"""Fault tolerance: restart driver, failure injection, straggler mitigation,
elastic re-meshing.

``TrainDriver`` wraps the jitted step with:

* periodic async checkpoints (atomic publish, see checkpoint.py);
* restart-on-failure: any exception classified as a node failure rolls the
  state back to the last published checkpoint and replays — because the data
  pipeline is a pure function of (seed, step), replay is bit-deterministic;
* straggler detection: per-step wall times feed an EMA; steps slower than
  ``straggler_factor`` x the rolling median raise a mitigation callback
  (on a real pod: quarantine the slow host / trigger re-shard; here the
  callback is observable by tests via `events`);
* elastic re-mesh: ``resume(new_mesh)`` restores the latest checkpoint onto
  a different mesh/shardings (devices lost or gained) and continues.
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from repro.train import checkpoint as ckpt_lib


class SimulatedNodeFailure(RuntimeError):
    """Raised by failure-injection hooks to emulate a lost node."""


@dataclass
class DriverConfig:
    ckpt_dir: str
    ckpt_every: int = 20
    keep: int = 3
    straggler_factor: float = 3.0
    straggler_window: int = 16
    max_restarts: int = 8


@dataclass
class TrainDriver:
    cfg: DriverConfig
    step_fn: Callable                     # (state, batch) -> (state, metrics)
    batch_fn: Callable                    # step -> device batch (deterministic)
    state: Any
    shardings: Any = None                 # target shardings for restore
    events: list = field(default_factory=list)
    _times: list = field(default_factory=list)

    def __post_init__(self):
        self._ckpt = ckpt_lib.AsyncCheckpointer(self.cfg.ckpt_dir,
                                                keep=self.cfg.keep)
        self._restarts = 0

    @property
    def step(self) -> int:
        return int(jax.device_get(self.state["step"]))

    def _detect_straggler(self, dt: float, step: int):
        self._times.append(dt)
        window = self._times[-self.cfg.straggler_window:]
        if len(window) >= 4:
            med = statistics.median(window[:-1])
            if dt > self.cfg.straggler_factor * med:
                self.events.append(("straggler", step, dt, med))
                self.mitigate_straggler(step, dt, med)

    def mitigate_straggler(self, step: int, dt: float, median: float):
        """Hook: on a real pod -> quarantine host, pre-empt its shards.
        Default: record only (tests observe `events`)."""

    def run(self, n_steps: int, *, failure_hook: Callable | None = None):
        """Run `n_steps`, surviving injected failures by restart-and-replay."""
        target = self.step + n_steps
        while self.step < target:
            step = self.step
            try:
                if failure_hook is not None:
                    failure_hook(step)
                batch = self.batch_fn(step)
                t0 = time.perf_counter()
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
                self._detect_straggler(time.perf_counter() - t0, step)
                new_step = step + 1
                if new_step % self.cfg.ckpt_every == 0:
                    self._ckpt.save_async(self.state, new_step)
                    self.events.append(("checkpoint", new_step))
            except SimulatedNodeFailure as e:
                self._restarts += 1
                self.events.append(("failure", step, str(e)))
                if self._restarts > self.cfg.max_restarts:
                    raise
                self._restore()
        self._ckpt.wait()
        return self.state

    def _restore(self):
        self._ckpt.wait()
        steps = ckpt_lib.latest_steps(self.cfg.ckpt_dir)
        if not steps:
            self.events.append(("restart_from_init", 0))
            return                      # keep current state (from step 0)
        abstract = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), self.state)
        self.state, step = ckpt_lib.restore(self.cfg.ckpt_dir, abstract,
                                            shardings=self.shardings)
        self.events.append(("restored", step))

    def resume_elastic(self, state_like: Any, shardings: Any):
        """Elastic restart: restore the latest checkpoint onto a NEW mesh
        (different device count / topology)."""
        self._ckpt.wait()
        self.shardings = shardings
        self.state, step = ckpt_lib.restore(self.cfg.ckpt_dir, state_like,
                                            shardings=shardings)
        self.events.append(("elastic_resume", step))
        return self.state
