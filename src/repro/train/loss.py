"""Losses: softmax cross-entropy with a vocab-chunked variant.

The chunked variant never materializes the full (B, S, V) f32 logits tensor:
the unembedding GEMM + logsumexp run per sequence chunk inside a scan.  At
gemma-scale vocab (256k) on train_4k this is the difference between a 4.2 GB
transient per device and a ~270 MB one — it is the §Perf memory-term lever
for the vocab-bound cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import mask_padded_vocab, rmsnorm
from repro.sharding import shard

F32 = jnp.float32


def softmax_xent(logits: jax.Array, labels: jax.Array, *,
                 z_loss: float = 0.0) -> jax.Array:
    """Mean CE over all positions.  logits (B,S,V) f32, labels (B,S) int."""
    logits = logits.astype(F32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = lse - gold
    if z_loss:
        ce = ce + z_loss * jnp.square(lse)
    return jnp.mean(ce)


def chunked_xent(params: dict, cfg: ModelConfig, hidden: jax.Array,
                 labels: jax.Array, *, chunk: int = 512,
                 z_loss: float = 0.0) -> jax.Array:
    """CE from final hidden states without materializing full logits.

    hidden (B,S,D) — pre-final-norm; labels (B,S)."""
    b, s, d = hidden.shape
    h = rmsnorm(params["final_norm"], hidden, cfg.norm_eps)
    w = params.get("unemb")
    if w is None:
        w = params["emb"].T
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    n = h.shape[1] // chunk
    hc = h.reshape(b, n, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, n, chunk).swapaxes(0, 1)
    valid_len = s

    def body(acc, inp):
        i, hh, ll = inp
        logits = jnp.einsum("bcd,dv->bcv", hh, w,
                            preferred_element_type=F32)
        if cfg.logit_softcap is not None:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        logits = mask_padded_vocab(cfg, logits)
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        ce = lse - gold
        if z_loss:
            ce = ce + z_loss * jnp.square(lse)
        pos = i * chunk + jnp.arange(chunk)
        ce = jnp.where(pos[None, :] < valid_len, ce, 0.0)
        return acc + jnp.sum(ce), None

    # Checkpoint the chunk body: without it the scan BACKWARD stacks every
    # chunk's (B, chunk, V) f32 logits — the exact buffer chunking exists to
    # avoid (measured 2.1 GiB x chunks on gemma-2b train).
    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), F32),
                            (jnp.arange(n), hc, lc))
    return total / (b * s)
