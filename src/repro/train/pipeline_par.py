"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

The paper's "PL spatial dataflow" regime — one layer group pinned to one
resource set, activations streaming stage-to-stage — is exactly pipeline
parallelism on TPU (DESIGN.md §2).  This module implements it for uniform
layer stacks: the stacked layer params (L, ...) are sharded over the stage
axis (L = n_stages * layers_per_stage); microbatches flow through stages with
``jax.lax.ppermute`` hand-offs; a rotating buffer keeps every stage busy
after the fill phase (the classic schedule: T = n_micro + n_stages - 1 ticks,
bubble fraction (S-1)/(M+S-1)).

This is also the execution model behind :func:`repro.core.lare.lare_tpu`'s
"pipelined-spatial" regime, so the LARE core-equivalence numbers and this
code describe the same machine.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


def pipeline_apply(layer_fn: Callable, stacked_params, x, *, mesh: Mesh,
                   axis: str = "pod", microbatches: int | None = None):
    """Run ``x`` through L stacked layers pipelined over ``axis``.

    layer_fn(params_slice, x_micro) -> x_micro;
    stacked_params leaves: (L, ...) with L % n_stages == 0;
    x: (B, ...) with B % microbatches == 0.
    """
    n_stages = mesh.shape[axis]
    n_micro = microbatches or n_stages
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)

    def staged(params_local, x_all):
        # params_local: (L/n_stages, ...) this stage's layers
        # x_all: full batch (replicated over `axis`)
        stage = jax.lax.axis_index(axis)
        micro = x_all.reshape((n_micro, b // n_micro) + x_all.shape[1:])

        def run_stage(xm):
            def body(h, pl):
                return layer_fn(pl, h), None
            h, _ = jax.lax.scan(body, xm, params_local)
            return h

        ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(micro[0])
        outs = jnp.zeros_like(micro)

        def tick(carry, t):
            buf, outs = carry
            # Stage 0 ingests microbatch t (if any remain).
            idx = jnp.clip(t, 0, n_micro - 1)
            injected = jnp.where(
                jnp.logical_and(stage == 0, t < n_micro)[None],
                micro[idx].reshape(-1), buf.reshape(-1)).reshape(buf.shape)
            worked = run_stage(injected)
            # Hand off to the next stage (ring; last stage's output wraps
            # to stage 0 where it is captured into `outs`).
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            passed = jax.lax.ppermute(worked, axis, perm)
            # Stage 0 captures the microbatch that finished at tick t
            # (micro m finishes at tick m + n_stages - 1).
            m_done = t - (n_stages - 1)
            capture = jnp.logical_and(stage == 0, m_done >= 0)
            outs = jax.lax.cond(
                capture,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, passed, jnp.clip(m_done, 0, n_micro - 1), 0),
                lambda o: o, outs)
            return (passed, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # Only stage 0's `outs` is meaningful; broadcast it.
        outs = jax.lax.psum(
            jnp.where((stage == 0), outs.reshape(-1),
                      jnp.zeros_like(outs).reshape(-1)).reshape(outs.shape),
            axis)
        return outs.reshape(x_all.shape)

    p_spec = jax.tree.map(lambda _: P(axis), stacked_params)
    return compat.shard_map(
        staged, mesh=mesh, in_specs=(p_spec, P()), out_specs=P(),
        check_vma=False,
    )(stacked_params, x)
