"""Optimizers in pure JAX (no optax): AdamW, Adafactor, SGD-momentum.

Large-scale posture:

* **State dtype** is configurable: f32, bf16, or int8 block-quantized
  (bitsandbytes-style, 256-element blocks with per-block absmax scales).
  deepseek-v3-671b cannot hold f32 AdamW moments on a 256-chip v5e pod
  (8 TB > 4 TB HBM); int8 states or Adafactor make it fit — the dry-run
  memory_analysis in EXPERIMENTS.md quantifies this.
* **ZeRO-1**: optimizer states inherit the parameters' FSDP sharding (the
  partitioner's "zero" axes), so moments are sharded over DP for free.
* **Adafactor** keeps factored second moments for >=2-D leaves (rank-1
  row/col statistics), the classic memory-floor option for giant models.

API:  opt = make(name, **hp);  state = opt.init(params);
      params, state = opt.update(grads, state, params, step).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
_BLOCK = 256


# ---------------------------------------------------------------------------
# int8 block quantization for moment tensors
# ---------------------------------------------------------------------------

def _q8_encode(x: jax.Array) -> dict:
    """Block-quantize to int8; shape is recovered from the paired param."""
    flat = x.astype(F32).reshape(-1)
    pad = (-flat.size) % _BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(F32)}


def _q8_decode(enc: dict, shape: tuple) -> jax.Array:
    blocks = enc["q"].astype(F32) * enc["s"]
    size = 1
    for d in shape:
        size *= d
    return blocks.reshape(-1)[:size].reshape(shape)


def _moment_store(x: jax.Array, dtype: str):
    if dtype == "float32":
        return x.astype(F32)
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    if dtype == "int8":
        return _q8_encode(x)
    raise ValueError(dtype)


def _moment_load(m, dtype: str, shape: tuple = ()) -> jax.Array:
    if dtype == "int8":
        return _q8_decode(m, shape)
    return m.astype(F32)


# Leaves above this element count (e.g. scan-stacked expert banks: a 671B
# MoE's (58, E, D, F) bank is ~2.6e9 elements per device shard) update
# slice-wise over the leading dim via lax.map, so the f32 working copies are
# per-layer (~MBs) instead of per-leaf (~10 GiB) — measured as the deepseek
# train cell's residual memory spike.
_MAP_MIN_ELEMS = 1 << 62   # disabled: GSPMD replicates map slices (see step.py)


def _maybe_map_update(fn, example_p, *trees):
    """Apply fn(*slices) over axis 0 when the leaf is a huge stacked bank."""
    if (example_p.ndim >= 3 and example_p.size >= _MAP_MIN_ELEMS
            and all(jax.tree.all(jax.tree.map(
                lambda a: hasattr(a, "shape") and a.shape[:1]
                == example_p.shape[:1], t)) for t in trees)):
        return jax.lax.map(lambda xs: fn(*xs), trees)
    return fn(*trees)


# ---------------------------------------------------------------------------
# Optimizer protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable       # (grads, state, params, step) -> (params, state)
    name: str


def _tree_cast(tree, fn):
    return jax.tree.map(fn, tree)


def make_adamw(*, lr: Callable | float = 1e-3, b1: float = 0.9,
               b2: float = 0.95, eps: float = 1e-8,
               weight_decay: float = 0.0,
               state_dtype: str = "float32") -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, F32), params)
        return {
            "m": jax.tree.map(lambda z: _moment_store(z, state_dtype), zeros),
            "v": jax.tree.map(lambda z: _moment_store(z, state_dtype), zeros),
        }

    def update(grads, state, params, step, scale=None):
        lr_t = lr_fn(step)
        t = step.astype(F32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        is_enc = lambda x: isinstance(x, dict) and set(x) == {"q", "s"}

        def upd(g, m_enc, v_enc, p):
            g = g.astype(F32)
            if scale is not None:
                g = g * scale
            m = b1 * _moment_load(m_enc, state_dtype, p.shape) + (1 - b1) * g
            # v is stored in sqrt-domain when quantized: linear int8 grids
            # cannot span v's dynamic range (v ~ g^2), sqrt(v) ~ |g| can.
            v_prev = _moment_load(v_enc, state_dtype, p.shape)
            if state_dtype == "int8":
                v_prev = jnp.square(v_prev)
            v = b2 * v_prev + (1 - b2) * g * g
            upd_ = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                upd_ = upd_ + weight_decay * p.astype(F32)
            new_p = (p.astype(F32) - lr_t * upd_).astype(p.dtype)
            v_store = jnp.sqrt(v) if state_dtype == "int8" else v
            return (new_p, _moment_store(m, state_dtype),
                    _moment_store(v_store, state_dtype))

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = jax.tree.flatten(state["m"], is_leaf=is_enc)[0]
        flat_v = jax.tree.flatten(state["v"], is_leaf=is_enc)[0]
        new = []
        for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
            if state_dtype == "int8":
                new.append(upd(g, m, v, p))
            else:
                new.append(_maybe_map_update(upd, p, g, m, v, p))
        return (tdef.unflatten([n[0] for n in new]),
                {"m": tdef.unflatten([n[1] for n in new]),
                 "v": tdef.unflatten([n[2] for n in new])})

    return Optimizer(init=init, update=update, name=f"adamw[{state_dtype}]")


def make_adafactor(*, lr: Callable | float = 1e-3, decay: float = 0.8,
                   eps: float = 1e-30, clip_threshold: float = 1.0,
                   weight_decay: float = 0.0) -> Optimizer:
    """Factored second moments (Shazeer & Stern) — beta1=0 variant."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def one(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], F32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)}
            return {"v": jnp.zeros_like(p, F32)}
        return {"v": jax.tree.map(one, params)}

    def update(grads, state, params, step, scale=None):
        lr_t = lr_fn(step)
        t = step.astype(F32) + 1.0
        beta2 = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g = g.astype(F32)
            if scale is not None:
                g = g * scale
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                         ) * vc[..., None, :]
                u = g / jnp.sqrt(denom + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g / jnp.sqrt(v + eps)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(F32)
            return (p.astype(F32) - lr_t * u).astype(p.dtype), new_s

        is_slot = lambda x: isinstance(x, dict) and (set(x) <= {"vr", "vc", "v"})
        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = jax.tree.flatten(state["v"], is_leaf=is_slot)[0]
        new = [_maybe_map_update(upd, p, g, s, p)
               for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = tdef.unflatten([n[0] for n in new])
        new_s = tdef.unflatten([n[1] for n in new])
        return new_p, {"v": new_s}

    return Optimizer(init=init, update=update, name="adafactor")


def make_sgd(*, lr: Callable | float = 1e-2, momentum: float = 0.9,
             nesterov: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, F32), params)}

    def update(grads, state, params, step, scale=None):
        lr_t = lr_fn(step)

        def upd(g, mu, p):
            g = g.astype(F32)
            if scale is not None:
                g = g * scale
            mu = momentum * mu + g
            d = g + momentum * mu if nesterov else mu
            return (p.astype(F32) - lr_t * d).astype(p.dtype), mu

        out = jax.tree.map(upd, grads, state["mu"], params)
        leaf = lambda x: isinstance(x, tuple) and len(x) == 2
        return (jax.tree.map(lambda t: t[0], out, is_leaf=leaf),
                {"mu": jax.tree.map(lambda t: t[1], out, is_leaf=leaf)})

    return Optimizer(init=init, update=update, name="sgd")


def make(name: str, **hp) -> Optimizer:
    if name == "adamw":
        return make_adamw(**hp)
    if name == "adafactor":
        return make_adafactor(**hp)
    if name == "sgd":
        return make_sgd(**hp)
    raise ValueError(name)
