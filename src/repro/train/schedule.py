"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, *, warmup_steps: int = 200,
                  total_steps: int = 10_000, final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * (step + 1.0) / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return lr


def constant(lr_value: float):
    return lambda step: jnp.asarray(lr_value, jnp.float32)
